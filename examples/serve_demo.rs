//! SERVING DEMO (DESIGN.md experiment "SERVE"): one device budget → a
//! replica fleet → micro-batched request scheduling under open-loop
//! traffic, with admission control doing explicit load shedding.
//!
//! Run: `cargo run --release --example serve_demo`

use acf::cnn::data::Dataset;
use acf::cnn::model::{Model, Weights};
use acf::fabric::device::by_name;
use acf::planner::Policy;
use acf::serve::{open_loop, plan_fleet, ServeConfig, ServeError, Server, DEFAULT_MAX_REPLICAS};

fn main() {
    let model = Model::lenet_tiny();
    let dev = by_name("zcu104").expect("catalog device");
    let policy = Policy::adaptive();

    println!("== 1. fleet planning: divide the {} budget until throughput peaks ==", dev.name);
    let fp = plan_fleet(&model, &dev, 200.0, &policy, None, DEFAULT_MAX_REPLICAS)
        .expect("lenet-tiny plans on the paper board");
    println!(
        "  {} replicas, each on a 1/{} shard: {:.0} img/s per replica, {:.0} img/s fleet (modeled)",
        fp.replicas, fp.replicas, fp.per_replica.images_per_sec, fp.fleet_img_s
    );
    let (dsp, lut) = fp.pressure();
    println!("  fleet pressure on the undivided part: DSP {:.1}%, LUT {:.1}%", dsp * 100.0, lut * 100.0);

    println!("\n== 2. deploy: persistent pipelines, shared weights ==");
    let weights = Weights::random(&model, 42);
    let server = Server::start(fp.deploy(model.clone(), weights.clone()), &ServeConfig::default());
    println!("  {} replica pipelines up ({} layer workers each)", fp.replicas, model.layers.len());

    println!("\n== 3. open-loop traffic with admission control ==");
    let corpus: Vec<Vec<i64>> =
        Dataset::generate(32, 7, 16, 16).images.iter().map(|i| i.pix.clone()).collect();
    let references: Vec<Vec<i64>> =
        corpus.iter().map(|img| acf::cnn::infer::infer(&model, &weights, img)).collect();
    let outcomes = open_loop(&server, &corpus, 400, 2_000.0, 0xACF5);
    let mut ok = 0usize;
    let mut shed = 0usize;
    let mut wrong = 0usize;
    for o in &outcomes {
        match &o.result {
            Ok(logits) => {
                if logits == &references[o.image_idx] {
                    ok += 1;
                } else {
                    wrong += 1;
                }
            }
            Err(ServeError::Overloaded { .. }) => shed += 1,
            Err(e) => panic!("unexpected serve error: {e}"),
        }
    }
    let snap = server.shutdown();
    println!("  {ok} served bit-exactly, {shed} shed at admission, {wrong} mismatched");
    println!(
        "  sustained {:.0} img/s, latency p50 {:.2} ms / p95 {:.2} ms / p99 {:.2} ms, queue peak {}",
        snap.sustained_img_s, snap.p50_ms, snap.p95_ms, snap.p99_ms, snap.queue_peak
    );
    for (ri, r) in snap.replicas.iter().enumerate() {
        println!(
            "  replica {ri}: {} images in {} micro-batches ({:.1}% busy)",
            r.images,
            r.batches,
            r.utilization * 100.0
        );
    }
    assert_eq!(wrong, 0, "serving path must stay bit-exact");
}
