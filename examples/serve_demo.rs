//! SERVING DEMO (DESIGN.md experiment "SERVE"): a heterogeneous device
//! catalog → one replica group per part, each with its own resource-driven
//! plan → throughput-weighted request scheduling under open-loop traffic,
//! with admission control doing explicit load shedding and the metrics
//! broken out per device group — then a second act: the live rebalancer
//! growing a deliberately under-provisioned fleet under a step load and
//! shrinking it back in the lull, from the memoized plan frontier.
//!
//! Run: `cargo run --release --example serve_demo`

use acf::cnn::data::Dataset;
use acf::cnn::model::{Model, Weights};
use acf::fabric::device::by_name;
use acf::planner::Policy;
use acf::serve::{
    open_loop, open_loop_tenants, FleetFrontier, FleetSpec, RebalanceConfig, Rebalancer,
    ServeConfig, ServeError, Server, TenantSpec,
};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let model = Model::lenet_tiny();
    let policy = Policy::adaptive();

    println!("== 1. fleet planning across a heterogeneous catalog ==");
    // The paper's board plus a smaller sibling and a DSP-starved edge
    // part: three very different resource envelopes in one fleet.
    let spec = FleetSpec::parse("zcu104,zu5ev,edge-nodsp", &[]).expect("built-in devices");
    let fp = spec
        .plan()
        .model(&model)
        .policy(&policy)
        .max_replicas(4)
        .run()
        .expect("lenet-tiny plans on every catalog part");
    for g in &fp.groups {
        let convs: Vec<String> = g
            .per_replica
            .convs()
            .map(|ep| format!("{} x{}", ep.kind.name(), ep.instances))
            .collect();
        let (dsp, lut) = g.pressure();
        println!(
            "  {}: {} replica(s) on 1/{} shards, {:.0} img/s group, convs [{}], DSP {:.1}% LUT {:.1}%",
            g.device.name,
            g.replicas,
            g.replicas,
            g.group_img_s,
            convs.join(", "),
            dsp * 100.0,
            lut * 100.0
        );
    }
    println!(
        "  fleet: {:.0} img/s modeled across {} replicas, {:.3} W static for the mix",
        fp.fleet_img_s,
        fp.replicas(),
        fp.static_w
    );

    println!("\n== 2. deploy: persistent pipelines, shared weights, per-group plans ==");
    let weights = Weights::random(&model, 42);
    let server = Server::start(fp.deploy(model.clone(), weights.clone()), &ServeConfig::default());
    println!(
        "  {} replica pipelines up across {} device groups ({} layer workers each)",
        fp.replicas(),
        fp.groups.len(),
        model.layers.len()
    );

    println!("\n== 3. open-loop traffic, throughput-weighted dispatch ==");
    let corpus: Vec<Vec<i64>> =
        Dataset::generate(32, 7, 16, 16).images.iter().map(|i| i.pix.clone()).collect();
    let references: Vec<Vec<i64>> =
        corpus.iter().map(|img| acf::cnn::infer::infer(&model, &weights, img)).collect();
    let outcomes = open_loop(&server, &corpus, 400, 2_000.0, 0xACF5);
    let mut ok = 0usize;
    let mut shed = 0usize;
    let mut wrong = 0usize;
    for o in &outcomes {
        match &o.result {
            Ok(logits) => {
                if logits == &references[o.image_idx] {
                    ok += 1;
                } else {
                    wrong += 1;
                }
            }
            Err(ServeError::Overloaded { .. }) => shed += 1,
            Err(e) => panic!("unexpected serve error: {e}"),
        }
    }
    let snap = server.shutdown();
    println!("  {ok} served bit-exactly, {shed} shed at admission, {wrong} mismatched");
    println!(
        "  sustained {:.0} img/s, latency p50 {:.2} ms / p95 {:.2} ms / p99 {:.2} ms, queue peak {}",
        snap.sustained_img_s, snap.p50_ms, snap.p95_ms, snap.p99_ms, snap.queue_peak
    );
    for g in &snap.groups {
        println!(
            "  {}: {} images over {} replica(s) ({:.1}% busy), p99 {:.2} ms, in-flight peak {}",
            g.label,
            g.images,
            g.replicas,
            g.utilization * 100.0,
            g.p99_ms,
            g.in_flight_peak
        );
    }
    assert_eq!(wrong, 0, "serving path must stay bit-exact across device groups");

    println!("\n== 4. dynamic rebalancing under a step load ==");
    // Start the paper's board at ONE replica although its frontier holds
    // more, then let the controller react to a saturating burst and the
    // silence after it. No planner runs here — only frontier lookups.
    let spec = FleetSpec::single(by_name("zcu104").unwrap(), None);
    let frontier = FleetFrontier::build(&model, &spec, 200.0, &policy, 3)
        .expect("zcu104 frontier");
    let fp = frontier.fleet_at(&[1]);
    let model_arc = Arc::new(model.clone());
    let weights_arc = Arc::new(weights.clone());
    let server = Arc::new(Server::start(
        fp.deploy_shared(Arc::clone(&model_arc), Arc::clone(&weights_arc)),
        &ServeConfig::default(),
    ));
    let rb = Rebalancer::start(
        Arc::clone(&server),
        frontier,
        &fp,
        vec![weights_arc],
        RebalanceConfig {
            window: Duration::from_millis(100),
            cooldown: Duration::from_millis(200),
            ..RebalanceConfig::default()
        },
    );
    println!("  phase 1 (low): {} replica(s)", server.live_counts()[0]);
    // Spike: closed-loop saturation from several threads for ~1.5 s.
    let mut spikers = Vec::new();
    for t in 0..6usize {
        let server = Arc::clone(&server);
        let corpus = corpus.clone();
        spikers.push(std::thread::spawn(move || {
            let t0 = std::time::Instant::now();
            let mut n = 0usize;
            while t0.elapsed() < Duration::from_millis(1500) {
                let idx = (t + n) % corpus.len();
                server.submit_wait(corpus[idx].clone()).unwrap().wait().unwrap();
                n += 1;
            }
            n
        }));
    }
    let spiked: usize = spikers.into_iter().map(|h| h.join().unwrap()).sum();
    println!(
        "  phase 2 (spike): {} closed-loop requests -> {} replica(s)",
        spiked,
        server.live_counts()[0]
    );
    // Lull: let the controller shrink back.
    std::thread::sleep(Duration::from_millis(1500));
    println!("  phase 3 (lull): {} replica(s)", server.live_counts()[0]);
    rb.stop();
    let snap = server.shutdown();
    println!("  rebalance timeline ({} action(s)):", snap.events.len());
    for e in &snap.events {
        println!(
            "    t={:.2}s {} {} {} -> {} ({})",
            e.at_secs, e.label, e.action, e.from, e.to, e.reason
        );
    }
    let g = &snap.groups[0];
    println!(
        "  churn: {} replicas spawned, {} drained cleanly, {} missed the drain deadline",
        g.spawned, g.drained, g.drain_failed
    );
    assert_eq!(snap.completed, snap.accepted, "no admitted request may be dropped");

    println!("\n== 5. multi-tenant: two models share one fleet under quota ==");
    // Two zcu104 parts carry two different models; two tenants route by
    // name and split admission 3:1 under weighted-fair queueing.
    let tiny = Arc::new(Model::lenet_tiny());
    let wide = Arc::new(Model::lenet_wide(2));
    let zoo_spec = FleetSpec::parse("zcu104,zcu104", &[]).expect("built-in devices");
    let zoo = zoo_spec
        .plan()
        .models(vec![Arc::clone(&tiny), Arc::clone(&wide)])
        .max_replicas(2)
        .run()
        .expect("both models plan on a zcu104 pair");
    for g in &zoo.groups {
        println!(
            "  {} [{}]: {} replica(s), {:.0} img/s group",
            g.device.name, zoo.models[g.model_id].name, g.replicas, g.group_img_s
        );
    }
    let zoo_weights =
        vec![Arc::new(Weights::random(&tiny, 42)), Arc::new(Weights::random(&wide, 42))];
    let mut cfg = ServeConfig::sized(16, 4);
    cfg.tenants.tenants = vec![
        TenantSpec::new("acme", "lenet-tiny", 3.0),
        TenantSpec::new("bitworks", "lenet-wide-2x", 1.0),
    ];
    let server = Server::start(zoo.deploy_zoo(&zoo_weights), &cfg);
    let corpora = vec![corpus.clone(), corpus.clone()];
    let outcomes = open_loop_tenants(&server, &corpora, 400, 2_500.0, 0xACF6);
    let served = outcomes.iter().filter(|(_, o)| o.result.is_ok()).count();
    let snap = server.shutdown();
    println!("  {served}/{} tenant-tagged requests served", outcomes.len());
    for t in &snap.tenants {
        println!(
            "  {} -> {} (quota {}): {} accepted, {:.1}% shed, p99 {:.2} ms",
            t.name, t.model, t.quota, t.accepted, t.shed_pct, t.p99_ms
        );
    }
    assert_eq!(snap.completed, snap.accepted, "tenanted admission keeps the promise too");
}
