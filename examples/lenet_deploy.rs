//! END-TO-END DRIVER (DESIGN.md experiment "E2E").
//!
//! Deploys the quantized LeNet-style CNN onto the simulated ZCU104 with
//! the resource-driven planner, then:
//!   0. prints the netlist optimizer's per-engine shrink table (the
//!      pass pipeline every planned engine ran through),
//!   1. spot-verifies each planned conv IP's *netlist* against the
//!      behavioral model (bit-exact),
//!   2. serves a batch of synthetic digit images through the threaded
//!      coordinator pipeline,
//!   3. cross-checks every logit vector against the AOT-compiled
//!      JAX/Pallas model executed via XLA/PJRT (the golden reference),
//!   4. reports modeled fabric throughput/latency, host throughput, and
//!      the resource/timing/power summary.
//!
//! Requires `make artifacts`. Run:
//!   `cargo run --release --example lenet_deploy`

use acf::cnn::data::Dataset;
use acf::cnn::infer::argmax;
use acf::cnn::model::Model;
use acf::coordinator::Deployment;
use acf::fabric::device::by_name;
use acf::planner::Policy;
use acf::runtime::{cpu_client, find_artifacts, load_weights, GoldenCnn, AOT_WEIGHT_SEED};

fn main() {
    let n_images = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200usize);
    let art = find_artifacts().expect("artifacts/ missing — run `make artifacts` first");
    let dev = by_name("zcu104").unwrap();
    let model = Model::lenet_tiny();
    let weights = load_weights(&art).expect("weights.json");
    // Sanity: the Python rng port derived the same weights our Rust RNG does.
    assert_eq!(weights, acf::cnn::model::Weights::random(&model, AOT_WEIGHT_SEED));

    println!("== deploy: {} on {} @ 200 MHz ==", model.name, dev.name);
    let dep = Deployment::new(model.clone(), weights, &dev, 200.0, &Policy::adaptive()).unwrap();
    for ep in &dep.plan.engines {
        println!(
            "  layer {}: {} x{} instances ({} work units/img)",
            ep.layer,
            ep.kind.name(),
            ep.instances,
            ep.work
        );
    }
    let (pd, pl) = dep.plan.pressure();
    println!("  resources: DSP {:.1}%  LUT {:.1}%", pd * 100.0, pl * 100.0);

    println!("\n== netlist optimization (pass pipeline, pre -> post at O2) ==");
    print!("{}", acf::report::opt_table().plain());

    println!("\n== netlist spot-verification of planned conv IPs ==");
    for ep in dep.plan.convs() {
        let chk = acf::sim::netlist_layer_check(&dep.model, &dep.plan, ep.layer, 0xE2E, 16).unwrap();
        println!(
            "  layer {}: {} windows through the {} netlist — exact ({:.1}% of ops evaluated)",
            ep.layer,
            chk.windows,
            ep.kind.name(),
            chk.activity.evaluated_fraction() * 100.0
        );
    }

    println!("\n== serve {n_images} synthetic digit images ==");
    let ds = Dataset::generate(n_images, 99, 16, 16);
    let images: Vec<Vec<i64>> = ds.images.iter().map(|i| i.pix.clone()).collect();
    let out = dep.infer_batch(&images).unwrap();
    let snap = dep.metrics.snapshot();

    println!("\n== golden cross-check (AOT JAX/Pallas via XLA PJRT) ==");
    let client = cpu_client().unwrap();
    let golden = GoldenCnn::load(&client, &art).unwrap();
    let mut exact = 0;
    let mut top1_agree = 0;
    let check = images.len().min(64); // PJRT dispatch per image; cap the pass
    for (img, fab) in images.iter().take(check).zip(&out) {
        let gold = golden.infer(img).unwrap();
        if &gold == fab {
            exact += 1;
        }
        if argmax(&gold) == argmax(fab) {
            top1_agree += 1;
        }
    }
    println!("  {exact}/{check} logit vectors bit-identical, {top1_agree}/{check} top-1 agreement");

    let perf = acf::sim::estimate(&dep.model, &dep.plan);
    println!("\n== results ==");
    println!("  modeled fabric throughput : {:.0} img/s @ 200 MHz", perf.throughput_img_s);
    println!("  modeled fabric latency    : {:.1} µs/image", perf.latency_us);
    println!("  host pipeline throughput  : {:.0} img/s (behavioral, {} threads)", snap.throughput(), dep.model.layers.len() + 1);
    println!("  bottleneck layer          : {}", perf.bottleneck);
    assert_eq!(exact, check, "fabric and golden must agree bit-exactly");
    println!("\nE2E OK");
}
