//! Sweep-B (DESIGN.md): explore the IP design space — operand widths,
//! kernel sizes, and the Conv_3 packing ceiling, with timing and
//! resources from the full synthesis/STA flow. Also prints Table I.
//!
//! Run: `cargo run --release --example ip_explorer`

use acf::fabric::device::by_name;
use acf::fixed::pack;
use acf::ips::{self, ConvKind, ConvParams};

fn main() {
    println!("TABLE I — characteristics\n{}", acf::report::table1().markdown());

    let dev = by_name("zcu104").unwrap();
    println!("\nSWEEP-B — operand width vs IP\n{}", acf::report::sweep_precision(&dev, 200.0).markdown());

    println!("\npacking ceilings (max symmetric operand width per kernel size):");
    for k in [1u32, 2, 3, 5, 7] {
        let w = pack::max_symmetric_bits(k);
        println!("  {k}x{k}: {w} bits{}", if k == 3 && w == 8 { "   <- the paper's Conv_3 limit" } else { "" });
    }

    println!("\nkernel-size scaling at 8 bits (Conv_1 vs Conv_2):");
    for k in [1u32, 2, 3, 5] {
        let p = ConvParams { k, ..ConvParams::paper_8bit() };
        for kind in [ConvKind::Conv1, ConvKind::Conv2] {
            if let Ok(ip) = ips::generate(kind, &p) {
                let u = acf::synth::synthesize(&ip.netlist);
                println!(
                    "  k={k} {:7} LUT {:4} Reg {:4} DSP {}  II={}",
                    kind.name(),
                    u.luts,
                    u.regs,
                    u.dsps,
                    ip.ii
                );
            }
        }
    }
}
