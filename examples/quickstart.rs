//! Quickstart: generate an IP, inspect its resources/timing/power, verify
//! it bit-exactly against its behavioral model, and let the planner pick
//! IPs for a small CNN.
//!
//! Run: `cargo run --release --example quickstart`

use acf::fabric::device::by_name;
use acf::ips::{self, verify, ConvKind, ConvParams};
use acf::planner::{plan, Policy};

fn main() {
    let dev = by_name("zcu104").expect("catalog device");
    let params = ConvParams::paper_8bit(); // 8-bit, 3x3 — the paper's setup

    println!("== 1. generate the four convolution IPs and report them ==");
    for kind in ConvKind::ALL {
        let ip = ips::generate(kind, &params).expect("paper config is always feasible");
        let u = acf::synth::synthesize(&ip.netlist);
        let t = acf::sta::analyze(&ip.netlist, 200.0, dev.speed_derate).unwrap();
        let p = acf::power::estimate(&u, &dev, 200.0, None);
        println!(
            "  {:7}  LUT {:4}  Reg {:4}  CLB {:3}  DSP {}  WNS {:+.3} ns  {:.3} W  ({} lane(s), II={})",
            kind.name(),
            u.luts,
            u.regs,
            u.clbs,
            u.dsps,
            t.wns_ns,
            p.total_w(),
            kind.lanes(),
            ip.ii
        );
    }

    println!("\n== 2. bit-exact verification: netlist vs behavioral model ==");
    for kind in ConvKind::ALL {
        let ip = ips::generate(kind, &params).unwrap();
        let n = verify::check_equivalence(&ip, 0x5EED ^ kind as u64, 16);
        println!("  {:7}  {} windows checked, all exact", kind.name(), n);
    }

    println!("\n== 3. resource-driven planning (the paper's adaptation) ==");
    let model = acf::cnn::model::Model::lenet_tiny();
    for dev_name in ["zcu104", "zu2cg", "edge-nodsp"] {
        let dev = by_name(dev_name).unwrap();
        match plan(&model, &dev, 200.0, &Policy::adaptive()) {
            Ok(p) => {
                let picks: Vec<String> = p
                    .engines
                    .iter()
                    .map(|ep| format!("L{}={}x{}", ep.layer, ep.kind.name(), ep.instances))
                    .collect();
                println!(
                    "  {:10}  {}  -> {:.0} img/s  (DSP {:.0}%, LUT {:.0}%)",
                    dev_name,
                    picks.join(", "),
                    p.images_per_sec,
                    p.pressure().0 * 100.0,
                    p.pressure().1 * 100.0
                );
            }
            Err(e) => println!("  {dev_name:10}  {e}"),
        }
    }
}
