//! Sweep-A (DESIGN.md): how the adaptive planner and the fixed-policy
//! baselines behave across the device catalog — the quantitative story
//! behind Table III. Prints throughput per device per policy and the IP
//! mix the adaptive planner chose.
//!
//! Run: `cargo run --release --example resource_sweep`

use acf::cnn::model::Model;
use acf::fabric::device::catalog;
use acf::planner::{baselines, plan, Policy};

fn main() {
    println!("{}", acf::report::sweep_adaptation(200.0).markdown());

    println!("\nadaptive IP mix per device (lenet-tiny):");
    let m = Model::lenet_tiny();
    for dev in catalog() {
        match plan(&m, &dev, 200.0, &Policy::adaptive()) {
            Ok(p) => {
                let mix: Vec<String> = p
                    .engines
                    .iter()
                    .map(|ep| format!("L{}: {} x{}", ep.layer, ep.kind.name(), ep.instances))
                    .collect();
                println!("  {:10} -> {}", dev.name, mix.join("; "));
            }
            Err(e) => println!("  {:10} -> {e}", dev.name),
        }
    }

    println!("\npolicy failure modes:");
    for pol in baselines::all() {
        let fails: Vec<String> = catalog()
            .into_iter()
            .filter(|d| plan(&m, d, 200.0, &pol).is_err())
            .map(|d| d.name)
            .collect();
        println!(
            "  {:15} infeasible on: {}",
            pol.name,
            if fails.is_empty() { "(none)".to_string() } else { fails.join(", ") }
        );
    }
}
