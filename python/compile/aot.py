"""AOT lowering: JAX/Pallas -> HLO *text* artifacts for the Rust runtime.

Interchange is HLO text (never ``.serialize()``): jax >= 0.5 emits protos
with 64-bit instruction ids that the xla crate's xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Artifacts (all under --out-dir, default ../artifacts):
  model.hlo.txt        f(image int32[in_ch*h*w]) -> (logits int32[10],)
                       — full lenet-tiny forward, weights baked in.
  window_k3_w8.hlo.txt f(win int32[9], coef int32[9]) -> (int32[1],)
                       — single IP window pass (runtime cross-check).
  weights.json         the baked weights (audited interchange with Rust).
  model.json           the model spec the weights belong to.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_mod
from . import rngport
from .kernels import convpass

WEIGHT_SEED = 2025


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer elides big literals
    # as `constant({...})`, which the text parser silently mis-reads —
    # baked weight matrices would arrive corrupted on the Rust side.
    text = comp.as_hlo_text(True)
    assert "{...}" not in text, "HLO printer elided a constant"
    return text


def build_model_artifact(out_dir: str) -> str:
    spec = rngport.lenet_tiny_spec()
    weights = rngport.random_weights(spec, WEIGHT_SEED)

    def fn(image):
        return (model_mod.forward(spec, weights, image),)

    n = spec["in_ch"] * spec["in_h"] * spec["in_w"]
    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((n,), jnp.int32))
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, "model.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    with open(os.path.join(out_dir, "weights.json"), "w") as f:
        json.dump(weights, f)
    with open(os.path.join(out_dir, "model.json"), "w") as f:
        json.dump(spec, f)
    return path


def build_window_artifact(out_dir: str) -> str:
    def fn(win, coef):
        return (convpass.window_kernel(win, coef, shift=7, out_bits=8, round_bias=0),)

    spec9 = jax.ShapeDtypeStruct((9,), jnp.int32)
    lowered = jax.jit(fn).lower(spec9, spec9)
    path = os.path.join(out_dir, "window_k3_w8.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    p1 = build_model_artifact(args.out_dir)
    p2 = build_window_artifact(args.out_dir)
    print(f"wrote {p1}")
    print(f"wrote {p2}")


if __name__ == "__main__":
    main()
