"""Pure-jnp oracle for the IP arithmetic contract.

This is the Python mirror of ``rust/src/fixed`` + ``ConvParams::window_ref``:
int32 arithmetic with int8-range values, truncating (floor) right-shift
requantization, saturation to ``out_bits``, channel-partial summation with
saturation, ReLU, 2x2 max-pool, and FC neurons. The Pallas kernels in
``convpass.py`` must match these functions bit-for-bit (pytest enforces
it), and the Rust behavioral/netlist stack implements the same contract,
so equality is transitive across all three layers of the system.
"""

import jax.numpy as jnp

I32 = jnp.int32


def sat(v, bits: int):
    """Saturate int32 values into a signed `bits`-bit range."""
    lo = -(1 << (bits - 1))
    hi = (1 << (bits - 1)) - 1
    return jnp.clip(v, lo, hi)


def requantize(acc, shift: int, out_bits: int):
    """Arithmetic right shift (floor) then saturate — Round::Truncate."""
    return sat(jnp.right_shift(acc, shift), out_bits)


def window_ref(window, coefs, shift: int, out_bits: int, round_bias: int = 0):
    """One IP pass: dot(window, coefs) + bias, requantized.

    window, coefs: int32 arrays of K*K elements.
    """
    acc = jnp.sum(window.astype(I32) * coefs.astype(I32)) + round_bias
    return requantize(acc, shift, out_bits)


def conv_pass_ref(x, w, shift: int, out_bits: int, round_bias: int = 0):
    """Single input-channel conv pass over a full plane.

    x: (ih, iw) int32 plane; w: (k, k) int32 coefficients.
    Returns (ih-k+1, iw-k+1) of per-window requantized values.
    """
    k = w.shape[0]
    oh = x.shape[0] - k + 1
    ow = x.shape[1] - k + 1
    acc = jnp.zeros((oh, ow), I32) + jnp.int32(round_bias)
    for dy in range(k):
        for dx in range(k):
            acc = acc + x[dy : dy + oh, dx : dx + ow].astype(I32) * w[dy, dx].astype(I32)
    return requantize(acc, shift, out_bits)


def conv_layer_ref(x, w, shift: int, out_bits: int, relu: bool, round_bias: int = 0):
    """Full conv layer: per-channel passes, saturated channel sum, ReLU.

    x: (in_ch, ih, iw); w: (out_ch, in_ch, k, k). Returns (out_ch, oh, ow).
    """
    out_ch, in_ch = w.shape[0], w.shape[1]
    planes = []
    for oc in range(out_ch):
        acc = None
        for ic in range(in_ch):
            p = conv_pass_ref(x[ic], w[oc, ic], shift, out_bits, round_bias)
            acc = p if acc is None else acc + p
        v = sat(acc, out_bits)
        if relu:
            v = jnp.maximum(v, 0)
        planes.append(v)
    return jnp.stack(planes)


def maxpool2_ref(x):
    """2x2 stride-2 max-pool over (ch, h, w)."""
    ch, h, w = x.shape
    oh, ow = h // 2, w // 2
    x = x[:, : oh * 2, : ow * 2].reshape(ch, oh, 2, ow, 2)
    return jnp.max(jnp.max(x, axis=4), axis=2)


def fc_layer_ref(x_flat, w, shift: int, out_bits: int, relu: bool, round_bias: int = 0):
    """FC layer: per-neuron dot + bias, requantized. w: (out, in).

    Implemented as broadcast-multiply + reduce rather than `w @ x`: the
    target xla_extension (0.5.1, the version the Rust `xla` crate binds)
    miscompiles s32 `dot` on CPU — multiply/reduce lowers to plain
    elementwise + reduction ops that round-trip correctly.
    """
    # dtype pinned: with x64 enabled jnp.sum would promote s32 -> s64.
    acc = jnp.sum(w.astype(I32) * x_flat.astype(I32)[None, :], axis=1, dtype=I32) + jnp.int32(round_bias)
    v = requantize(acc, shift, out_bits)
    if relu:
        v = jnp.maximum(v, 0)
    return v
