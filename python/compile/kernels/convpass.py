"""L1 — Pallas kernels modeling the convolution IPs' arithmetic.

Two kernels, both ``interpret=True`` (the CPU PJRT plugin cannot execute
Mosaic custom-calls; see /opt/xla-example/README.md):

* ``conv_pass``    — the plain serial-MAC pass (``Conv_1``/``Conv_2``/
  ``Conv_4`` lane arithmetic): a K x K sweep accumulated in int32,
  requantized per window. The kernel expresses the HBM->VMEM window
  schedule with the accumulator-carried sweep the VHDL expresses with a
  coefficient counter (DESIGN.md §Hardware-Adaptation).
* ``conv_pass_packed`` — the ``Conv_3`` dual-pixel DSP packing, bit-exact:
  two pixel planes are packed into one wide product stream
  ``(a1 << S + a2) * b`` with int64 lanes, accumulated, then lane-split
  with the borrow correction — validating the exact correction logic the
  fabric implements around the DSP48E2.

Both must match ``ref.conv_pass_ref`` exactly (pytest enforces it).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

I32 = jnp.int32
I64 = jnp.int64


def _conv_pass_kernel(x_ref, w_ref, o_ref, *, k: int, shift: int, out_bits: int, round_bias: int):
    oh, ow = o_ref.shape
    acc = jnp.full((oh, ow), round_bias, I32)
    # The K*K coefficient sweep — the serial-MAC schedule, vectorized over
    # every output position of the block (one MAC per "cycle" per window).
    for dy in range(k):
        for dx in range(k):
            acc = acc + x_ref[dy : dy + oh, dx : dx + ow] * w_ref[dy, dx]
    o_ref[...] = ref.requantize(acc, shift, out_bits)


def conv_pass(x, w, *, shift: int, out_bits: int, round_bias: int = 0):
    """Single-channel conv pass via the Pallas serial-MAC kernel.

    x: (ih, iw) int32; w: (k, k) int32 -> (ih-k+1, iw-k+1) int32.
    """
    k = int(w.shape[0])
    oh, ow = x.shape[0] - k + 1, x.shape[1] - k + 1
    kern = functools.partial(
        _conv_pass_kernel, k=k, shift=shift, out_bits=out_bits, round_bias=round_bias
    )
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((oh, ow), I32),
        interpret=True,
    )(x.astype(I32), w.astype(I32))


def _conv_pass_packed_kernel(
    x1_ref, x2_ref, w_ref, o1_ref, o2_ref, *, k: int, s: int, shift: int, out_bits: int, round_bias: int
):
    oh, ow = o1_ref.shape
    # Clamp the high-lane pixel min -> min+1 at the port boundary — the
    # Conv_3 "reduced precision" (see rust fixed::pack::needs_high_clamp).
    x1 = jnp.maximum(x1_ref[...].astype(I64), jnp.int64(-127))
    x2 = x2_ref[...].astype(I64)
    acc = jnp.full((oh, ow), (round_bias << s) + round_bias, I64)
    for dy in range(k):
        for dx in range(k):
            packed = (x1[dy : dy + oh, dx : dx + ow] << s) + x2[dy : dy + oh, dx : dx + ow]
            acc = acc + packed * w_ref[dy, dx].astype(I64)
    # Lane split with borrow correction: low = sext(acc[s-1:0]).
    low = (acc & ((1 << s) - 1)) - ((acc >> (s - 1) & 1) << s)
    high = (acc - low) >> s
    o1_ref[...] = ref.requantize(high.astype(I32), shift, out_bits)
    o2_ref[...] = ref.requantize(low.astype(I32), shift, out_bits)


def conv_pass_packed(x1, x2, w, *, shift: int, out_bits: int, round_bias: int = 0, data_bits: int = 8):
    """Dual-pixel packed pass (Conv_3): two planes through one multiplier.

    Returns (o1, o2) — the high- and low-lane outputs. Operand width is
    limited exactly as on the DSP48E2: S + data_bits <= 27.
    """
    k = int(w.shape[0])
    n = k * k
    # Same feasibility derivation as fixed::pack::feasible.
    import math

    s = 2 * data_bits - 1 + (0 if n <= 1 else math.ceil(math.log2(n)))
    if s + data_bits > 27:
        raise ValueError(
            f"packing infeasible: {data_bits}-bit operands over {k}x{k} need "
            f"S={s}, S+w={s + data_bits} > 27"
        )
    oh, ow = x1.shape[0] - k + 1, x1.shape[1] - k + 1
    kern = functools.partial(
        _conv_pass_packed_kernel, k=k, s=s, shift=shift, out_bits=out_bits, round_bias=round_bias
    )
    return pl.pallas_call(
        kern,
        out_shape=(
            jax.ShapeDtypeStruct((oh, ow), I32),
            jax.ShapeDtypeStruct((oh, ow), I32),
        ),
        interpret=True,
    )(x1.astype(I32), x2.astype(I32), w.astype(I32))


def _window_kernel(win_ref, coef_ref, o_ref, *, shift: int, out_bits: int, round_bias: int):
    acc = jnp.sum(win_ref[...] * coef_ref[...]) + round_bias
    o_ref[...] = ref.requantize(jnp.reshape(acc, (1,)), shift, out_bits)


def window_kernel(win, coef, *, shift: int, out_bits: int, round_bias: int = 0):
    """Single-window IP pass as a standalone kernel (exported as an AOT
    artifact so the Rust runtime can cross-check window semantics)."""
    kern = functools.partial(_window_kernel, shift=shift, out_bits=out_bits, round_bias=round_bias)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((1,), I32),
        interpret=True,
    )(win.astype(I32), coef.astype(I32))
