"""Bit-faithful port of ``rust/src/util/rng.rs`` (xorshift64*) and
``Weights::random``, so Python and Rust derive IDENTICAL model weights
from the same seed — no weight file has to cross the build boundary for
the two sides to agree (though ``aot.py`` still writes ``weights.json``
as the audited interchange).
"""

MASK = (1 << 64) - 1


class Rng:
    """xorshift64* — see rust/src/util/rng.rs."""

    def __init__(self, seed: int):
        self.state = seed & MASK if seed != 0 else 0x9E3779B97F4A7C15

    def next_u64(self) -> int:
        x = self.state
        x ^= x >> 12
        x = (x ^ (x << 25)) & MASK
        x ^= x >> 27
        self.state = x
        return (x * 0x2545F4914F6CDD1D) & MASK

    def below(self, n: int) -> int:
        """Uniform in [0, n) with Lemire-style rejection (bias-free)."""
        assert n > 0
        threshold = ((1 << 64) - n) % n
        while True:
            r = self.next_u64()
            if r >= threshold:
                return r % n

    def range_i64(self, lo: int, hi: int) -> int:
        assert lo <= hi
        span = hi - lo + 1
        return lo + self.below(span)


def lenet_tiny_spec():
    """Mirror of rust Model::lenet_tiny() — geometry + per-layer params."""
    p = dict(k=3, data_bits=8, coef_bits=8, out_bits=8, shift=7, round_bias=0)
    return dict(
        name="lenet-tiny",
        in_h=16,
        in_w=16,
        in_ch=1,
        layers=[
            dict(type="conv", in_ch=1, out_ch=4, relu=True, **p),
            dict(type="maxpool"),
            dict(type="conv", in_ch=4, out_ch=8, relu=True, **p),
            dict(type="maxpool"),
            dict(type="fc", out_dim=10, relu=False, **p),
        ],
    )


def shapes(spec):
    """Mirror of rust Model::shapes()."""
    h, w, ch = spec["in_h"], spec["in_w"], spec["in_ch"]
    out = []
    for layer in spec["layers"]:
        if layer["type"] == "conv":
            k = layer["k"]
            h, w, ch = h - k + 1, w - k + 1, layer["out_ch"]
        elif layer["type"] == "maxpool":
            h, w = h // 2, w // 2
        elif layer["type"] == "fc":
            h, w, ch = 1, 1, layer["out_dim"]
        out.append((h, w, ch))
    return out


def random_weights(spec, seed: int):
    """Mirror of rust Weights::random — SAME draw order, SAME values."""
    rng = Rng(seed)
    conv, fc = [], []
    shp = shapes(spec)
    prev = (spec["in_h"], spec["in_w"], spec["in_ch"])
    for i, layer in enumerate(spec["layers"]):
        if layer["type"] == "conv":
            taps = layer["k"] * layer["k"]
            hi = (1 << (layer["coef_bits"] - 1)) - 1
            conv.append(
                [
                    [[rng.range_i64(-hi, hi) for _ in range(taps)] for _ in range(layer["in_ch"])]
                    for _ in range(layer["out_ch"])
                ]
            )
        elif layer["type"] == "fc":
            in_dim = prev[0] * prev[1] * prev[2]
            hi = (1 << (layer["coef_bits"] - 1)) - 1
            fc.append(
                [[rng.range_i64(-hi, hi) for _ in range(in_dim)] for _ in range(layer["out_dim"])]
            )
        prev = shp[i]
    return dict(conv=conv, fc=fc)
