"""Build-time compile package (L1 Pallas kernels + L2 JAX model + AOT).

x64 is enabled globally: the Conv_3 packed kernel needs real int64 lanes
(without it jnp silently truncates to int32 and the lane split corrupts).
"""

import jax

jax.config.update("jax_enable_x64", True)
