"""L2 — the quantized CNN forward pass composed from the L1 kernels.

``forward(spec, weights, image)`` computes logits with EXACTLY the layer
arithmetic contract documented in ``rust/src/cnn`` (per-channel IP passes,
saturated channel sums, ReLU, 2x2 max-pool, FC). The conv passes go
through the Pallas ``conv_pass`` kernel so the lowered HLO contains the
kernel's computation; ``forward_ref`` is the same graph on the pure-jnp
oracle for differential testing.
"""

import jax.numpy as jnp

from .kernels import convpass, ref

I32 = jnp.int32


def _conv_layer(x, w, layer, pass_fn):
    out_ch, in_ch = len(w), len(w[0])
    k = layer["k"]
    planes = []
    for oc in range(out_ch):
        acc = None
        for ic in range(in_ch):
            wk = jnp.array(w[oc][ic], I32).reshape(k, k)
            p = pass_fn(
                x[ic],
                wk,
                shift=layer["shift"],
                out_bits=layer["out_bits"],
                round_bias=layer["round_bias"],
            )
            acc = p if acc is None else acc + p
        v = ref.sat(acc, layer["out_bits"])
        if layer["relu"]:
            v = jnp.maximum(v, 0)
        planes.append(v)
    return jnp.stack(planes)


def _forward(spec, weights, image, pass_fn):
    x = image.reshape(spec["in_ch"], spec["in_h"], spec["in_w"]).astype(I32)
    conv_i = 0
    fc_i = 0
    for layer in spec["layers"]:
        if layer["type"] == "conv":
            x = _conv_layer(x, weights["conv"][conv_i], layer, pass_fn)
            conv_i += 1
        elif layer["type"] == "maxpool":
            x = ref.maxpool2_ref(x)
        elif layer["type"] == "fc":
            flat = x.reshape(-1)
            w = jnp.array(weights["fc"][fc_i], I32)
            x = ref.fc_layer_ref(
                flat, w, layer["shift"], layer["out_bits"], layer["relu"], layer["round_bias"]
            ).reshape(1, 1, -1)
            fc_i += 1
        else:
            raise ValueError(f"unknown layer {layer['type']}")
    return x.reshape(-1)


def forward(spec, weights, image):
    """Logits via the Pallas conv kernel (what gets AOT-exported)."""

    def pass_fn(x, w, *, shift, out_bits, round_bias):
        return convpass.conv_pass(x, w, shift=shift, out_bits=out_bits, round_bias=round_bias)

    return _forward(spec, weights, image, pass_fn)


def forward_ref(spec, weights, image):
    """Logits via the pure-jnp oracle (differential-test twin)."""

    def pass_fn(x, w, *, shift, out_bits, round_bias):
        return ref.conv_pass_ref(x, w, shift, out_bits, round_bias)

    return _forward(spec, weights, image, pass_fn)
