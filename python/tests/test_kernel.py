"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes, widths, and operand corners; every property is
bit-exact equality (no tolerance — this is integer hardware arithmetic).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import convpass, ref

SET = settings(max_examples=40, deadline=None)


def rand_plane(data, h, w, lo=-127, hi=127):
    return np.array(
        [[data.draw(st.integers(lo, hi)) for _ in range(w)] for _ in range(h)], np.int32
    )


@SET
@given(st.data())
def test_conv_pass_matches_ref(data):
    k = data.draw(st.sampled_from([1, 2, 3, 5]))
    h = data.draw(st.integers(k, k + 6))
    w = data.draw(st.integers(k, k + 6))
    shift = data.draw(st.integers(0, 10))
    x = rand_plane(data, h, w)
    wk = rand_plane(data, k, k, -128, 127)
    got = convpass.conv_pass(jnp.array(x), jnp.array(wk), shift=shift, out_bits=8)
    want = ref.conv_pass_ref(jnp.array(x), jnp.array(wk), shift, 8)
    np.testing.assert_array_equal(np.array(got), np.array(want))


@SET
@given(st.data())
def test_conv_pass_packed_matches_two_refs(data):
    k = 3
    h = data.draw(st.integers(3, 8))
    w = data.draw(st.integers(3, 8))
    x1 = rand_plane(data, h, w, -128, 127)  # full range: clamp must handle -128
    x2 = rand_plane(data, h, w, -128, 127)
    wk = rand_plane(data, k, k, -128, 127)
    o1, o2 = convpass.conv_pass_packed(
        jnp.array(x1), jnp.array(x2), jnp.array(wk), shift=7, out_bits=8
    )
    # High lane sees the port-boundary clamp (min -> min+1), low lane is exact.
    want1 = ref.conv_pass_ref(jnp.array(np.maximum(x1, -127)), jnp.array(wk), 7, 8)
    want2 = ref.conv_pass_ref(jnp.array(x2), jnp.array(wk), 7, 8)
    np.testing.assert_array_equal(np.array(o1), np.array(want1))
    np.testing.assert_array_equal(np.array(o2), np.array(want2))


def test_packed_rejects_wide_operands():
    x = jnp.zeros((5, 5), jnp.int32)
    w = jnp.zeros((3, 3), jnp.int32)
    with pytest.raises(ValueError, match="packing infeasible"):
        convpass.conv_pass_packed(x, x, w, shift=7, out_bits=8, data_bits=9)


def test_window_kernel_corners():
    ones = jnp.ones(9, jnp.int32)
    assert int(convpass.window_kernel(jnp.arange(9), ones, shift=0, out_bits=8)[0]) == 36
    big = jnp.full(9, 127, jnp.int32)
    neg = jnp.full(9, -128, jnp.int32)
    assert int(convpass.window_kernel(big, big, shift=7, out_bits=8)[0]) == 127
    assert int(convpass.window_kernel(big, neg, shift=7, out_bits=8)[0]) == -128


@SET
@given(st.data())
def test_window_kernel_matches_ref(data):
    win = np.array([data.draw(st.integers(-128, 127)) for _ in range(9)], np.int32)
    coef = np.array([data.draw(st.integers(-128, 127)) for _ in range(9)], np.int32)
    shift = data.draw(st.integers(0, 9))
    got = int(convpass.window_kernel(jnp.array(win), jnp.array(coef), shift=shift, out_bits=8)[0])
    want = int(ref.window_ref(jnp.array(win), jnp.array(coef), shift, 8))
    assert got == want


def test_requantize_floor_semantics():
    # Arithmetic shift = floor division; -1 >> 4 stays -1.
    assert int(ref.requantize(jnp.int32(-1), 4, 8)) == -1
    assert int(ref.requantize(jnp.int32(-160), 4, 8)) == -10
    assert int(ref.requantize(jnp.int32(10), 2, 8)) == 2
    assert int(ref.requantize(jnp.int32(1 << 20), 4, 8)) == 127


def test_round_bias_injection():
    # bias = 2^(shift-1) gives round-half-up behavior through floor shift.
    win = jnp.array([1] + [0] * 8, jnp.int32)
    coef = jnp.array([65] + [0] * 8, jnp.int32)  # 65/128 = 0.51
    assert int(ref.window_ref(win, coef, 7, 8, round_bias=0)) == 0
    assert int(ref.window_ref(win, coef, 7, 8, round_bias=64)) == 1
