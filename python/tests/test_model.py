"""L2 correctness: the model graph, the rng port, and pool/fc pieces."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model as M
from compile import rngport
from compile.kernels import ref

SET = settings(max_examples=20, deadline=None)


def test_rng_port_known_stream():
    # Matches rust util::rng (same algorithm, same constants).
    r = rngport.Rng(7)
    a = [r.next_u64() for _ in range(4)]
    r2 = rngport.Rng(7)
    b = [r2.next_u64() for _ in range(4)]
    assert a == b
    assert all(0 <= v < (1 << 64) for v in a)
    r0 = rngport.Rng(0)
    assert r0.next_u64() != 0


def test_weights_shapes():
    spec = rngport.lenet_tiny_spec()
    w = rngport.random_weights(spec, 2025)
    assert len(w["conv"]) == 2
    assert len(w["conv"][0]) == 4 and len(w["conv"][0][0]) == 1
    assert len(w["conv"][1]) == 8 and len(w["conv"][1][0]) == 4
    assert len(w["fc"]) == 1
    assert len(w["fc"][0]) == 10 and len(w["fc"][0][0]) == 32
    flat = [v for l in w["conv"] for oc in l for ic in oc for v in ic]
    assert all(-127 <= v <= 127 for v in flat), "symmetric weight range"


def test_forward_pallas_equals_ref():
    spec = rngport.lenet_tiny_spec()
    w = rngport.random_weights(spec, 11)
    rng = np.random.RandomState(0)
    for _ in range(3):
        img = jnp.array(rng.randint(-127, 128, 256), jnp.int32)
        a = M.forward(spec, w, img)
        b = M.forward_ref(spec, w, img)
        np.testing.assert_array_equal(np.array(a), np.array(b))


def test_forward_output_range():
    spec = rngport.lenet_tiny_spec()
    w = rngport.random_weights(spec, 3)
    img = jnp.array(np.full(256, 127), jnp.int32)
    out = np.array(M.forward(spec, w, img))
    assert out.shape == (10,)
    assert out.min() >= -128 and out.max() <= 127


@SET
@given(st.data())
def test_maxpool_matches_numpy(data):
    ch = data.draw(st.integers(1, 3))
    h = data.draw(st.integers(2, 9))
    w = data.draw(st.integers(2, 9))
    x = np.array(
        [[[data.draw(st.integers(-128, 127)) for _ in range(w)] for _ in range(h)] for _ in range(ch)],
        np.int32,
    )
    got = np.array(ref.maxpool2_ref(jnp.array(x)))
    oh, ow = h // 2, w // 2
    want = x[:, : oh * 2, : ow * 2].reshape(ch, oh, 2, ow, 2).max(axis=(2, 4))
    np.testing.assert_array_equal(got, want)


@SET
@given(st.data())
def test_fc_matches_numpy(data):
    n = data.draw(st.integers(1, 40))
    out = data.draw(st.integers(1, 8))
    shift = data.draw(st.integers(0, 10))
    x = np.array([data.draw(st.integers(-128, 127)) for _ in range(n)], np.int32)
    w = np.array([[data.draw(st.integers(-128, 127)) for _ in range(n)] for _ in range(out)], np.int32)
    got = np.array(ref.fc_layer_ref(jnp.array(x), jnp.array(w), shift, 8, False))
    acc = (w.astype(np.int64) @ x.astype(np.int64)) >> shift
    want = np.clip(acc, -128, 127).astype(np.int32)
    np.testing.assert_array_equal(got, want)


def test_conv_layer_channel_sum_saturates():
    # Force big positive partials: channel sum must clip at +127.
    spec_layer = dict(k=1, shift=0, out_bits=8, relu=False, round_bias=0)
    x = jnp.full((4, 2, 2), 100, jnp.int32)
    w = jnp.full((1, 4, 1, 1), 1, jnp.int32)
    out = ref.conv_layer_ref(x, w, 0, 8, False)
    assert int(out[0, 0, 0]) == 127
    del spec_layer
