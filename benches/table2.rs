//! Bench target for paper Table II: regenerates the resource/WNS/power
//! table on the simulated ZCU104 and times the synthesis + STA + power
//! flow per IP.
use acf::fabric::device::by_name;
use acf::ips::{self, ConvKind, ConvParams};
use acf::util::bench::{report, Bench};

fn main() {
    let dev = by_name("zcu104").unwrap();
    println!("{}", "=".repeat(72));
    println!("TABLE II — RESOURCE UTILIZATION OF CONVOLUTION IPS");
    println!("(measured via synthesis/STA/power models on simulated {} @ 200 MHz,", dev.name);
    println!(" 8-bit fixed point, 3x3 kernel | right half: paper-published values)");
    println!("{}", "=".repeat(72));
    print!("{}", acf::report::table2(&dev, 200.0).plain());

    let b = Bench::default();
    let p = ConvParams::paper_8bit();
    let mut stats = Vec::new();
    for kind in ConvKind::ALL {
        let ip = ips::generate(kind, &p).unwrap();
        stats.push(b.run(&format!("synth+sta+power {}", kind.name()), || {
            let u = acf::synth::synthesize(&ip.netlist);
            let t = acf::sta::analyze(&ip.netlist, 200.0, dev.speed_derate).unwrap();
            let pw = acf::power::estimate(&u, &dev, 200.0, None);
            (u.luts, t.wns_ns, pw.total_w())
        }));
    }
    report("reporting flow", &stats);
}
