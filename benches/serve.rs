//! Serving-tier benchmarks (DESIGN.md experiment "SERVE"):
//!   - closed-loop single-request round trip through the scheduler +
//!     persistent pipeline,
//!   - micro-batched fleet throughput (32-request bursts),
//!   - request-latency distribution and sustained img/s under a fixed
//!     open-loop offered load (the SLO-facing series),
//!   - the heterogeneous-fleet series: zcu104+zu5ev vs zcu104-only,
//!     modeled throughput normalized per modeled static watt (the
//!     equal-power comparison), plus a measured open-loop run on the mix.
//!
//! Emits `BENCH_serve.json` next to `BENCH_hotpath.json` so serving
//! regressions are visible across runs. Flat-valued figures of merit are
//! reported through the same `Stats` shape: latency cases carry the
//! distribution's min/p50/mean/max, rate-like cases are expressed as ns
//! per image (or ns·W per image for the power-normalized series) so
//! regressions trend the same direction as every other series.

use acf::cnn::data::Dataset;
use acf::cnn::model::{Model, Weights};
use acf::fabric::device::by_name;
use acf::serve::{open_loop, FleetSpec, ServeConfig, Server};
use acf::trace::{RingSink, Tracer};
use acf::util::bench::{quick_env, report, write_json, Bench, Stats};
use std::sync::Arc;

fn main() {
    // ACF_BENCH_QUICK=1 (CI): shorter timing budgets and smaller
    // open-loop runs so the bench job finishes in minutes. The modeled
    // series are identical in both modes — only measured series shrink.
    let b = Bench::from_env();
    let open_requests: usize = if quick_env() { 150 } else { 600 };
    if quick_env() {
        println!("ACF_BENCH_QUICK=1: quick mode ({open_requests}-request open loops)");
    }
    let model = Model::lenet_tiny();
    let dev = by_name("zcu104").unwrap();
    let weights = Weights::random(&model, 1);
    // Fixed replica count so the series is comparable across machines.
    let fp = FleetSpec::single(dev.clone(), Some(2)).plan().model(&model).run().unwrap();
    let corpus: Vec<Vec<i64>> =
        Dataset::generate(32, 2, 16, 16).images.iter().map(|i| i.pix.clone()).collect();
    let mut stats = Vec::new();

    // 1. Closed-loop round trip: submit one request, wait for its logits.
    {
        let server = Server::start(fp.deploy(model.clone(), weights.clone()), &ServeConfig::default());
        let s = b.run("serve: closed-loop request round trip (2 replicas)", || {
            server.submit_wait(corpus[0].clone()).unwrap().wait().unwrap()
        });
        println!("closed loop: {:.0} req/s", s.throughput());
        stats.push(s);
        drop(server.shutdown());
    }

    // 2. Micro-batched burst: 32 requests in flight at once.
    {
        let server = Server::start(fp.deploy(model.clone(), weights.clone()), &ServeConfig::default());
        let s = b.run("serve: 32-request burst (2 replicas)", || {
            let pendings: Vec<_> = corpus
                .iter()
                .map(|img| server.submit_wait(img.clone()).unwrap())
                .collect();
            pendings.into_iter().map(|p| p.wait().unwrap().len()).sum::<usize>()
        });
        println!("burst: {:.0} img/s (batch 32)", 32.0 * s.throughput());
        stats.push(s);
        drop(server.shutdown());
    }

    // 3. Fixed offered load: open loop at 1500 img/s.
    {
        const OFFERED: f64 = 1_500.0;
        let requests = open_requests;
        let server = Server::start(fp.deploy(model.clone(), weights.clone()), &ServeConfig::default());
        let outcomes = open_loop(&server, &corpus, requests, OFFERED, 0xBE7C);
        let served = outcomes.iter().filter(|o| o.result.is_ok()).count();
        let snap = server.shutdown();
        println!(
            "open loop @ {OFFERED:.0} img/s offered: {served}/{requests} served, \
             sustained {:.0} img/s, p50 {:.2} ms p95 {:.2} ms p99 {:.2} ms, {} shed",
            snap.sustained_img_s, snap.p50_ms, snap.p95_ms, snap.p99_ms, snap.rejected
        );
        stats.push(Stats::flat(
            format!("serve: p99 latency @ {OFFERED:.0} img/s offered (2 replicas)"),
            snap.completed,
            snap.p99_ms * 1e6,
        ));
        stats.push(Stats::flat(
            format!("serve: p50 latency @ {OFFERED:.0} img/s offered (2 replicas)"),
            snap.completed,
            snap.p50_ms * 1e6,
        ));
        stats.push(Stats::flat(
            format!("serve: sustained ns/img @ {OFFERED:.0} img/s offered (2 replicas)"),
            snap.completed,
            1e9 / snap.sustained_img_s.max(1e-9),
        ));
    }

    // 3b. The same offered load with full tracing on: every request
    //     records its six-stage span chain (plus per-layer pipeline spans)
    //     into the bounded ring sink. The relation gate in
    //     BENCH_baseline/relations.json pins this series to within 15% of
    //     the untraced one — the measured cost of observability.
    {
        const OFFERED: f64 = 1_500.0;
        let requests = open_requests;
        let tracer = Tracer::ring(RingSink::DEFAULT_CAP);
        let cfg = ServeConfig { tracer: tracer.clone(), ..ServeConfig::default() };
        let server = Server::start(fp.deploy(model.clone(), weights.clone()), &cfg);
        let outcomes = open_loop(&server, &corpus, requests, OFFERED, 0xBE7C);
        let served = outcomes.iter().filter(|o| o.result.is_ok()).count();
        let snap = server.shutdown();
        let events = tracer.drain();
        println!(
            "traced open loop @ {OFFERED:.0} img/s offered: {served}/{requests} served, \
             sustained {:.0} img/s, {} trace events ({} dropped)",
            snap.sustained_img_s,
            events.len(),
            tracer.dropped()
        );
        stats.push(Stats::flat(
            format!("serve: traced sustained ns/img @ {OFFERED:.0} img/s offered (2 replicas)"),
            snap.completed,
            1e9 / snap.sustained_img_s.max(1e-9),
        ));
    }

    // 4. Heterogeneous fleet: zcu104+zu5ev mix vs zcu104-only, compared
    //    at equal modeled static power by normalizing modeled throughput
    //    per static watt (a powered part burns its full static power
    //    whatever its shard).
    {
        let spec = FleetSpec::parse("zcu104,zu5ev", &[]).unwrap();
        let hetero = spec.plan().model(&model).max_replicas(4).run().unwrap();
        let single =
            FleetSpec::single(dev.clone(), None).plan().model(&model).max_replicas(4).run().unwrap();
        let per_watt = |img_s: f64, watts: f64| img_s / watts.max(1e-9);
        let hetero_eff = per_watt(hetero.fleet_img_s, hetero.static_w);
        let single_eff = per_watt(single.fleet_img_s, single.static_w);
        println!(
            "hetero zcu104+zu5ev: {:.0} img/s @ {:.3} W static ({:.0} img/s/W) vs \
             zcu104-only: {:.0} img/s @ {:.3} W static ({:.0} img/s/W)",
            hetero.fleet_img_s,
            hetero.static_w,
            hetero_eff,
            single.fleet_img_s,
            single.static_w,
            single_eff
        );
        // ns·W per image: lower is better, same trend direction as every
        // other series.
        stats.push(Stats::flat(
            "serve: modeled ns*W/img — zcu104+zu5ev heterogeneous fleet".to_string(),
            hetero.replicas() as u64,
            1e9 / hetero_eff.max(1e-9),
        ));
        stats.push(Stats::flat(
            "serve: modeled ns*W/img — zcu104-only fleet".to_string(),
            single.replicas() as u64,
            1e9 / single_eff.max(1e-9),
        ));
        // Raw modeled ns/img for both fleets: the series the CI relation
        // gate pins ("the mix must model at least as fast as the best
        // single part" — PR 4's composition win).
        stats.push(Stats::flat(
            "serve: modeled ns/img — zcu104+zu5ev heterogeneous fleet".to_string(),
            hetero.replicas() as u64,
            1e9 / hetero.fleet_img_s.max(1e-9),
        ));
        stats.push(Stats::flat(
            "serve: modeled ns/img — zcu104-only fleet".to_string(),
            single.replicas() as u64,
            1e9 / single.fleet_img_s.max(1e-9),
        ));

        // Measured: open loop on the mix, per-group dispatch visible.
        const OFFERED: f64 = 1_500.0;
        let requests = open_requests;
        let server =
            Server::start(hetero.deploy(model.clone(), weights.clone()), &ServeConfig::default());
        let outcomes = open_loop(&server, &corpus, requests, OFFERED, 0xBE7D);
        let served = outcomes.iter().filter(|o| o.result.is_ok()).count();
        let snap = server.shutdown();
        println!(
            "hetero open loop @ {OFFERED:.0} img/s offered: {served}/{requests} served, \
             sustained {:.0} img/s, p99 {:.2} ms",
            snap.sustained_img_s, snap.p99_ms
        );
        for g in &snap.groups {
            println!(
                "  {}: {} images / {} replica(s), {:.1}% busy, p99 {:.2} ms",
                g.label,
                g.images,
                g.replicas,
                g.utilization * 100.0,
                g.p99_ms
            );
        }
        stats.push(Stats::flat(
            format!("serve: hetero sustained ns/img @ {OFFERED:.0} img/s offered (zcu104+zu5ev)"),
            snap.completed,
            1e9 / snap.sustained_img_s.max(1e-9),
        ));
        stats.push(Stats::flat(
            format!("serve: hetero p99 latency @ {OFFERED:.0} img/s offered (zcu104+zu5ev)"),
            snap.completed,
            snap.p99_ms * 1e6,
        ));
    }

    // 5. Multi-model consolidation: two models sharing one four-part
    //    fleet vs two dedicated two-part fleets, modeled ns/img. The
    //    relation gate pins the shared fleet to >= 0.9x the dedicated
    //    total — consolidation must not cost meaningful throughput.
    {
        let tiny = Arc::new(Model::lenet_tiny());
        let wide = Arc::new(Model::lenet_wide(2));
        let shared_spec = FleetSpec::parse("zcu104,zcu104", &[]).unwrap();
        let shared = shared_spec
            .plan()
            .models(vec![Arc::clone(&tiny), Arc::clone(&wide)])
            .max_replicas(2)
            .run()
            .unwrap();
        let half = |m: &Model| {
            FleetSpec::single(dev.clone(), None).plan().model(m).max_replicas(2).run().unwrap()
        };
        let dedicated_img_s = half(&tiny).fleet_img_s + half(&wide).fleet_img_s;
        println!(
            "two-model shared fleet: {:.0} img/s across {} groups vs {:.0} img/s on \
             dedicated halves ({:.1}% of dedicated)",
            shared.fleet_img_s,
            shared.groups.len(),
            dedicated_img_s,
            100.0 * shared.fleet_img_s / dedicated_img_s.max(1e-9)
        );
        stats.push(Stats::flat(
            "serve: modeled ns/img — two-model shared fleet (lenet-tiny + lenet-wide-2x)"
                .to_string(),
            shared.replicas() as u64,
            1e9 / shared.fleet_img_s.max(1e-9),
        ));
        stats.push(Stats::flat(
            "serve: modeled ns/img — two dedicated single-model fleets".to_string(),
            shared.replicas() as u64,
            1e9 / dedicated_img_s.max(1e-9),
        ));
    }

    report("serving tier", &stats);
    match write_json("BENCH_serve.json", "serve", &stats) {
        Ok(()) => println!("\nwrote BENCH_serve.json ({} cases)", stats.len()),
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }
}
