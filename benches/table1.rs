//! Bench target for paper Table I: regenerates the characteristics table
//! and times IP generation itself.
use acf::ips::{self, ConvKind, ConvParams};
use acf::util::bench::{report, Bench};

fn main() {
    println!("{}", "=".repeat(72));
    println!("TABLE I — CHARACTERISTICS OF DEVELOPED CONVOLUTION IPS (regenerated)");
    println!("{}", "=".repeat(72));
    print!("{}", acf::report::table1().plain());

    let b = Bench::default();
    let p = ConvParams::paper_8bit();
    let stats: Vec<_> = ConvKind::ALL
        .iter()
        .map(|&k| b.run(&format!("generate {}", k.name()), || ips::generate(k, &p).unwrap()))
        .collect();
    report("IP netlist generation", &stats);
}
