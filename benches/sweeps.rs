//! Bench target for the supporting series (DESIGN.md Sweep-A/Sweep-B):
//! adaptation across devices and the precision sweep.
use acf::fabric::device::by_name;
use acf::util::bench::{report, Bench};

fn main() {
    println!("{}", "=".repeat(72));
    println!("SWEEP-A — throughput (img/s) per device per policy (lenet-wide-4x)");
    println!("{}", "=".repeat(72));
    print!("{}", acf::report::sweep_adaptation(200.0).plain());

    let dev = by_name("zcu104").unwrap();
    println!();
    println!("{}", "=".repeat(72));
    println!("SWEEP-B — operand width vs IP (the Conv_3 8-bit ceiling)");
    println!("{}", "=".repeat(72));
    print!("{}", acf::report::sweep_precision(&dev, 200.0).plain());

    let b = Bench::quick();
    let s1 = b.run("sweep_adaptation", || acf::report::sweep_adaptation(200.0));
    let s2 = b.run("sweep_precision", || acf::report::sweep_precision(&dev, 200.0));
    report("sweeps", &[s1, s2]);
}
