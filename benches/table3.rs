//! Bench target for paper Table III: regenerates the comparison table
//! (ratings derived from the policy sweep) and times a full policy
//! assessment.
use acf::util::bench::{report, Bench};

fn main() {
    println!("{}", "=".repeat(72));
    println!("TABLE III — COMPARISON OF OPTIMIZATION TECHNIQUES FOR CNNs ON FPGAS");
    println!("(columns: this work + the three related-work postures, evaluated");
    println!(" quantitatively on identical planner infrastructure — see DESIGN.md)");
    println!("{}", "=".repeat(72));
    print!("{}", acf::report::table3(200.0).plain());

    println!("\nunderlying quantitative assessment:");
    for a in acf::report::assess_policies(200.0) {
        println!(
            "  {:15} infeasible {}/{} devices | 12-bit: {} | scalability {:.2} | flexibility {:.2}",
            a.policy,
            a.failed_devices,
            a.total_devices,
            if a.multi_precision { "yes" } else { "no" },
            a.scalability,
            a.flexibility
        );
    }

    let b = Bench::quick();
    let s = b.run("assess_policies (full sweep)", || acf::report::assess_policies(200.0));
    report("policy sweep", &[s]);
}
