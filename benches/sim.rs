//! Lane-parallel netlist-simulator throughput (DESIGN.md §Perf item 7).
//!
//! Measures one full verify pass (K² = 9 settle+tick cycles, drivers
//! included) of the Conv_3 IP — the paper's densest mix of LUT fabric,
//! carry chains, FFs, and a packed DSP — at 1, 8, and 64 simulator
//! lanes. The 1-lane case is the scalar baseline (it takes the
//! index-the-truth-table path); the others evaluate every lane in the
//! same pass via Shannon mux-tree LUT reduction and bitwise
//! carry/FF words.
//!
//! Also measures the event-driven settle scheduler's occupancy
//! sensitivity on Conv_1 (the logic-only IP, where settle cost dominates
//! a pass): a quiet stimulus — uniform constant windows, constant
//! coefficient stream — against a churning one, each under both the
//! event-driven settle and the forced dense sweep.
//! `BENCH_baseline/relations.json` pins the ordering (event ≥ 2× dense
//! images/s when quiet, within 10% at full churn) machine-independently.
//!
//! Emits `BENCH_sim.json` with the raw timing series plus derived
//! cycles/sec and images/sec per occupancy, so the lane-packing speedup
//! is tracked across runs next to `BENCH_hotpath.json` and
//! `BENCH_serve.json`.
use acf::ips::verify::{random_stimulus_lanes, IpPorts, LaneStimulus};
use acf::ips::{self, ConvKind, ConvParams};
use acf::netlist::sim::Sim;
use acf::util::bench::{quick_env, report, stats_json, Bench, Stats};
use acf::util::json::{obj, Json};
use acf::util::rng::Rng;

fn main() {
    // ACF_BENCH_QUICK=1 (CI) shrinks timing budgets; the lane sweep and
    // modeled series are identical in both modes.
    let b = Bench::from_env();
    if quick_env() {
        println!("ACF_BENCH_QUICK=1: quick mode");
    }
    let p = ConvParams::paper_8bit();
    let ip = ips::generate(ConvKind::Conv3, &p).unwrap();
    let taps = p.taps() as usize;
    let ip_lanes = ip.kind.lanes() as usize;
    println!(
        "Conv_3 netlist: {} cells, {} IP lanes, II = {taps} cycles/pass",
        ip.netlist.n_cells(),
        ip_lanes
    );

    let mut stats = Vec::new();
    let mut derived: Vec<Json> = Vec::new();
    let mut baseline_pass_ns = 0.0f64;
    for &lanes in &[1usize, 8, 64] {
        let mut rng = Rng::new(0x51A1);
        let (per_lane, coefs) = random_stimulus_lanes(&ip, &mut rng, lanes, 1);
        let mut sim = Sim::with_lanes(&ip.netlist, lanes).unwrap();
        let ports = IpPorts::resolve(&sim, ip_lanes);
        ports.reset(&mut sim, &p);
        let label = if lanes == 1 {
            "Conv_3 verify pass (scalar 1-lane)".to_string()
        } else {
            format!("Conv_3 verify pass ({lanes}-lane)")
        };
        let s = b.run(&label, || {
            // Window data is stable across a pass; the coefficient streams.
            ports.drive_windows_lanes(&mut sim, &p, &per_lane, 0);
            for phase in 0..taps {
                ports.drive_coef(&mut sim, &p, &coefs, phase);
                sim.settle();
                sim.tick();
            }
        });
        if lanes == 1 {
            baseline_pass_ns = s.median_ns;
        }
        let passes_per_sec = s.throughput();
        let cycles_per_sec = passes_per_sec * taps as f64;
        let images_per_sec = passes_per_sec * (lanes * ip_lanes) as f64;
        let speedup = if baseline_pass_ns > 0.0 {
            (baseline_pass_ns / s.median_ns) * lanes as f64
        } else {
            1.0
        };
        let st = sim.settle_stats();
        println!(
            "{label}: {:.2}M cycles/s, {:.2}M img/s ({speedup:.1}x scalar img/s) — \
             {} settles ({} dense / {} event), {:.1}% of ops evaluated",
            cycles_per_sec / 1e6,
            images_per_sec / 1e6,
            st.settles,
            st.dense_settles,
            st.event_settles(),
            st.evaluated_fraction() * 100.0
        );
        derived.push(obj([
            ("name", label.as_str().into()),
            ("lanes", lanes.into()),
            ("cycles_per_sec", cycles_per_sec.into()),
            ("images_per_sec", images_per_sec.into()),
            ("img_s_speedup_vs_scalar", speedup.into()),
        ]));
        stats.push(s);
        // Per-image host cost as a flat case: the series the CI relation
        // gate pins ("64-lane must be ≥ 8x cheaper per image than
        // scalar" — PR 3's lane-packing win, machine-independent as a
        // same-run ratio).
        stats.push(Stats::flat(
            format!("sim: measured ns/img — Conv_3 verify at {lanes} lane(s)"),
            (lanes * ip_lanes) as u64,
            1e9 / images_per_sec.max(1e-9),
        ));
    }

    // Modeled per-image FPGA time at 200 MHz with full lane occupancy —
    // pure arithmetic over the IP's initiation interval, gated against
    // the committed baseline in CI.
    stats.push(Stats::flat(
        "sim: modeled ns/img — Conv_3 @ 200 MHz, 64 sim lanes".to_string(),
        (64 * ip_lanes) as u64,
        taps as f64 * 5.0 / (64.0 * ip_lanes as f64),
    ));

    // ---- event-driven settle: occupancy sensitivity (Conv_1) ----
    //
    // Conv_1 is the logic-only IP (no DSP), so settle cost dominates a
    // pass and the event scheduler's win/overhead is what gets timed.
    // Low occupancy drives uniform constant windows and a constant
    // coefficient stream: a window-mux select change lands on identical
    // element values, so only the phase counter and accumulator cones
    // stay active and the multiplier fabric is quiet. High occupancy
    // streams a fresh random coefficient every phase against random
    // windows, churning the whole datapath. Each mode runs under the
    // event-driven settle and the forced dense sweep; relations.json
    // pins the ordering so `acf bench-check` gates it in CI.
    let ip1 = ips::generate(ConvKind::Conv1, &p).unwrap();
    let ip1_lanes = ip1.kind.lanes() as usize;
    println!(
        "\nConv_1 netlist: {} cells (logic-only), occupancy series at 64 sim lanes",
        ip1.netlist.n_cells()
    );
    let low_stim: Vec<LaneStimulus> =
        (0..64).map(|_| vec![vec![vec![21i64; taps]; ip1_lanes]]).collect();
    let low_coefs = vec![9i64; taps];
    let mut rng = Rng::new(0x0CC1);
    let (high_stim, high_coefs) = random_stimulus_lanes(&ip1, &mut rng, 64, 1);
    for (occ, stim, coefs) in
        [("low", &low_stim, &low_coefs), ("high", &high_stim, &high_coefs)]
    {
        for (mode, dense) in [("event", false), ("dense", true)] {
            let mut sim = Sim::with_lanes(&ip1.netlist, 64).unwrap();
            sim.set_force_dense(dense);
            let ports = IpPorts::resolve(&sim, ip1_lanes);
            ports.reset(&mut sim, &p);
            let label = format!("Conv_1 {mode} settle, {occ} occupancy (64-lane pass)");
            let s = b.run(&label, || {
                ports.drive_windows_lanes(&mut sim, &p, stim, 0);
                for phase in 0..taps {
                    ports.drive_coef(&mut sim, &p, coefs, phase);
                    sim.settle();
                    sim.tick();
                }
            });
            let st = sim.settle_stats();
            let images_per_sec = s.throughput() * (64 * ip1_lanes) as f64;
            println!(
                "{label}: {:.2}M img/s — {} settles ({} dense / {} event), \
                 {:.1}% of ops evaluated",
                images_per_sec / 1e6,
                st.settles,
                st.dense_settles,
                st.event_settles(),
                st.evaluated_fraction() * 100.0
            );
            derived.push(obj([
                ("name", label.as_str().into()),
                ("occupancy", occ.into()),
                ("mode", mode.into()),
                ("images_per_sec", images_per_sec.into()),
                ("settles", st.settles.into()),
                ("dense_settles", st.dense_settles.into()),
                ("evaluated_fraction", st.evaluated_fraction().into()),
            ]));
            stats.push(s);
            // Flat ns/img series — the endpoints relations.json pins.
            stats.push(Stats::flat(
                format!("sim: measured ns/img — Conv_1 {mode} settle, {occ} occupancy (64-lane)"),
                (64 * ip1_lanes) as u64,
                1e9 / images_per_sec.max(1e-9),
            ));
        }
    }

    // ---- netlist optimizer: opt vs no-opt (Conv_1) ----
    //
    // The pass pipeline (DESIGN.md §Netlist optimization) shrinks the
    // builder's raw output before simulation; this series times the same
    // 64-lane verify pass on the raw Conv_1 netlist and on the O2-optimized
    // one, and records both LUT counts. relations.json pins two invariants
    // machine-independently: the optimized netlist must simulate at least
    // as many img/s (small timer-jitter slack), and optimization must
    // never add LUTs (strict, deterministic cell counts).
    let raw_ip1 = ips::conv1::generate(&p).unwrap();
    let mut opt_ip1 = ips::conv1::generate(&p).unwrap();
    let rep = acf::netlist::opt::optimize_at(&mut opt_ip1.netlist, acf::netlist::opt::OptLevel::O2);
    println!(
        "\nConv_1 opt pipeline: {} -> {} cells ({} removed, {} nets dropped, {} fixpoint round(s))",
        rep.pre_cells,
        rep.post_cells,
        rep.cells_removed(),
        rep.nets_removed(),
        rep.iterations
    );
    for (variant, ip1) in [("unoptimized", &raw_ip1), ("optimized", &opt_ip1)] {
        let mut rng = Rng::new(0x09F7);
        let (stim, coefs) = random_stimulus_lanes(ip1, &mut rng, 64, 1);
        let mut sim = Sim::with_lanes(&ip1.netlist, 64).unwrap();
        let ports = IpPorts::resolve(&sim, ip1_lanes);
        ports.reset(&mut sim, &p);
        let label = format!("Conv_1 {variant} netlist (64-lane pass)");
        let s = b.run(&label, || {
            ports.drive_windows_lanes(&mut sim, &p, &stim, 0);
            for phase in 0..taps {
                ports.drive_coef(&mut sim, &p, &coefs, phase);
                sim.settle();
                sim.tick();
            }
        });
        let images_per_sec = s.throughput() * (64 * ip1_lanes) as f64;
        let luts = *ip1.netlist.census().get(&acf::fabric::Prim::Lut).unwrap_or(&0);
        println!(
            "{label}: {:.2}M img/s, {} cells, {luts} LUTs",
            images_per_sec / 1e6,
            ip1.netlist.n_cells()
        );
        derived.push(obj([
            ("name", label.as_str().into()),
            ("variant", variant.into()),
            ("images_per_sec", images_per_sec.into()),
            ("cells", ip1.netlist.n_cells().into()),
            ("luts", luts.into()),
        ]));
        stats.push(s);
        stats.push(Stats::flat(
            format!("sim: measured ns/img — Conv_1 {variant} netlist (64-lane)"),
            (64 * ip1_lanes) as u64,
            1e9 / images_per_sec.max(1e-9),
        ));
        stats.push(Stats::flat(
            format!("sim: netlist LUT count — Conv_1 {variant}"),
            1,
            luts as f64,
        ));
    }

    report("lane-parallel netlist sim", &stats);
    let doc = obj([
        ("bench", "sim".into()),
        ("cases", stats_json(&stats)),
        ("derived", Json::Arr(derived)),
    ]);
    match std::fs::write("BENCH_sim.json", doc.dump()) {
        Ok(()) => println!("\nwrote BENCH_sim.json ({} cases)", stats.len()),
        Err(e) => eprintln!("could not write BENCH_sim.json: {e}"),
    }
}
