//! Hot-path micro-benchmarks (the §Perf targets in DESIGN.md):
//!   - netlist simulator cell-eval throughput,
//!   - behavioral window throughput (coordinator inner loop),
//!   - planner end-to-end latency (the unified engine-registry loop),
//!   - threaded pipeline images/s.
//!
//! Emits `BENCH_hotpath.json` (our harness's machine-readable series —
//! criterion is unavailable offline) so planner regressions are visible
//! across runs.
use acf::cnn::data::Dataset;
use acf::cnn::model::{Model, Weights};
use acf::coordinator::Deployment;
use acf::fabric::device::by_name;
use acf::ips::{self, ConvKind, ConvParams};
use acf::netlist::sim::Sim;
use acf::planner::Policy;
use acf::util::bench::{quick_env, report, write_json, Bench, Stats};

fn main() {
    // ACF_BENCH_QUICK=1 (CI) shrinks timing budgets; modeled series are
    // identical in both modes.
    let b = Bench::from_env();
    if quick_env() {
        println!("ACF_BENCH_QUICK=1: quick mode");
    }
    let p = ConvParams::paper_8bit();
    let mut stats = Vec::new();

    // 1. Netlist sim: cycles/s on Conv_1 (biggest netlist).
    let ip = ips::generate(ConvKind::Conv1, &p).unwrap();
    let n_cells = ip.netlist.n_cells();
    {
        let mut sim = Sim::new(&ip.netlist).unwrap();
        sim.set_input("en", 1);
        sim.set_input("rst", 0);
        sim.set_input("coef", 0x55);
        for e in 0..9 {
            sim.set_input_field("win0", e * 8, 8, (e as u64 * 37) & 0xFF);
        }
        let s = b.run("netlist sim: Conv_1 settle+tick", || {
            sim.settle();
            sim.tick();
        });
        let evals_per_sec = s.throughput() * n_cells as f64;
        println!("Conv_1 netlist: {n_cells} cells -> {:.2}M cell-evals/s", evals_per_sec / 1e6);
        stats.push(s);
    }

    // 2. Behavioral window throughput.
    {
        let coefs: Vec<i64> = (0..9).map(|i| (i * 13 % 100) - 50).collect();
        let win: Vec<i64> = (0..9).map(|i| (i * 29 % 200) - 100).collect();
        let s = b.run("behavioral window_ref", || p.window_ref(&win, &coefs));
        println!("behavioral: {:.1}M windows/s", s.throughput() / 1e6);
        stats.push(s);
    }

    // 3. Planner latency: the uniform engine loop, small and wide models.
    //    (First call per (model, device) pays generation+synthesis+STA;
    //    the memo cache then reduces plan() to the binary search itself —
    //    which is exactly the regression these series track.)
    {
        let dev = by_name("zcu104").unwrap();
        for m in [Model::lenet_tiny(), Model::lenet_wide(4)] {
            let s = b.run(&format!("planner::plan ({}/zcu104)", m.name), || {
                acf::planner::plan(&m, &dev, 200.0, &Policy::adaptive()).unwrap()
            });
            stats.push(s);
        }
        let edge = by_name("edge-nodsp").unwrap();
        let m = Model::lenet_tiny();
        let s = b.run("planner::plan (lenet-tiny/edge-nodsp)", || {
            acf::planner::plan(&m, &edge, 200.0, &Policy::adaptive()).unwrap()
        });
        stats.push(s);

        // Modeled plan quality (deterministic — these gate in CI through
        // `acf bench-check`): per-image time of the chosen engine mix.
        // A change that degrades engine selection shows up here even if
        // the planner got faster.
        for (m, d) in [
            (Model::lenet_tiny(), &dev),
            (Model::lenet_wide(4), &dev),
            (Model::lenet_tiny(), &edge),
        ] {
            let p = acf::planner::plan(&m, d, 200.0, &Policy::adaptive()).unwrap();
            stats.push(Stats::flat(
                format!("plan: modeled ns/img — {} on {} (adaptive)", m.name, d.name),
                1,
                1e9 / p.images_per_sec.max(1e-9),
            ));
        }
    }

    // 4. Threaded pipeline throughput.
    {
        let m = Model::lenet_tiny();
        let w = Weights::random(&m, 1);
        let dev = by_name("zcu104").unwrap();
        let dep = Deployment::new(m, w, &dev, 200.0, &Policy::adaptive()).unwrap();
        let ds = Dataset::generate(32, 2, 16, 16);
        let images: Vec<Vec<i64>> = ds.images.iter().map(|i| i.pix.clone()).collect();
        let s = b.run("pipeline infer_batch(32)", || dep.infer_batch(&images).unwrap());
        println!("pipeline: {:.0} img/s (batch 32)", 32.0 * s.throughput());
        stats.push(s);
    }

    report("hot paths", &stats);
    match write_json("BENCH_hotpath.json", "hotpath", &stats) {
        Ok(()) => println!("\nwrote BENCH_hotpath.json ({} cases)", stats.len()),
        Err(e) => eprintln!("could not write BENCH_hotpath.json: {e}"),
    }
}
