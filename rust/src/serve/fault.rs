//! Fault injection primitives for the scenario harness.
//!
//! Three fault classes, mirroring the failures an edge fleet actually
//! sees (ISSUE 8 / the ROADMAP scenario arc):
//!
//! * **Replica death** — one replica of a group disappears without a
//!   drain window ([`FaultKind::ReplicaDeath`]). The scheduler unlists
//!   it immediately; in-flight work finishes or is rewound through the
//!   bounced-handoff path, and queued traffic reroutes to survivors.
//! * **Group loss** — every replica of a device group dies at once
//!   ([`FaultKind::GroupLoss`]), e.g. a board falls off the fabric.
//!   Surviving groups absorb the traffic; losing the *fleet's* last
//!   replica is a legal injection whose outcome is a failed scenario
//!   verdict, not a process error.
//! * **Latency degradation** — a replica keeps answering but slower by
//!   a multiplicative factor for a bounded duration
//!   ([`FaultKind::LatencyDegrade`]), modeling thermal throttling or a
//!   congested link. Injected at the dispatch boundary via
//!   [`LatencyShim`] so admission, batching, and rebalance signals all
//!   see the real (degraded) service rate.
//!
//! Every injection and its outcome is recorded as a [`FaultEvent`] in
//! [`crate::serve::FleetMetrics`] and mirrored as an instant on the
//! trace control tracks, so a failing scenario exports a Chrome trace
//! of exactly what happened and when.

use crate::util::sync::lock_ok;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

/// A scheduled fault, relative to the phase that carries it: fires at
/// `at_frac` of the way through the phase's arrival window.
#[derive(Debug, Clone)]
pub struct FaultSpec {
    /// Position within the phase, in `[0, 1]` of the phase's span.
    pub at_frac: f64,
    pub kind: FaultKind,
}

/// What to inject. Targets are device-group indices (the scenario
/// engine picks a concrete replica within the group deterministically:
/// the highest-id live one).
#[derive(Debug, Clone, PartialEq)]
pub enum FaultKind {
    /// Kill one live replica of `group`, without drain.
    ReplicaDeath { group: usize },
    /// Kill every live replica of `group` at once.
    GroupLoss { group: usize },
    /// Multiply the service time of one replica of `group` by `factor`
    /// for `duration`, then restore it.
    LatencyDegrade { group: usize, factor: f64, duration: Duration },
}

impl FaultKind {
    /// The device group this fault targets.
    pub fn group(&self) -> usize {
        match *self {
            FaultKind::ReplicaDeath { group }
            | FaultKind::GroupLoss { group }
            | FaultKind::LatencyDegrade { group, .. } => group,
        }
    }

    /// Short machine-readable name, used in verdict JSON and traces.
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::ReplicaDeath { .. } => "replica_death",
            FaultKind::GroupLoss { .. } => "group_loss",
            FaultKind::LatencyDegrade { .. } => "latency_degrade",
        }
    }
}

/// What a recorded fault event *was* — injections plus the outcomes the
/// fleet derived from them (a replica death that empties a group also
/// logs a [`FaultEventKind::GroupLost`]; emptying the fleet logs
/// [`FaultEventKind::FleetLost`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultEventKind {
    ReplicaDeath,
    GroupLoss,
    /// Outcome: a group's last live replica is gone; traffic reroutes.
    GroupLost,
    /// Outcome: the fleet's last live replica is gone; nothing can
    /// serve. A scenario run turns this into a FAIL verdict.
    FleetLost,
    LatencyDegrade,
    /// A latency degradation's duration elapsed and the replica's
    /// service rate was restored.
    LatencyRestore,
}

impl std::fmt::Display for FaultEventKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            FaultEventKind::ReplicaDeath => "replica_death",
            FaultEventKind::GroupLoss => "group_loss",
            FaultEventKind::GroupLost => "group_lost",
            FaultEventKind::FleetLost => "fleet_lost",
            FaultEventKind::LatencyDegrade => "latency_degrade",
            FaultEventKind::LatencyRestore => "latency_restore",
        })
    }
}

/// One entry of the fault timeline kept by
/// [`crate::serve::FleetMetrics`]: when (seconds on the metrics clock),
/// what, and to whom.
#[derive(Debug, Clone)]
pub struct FaultEvent {
    /// Seconds since the metrics epoch.
    pub at_secs: f64,
    pub kind: FaultEventKind,
    /// Device group the event concerns (`None` for fleet-wide events).
    pub group: Option<usize>,
    /// Replica the event concerns (`None` for group/fleet events).
    pub replica: Option<usize>,
    /// Free-form context ("factor 4x for 200ms", "2 survivors", ...).
    pub detail: String,
}

/// The dispatch-boundary latency shim: a per-replica map of extra
/// synthetic delay. The scheduler consults it once per micro-batch
/// handoff — *before* the batch enters the replica's pipeline — so a
/// degraded replica's slowdown is visible to everything downstream
/// (latency reservoirs, utilization windows, rebalance signals) exactly
/// as a genuinely slow device would be.
#[derive(Debug, Default)]
pub struct LatencyShim {
    delays: Mutex<BTreeMap<usize, Duration>>,
}

impl LatencyShim {
    pub fn new() -> LatencyShim {
        LatencyShim::default()
    }

    /// Inject `extra` delay per micro-batch on `replica` (replaces any
    /// previous injection on that replica).
    pub fn inject(&self, replica: usize, extra: Duration) {
        lock_ok(&self.delays).insert(replica, extra);
    }

    /// Remove the injection on `replica`; returns whether one existed.
    pub fn clear(&self, replica: usize) -> bool {
        lock_ok(&self.delays).remove(&replica).is_some()
    }

    /// Drop every injection (end-of-scenario cleanup).
    pub fn clear_all(&self) {
        lock_ok(&self.delays).clear();
    }

    /// The extra delay currently injected on `replica`, if any.
    pub fn delay_of(&self, replica: usize) -> Option<Duration> {
        lock_ok(&self.delays).get(&replica).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_shim_injects_and_clears() {
        let shim = LatencyShim::new();
        assert_eq!(shim.delay_of(3), None);
        shim.inject(3, Duration::from_millis(20));
        assert_eq!(shim.delay_of(3), Some(Duration::from_millis(20)));
        // Re-injection replaces.
        shim.inject(3, Duration::from_millis(5));
        assert_eq!(shim.delay_of(3), Some(Duration::from_millis(5)));
        // Other replicas unaffected.
        assert_eq!(shim.delay_of(0), None);
        assert!(shim.clear(3));
        assert!(!shim.clear(3), "second clear finds nothing");
        assert_eq!(shim.delay_of(3), None);
        shim.inject(1, Duration::from_millis(1));
        shim.inject(2, Duration::from_millis(2));
        shim.clear_all();
        assert_eq!(shim.delay_of(1), None);
        assert_eq!(shim.delay_of(2), None);
    }

    #[test]
    fn fault_kind_names_and_groups() {
        let k = FaultKind::LatencyDegrade {
            group: 2,
            factor: 4.0,
            duration: Duration::from_millis(100),
        };
        assert_eq!(k.name(), "latency_degrade");
        assert_eq!(k.group(), 2);
        assert_eq!(FaultKind::ReplicaDeath { group: 0 }.name(), "replica_death");
        assert_eq!(FaultKind::GroupLoss { group: 1 }.group(), 1);
        assert_eq!(FaultEventKind::FleetLost.to_string(), "fleet_lost");
    }
}
