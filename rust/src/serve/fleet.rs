//! Fleet planning: turn a *catalog* of device budgets into a serving
//! fleet by running the resource-driven planner under divided budgets.
//!
//! This is the paper's scarcity logic lifted two levels up. PR 2 asked
//! "how many whole copies of the planned network fit ONE device?"; real
//! edge deployments mix parts with very different DSP/LUT/BRAM balances,
//! so the fleet planner now takes a [`FleetSpec`] — a list of
//! `(Device, forced count?)` entries, one per physical part — and plans a
//! *replica group* per device:
//!
//! 1. **Per-device frontier.** For each device, the monotone shard scan
//!    from PR 2 builds the count → plan frontier: candidate count `r`
//!    plans one replica against an equal `1/r` shard
//!    ([`crate::fabric::device::Device::shard`]), with the model's
//!    coefficient BRAM charged off the top *per replica* (weights do not
//!    shrink with the shard — [`crate::planner::coefficient_bram18`]).
//!    The scan stops at the first infeasible count.
//! 2. **Cross-device composition.** Each device contributes its
//!    throughput-argmax count. Without a target the fleet is every
//!    listed device at that count (throughput is additive across parts).
//!    Under `--target-img-s` the composition instead minimizes modeled
//!    static power: forced entries are always kept, optional devices are
//!    added greedily by throughput-per-static-watt until the target is
//!    met, then a drop pass removes any device the target can spare.
//!
//! Replicas on different parts legitimately run *different* plans — the
//! same per-layer IP substitutions the paper's Table III sweeps show
//! across resource envelopes, now live inside one fleet.

use crate::cnn::model::{Model, Weights};
use crate::coordinator::Deployment;
use crate::fabric::device::{by_name, Device};
use crate::planner::{coefficient_bram18, plan_under_fraction, Plan, PlanError, Policy};
use crate::synth::Utilization;
use std::sync::Arc;

/// Default ceiling on the per-device replica search (CLI `--max-replicas`
/// raises it).
pub const DEFAULT_MAX_REPLICAS: usize = 8;

/// One requested fleet member: a physical part, optionally pinned to an
/// exact replica count (`None` = search `1..=max_replicas`).
#[derive(Debug, Clone)]
pub struct FleetEntry {
    pub device: Device,
    pub count: Option<usize>,
}

/// What the fleet should be built from: one entry per physical part.
/// Listing the same part twice means two boards, each its own group.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    pub entries: Vec<FleetEntry>,
}

impl FleetSpec {
    /// A one-device spec (the PR 2 surface).
    pub fn single(device: Device, count: Option<usize>) -> FleetSpec {
        FleetSpec { entries: vec![FleetEntry { device, count }] }
    }

    /// Parse the CLI form `name[:count],name[:count],...` (e.g.
    /// `zcu104,zu5ev:2`). Names resolve against `extra` (a `--catalog`
    /// file, case-insensitive on name or part) first, then the built-in
    /// catalog.
    pub fn parse(spec: &str, extra: &[Device]) -> Result<FleetSpec, String> {
        let mut entries = Vec::new();
        for item in spec.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            let (name, count) = match item.split_once(':') {
                Some((n, c)) => {
                    let count: usize = c
                        .parse()
                        .map_err(|_| format!("bad replica count '{c}' in '{item}'"))?;
                    if count == 0 {
                        return Err(format!("replica count must be >= 1 in '{item}'"));
                    }
                    (n, Some(count))
                }
                None => (item, None),
            };
            let lower = name.to_ascii_lowercase();
            let device = extra
                .iter()
                .find(|d| d.name.to_ascii_lowercase() == lower || d.part.to_ascii_lowercase() == lower)
                .cloned()
                .or_else(|| by_name(name))
                .ok_or_else(|| format!("unknown device '{name}' (not in --catalog or built-ins)"))?;
            entries.push(FleetEntry { device, count });
        }
        if entries.is_empty() {
            return Err("empty device list".into());
        }
        Ok(FleetSpec { entries })
    }
}

/// One device's replica group inside a planned fleet.
#[derive(Debug, Clone)]
pub struct GroupPlan {
    /// The undivided physical part this group runs on.
    pub device: Device,
    /// Index of the [`FleetSpec`] entry (and therefore the
    /// [`FleetFrontier`] group) this plan came from — the same part can
    /// be listed twice (two boards), so names are not a key.
    pub spec_entry: usize,
    pub replicas: usize,
    /// The plan every replica of this group deploys (made against
    /// `device.shard(replicas)` with per-replica coefficient BRAM
    /// reserved off the top).
    pub per_replica: Plan,
    /// RAMB18s of coefficient storage *per replica* (does not shrink with
    /// the shard).
    pub coef_bram18: u64,
    /// Whole-group utilization on the undivided part: `replicas ×`
    /// (engine resources + coefficient store).
    pub total: Utilization,
    /// Modeled replica-sum throughput of this group.
    pub group_img_s: f64,
}

impl GroupPlan {
    /// Group pressure on its undivided device: (DSP fraction, LUT fraction).
    pub fn pressure(&self) -> (f64, f64) {
        (self.device.dsp_util(self.total.dsps), self.device.lut_util(self.total.luts))
    }
}

/// A planned serving fleet: one replica group per device, each group
/// running its own plan.
#[derive(Debug, Clone)]
pub struct FleetPlan {
    pub clock_mhz: f64,
    pub groups: Vec<GroupPlan>,
    /// Modeled fleet throughput: the sum over groups (throughput is
    /// additive across physical parts).
    pub fleet_img_s: f64,
    /// Modeled static power of the mix: one full `static_w` per included
    /// part (a powered part burns its static power whatever its shard).
    pub static_w: f64,
    /// The SLO the search was asked to meet, if any.
    pub target_img_s: Option<f64>,
    /// Whether `fleet_img_s` meets `target_img_s` (true when no target).
    pub meets_target: bool,
}

impl FleetPlan {
    /// Total replica count across all device groups.
    pub fn replicas(&self) -> usize {
        self.groups.iter().map(|g| g.replicas).sum()
    }

    /// Device-group index of each replica, group-major — the same order
    /// [`FleetPlan::deploy`] emits replicas in (what
    /// [`crate::serve::Server::start_grouped`] consumes).
    pub fn replica_groups(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.replicas());
        for (gi, g) in self.groups.iter().enumerate() {
            for _ in 0..g.replicas {
                out.push(gi);
            }
        }
        out
    }

    /// Display label per device group (the part's name).
    pub fn group_labels(&self) -> Vec<String> {
        self.groups.iter().map(|g| g.device.name.clone()).collect()
    }

    /// Deploy the fleet: one persistent pipeline per replica, group-major
    /// order, all sharing one model and one weight set. Replicas of
    /// different groups run different plans.
    pub fn deploy(&self, model: Model, weights: Weights) -> Vec<Arc<Deployment>> {
        self.deploy_shared(Arc::new(model), Arc::new(weights))
    }

    /// [`FleetPlan::deploy`] against already-shared model/weight handles —
    /// what the rebalancer uses so replicas it spins up later share the
    /// exact same allocations as the initial fleet.
    pub fn deploy_shared(&self, model: Arc<Model>, weights: Arc<Weights>) -> Vec<Arc<Deployment>> {
        let mut out = Vec::with_capacity(self.replicas());
        for g in &self.groups {
            for _ in 0..g.replicas {
                out.push(Arc::new(Deployment::with_plan(
                    Arc::clone(&model),
                    Arc::clone(&weights),
                    g.per_replica.clone(),
                )));
            }
        }
        out
    }
}

/// Plan one device's replica group at an exact count. Errors if one
/// replica cannot be planned under `1/count` of the device (including
/// when the part's BRAM cannot hold `count` coefficient copies).
fn plan_group(
    model: &Model,
    dev: &Device,
    spec_entry: usize,
    clock_mhz: f64,
    policy: &Policy,
    count: usize,
) -> Result<GroupPlan, PlanError> {
    let r = count.max(1);
    let per_replica = plan_under_fraction(model, dev, clock_mhz, policy, r as u64)?;
    let coef = coefficient_bram18(model);
    let mut total = per_replica.total.times(r as u64);
    total.bram18 += coef * r as u64;
    Ok(GroupPlan {
        device: dev.clone(),
        spec_entry,
        replicas: r,
        group_img_s: r as f64 * per_replica.images_per_sec,
        coef_bram18: coef,
        per_replica,
        total,
    })
}

/// One device's memoized count → plan frontier: `counts[c - 1]` is the
/// group plan at `c` replicas (each against a `1/c` shard with its
/// coefficient BRAM charged). Built once at plan time; the live
/// rebalancer resizes groups by *indexing* this — no planner run ever
/// happens while traffic is flowing.
#[derive(Debug, Clone)]
pub struct GroupFrontier {
    pub device: Device,
    /// Index of the [`FleetSpec`] entry this frontier belongs to.
    pub spec_entry: usize,
    /// Forced replica count, if the spec pinned one (the rebalancer
    /// leaves forced groups alone).
    pub forced: Option<usize>,
    counts: Vec<GroupPlan>,
}

impl GroupFrontier {
    /// Largest feasible replica count (the frontier is contiguous from 1:
    /// shards only shrink as the count grows, so feasibility is monotone).
    pub fn max_count(&self) -> usize {
        self.forced.unwrap_or(self.counts.len())
    }

    /// Smallest plannable count (1, or the forced count when pinned).
    pub fn min_count(&self) -> usize {
        self.forced.unwrap_or(1)
    }

    /// The memoized group plan at `count` replicas.
    pub fn at(&self, count: usize) -> &GroupPlan {
        if let Some(f) = self.forced {
            assert_eq!(count, f, "group is pinned to {f} replicas");
            return &self.counts[0];
        }
        assert!(
            (1..=self.counts.len()).contains(&count),
            "count {count} outside frontier 1..={}",
            self.counts.len()
        );
        &self.counts[count - 1]
    }

    /// The throughput-argmax candidate (ties go to more replicas — more
    /// concurrent request capacity at the same rate).
    pub fn argmax(&self) -> &GroupPlan {
        self.counts
            .iter()
            .max_by(|a, b| {
                (a.group_img_s, a.replicas)
                    .partial_cmp(&(b.group_img_s, b.replicas))
                    .expect("throughput is finite")
            })
            .expect("frontier is non-empty")
    }
}

/// The memoized fleet-wide plan frontier: one [`GroupFrontier`] per
/// feasible spec entry. This is what PR 4's composition search walks and
/// what the PR 5 rebalancer keeps attached at serve time.
#[derive(Debug, Clone)]
pub struct FleetFrontier {
    pub clock_mhz: f64,
    pub groups: Vec<GroupFrontier>,
}

impl FleetFrontier {
    /// Build every device's count frontier: candidates at `1..=max`
    /// (or exactly the forced count), stopping at the first infeasible
    /// count. A forced count that cannot plan is the caller's mistake
    /// (error); an unforced device that fits nothing just sits the fleet
    /// out — unless *no* device fits, which returns the first error.
    pub fn build(
        model: &Model,
        spec: &FleetSpec,
        clock_mhz: f64,
        policy: &Policy,
        max_replicas: usize,
    ) -> Result<FleetFrontier, PlanError> {
        assert!(!spec.entries.is_empty(), "a fleet spec needs at least one device");
        let mut groups = Vec::new();
        let mut first_err: Option<PlanError> = None;
        for (si, entry) in spec.entries.iter().enumerate() {
            let built: Result<Vec<GroupPlan>, PlanError> = match entry.count {
                Some(r) => plan_group(model, &entry.device, si, clock_mhz, policy, r)
                    .map(|g| vec![g]),
                None => {
                    let mut out = Vec::new();
                    let mut err: Option<PlanError> = None;
                    for r in 1..=max_replicas.max(1) {
                        match plan_group(model, &entry.device, si, clock_mhz, policy, r) {
                            Ok(g) => out.push(g),
                            Err(e) => {
                                err = Some(e);
                                break;
                            }
                        }
                    }
                    if out.is_empty() {
                        Err(err.expect("loop ran at least once"))
                    } else {
                        Ok(out)
                    }
                }
            };
            match built {
                Ok(counts) => groups.push(GroupFrontier {
                    device: entry.device.clone(),
                    spec_entry: si,
                    forced: entry.count,
                    counts,
                }),
                Err(e) if entry.count.is_some() => return Err(e),
                Err(e) => first_err = first_err.or(Some(e)),
            }
        }
        if groups.is_empty() {
            return Err(first_err.expect("at least one entry failed"));
        }
        Ok(FleetFrontier { clock_mhz, groups })
    }

    /// Assemble a [`FleetPlan`] at explicit per-group counts (`counts[i]`
    /// replicas for `groups[i]`; 0 leaves the group out). This is the
    /// rebalancer's entry point for "what would the fleet look like at
    /// these counts" and the test harness's way to start a fleet below
    /// its argmax.
    pub fn fleet_at(&self, counts: &[usize]) -> FleetPlan {
        assert_eq!(counts.len(), self.groups.len(), "one count per frontier group");
        let chosen: Vec<GroupPlan> = self
            .groups
            .iter()
            .zip(counts)
            .filter(|(_, &c)| c > 0)
            .map(|(g, &c)| g.at(c).clone())
            .collect();
        assert!(!chosen.is_empty(), "a fleet needs at least one replica");
        compose(self.clock_mhz, chosen, None)
    }
}

/// Finalize a fleet from chosen group plans.
fn compose(clock_mhz: f64, groups: Vec<GroupPlan>, target_img_s: Option<f64>) -> FleetPlan {
    let fleet_img_s = groups.iter().map(|g| g.group_img_s).sum::<f64>();
    let static_w = groups.iter().map(|g| g.device.static_w).sum::<f64>();
    FleetPlan {
        clock_mhz,
        groups,
        fleet_img_s,
        static_w,
        target_img_s,
        meets_target: target_img_s.map(|t| fleet_img_s >= t).unwrap_or(true),
    }
}

/// Plan a heterogeneous fleet across `spec`'s devices.
///
/// Without a target: every listed device serves at its throughput-argmax
/// replica count — throughput is additive across parts, so the per-device
/// argmax composes to the fleet argmax. Devices that cannot carry even
/// one replica are skipped (unless their count was forced, which is an
/// error); if no device can, the first planning error is returned.
///
/// With `target_img_s`: the cheapest modeled-static-power mix meeting the
/// target. Forced entries are always included at their forced count;
/// optional devices are added greedily by modeled throughput per static
/// watt until the target is met, then a drop pass removes (most power-
/// hungry first) any optional device the target can spare. If even the
/// full mix falls short, everything is included and `meets_target` is
/// `false` so the caller can degrade explicitly instead of silently.
pub fn plan_fleet_spec(
    model: &Model,
    spec: &FleetSpec,
    clock_mhz: f64,
    policy: &Policy,
    target_img_s: Option<f64>,
    max_replicas: usize,
) -> Result<FleetPlan, PlanError> {
    let frontier = FleetFrontier::build(model, spec, clock_mhz, policy, max_replicas)?;
    Ok(compose_frontier(&frontier, target_img_s))
}

/// The PR 4 composition search over an already-built frontier: per-group
/// argmax candidates, then (under a target) the cheapest static-power
/// mix. Separated from [`plan_fleet_spec`] so the rebalancer can re-run
/// composition against its memoized frontier without replanning.
pub fn compose_frontier(frontier: &FleetFrontier, target_img_s: Option<f64>) -> FleetPlan {
    let candidates: Vec<(GroupPlan, bool)> = frontier
        .groups
        .iter()
        .map(|g| (g.argmax().clone(), g.forced.is_some()))
        .collect();
    assert!(!candidates.is_empty(), "frontier has at least one group");
    let clock_mhz = frontier.clock_mhz;

    let chosen: Vec<GroupPlan> = match target_img_s {
        None => candidates.into_iter().map(|(g, _)| g).collect(),
        Some(target) => {
            let mut included: Vec<(GroupPlan, bool)> = Vec::new();
            let mut optional: Vec<GroupPlan> = Vec::new();
            for (g, forced) in candidates {
                if forced {
                    included.push((g, true));
                } else {
                    optional.push(g);
                }
            }
            // Greedy add by throughput per static watt. A fleet is never
            // empty: with no forced entries at least one optional group
            // joins, whatever the target.
            optional.sort_by(|a, b| {
                let ea = a.group_img_s / a.device.static_w.max(1e-12);
                let eb = b.group_img_s / b.device.static_w.max(1e-12);
                eb.partial_cmp(&ea).expect("efficiency is finite")
            });
            let sum = |v: &[(GroupPlan, bool)]| v.iter().map(|(g, _)| g.group_img_s).sum::<f64>();
            let mut optional = optional.into_iter();
            while included.is_empty() || sum(&included) < target {
                match optional.next() {
                    Some(g) => included.push((g, false)),
                    None => break,
                }
            }
            // Drop pass: shed the most power-hungry optional groups the
            // target can spare (greedy add can overshoot).
            let mut order: Vec<usize> = (0..included.len()).filter(|&i| !included[i].1).collect();
            order.sort_by(|&i, &j| {
                included[j]
                    .0
                    .device
                    .static_w
                    .partial_cmp(&included[i].0.device.static_w)
                    .expect("power is finite")
            });
            let mut dropped = vec![false; included.len()];
            let mut live = sum(&included);
            let mut kept = included.len();
            for i in order {
                // Never shed the last group: a degenerate (e.g. zero)
                // target still gets a serving fleet.
                if kept > 1 && live - included[i].0.group_img_s >= target {
                    live -= included[i].0.group_img_s;
                    dropped[i] = true;
                    kept -= 1;
                }
            }
            included
                .into_iter()
                .zip(dropped)
                .filter(|(_, d)| !d)
                .map(|((g, _), _)| g)
                .collect()
        }
    };
    assert!(!chosen.is_empty(), "composition keeps at least one group");
    compose(clock_mhz, chosen, target_img_s)
}

/// A plan's engine signature: `(layer, kind, instances)` per engine
/// site. Two shard plans with equal signatures deploy identical
/// pipelines, so a group can be resized by adding/retiring replicas
/// *incrementally* instead of rolling the whole group onto new plans —
/// the common case for models far from the resource ceiling.
pub fn plan_signature(plan: &Plan) -> Vec<(usize, crate::ips::engine::EngineKind, u64)> {
    plan.engines.iter().map(|e| (e.layer, e.kind, e.instances)).collect()
}

/// Plan a single-device fleet of exactly `replicas` copies (the CLI's
/// `--replicas` override). Errors if one replica cannot be planned under
/// `1/replicas` of the device.
pub fn plan_fixed_fleet(
    model: &Model,
    dev: &Device,
    clock_mhz: f64,
    policy: &Policy,
    replicas: usize,
    target_img_s: Option<f64>,
) -> Result<FleetPlan, PlanError> {
    let spec = FleetSpec::single(dev.clone(), Some(replicas.max(1)));
    plan_fleet_spec(model, &spec, clock_mhz, policy, target_img_s, replicas.max(1))
}

/// Search replica counts `1..=max_replicas` for the best single-device
/// fleet (the PR 2 surface; a one-entry [`plan_fleet_spec`]).
pub fn plan_fleet(
    model: &Model,
    dev: &Device,
    clock_mhz: f64,
    policy: &Policy,
    target_img_s: Option<f64>,
    max_replicas: usize,
) -> Result<FleetPlan, PlanError> {
    let spec = FleetSpec::single(dev.clone(), None);
    plan_fleet_spec(model, &spec, clock_mhz, policy, target_img_s, max_replicas)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::device::by_name;

    fn adaptive() -> Policy {
        Policy::adaptive()
    }

    #[test]
    fn lenet_tiny_on_zcu104_replicates() {
        let m = Model::lenet_tiny();
        let dev = by_name("zcu104").unwrap();
        let fp = plan_fleet(&m, &dev, 200.0, &adaptive(), None, DEFAULT_MAX_REPLICAS).unwrap();
        assert_eq!(fp.groups.len(), 1);
        let g = &fp.groups[0];
        // The acceptance bar: the default device carries at least two
        // replicas, and the fleet out-models a single whole-device plan.
        assert!(g.replicas >= 2, "only {} replica(s)", g.replicas);
        assert!(g.total.fits(&dev), "group must fit the undivided device");
        let single = crate::planner::plan(&m, &dev, 200.0, &adaptive()).unwrap();
        assert!(
            fp.fleet_img_s >= single.images_per_sec,
            "fleet {} < single {}",
            fp.fleet_img_s,
            single.images_per_sec
        );
        assert!(fp.meets_target);
        let (d, l) = g.pressure();
        assert!(d <= 1.0 && l <= 1.0);
        // Coefficient storage is charged per replica in the group total.
        assert!(g.coef_bram18 > 0);
        assert!(g.total.bram18 >= g.coef_bram18 * g.replicas as u64);
    }

    #[test]
    fn single_device_search_maximizes_fleet_throughput() {
        // Without an SLO the pick must dominate every feasible fixed
        // count — the search is argmax, not largest-feasible.
        let m = Model::lenet_tiny();
        for dev_name in ["zcu104", "zu2cg", "edge-nodsp"] {
            let dev = by_name(dev_name).unwrap();
            let Ok(best) = plan_fleet(&m, &dev, 200.0, &adaptive(), None, 6) else {
                continue;
            };
            for r in 1..=6usize {
                if let Ok(fp) = plan_fixed_fleet(&m, &dev, 200.0, &adaptive(), r, None) {
                    assert!(
                        best.fleet_img_s >= fp.fleet_img_s - 1e-6,
                        "{dev_name}: picked {} img/s @ r={}, but r={r} models {} img/s",
                        best.fleet_img_s,
                        best.replicas(),
                        fp.fleet_img_s
                    );
                }
            }
        }
    }

    #[test]
    fn heterogeneous_fleet_is_the_sum_of_its_groups() {
        let m = Model::lenet_tiny();
        let spec = FleetSpec {
            entries: vec![
                FleetEntry { device: by_name("zcu104").unwrap(), count: None },
                FleetEntry { device: by_name("zu5ev").unwrap(), count: None },
            ],
        };
        let fp = plan_fleet_spec(&m, &spec, 200.0, &adaptive(), None, 4).unwrap();
        assert_eq!(fp.groups.len(), 2);
        assert_eq!(fp.group_labels(), vec!["zcu104".to_string(), "zu5ev".to_string()]);
        let sum: f64 = fp.groups.iter().map(|g| g.group_img_s).sum();
        assert!((fp.fleet_img_s - sum).abs() < 1e-6);
        let zcu = plan_fleet(&m, &by_name("zcu104").unwrap(), 200.0, &adaptive(), None, 4).unwrap();
        let zu5 = plan_fleet(&m, &by_name("zu5ev").unwrap(), 200.0, &adaptive(), None, 4).unwrap();
        // Composition is per-device argmax, so the mix models exactly the
        // two single-device fleets added together — and beats both.
        assert!((fp.fleet_img_s - (zcu.fleet_img_s + zu5.fleet_img_s)).abs() < 1e-6);
        assert!(fp.fleet_img_s > zcu.fleet_img_s.max(zu5.fleet_img_s));
        // Group-major replica bookkeeping is consistent.
        assert_eq!(fp.replicas(), fp.groups[0].replicas + fp.groups[1].replicas);
        let rg = fp.replica_groups();
        assert_eq!(rg.len(), fp.replicas());
        assert_eq!(rg.iter().filter(|&&g| g == 0).count(), fp.groups[0].replicas);
        // Static power is one full part each.
        assert!((fp.static_w - (0.593 + 0.45)).abs() < 1e-9);
    }

    #[test]
    fn forced_counts_are_pinned_and_validated() {
        let m = Model::lenet_tiny();
        let spec = FleetSpec {
            entries: vec![
                FleetEntry { device: by_name("zcu104").unwrap(), count: Some(2) },
                FleetEntry { device: by_name("zu5ev").unwrap(), count: Some(1) },
            ],
        };
        let fp = plan_fleet_spec(&m, &spec, 200.0, &adaptive(), None, 8).unwrap();
        assert_eq!(fp.groups[0].replicas, 2);
        assert_eq!(fp.groups[1].replicas, 1);
        // A forced count the device cannot hold is an error, not a skip.
        let spec = FleetSpec::single(by_name("edge-nodsp").unwrap(), Some(64));
        assert!(plan_fleet_spec(&m, &spec, 200.0, &adaptive(), None, 8).is_err());
    }

    #[test]
    fn target_picks_cheapest_static_power_mix() {
        let m = Model::lenet_tiny();
        let zcu = by_name("zcu104").unwrap();
        let zu5 = by_name("zu5ev").unwrap();
        let spec = FleetSpec {
            entries: vec![
                FleetEntry { device: zcu.clone(), count: None },
                FleetEntry { device: zu5.clone(), count: None },
            ],
        };
        let free = plan_fleet_spec(&m, &spec, 200.0, &adaptive(), None, 4).unwrap();
        // A target one device alone can meet: the composition must shed
        // the other part's static power.
        let one_dev_target = free.groups.iter().map(|g| g.group_img_s).fold(f64::MAX, f64::min) * 0.5;
        let fp = plan_fleet_spec(&m, &spec, 200.0, &adaptive(), Some(one_dev_target), 4).unwrap();
        assert!(fp.meets_target);
        assert_eq!(fp.groups.len(), 1, "one part suffices for the target");
        assert!(fp.static_w < free.static_w);
        // An unmeetable target keeps the whole mix, flagged.
        let fp = plan_fleet_spec(&m, &spec, 200.0, &adaptive(), Some(1e15), 4).unwrap();
        assert!(!fp.meets_target);
        assert_eq!(fp.groups.len(), 2);
        // A forced entry is never shed, even when the other part covers
        // the target more efficiently.
        let spec = FleetSpec {
            entries: vec![
                FleetEntry { device: zcu.clone(), count: None },
                FleetEntry { device: zu5.clone(), count: Some(1) },
            ],
        };
        let fp =
            plan_fleet_spec(&m, &spec, 200.0, &adaptive(), Some(one_dev_target), 4).unwrap();
        assert!(fp.groups.iter().any(|g| g.device.name == "zu5ev"));
    }

    #[test]
    fn coefficient_bram_caps_replica_counts() {
        let m = Model::lenet_tiny();
        let coef = crate::planner::coefficient_bram18(&m);
        // A part with abundant logic but BRAM for exactly one coefficient
        // copy: the old floor-divide would have packed more replicas.
        let mut dev = by_name("zcu104").unwrap();
        dev.name = "bram-starved".into();
        dev.bram18 = coef + 1;
        let fp = plan_fleet(&m, &dev, 200.0, &adaptive(), None, 4).unwrap();
        assert_eq!(fp.replicas(), 1, "BRAM reserve must cap the fleet at one replica");
        assert!(plan_fixed_fleet(&m, &dev, 200.0, &adaptive(), 2, None).is_err());
        // With BRAM for two copies the cap moves to two.
        dev.bram18 = 2 * coef;
        let fp = plan_fleet(&m, &dev, 200.0, &adaptive(), None, 4).unwrap();
        assert_eq!(fp.replicas(), 2);
        assert!(fp.groups[0].total.bram18 <= dev.bram18);
    }

    #[test]
    fn spec_parsing_names_counts_and_catalogs() {
        let spec = FleetSpec::parse("zcu104,zu5ev:2", &[]).unwrap();
        assert_eq!(spec.entries.len(), 2);
        assert_eq!(spec.entries[0].device.name, "zcu104");
        assert_eq!(spec.entries[0].count, None);
        assert_eq!(spec.entries[1].device.name, "zu5ev");
        assert_eq!(spec.entries[1].count, Some(2));
        // Extra catalog devices shadow nothing but are reachable by name,
        // case-insensitively.
        let mut custom = by_name("zu2cg").unwrap();
        custom.name = "myboard".into();
        let spec = FleetSpec::parse("MyBoard:1,zcu104", &[custom]).unwrap();
        assert_eq!(spec.entries[0].device.name, "myboard");
        assert_eq!(spec.entries[0].count, Some(1));
        // Errors: unknown device, bad count, zero count, empty list.
        assert!(FleetSpec::parse("nosuchpart", &[]).is_err());
        assert!(FleetSpec::parse("zcu104:x", &[]).is_err());
        assert!(FleetSpec::parse("zcu104:0", &[]).is_err());
        assert!(FleetSpec::parse("", &[]).is_err());
        assert!(FleetSpec::parse(" , ", &[]).is_err());
    }

    #[test]
    fn frontier_memoizes_counts_and_composes_at_any_point() {
        let m = Model::lenet_tiny();
        let spec = FleetSpec {
            entries: vec![
                FleetEntry { device: by_name("zcu104").unwrap(), count: None },
                FleetEntry { device: by_name("zu5ev").unwrap(), count: None },
            ],
        };
        let fr = FleetFrontier::build(&m, &spec, 200.0, &adaptive(), 4).unwrap();
        assert_eq!(fr.groups.len(), 2);
        assert_eq!(fr.groups[0].spec_entry, 0);
        assert!(fr.groups[0].max_count() >= 2, "zcu104 carries at least two replicas");
        // at() returns exactly the plan the full search would make.
        for c in 1..=fr.groups[0].max_count() {
            let g = fr.groups[0].at(c);
            assert_eq!(g.replicas, c);
            let zcu = by_name("zcu104").unwrap();
            let direct = plan_fixed_fleet(&m, &zcu, 200.0, &adaptive(), c, None).unwrap();
            assert!((g.group_img_s - direct.groups[0].group_img_s).abs() < 1e-6);
        }
        // Composition over the frontier == the one-shot search.
        let via_frontier = compose_frontier(&fr, None);
        let direct = plan_fleet_spec(&m, &spec, 200.0, &adaptive(), None, 4).unwrap();
        assert!((via_frontier.fleet_img_s - direct.fleet_img_s).abs() < 1e-6);
        assert_eq!(via_frontier.replicas(), direct.replicas());
        // fleet_at pins explicit counts — including starting BELOW the
        // argmax (the rebalancer's low-water starting point) and leaving
        // a group out entirely.
        let low = fr.fleet_at(&[1, 1]);
        assert_eq!(low.replicas(), 2);
        assert_eq!(low.groups.len(), 2);
        assert!(low.fleet_img_s <= via_frontier.fleet_img_s + 1e-9);
        let solo = fr.fleet_at(&[1, 0]);
        assert_eq!(solo.groups.len(), 1);
        assert_eq!(solo.groups[0].device.name, "zcu104");
    }

    #[test]
    fn plan_signature_detects_identical_and_different_shard_plans() {
        let m = Model::lenet_tiny();
        let fr = FleetFrontier::build(
            &m,
            &FleetSpec::single(by_name("zcu104").unwrap(), None),
            200.0,
            &adaptive(),
            3,
        )
        .unwrap();
        let g = &fr.groups[0];
        // A plan's signature equals itself and is stable across clones.
        let s1 = plan_signature(&g.at(1).per_replica);
        assert_eq!(s1, plan_signature(&g.at(1).per_replica.clone()));
        // Different devices produce different signatures (the edge part
        // substitutes IPs — the paper's adaptive story).
        let edge = FleetFrontier::build(
            &m,
            &FleetSpec::single(by_name("edge-nodsp").unwrap(), None),
            200.0,
            &adaptive(),
            1,
        )
        .unwrap();
        assert_ne!(s1, plan_signature(&edge.groups[0].at(1).per_replica));
    }

    #[test]
    fn deploy_shares_weights_across_groups() {
        let m = Model::lenet_tiny();
        let spec = FleetSpec {
            entries: vec![
                FleetEntry { device: by_name("zcu104").unwrap(), count: Some(1) },
                FleetEntry { device: by_name("zu5ev").unwrap(), count: Some(1) },
            ],
        };
        let fp = plan_fleet_spec(&m, &spec, 200.0, &adaptive(), None, 2).unwrap();
        let reps = fp.deploy(m, Weights::random(&Model::lenet_tiny(), 42));
        assert_eq!(reps.len(), 2);
        assert!(Arc::ptr_eq(&reps[0].weights, &reps[1].weights));
        assert!(Arc::ptr_eq(&reps[0].model, &reps[1].model));
        // Replicas of different groups carry their own group's plan...
        assert_eq!(reps[0].plan.device.name, "zcu104");
        assert_eq!(reps[1].plan.device.name, "zu5ev");
        // ...and both pipelines are live and bit-identical.
        let img = vec![0i64; 256];
        assert_eq!(reps[0].infer_one(&img).unwrap(), reps[1].infer_one(&img).unwrap());
    }
}
