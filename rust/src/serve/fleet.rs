//! Fleet planning: turn a *catalog* of device budgets into a serving
//! fleet by running the resource-driven planner under divided budgets.
//!
//! This is the paper's scarcity logic lifted two levels up. PR 2 asked
//! "how many whole copies of the planned network fit ONE device?"; real
//! edge deployments mix parts with very different DSP/LUT/BRAM balances
//! AND host several networks at once, so the fleet planner now walks a
//! **model×device** frontier:
//!
//! 1. **Per-(device, model) frontier.** For each spec entry and each zoo
//!    model, the monotone shard scan from PR 2 builds the count → plan
//!    frontier: candidate count `r` plans one replica against an equal
//!    `1/r` shard ([`crate::fabric::device::Device::shard`]), with the
//!    model's coefficient BRAM charged off the top *per replica*
//!    (weights do not shrink with the shard —
//!    [`crate::planner::coefficient_bram18`]). The scan stops at the
//!    first infeasible count. The PR 5 memoized frontier keys extend
//!    with the model id: a [`GroupFrontier`] is now one `(spec entry,
//!    model)` pair, so the live rebalancer can shift a device group
//!    *between models* by indexing a different frontier row — no planner
//!    run ever happens while traffic is flowing.
//! 2. **Cross-device composition.** Single-model fleets keep the PR 4
//!    search exactly: per-device throughput argmax, or (under
//!    `--target-img-s`) the cheapest static-power mix. Multi-model
//!    fleets add an assignment step: each physical entry carries exactly
//!    one model (one bitstream per board), entries greedily take the
//!    model they model fastest, then a coverage repair donates the
//!    cheapest entry to any model left without a group.
//!
//! The single planning entry point is the [`FleetSpec::plan`] builder;
//! the free functions from PRs 2/4 survive only as deprecated shims.

use crate::cnn::model::{Model, Weights};
use crate::coordinator::Deployment;
use crate::fabric::device::{by_name, Device};
use crate::planner::{coefficient_bram18, plan_under_fraction, Plan, PlanError, Policy};
use crate::synth::Utilization;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Default ceiling on the per-device replica search (CLI `--max-replicas`
/// raises it).
pub const DEFAULT_MAX_REPLICAS: usize = 8;

/// One requested fleet member: a physical part, optionally pinned to an
/// exact replica count (`None` = search `1..=max_replicas`).
#[derive(Debug, Clone)]
pub struct FleetEntry {
    pub device: Device,
    pub count: Option<usize>,
}

/// What the fleet should be built from: one entry per physical part.
/// Listing the same part twice means two boards, each its own group.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    pub entries: Vec<FleetEntry>,
}

impl FleetSpec {
    /// A one-device spec (the PR 2 surface).
    pub fn single(device: Device, count: Option<usize>) -> FleetSpec {
        FleetSpec { entries: vec![FleetEntry { device, count }] }
    }

    /// Parse the CLI form `name[:count],name[:count],...` (e.g.
    /// `zcu104,zu5ev:2`). Names resolve against `extra` (a `--catalog`
    /// file, case-insensitive on name or part) first, then the built-in
    /// catalog.
    pub fn parse(spec: &str, extra: &[Device]) -> Result<FleetSpec, String> {
        let mut entries = Vec::new();
        for item in spec.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            let (name, count) = match item.split_once(':') {
                Some((n, c)) => {
                    let count: usize = c
                        .parse()
                        .map_err(|_| format!("bad replica count '{c}' in '{item}'"))?;
                    if count == 0 {
                        return Err(format!("replica count must be >= 1 in '{item}'"));
                    }
                    (n, Some(count))
                }
                None => (item, None),
            };
            let lower = name.to_ascii_lowercase();
            let device = extra
                .iter()
                .find(|d| d.name.to_ascii_lowercase() == lower || d.part.to_ascii_lowercase() == lower)
                .cloned()
                .or_else(|| by_name(name))
                .ok_or_else(|| format!("unknown device '{name}' (not in --catalog or built-ins)"))?;
            entries.push(FleetEntry { device, count });
        }
        if entries.is_empty() {
            return Err("empty device list".into());
        }
        Ok(FleetSpec { entries })
    }

    /// THE fleet-planning entry point: a builder owning model assignment,
    /// clock, policy, target throughput, and the replica-search ceiling.
    ///
    /// ```text
    /// spec.plan().model(&m).target_img_s(Some(9e5)).run()?        // one model
    /// spec.plan().models(zoo).max_replicas(6).run()?              // model zoo
    /// spec.plan().model(&m).frontier()?                           // memoized frontier
    /// ```
    pub fn plan(&self) -> FleetPlanner {
        FleetPlanner {
            spec: self.clone(),
            models: Vec::new(),
            clock_mhz: 200.0,
            policy: Policy::adaptive(),
            target_img_s: None,
            max_replicas: DEFAULT_MAX_REPLICAS,
        }
    }
}

/// Builder returned by [`FleetSpec::plan`] — the only supported way to
/// turn a spec into a [`FleetFrontier`] / [`FleetPlan`]. Replaces the
/// PR 2/4 free functions (`plan_fleet`, `plan_fleet_spec`,
/// `plan_fixed_fleet`), which now shim onto it.
#[derive(Debug, Clone)]
pub struct FleetPlanner {
    spec: FleetSpec,
    models: Vec<Arc<Model>>,
    clock_mhz: f64,
    policy: Policy,
    target_img_s: Option<f64>,
    max_replicas: usize,
}

impl FleetPlanner {
    /// Assign one model to the whole fleet (the classic surface).
    pub fn model(mut self, model: &Model) -> Self {
        self.models = vec![Arc::new(model.clone())];
        self
    }

    /// Assign a model zoo: composition decides which device groups carry
    /// which models. Model ids are indexes into this list.
    pub fn models(mut self, models: Vec<Arc<Model>>) -> Self {
        self.models = models;
        self
    }

    pub fn clock_mhz(mut self, mhz: f64) -> Self {
        self.clock_mhz = mhz;
        self
    }

    pub fn policy(mut self, policy: &Policy) -> Self {
        self.policy = policy.clone();
        self
    }

    /// Modeled-throughput SLO the composition must meet (power-aware mix).
    pub fn target_img_s(mut self, target: Option<f64>) -> Self {
        self.target_img_s = target;
        self
    }

    pub fn max_replicas(mut self, max: usize) -> Self {
        self.max_replicas = max.max(1);
        self
    }

    /// Build the memoized model×device frontier without composing it —
    /// what the CLI hands the rebalancer.
    pub fn frontier(&self) -> Result<FleetFrontier, PlanError> {
        assert!(!self.models.is_empty(), "assign a model first: spec.plan().model(&m)");
        FleetFrontier::build_zoo(
            self.models.clone(),
            &self.spec,
            self.clock_mhz,
            &self.policy,
            self.max_replicas,
        )
    }

    /// Build the frontier and compose the fleet. Errors if any zoo model
    /// ends up without a device group to carry it.
    pub fn run(&self) -> Result<FleetPlan, PlanError> {
        let frontier = self.frontier()?;
        let plan = compose_frontier(&frontier, self.target_img_s);
        for (mi, m) in frontier.models.iter().enumerate() {
            if !plan.groups.iter().any(|g| g.model_id == mi) {
                return Err(PlanError::Infeasible {
                    device: "fleet".into(),
                    reason: format!(
                        "no device group left to carry model '{}' — list at least one device per model",
                        m.name
                    ),
                });
            }
        }
        Ok(plan)
    }
}

/// One device's replica group inside a planned fleet.
#[derive(Debug, Clone)]
pub struct GroupPlan {
    /// The undivided physical part this group runs on.
    pub device: Device,
    /// Index of the [`FleetSpec`] entry (and therefore the physical
    /// board) this plan came from — the same part can be listed twice
    /// (two boards), so names are not a key.
    pub spec_entry: usize,
    /// Index into the plan's model zoo ([`FleetPlan::models`]) of the
    /// model every replica of this group serves.
    pub model_id: usize,
    pub replicas: usize,
    /// The plan every replica of this group deploys (made against
    /// `device.shard(replicas)` with per-replica coefficient BRAM
    /// reserved off the top).
    pub per_replica: Plan,
    /// RAMB18s of coefficient storage *per replica* (does not shrink with
    /// the shard).
    pub coef_bram18: u64,
    /// Whole-group utilization on the undivided part: `replicas ×`
    /// (engine resources + coefficient store).
    pub total: Utilization,
    /// Modeled replica-sum throughput of this group.
    pub group_img_s: f64,
}

impl GroupPlan {
    /// Group pressure on its undivided device: (DSP fraction, LUT fraction).
    pub fn pressure(&self) -> (f64, f64) {
        (self.device.dsp_util(self.total.dsps), self.device.lut_util(self.total.luts))
    }
}

/// A planned serving fleet: one replica group per physical board, each
/// group running its own plan for its assigned model.
#[derive(Debug, Clone)]
pub struct FleetPlan {
    pub clock_mhz: f64,
    /// The model zoo this plan spans; [`GroupPlan::model_id`] indexes it.
    /// Single-model fleets have exactly one entry.
    pub models: Vec<Arc<Model>>,
    pub groups: Vec<GroupPlan>,
    /// Modeled fleet throughput: the sum over groups (throughput is
    /// additive across physical parts).
    pub fleet_img_s: f64,
    /// Modeled static power of the mix: one full `static_w` per included
    /// part (a powered part burns its static power whatever its shard).
    pub static_w: f64,
    /// The SLO the search was asked to meet, if any.
    pub target_img_s: Option<f64>,
    /// Whether `fleet_img_s` meets `target_img_s` (true when no target).
    pub meets_target: bool,
}

impl FleetPlan {
    /// Total replica count across all device groups.
    pub fn replicas(&self) -> usize {
        self.groups.iter().map(|g| g.replicas).sum()
    }

    /// Device-group index of each replica, group-major — the same order
    /// the deploy methods emit replicas in.
    pub fn replica_groups(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.replicas());
        for (gi, g) in self.groups.iter().enumerate() {
            for _ in 0..g.replicas {
                out.push(gi);
            }
        }
        out
    }

    /// Display label per device group: the part's name, qualified with
    /// the model name when the plan spans more than one model.
    pub fn group_labels(&self) -> Vec<String> {
        self.groups
            .iter()
            .map(|g| {
                if self.models.len() > 1 {
                    format!("{}/{}", g.device.name, self.models[g.model_id].name)
                } else {
                    g.device.name.clone()
                }
            })
            .collect()
    }

    /// Modeled throughput of the groups carrying `model_id`.
    pub fn model_img_s(&self, model_id: usize) -> f64 {
        self.groups.iter().filter(|g| g.model_id == model_id).map(|g| g.group_img_s).sum()
    }

    /// Deploy the fleet: one persistent pipeline per replica, group-major
    /// order, all sharing one model and one weight set. Replicas of
    /// different groups run different plans. Single-model plans only —
    /// model-zoo plans deploy with [`FleetPlan::deploy_zoo`].
    pub fn deploy(&self, model: Model, weights: Weights) -> FleetHandle {
        self.deploy_shared(Arc::new(model), Arc::new(weights))
    }

    /// [`FleetPlan::deploy`] against already-shared model/weight handles —
    /// what the rebalancer uses so replicas it spins up later share the
    /// exact same allocations as the initial fleet.
    pub fn deploy_shared(&self, model: Arc<Model>, weights: Arc<Weights>) -> FleetHandle {
        assert!(
            self.models.len() <= 1,
            "this plan spans {} models; deploy it with deploy_zoo(weights_per_model)",
            self.models.len()
        );
        let zoo = ZooWeights { models: vec![Arc::clone(&model)], weights: vec![weights] };
        self.deploy_with(&zoo, |_| 0)
    }

    /// Deploy a model-zoo fleet: `weights[model_id]` pairs with
    /// [`FleetPlan::models`], and each group's replicas are built from
    /// their group's assigned model.
    pub fn deploy_zoo(&self, weights: &[Arc<Weights>]) -> FleetHandle {
        assert_eq!(weights.len(), self.models.len(), "one weight set per zoo model");
        let zoo = ZooWeights { models: self.models.clone(), weights: weights.to_vec() };
        self.deploy_with(&zoo, |g| g.model_id)
    }

    fn deploy_with(&self, zoo: &ZooWeights, model_of: impl Fn(&GroupPlan) -> usize) -> FleetHandle {
        let mut replicas = Vec::with_capacity(self.replicas());
        let mut models = Vec::with_capacity(self.groups.len());
        for g in &self.groups {
            let mi = model_of(g);
            models.push(Arc::clone(&zoo.models[mi]));
            for _ in 0..g.replicas {
                replicas.push(Arc::new(Deployment::with_plan(
                    Arc::clone(&zoo.models[mi]),
                    Arc::clone(&zoo.weights[mi]),
                    g.per_replica.clone(),
                )));
            }
        }
        FleetHandle::new(replicas, self.replica_groups(), self.group_labels(), models)
    }
}

struct ZooWeights {
    models: Vec<Arc<Model>>,
    weights: Vec<Arc<Weights>>,
}

/// Everything [`crate::serve::Server::start`] needs to serve a deployed
/// fleet: the replica pipelines, their group topology, display labels,
/// and the model each group carries. Produced by the
/// [`FleetPlan::deploy`] family; hand-assembled in tests via
/// [`FleetHandle::solo`] / [`FleetHandle::new`].
#[derive(Clone)]
pub struct FleetHandle {
    /// Replica deployments, group-major (all of group 0, then group 1, …).
    pub replicas: Vec<Arc<Deployment>>,
    /// Group index of each replica (parallel to `replicas`).
    pub groups: Vec<usize>,
    /// Display label per group.
    pub labels: Vec<String>,
    /// The model each group serves (parallel to `labels`).
    pub models: Vec<Arc<Model>>,
}

impl FleetHandle {
    pub fn new(
        replicas: Vec<Arc<Deployment>>,
        groups: Vec<usize>,
        labels: Vec<String>,
        models: Vec<Arc<Model>>,
    ) -> FleetHandle {
        assert!(!replicas.is_empty(), "a fleet needs at least one replica");
        assert_eq!(replicas.len(), groups.len(), "one group index per replica");
        assert_eq!(labels.len(), models.len(), "one model per group label");
        assert!(
            groups.iter().all(|&g| g < labels.len()),
            "replica group index out of range"
        );
        FleetHandle { replicas, groups, labels, models }
    }

    /// The 1-group special case: every replica in one group called
    /// "fleet", serving the model its deployments were built with.
    pub fn solo(replicas: Vec<Arc<Deployment>>) -> FleetHandle {
        assert!(!replicas.is_empty(), "a fleet needs at least one replica");
        let model = Arc::clone(&replicas[0].model);
        let groups = vec![0; replicas.len()];
        FleetHandle::new(replicas, groups, vec!["fleet".into()], vec![model])
    }

    pub fn n_groups(&self) -> usize {
        self.labels.len()
    }
}

/// Plan one device's replica group at an exact count. Errors if one
/// replica cannot be planned under `1/count` of the device (including
/// when the part's BRAM cannot hold `count` coefficient copies).
fn plan_group(
    model: &Model,
    model_id: usize,
    dev: &Device,
    spec_entry: usize,
    clock_mhz: f64,
    policy: &Policy,
    count: usize,
) -> Result<GroupPlan, PlanError> {
    let r = count.max(1);
    let per_replica = plan_under_fraction(model, dev, clock_mhz, policy, r as u64)?;
    let coef = coefficient_bram18(model);
    let mut total = per_replica.total.times(r as u64);
    total.bram18 += coef * r as u64;
    Ok(GroupPlan {
        device: dev.clone(),
        spec_entry,
        model_id,
        replicas: r,
        group_img_s: r as f64 * per_replica.images_per_sec,
        coef_bram18: coef,
        per_replica,
        total,
    })
}

/// One `(spec entry, model)` pair's memoized count → plan frontier:
/// `counts[c - 1]` is the group plan at `c` replicas (each against a
/// `1/c` shard with its coefficient BRAM charged). Built once at plan
/// time; the live rebalancer resizes groups by *indexing* this — and
/// shifts a board between models by indexing the row with the same
/// `spec_entry` and a different `model_id`.
#[derive(Debug, Clone)]
pub struct GroupFrontier {
    pub device: Device,
    /// Index of the [`FleetSpec`] entry this frontier belongs to.
    pub spec_entry: usize,
    /// Index into [`FleetFrontier::models`] — the PR 5 memo key extended
    /// with the model id.
    pub model_id: usize,
    /// Forced replica count, if the spec pinned one (the rebalancer
    /// leaves forced groups alone).
    pub forced: Option<usize>,
    counts: Vec<GroupPlan>,
}

impl GroupFrontier {
    /// Largest feasible replica count (the frontier is contiguous from 1:
    /// shards only shrink as the count grows, so feasibility is monotone).
    pub fn max_count(&self) -> usize {
        self.forced.unwrap_or(self.counts.len())
    }

    /// Smallest plannable count (1, or the forced count when pinned).
    pub fn min_count(&self) -> usize {
        self.forced.unwrap_or(1)
    }

    /// The memoized group plan at `count` replicas.
    pub fn at(&self, count: usize) -> &GroupPlan {
        if let Some(f) = self.forced {
            assert_eq!(count, f, "group is pinned to {f} replicas");
            return &self.counts[0];
        }
        assert!(
            (1..=self.counts.len()).contains(&count),
            "count {count} outside frontier 1..={}",
            self.counts.len()
        );
        &self.counts[count - 1]
    }

    /// The throughput-argmax candidate (ties go to more replicas — more
    /// concurrent request capacity at the same rate).
    pub fn argmax(&self) -> &GroupPlan {
        self.counts
            .iter()
            .max_by(|a, b| {
                (a.group_img_s, a.replicas)
                    .partial_cmp(&(b.group_img_s, b.replicas))
                    .expect("throughput is finite")
            })
            .expect("frontier is non-empty")
    }
}

/// The memoized fleet-wide plan frontier: one [`GroupFrontier`] per
/// feasible `(spec entry, model)` pair. This is what composition walks
/// and what the PR 5 rebalancer keeps attached at serve time.
#[derive(Debug, Clone)]
pub struct FleetFrontier {
    pub clock_mhz: f64,
    /// The model zoo the frontier spans; `GroupFrontier::model_id`
    /// indexes it.
    pub models: Vec<Arc<Model>>,
    pub groups: Vec<GroupFrontier>,
}

impl FleetFrontier {
    /// Build the single-model frontier (the PR 5 surface): candidates at
    /// `1..=max` (or exactly the forced count), stopping at the first
    /// infeasible count. A forced count that cannot plan is the caller's
    /// mistake (error); an unforced device that fits nothing just sits
    /// the fleet out — unless *no* device fits, which returns the first
    /// error.
    pub fn build(
        model: &Model,
        spec: &FleetSpec,
        clock_mhz: f64,
        policy: &Policy,
        max_replicas: usize,
    ) -> Result<FleetFrontier, PlanError> {
        FleetFrontier::build_zoo(vec![Arc::new(model.clone())], spec, clock_mhz, policy, max_replicas)
    }

    /// [`FleetFrontier::build`] over a model zoo: one [`GroupFrontier`]
    /// per feasible `(spec entry, model)` pair, entry-major. An entry
    /// infeasible for *some* models simply lacks those rows; an entry
    /// infeasible for *every* model is an error when forced, otherwise it
    /// sits the fleet out.
    pub fn build_zoo(
        models: Vec<Arc<Model>>,
        spec: &FleetSpec,
        clock_mhz: f64,
        policy: &Policy,
        max_replicas: usize,
    ) -> Result<FleetFrontier, PlanError> {
        assert!(!spec.entries.is_empty(), "a fleet spec needs at least one device");
        assert!(!models.is_empty(), "a fleet needs at least one model");
        let mut groups = Vec::new();
        let mut first_err: Option<PlanError> = None;
        for (si, entry) in spec.entries.iter().enumerate() {
            let mut entry_rows = Vec::new();
            let mut entry_err: Option<PlanError> = None;
            for (mi, model) in models.iter().enumerate() {
                let built: Result<Vec<GroupPlan>, PlanError> = match entry.count {
                    Some(r) => {
                        plan_group(model, mi, &entry.device, si, clock_mhz, policy, r).map(|g| vec![g])
                    }
                    None => {
                        let mut out = Vec::new();
                        let mut err: Option<PlanError> = None;
                        for r in 1..=max_replicas.max(1) {
                            match plan_group(model, mi, &entry.device, si, clock_mhz, policy, r) {
                                Ok(g) => out.push(g),
                                Err(e) => {
                                    err = Some(e);
                                    break;
                                }
                            }
                        }
                        if out.is_empty() {
                            Err(err.expect("loop ran at least once"))
                        } else {
                            Ok(out)
                        }
                    }
                };
                match built {
                    Ok(counts) => entry_rows.push(GroupFrontier {
                        device: entry.device.clone(),
                        spec_entry: si,
                        model_id: mi,
                        forced: entry.count,
                        counts,
                    }),
                    Err(e) => entry_err = entry_err.or(Some(e)),
                }
            }
            if entry_rows.is_empty() {
                match entry_err.expect("at least one model was tried") {
                    e if entry.count.is_some() => return Err(e),
                    e => first_err = first_err.or(Some(e)),
                }
            } else {
                groups.extend(entry_rows);
            }
        }
        if groups.is_empty() {
            return Err(first_err.expect("at least one entry failed"));
        }
        Ok(FleetFrontier { clock_mhz, models, groups })
    }

    /// Assemble a [`FleetPlan`] at explicit per-group counts (`counts[i]`
    /// replicas for `groups[i]`; 0 leaves the group out). This is the
    /// rebalancer's entry point for "what would the fleet look like at
    /// these counts" and the test harness's way to start a fleet below
    /// its argmax. At most one model may be live per spec entry — a
    /// physical board carries one bitstream.
    pub fn fleet_at(&self, counts: &[usize]) -> FleetPlan {
        assert_eq!(counts.len(), self.groups.len(), "one count per frontier group");
        let chosen: Vec<GroupPlan> = self
            .groups
            .iter()
            .zip(counts)
            .filter(|(_, &c)| c > 0)
            .map(|(g, &c)| g.at(c).clone())
            .collect();
        assert!(!chosen.is_empty(), "a fleet needs at least one replica");
        let mut seen = std::collections::BTreeSet::new();
        for g in &chosen {
            assert!(
                seen.insert(g.spec_entry),
                "spec entry {} selected for two models at once",
                g.spec_entry
            );
        }
        compose(self.clock_mhz, self.models.clone(), chosen, None)
    }
}

/// Finalize a fleet from chosen group plans.
fn compose(
    clock_mhz: f64,
    models: Vec<Arc<Model>>,
    groups: Vec<GroupPlan>,
    target_img_s: Option<f64>,
) -> FleetPlan {
    let fleet_img_s = groups.iter().map(|g| g.group_img_s).sum::<f64>();
    let static_w = groups.iter().map(|g| g.device.static_w).sum::<f64>();
    FleetPlan {
        clock_mhz,
        models,
        groups,
        fleet_img_s,
        static_w,
        target_img_s,
        meets_target: target_img_s.map(|t| fleet_img_s >= t).unwrap_or(true),
    }
}

/// The composition search over an already-built frontier. Separated from
/// the [`FleetSpec::plan`] builder so the rebalancer can re-run
/// composition against its memoized frontier without replanning.
///
/// Single-model frontiers keep the PR 4 search exactly: per-group argmax
/// candidates, then (under a target) the cheapest static-power mix —
/// forced entries always kept, optional devices added greedily by
/// throughput per static watt, then a drop pass sheds what the target
/// can spare.
///
/// Model-zoo frontiers first *assign* a model to every physical entry
/// (each board runs one bitstream): entries take the model they model
/// fastest, then a coverage repair reassigns the entry whose donation
/// costs the least fleet throughput to any model left uncovered. Under a
/// target the drop pass then sheds optional entries (most power-hungry
/// first) as long as the target holds and every model keeps its last
/// group.
pub fn compose_frontier(frontier: &FleetFrontier, target_img_s: Option<f64>) -> FleetPlan {
    assert!(!frontier.groups.is_empty(), "frontier has at least one group");
    let clock_mhz = frontier.clock_mhz;
    let n_models = frontier.models.len();

    if n_models <= 1 {
        let candidates: Vec<(GroupPlan, bool)> = frontier
            .groups
            .iter()
            .map(|g| (g.argmax().clone(), g.forced.is_some()))
            .collect();

        let chosen: Vec<GroupPlan> = match target_img_s {
            None => candidates.into_iter().map(|(g, _)| g).collect(),
            Some(target) => {
                let mut included: Vec<(GroupPlan, bool)> = Vec::new();
                let mut optional: Vec<GroupPlan> = Vec::new();
                for (g, forced) in candidates {
                    if forced {
                        included.push((g, true));
                    } else {
                        optional.push(g);
                    }
                }
                // Greedy add by throughput per static watt. A fleet is never
                // empty: with no forced entries at least one optional group
                // joins, whatever the target.
                optional.sort_by(|a, b| {
                    let ea = a.group_img_s / a.device.static_w.max(1e-12);
                    let eb = b.group_img_s / b.device.static_w.max(1e-12);
                    eb.partial_cmp(&ea).expect("efficiency is finite")
                });
                let sum = |v: &[(GroupPlan, bool)]| v.iter().map(|(g, _)| g.group_img_s).sum::<f64>();
                let mut optional = optional.into_iter();
                while included.is_empty() || sum(&included) < target {
                    match optional.next() {
                        Some(g) => included.push((g, false)),
                        None => break,
                    }
                }
                // Drop pass: shed the most power-hungry optional groups the
                // target can spare (greedy add can overshoot).
                let mut order: Vec<usize> = (0..included.len()).filter(|&i| !included[i].1).collect();
                order.sort_by(|&i, &j| {
                    included[j]
                        .0
                        .device
                        .static_w
                        .partial_cmp(&included[i].0.device.static_w)
                        .expect("power is finite")
                });
                let mut dropped = vec![false; included.len()];
                let mut live = sum(&included);
                let mut kept = included.len();
                for i in order {
                    // Never shed the last group: a degenerate (e.g. zero)
                    // target still gets a serving fleet.
                    if kept > 1 && live - included[i].0.group_img_s >= target {
                        live -= included[i].0.group_img_s;
                        dropped[i] = true;
                        kept -= 1;
                    }
                }
                included
                    .into_iter()
                    .zip(dropped)
                    .filter(|(_, d)| !d)
                    .map(|((g, _), _)| g)
                    .collect()
            }
        };
        assert!(!chosen.is_empty(), "composition keeps at least one group");
        return compose(clock_mhz, frontier.models.clone(), chosen, target_img_s);
    }

    // --- Model-zoo assignment ---------------------------------------
    // Per physical entry: the argmax candidate for each model it can run.
    let mut entries: BTreeMap<usize, (bool, BTreeMap<usize, GroupPlan>)> = BTreeMap::new();
    for g in &frontier.groups {
        let slot = entries.entry(g.spec_entry).or_insert_with(|| (g.forced.is_some(), BTreeMap::new()));
        slot.1.insert(g.model_id, g.argmax().clone());
    }

    // Each entry takes the model it models fastest (ties → lower model id).
    let mut assign: BTreeMap<usize, usize> = BTreeMap::new();
    for (&si, (_, cands)) in &entries {
        let best = cands
            .iter()
            .max_by(|(ai, a), (bi, b)| {
                (a.group_img_s, std::cmp::Reverse(*ai))
                    .partial_cmp(&(b.group_img_s, std::cmp::Reverse(*bi)))
                    .expect("throughput is finite")
            })
            .map(|(&mi, _)| mi)
            .expect("entry has at least one feasible model");
        assign.insert(si, best);
    }

    // Coverage repair: every model should hold at least one entry. Donate
    // the entry whose reassignment loses the least fleet throughput, from
    // a model that keeps another entry.
    for mi in 0..n_models {
        if assign.values().any(|&m| m == mi) {
            continue;
        }
        let mut best: Option<(f64, usize)> = None; // (throughput loss, entry)
        for (&si, &cur) in &assign {
            let (_, cands) = &entries[&si];
            let Some(cand) = cands.get(&mi) else { continue };
            if assign.values().filter(|&&m| m == cur).count() < 2 {
                continue; // donor model would go uncovered
            }
            let loss = cands[&cur].group_img_s - cand.group_img_s;
            if best.map(|(l, _)| loss < l).unwrap_or(true) {
                best = Some((loss, si));
            }
        }
        if let Some((_, si)) = best {
            assign.insert(si, mi);
        }
        // No donor: the model stays uncovered; FleetPlanner::run surfaces it.
    }

    let mut chosen: Vec<GroupPlan> = assign
        .iter()
        .map(|(si, mi)| entries[si].1[mi].clone())
        .collect();

    // Target drop pass: shed optional entries (most power-hungry first)
    // while the target holds and every model keeps its last group.
    if let Some(target) = target_img_s {
        let mut order: Vec<usize> = (0..chosen.len())
            .filter(|&i| !entries[&chosen[i].spec_entry].0)
            .collect();
        order.sort_by(|&i, &j| {
            chosen[j]
                .device
                .static_w
                .partial_cmp(&chosen[i].device.static_w)
                .expect("power is finite")
        });
        let mut dropped = vec![false; chosen.len()];
        let mut live: f64 = chosen.iter().map(|g| g.group_img_s).sum();
        for i in order {
            let mi = chosen[i].model_id;
            let peers = chosen
                .iter()
                .enumerate()
                .filter(|(j, g)| !dropped[*j] && g.model_id == mi)
                .count();
            if peers > 1 && live - chosen[i].group_img_s >= target {
                live -= chosen[i].group_img_s;
                dropped[i] = true;
            }
        }
        chosen = chosen
            .into_iter()
            .zip(dropped)
            .filter(|(_, d)| !d)
            .map(|(g, _)| g)
            .collect();
    }

    assert!(!chosen.is_empty(), "composition keeps at least one group");
    compose(clock_mhz, frontier.models.clone(), chosen, target_img_s)
}

/// A plan's engine signature: `(layer, kind, instances)` per engine
/// site. Two shard plans with equal signatures deploy identical
/// pipelines, so a group can be resized by adding/retiring replicas
/// *incrementally* instead of rolling the whole group onto new plans —
/// the common case for models far from the resource ceiling.
pub fn plan_signature(plan: &Plan) -> Vec<(usize, crate::ips::engine::EngineKind, u64)> {
    plan.engines.iter().map(|e| (e.layer, e.kind, e.instances)).collect()
}

/// Shim for the pre-zoo API.
#[deprecated(note = "use the FleetSpec::plan() builder: spec.plan().model(&m).run()")]
pub fn plan_fleet_spec(
    model: &Model,
    spec: &FleetSpec,
    clock_mhz: f64,
    policy: &Policy,
    target_img_s: Option<f64>,
    max_replicas: usize,
) -> Result<FleetPlan, PlanError> {
    spec.plan()
        .model(model)
        .clock_mhz(clock_mhz)
        .policy(policy)
        .target_img_s(target_img_s)
        .max_replicas(max_replicas)
        .run()
}

/// Shim for the pre-zoo API (CLI `--replicas` override).
#[deprecated(note = "use the FleetSpec::plan() builder on FleetSpec::single(dev, Some(replicas))")]
pub fn plan_fixed_fleet(
    model: &Model,
    dev: &Device,
    clock_mhz: f64,
    policy: &Policy,
    replicas: usize,
    target_img_s: Option<f64>,
) -> Result<FleetPlan, PlanError> {
    FleetSpec::single(dev.clone(), Some(replicas.max(1)))
        .plan()
        .model(model)
        .clock_mhz(clock_mhz)
        .policy(policy)
        .target_img_s(target_img_s)
        .max_replicas(replicas.max(1))
        .run()
}

/// Shim for the pre-zoo API (single-device replica search).
#[deprecated(note = "use the FleetSpec::plan() builder on FleetSpec::single(dev, None)")]
pub fn plan_fleet(
    model: &Model,
    dev: &Device,
    clock_mhz: f64,
    policy: &Policy,
    target_img_s: Option<f64>,
    max_replicas: usize,
) -> Result<FleetPlan, PlanError> {
    FleetSpec::single(dev.clone(), None)
        .plan()
        .model(model)
        .clock_mhz(clock_mhz)
        .policy(policy)
        .target_img_s(target_img_s)
        .max_replicas(max_replicas)
        .run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::device::by_name;

    fn adaptive() -> Policy {
        Policy::adaptive()
    }

    /// Builder shorthand: single-device replica search.
    fn search_one(m: &Model, dev: &Device, max: usize) -> Result<FleetPlan, PlanError> {
        FleetSpec::single(dev.clone(), None).plan().model(m).max_replicas(max).run()
    }

    /// Builder shorthand: single-device fixed count.
    fn fixed_one(m: &Model, dev: &Device, replicas: usize) -> Result<FleetPlan, PlanError> {
        FleetSpec::single(dev.clone(), Some(replicas)).plan().model(m).max_replicas(replicas).run()
    }

    #[test]
    fn lenet_tiny_on_zcu104_replicates() {
        let m = Model::lenet_tiny();
        let dev = by_name("zcu104").unwrap();
        let fp = search_one(&m, &dev, DEFAULT_MAX_REPLICAS).unwrap();
        assert_eq!(fp.groups.len(), 1);
        let g = &fp.groups[0];
        // The acceptance bar: the default device carries at least two
        // replicas, and the fleet out-models a single whole-device plan.
        assert!(g.replicas >= 2, "only {} replica(s)", g.replicas);
        assert!(g.total.fits(&dev), "group must fit the undivided device");
        let single = crate::planner::plan(&m, &dev, 200.0, &adaptive()).unwrap();
        assert!(
            fp.fleet_img_s >= single.images_per_sec,
            "fleet {} < single {}",
            fp.fleet_img_s,
            single.images_per_sec
        );
        assert!(fp.meets_target);
        let (d, l) = g.pressure();
        assert!(d <= 1.0 && l <= 1.0);
        // Coefficient storage is charged per replica in the group total.
        assert!(g.coef_bram18 > 0);
        assert!(g.total.bram18 >= g.coef_bram18 * g.replicas as u64);
    }

    #[test]
    fn single_device_search_maximizes_fleet_throughput() {
        // Without an SLO the pick must dominate every feasible fixed
        // count — the search is argmax, not largest-feasible.
        let m = Model::lenet_tiny();
        for dev_name in ["zcu104", "zu2cg", "edge-nodsp"] {
            let dev = by_name(dev_name).unwrap();
            let Ok(best) = search_one(&m, &dev, 6) else {
                continue;
            };
            for r in 1..=6usize {
                if let Ok(fp) = fixed_one(&m, &dev, r) {
                    assert!(
                        best.fleet_img_s >= fp.fleet_img_s - 1e-6,
                        "{dev_name}: picked {} img/s @ r={}, but r={r} models {} img/s",
                        best.fleet_img_s,
                        best.replicas(),
                        fp.fleet_img_s
                    );
                }
            }
        }
    }

    #[test]
    fn heterogeneous_fleet_is_the_sum_of_its_groups() {
        let m = Model::lenet_tiny();
        let spec = FleetSpec {
            entries: vec![
                FleetEntry { device: by_name("zcu104").unwrap(), count: None },
                FleetEntry { device: by_name("zu5ev").unwrap(), count: None },
            ],
        };
        let fp = spec.plan().model(&m).max_replicas(4).run().unwrap();
        assert_eq!(fp.groups.len(), 2);
        assert_eq!(fp.group_labels(), vec!["zcu104".to_string(), "zu5ev".to_string()]);
        let sum: f64 = fp.groups.iter().map(|g| g.group_img_s).sum();
        assert!((fp.fleet_img_s - sum).abs() < 1e-6);
        let zcu = search_one(&m, &by_name("zcu104").unwrap(), 4).unwrap();
        let zu5 = search_one(&m, &by_name("zu5ev").unwrap(), 4).unwrap();
        // Composition is per-device argmax, so the mix models exactly the
        // two single-device fleets added together — and beats both.
        assert!((fp.fleet_img_s - (zcu.fleet_img_s + zu5.fleet_img_s)).abs() < 1e-6);
        assert!(fp.fleet_img_s > zcu.fleet_img_s.max(zu5.fleet_img_s));
        // Group-major replica bookkeeping is consistent.
        assert_eq!(fp.replicas(), fp.groups[0].replicas + fp.groups[1].replicas);
        let rg = fp.replica_groups();
        assert_eq!(rg.len(), fp.replicas());
        assert_eq!(rg.iter().filter(|&&g| g == 0).count(), fp.groups[0].replicas);
        // Static power is one full part each.
        assert!((fp.static_w - (0.593 + 0.45)).abs() < 1e-9);
    }

    #[test]
    fn forced_counts_are_pinned_and_validated() {
        let m = Model::lenet_tiny();
        let spec = FleetSpec {
            entries: vec![
                FleetEntry { device: by_name("zcu104").unwrap(), count: Some(2) },
                FleetEntry { device: by_name("zu5ev").unwrap(), count: Some(1) },
            ],
        };
        let fp = spec.plan().model(&m).max_replicas(8).run().unwrap();
        assert_eq!(fp.groups[0].replicas, 2);
        assert_eq!(fp.groups[1].replicas, 1);
        // A forced count the device cannot hold is an error, not a skip.
        let spec = FleetSpec::single(by_name("edge-nodsp").unwrap(), Some(64));
        assert!(spec.plan().model(&m).max_replicas(8).run().is_err());
    }

    #[test]
    fn target_picks_cheapest_static_power_mix() {
        let m = Model::lenet_tiny();
        let zcu = by_name("zcu104").unwrap();
        let zu5 = by_name("zu5ev").unwrap();
        let spec = FleetSpec {
            entries: vec![
                FleetEntry { device: zcu.clone(), count: None },
                FleetEntry { device: zu5.clone(), count: None },
            ],
        };
        let free = spec.plan().model(&m).max_replicas(4).run().unwrap();
        // A target one device alone can meet: the composition must shed
        // the other part's static power.
        let one_dev_target = free.groups.iter().map(|g| g.group_img_s).fold(f64::MAX, f64::min) * 0.5;
        let fp = spec.plan().model(&m).max_replicas(4).target_img_s(Some(one_dev_target)).run().unwrap();
        assert!(fp.meets_target);
        assert_eq!(fp.groups.len(), 1, "one part suffices for the target");
        assert!(fp.static_w < free.static_w);
        // An unmeetable target keeps the whole mix, flagged.
        let fp = spec.plan().model(&m).max_replicas(4).target_img_s(Some(1e15)).run().unwrap();
        assert!(!fp.meets_target);
        assert_eq!(fp.groups.len(), 2);
        // A forced entry is never shed, even when the other part covers
        // the target more efficiently.
        let spec = FleetSpec {
            entries: vec![
                FleetEntry { device: zcu.clone(), count: None },
                FleetEntry { device: zu5.clone(), count: Some(1) },
            ],
        };
        let fp = spec.plan().model(&m).max_replicas(4).target_img_s(Some(one_dev_target)).run().unwrap();
        assert!(fp.groups.iter().any(|g| g.device.name == "zu5ev"));
    }

    #[test]
    fn coefficient_bram_caps_replica_counts() {
        let m = Model::lenet_tiny();
        let coef = crate::planner::coefficient_bram18(&m);
        // A part with abundant logic but BRAM for exactly one coefficient
        // copy: the old floor-divide would have packed more replicas.
        let mut dev = by_name("zcu104").unwrap();
        dev.name = "bram-starved".into();
        dev.bram18 = coef + 1;
        let fp = search_one(&m, &dev, 4).unwrap();
        assert_eq!(fp.replicas(), 1, "BRAM reserve must cap the fleet at one replica");
        assert!(fixed_one(&m, &dev, 2).is_err());
        // With BRAM for two copies the cap moves to two.
        dev.bram18 = 2 * coef;
        let fp = search_one(&m, &dev, 4).unwrap();
        assert_eq!(fp.replicas(), 2);
        assert!(fp.groups[0].total.bram18 <= dev.bram18);
    }

    #[test]
    fn spec_parsing_names_counts_and_catalogs() {
        let spec = FleetSpec::parse("zcu104,zu5ev:2", &[]).unwrap();
        assert_eq!(spec.entries.len(), 2);
        assert_eq!(spec.entries[0].device.name, "zcu104");
        assert_eq!(spec.entries[0].count, None);
        assert_eq!(spec.entries[1].device.name, "zu5ev");
        assert_eq!(spec.entries[1].count, Some(2));
        // Extra catalog devices shadow nothing but are reachable by name,
        // case-insensitively.
        let mut custom = by_name("zu2cg").unwrap();
        custom.name = "myboard".into();
        let spec = FleetSpec::parse("MyBoard:1,zcu104", &[custom]).unwrap();
        assert_eq!(spec.entries[0].device.name, "myboard");
        assert_eq!(spec.entries[0].count, Some(1));
        // Errors: unknown device, bad count, zero count, empty list.
        assert!(FleetSpec::parse("nosuchpart", &[]).is_err());
        assert!(FleetSpec::parse("zcu104:x", &[]).is_err());
        assert!(FleetSpec::parse("zcu104:0", &[]).is_err());
        assert!(FleetSpec::parse("", &[]).is_err());
        assert!(FleetSpec::parse(" , ", &[]).is_err());
    }

    #[test]
    fn frontier_memoizes_counts_and_composes_at_any_point() {
        let m = Model::lenet_tiny();
        let spec = FleetSpec {
            entries: vec![
                FleetEntry { device: by_name("zcu104").unwrap(), count: None },
                FleetEntry { device: by_name("zu5ev").unwrap(), count: None },
            ],
        };
        let fr = spec.plan().model(&m).max_replicas(4).frontier().unwrap();
        assert_eq!(fr.groups.len(), 2);
        assert_eq!(fr.groups[0].spec_entry, 0);
        assert_eq!(fr.groups[0].model_id, 0);
        assert!(fr.groups[0].max_count() >= 2, "zcu104 carries at least two replicas");
        // at() returns exactly the plan the full search would make.
        for c in 1..=fr.groups[0].max_count() {
            let g = fr.groups[0].at(c);
            assert_eq!(g.replicas, c);
            let zcu = by_name("zcu104").unwrap();
            let direct = fixed_one(&m, &zcu, c).unwrap();
            assert!((g.group_img_s - direct.groups[0].group_img_s).abs() < 1e-6);
        }
        // Composition over the frontier == the one-shot search.
        let via_frontier = compose_frontier(&fr, None);
        let direct = spec.plan().model(&m).max_replicas(4).run().unwrap();
        assert!((via_frontier.fleet_img_s - direct.fleet_img_s).abs() < 1e-6);
        assert_eq!(via_frontier.replicas(), direct.replicas());
        // fleet_at pins explicit counts — including starting BELOW the
        // argmax (the rebalancer's low-water starting point) and leaving
        // a group out entirely.
        let low = fr.fleet_at(&[1, 1]);
        assert_eq!(low.replicas(), 2);
        assert_eq!(low.groups.len(), 2);
        assert!(low.fleet_img_s <= via_frontier.fleet_img_s + 1e-9);
        let solo = fr.fleet_at(&[1, 0]);
        assert_eq!(solo.groups.len(), 1);
        assert_eq!(solo.groups[0].device.name, "zcu104");
    }

    #[test]
    fn plan_signature_detects_identical_and_different_shard_plans() {
        let m = Model::lenet_tiny();
        let fr = FleetSpec::single(by_name("zcu104").unwrap(), None)
            .plan()
            .model(&m)
            .max_replicas(3)
            .frontier()
            .unwrap();
        let g = &fr.groups[0];
        // A plan's signature equals itself and is stable across clones.
        let s1 = plan_signature(&g.at(1).per_replica);
        assert_eq!(s1, plan_signature(&g.at(1).per_replica.clone()));
        // Different devices produce different signatures (the edge part
        // substitutes IPs — the paper's adaptive story).
        let edge = FleetSpec::single(by_name("edge-nodsp").unwrap(), None)
            .plan()
            .model(&m)
            .max_replicas(1)
            .frontier()
            .unwrap();
        assert_ne!(s1, plan_signature(&edge.groups[0].at(1).per_replica));
    }

    #[test]
    fn deploy_shares_weights_across_groups() {
        let m = Model::lenet_tiny();
        let spec = FleetSpec {
            entries: vec![
                FleetEntry { device: by_name("zcu104").unwrap(), count: Some(1) },
                FleetEntry { device: by_name("zu5ev").unwrap(), count: Some(1) },
            ],
        };
        let fp = spec.plan().model(&m).max_replicas(2).run().unwrap();
        let fleet = fp.deploy(m, Weights::random(&Model::lenet_tiny(), 42));
        let reps = &fleet.replicas;
        assert_eq!(reps.len(), 2);
        assert!(Arc::ptr_eq(&reps[0].weights, &reps[1].weights));
        assert!(Arc::ptr_eq(&reps[0].model, &reps[1].model));
        // Replicas of different groups carry their own group's plan...
        assert_eq!(reps[0].plan.device.name, "zcu104");
        assert_eq!(reps[1].plan.device.name, "zu5ev");
        // ...and both pipelines are live and bit-identical.
        let img = vec![0i64; 256];
        assert_eq!(reps[0].infer_one(&img).unwrap(), reps[1].infer_one(&img).unwrap());
        // The handle mirrors the plan's topology.
        assert_eq!(fleet.groups, vec![0, 1]);
        assert_eq!(fleet.labels, vec!["zcu104".to_string(), "zu5ev".to_string()]);
        assert_eq!(fleet.n_groups(), 2);
    }

    #[test]
    fn zoo_frontier_assigns_each_board_one_model_and_covers_all() {
        let tiny = Arc::new(Model::lenet_tiny());
        let wide = Arc::new(Model::lenet_wide(2));
        let spec = FleetSpec {
            entries: vec![
                FleetEntry { device: by_name("zcu104").unwrap(), count: None },
                FleetEntry { device: by_name("zu5ev").unwrap(), count: None },
            ],
        };
        let planner = spec
            .plan()
            .models(vec![Arc::clone(&tiny), Arc::clone(&wide)])
            .max_replicas(4);
        let fr = planner.frontier().unwrap();
        // Frontier keys extend with the model id: up to one row per
        // (entry, model) pair, and both models appear.
        assert!(fr.groups.iter().any(|g| g.model_id == 0));
        assert!(fr.groups.iter().any(|g| g.model_id == 1));
        for g in &fr.groups {
            assert!(g.spec_entry < 2 && g.model_id < 2);
        }
        let fp = planner.run().unwrap();
        // One bitstream per board, every model covered.
        assert_eq!(fp.groups.len(), 2);
        assert_ne!(fp.groups[0].model_id, fp.groups[1].model_id);
        let entries: Vec<usize> = fp.groups.iter().map(|g| g.spec_entry).collect();
        assert_eq!(entries, vec![0, 1]);
        // Labels qualify with the model so two boards of one part stay
        // distinguishable.
        for (label, g) in fp.group_labels().iter().zip(&fp.groups) {
            assert!(label.contains(&g.device.name));
            assert!(label.contains(&fp.models[g.model_id].name));
        }
        // Per-model throughput partitions the fleet total.
        assert!((fp.model_img_s(0) + fp.model_img_s(1) - fp.fleet_img_s).abs() < 1e-9);
        assert!(fp.model_img_s(0) > 0.0 && fp.model_img_s(1) > 0.0);
    }

    #[test]
    fn zoo_coverage_beats_pure_argmax_when_one_model_dominates() {
        // lenet-tiny models faster than lenet-wide on every part, so the
        // throughput argmax alone would give both boards to tiny; the
        // coverage repair must still hand one board to wide.
        let tiny = Arc::new(Model::lenet_tiny());
        let wide = Arc::new(Model::lenet_wide(2));
        let spec = FleetSpec {
            entries: vec![
                FleetEntry { device: by_name("zcu104").unwrap(), count: None },
                FleetEntry { device: by_name("zcu104").unwrap(), count: None },
            ],
        };
        let fp = spec
            .plan()
            .models(vec![tiny, wide])
            .max_replicas(4)
            .run()
            .unwrap();
        let tiny_groups = fp.groups.iter().filter(|g| g.model_id == 0).count();
        let wide_groups = fp.groups.iter().filter(|g| g.model_id == 1).count();
        assert_eq!((tiny_groups, wide_groups), (1, 1));
        // With one board and two models, coverage is impossible: the
        // builder surfaces it instead of silently serving one model.
        let solo = FleetSpec::single(by_name("zcu104").unwrap(), None);
        let err = solo
            .plan()
            .models(vec![Arc::new(Model::lenet_tiny()), Arc::new(Model::lenet_wide(2))])
            .run()
            .unwrap_err();
        assert!(err.to_string().contains("lenet"), "names the uncovered model: {err}");
    }

    #[test]
    fn zoo_deploy_builds_each_group_from_its_model() {
        let tiny = Arc::new(Model::lenet_tiny());
        let wide = Arc::new(Model::lenet_wide(2));
        let spec = FleetSpec {
            entries: vec![
                FleetEntry { device: by_name("zcu104").unwrap(), count: Some(1) },
                FleetEntry { device: by_name("zu5ev").unwrap(), count: Some(1) },
            ],
        };
        let fp = spec
            .plan()
            .models(vec![Arc::clone(&tiny), Arc::clone(&wide)])
            .max_replicas(2)
            .run()
            .unwrap();
        let weights: Vec<Arc<Weights>> = fp
            .models
            .iter()
            .map(|m| Arc::new(Weights::random(m, 42)))
            .collect();
        let fleet = fp.deploy_zoo(&weights);
        assert_eq!(fleet.replicas.len(), 2);
        for (ri, &gi) in fleet.groups.iter().enumerate() {
            let expect = &fp.models[fp.groups[gi].model_id];
            assert!(Arc::ptr_eq(&fleet.replicas[ri].model, &fleet.models[gi]));
            assert_eq!(fleet.replicas[ri].model.name, expect.name);
        }
        // A multi-model plan refuses the single-model deploy surface.
        let result = std::panic::catch_unwind(|| {
            fp.deploy_shared(Arc::clone(&tiny), Arc::new(Weights::random(&tiny, 1)))
        });
        assert!(result.is_err(), "deploy_shared must reject zoo plans");
    }

    #[test]
    fn solo_handle_is_the_one_group_special_case() {
        let m = Model::lenet_tiny();
        let dev = by_name("zcu104").unwrap();
        let fp = fixed_one(&m, &dev, 2).unwrap();
        let reps = fp.deploy(m, Weights::random(&Model::lenet_tiny(), 7)).replicas;
        let handle = FleetHandle::solo(reps);
        assert_eq!(handle.n_groups(), 1);
        assert_eq!(handle.labels, vec!["fleet".to_string()]);
        assert_eq!(handle.groups, vec![0, 0]);
        assert!(Arc::ptr_eq(&handle.models[0], &handle.replicas[0].model));
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_still_plan() {
        let m = Model::lenet_tiny();
        let dev = by_name("zcu104").unwrap();
        let via_shim = plan_fleet(&m, &dev, 200.0, &adaptive(), None, 4).unwrap();
        let via_builder = search_one(&m, &dev, 4).unwrap();
        assert!((via_shim.fleet_img_s - via_builder.fleet_img_s).abs() < 1e-9);
        let fixed = plan_fixed_fleet(&m, &dev, 200.0, &adaptive(), 2, None).unwrap();
        assert_eq!(fixed.replicas(), 2);
        let spec = FleetSpec::single(dev, None);
        let via_spec = plan_fleet_spec(&m, &spec, 200.0, &adaptive(), None, 4).unwrap();
        assert!((via_spec.fleet_img_s - via_builder.fleet_img_s).abs() < 1e-9);
    }
}
