//! Fleet planning: turn one device budget into a multi-replica serving
//! fleet by running the resource-driven planner under divided budgets.
//!
//! This is the paper's scarcity logic lifted one level up: instead of
//! asking "which engine fits this layer under the device budget?", the
//! fleet planner asks "how many whole copies of the planned network fit
//! this device, and which copy count maximizes fleet throughput (or is
//! the largest one still meeting a target SLO)?". Each candidate count
//! `r` plans one replica against an equal `1/r` device shard
//! ([`crate::fabric::device::Device::shard`]); `r` such replicas are
//! guaranteed to fit the whole part, and modeled fleet throughput is the
//! replica-sum `r × images_per_sec`.

use crate::cnn::model::{Model, Weights};
use crate::coordinator::Deployment;
use crate::fabric::device::Device;
use crate::planner::{plan_under_fraction, Plan, PlanError, Policy};
use crate::synth::Utilization;
use std::sync::Arc;

/// Default ceiling on the replica search (CLI `--max-replicas` raises it).
pub const DEFAULT_MAX_REPLICAS: usize = 8;

/// A planned serving fleet: `replicas` identical copies of `per_replica`,
/// each owning an equal shard of `device`.
#[derive(Debug, Clone)]
pub struct FleetPlan {
    pub device: Device,
    pub clock_mhz: f64,
    pub replicas: usize,
    /// The plan every replica deploys (made against `device.shard(replicas)`).
    pub per_replica: Plan,
    /// Whole-fleet utilization (`replicas ×` the per-replica total).
    pub total: Utilization,
    /// Modeled replica-sum throughput: `replicas × per_replica.images_per_sec`.
    pub fleet_img_s: f64,
    /// The SLO the search was asked to meet, if any.
    pub target_img_s: Option<f64>,
    /// Whether `fleet_img_s` meets `target_img_s` (true when no target).
    pub meets_target: bool,
}

impl FleetPlan {
    /// Fleet pressure on the undivided device: (DSP fraction, LUT fraction).
    pub fn pressure(&self) -> (f64, f64) {
        (self.device.dsp_util(self.total.dsps), self.device.lut_util(self.total.luts))
    }

    /// Deploy the fleet: `replicas` persistent pipelines sharing one
    /// model and one weight set.
    pub fn deploy(&self, model: Model, weights: Weights) -> Vec<Arc<Deployment>> {
        let model = Arc::new(model);
        let weights = Arc::new(weights);
        (0..self.replicas)
            .map(|_| {
                Arc::new(Deployment::with_plan(
                    Arc::clone(&model),
                    Arc::clone(&weights),
                    self.per_replica.clone(),
                ))
            })
            .collect()
    }
}

/// Plan a fleet of exactly `replicas` copies (the CLI's `--replicas`
/// override). Errors if one replica cannot be planned under `1/replicas`
/// of the device.
pub fn plan_fixed_fleet(
    model: &Model,
    dev: &Device,
    clock_mhz: f64,
    policy: &Policy,
    replicas: usize,
    target_img_s: Option<f64>,
) -> Result<FleetPlan, PlanError> {
    let r = replicas.max(1);
    let per_replica = plan_under_fraction(model, dev, clock_mhz, policy, r as u64)?;
    let fleet_img_s = r as f64 * per_replica.images_per_sec;
    Ok(FleetPlan {
        device: dev.clone(),
        clock_mhz,
        replicas: r,
        total: per_replica.total.times(r as u64),
        fleet_img_s,
        target_img_s,
        meets_target: target_img_s.map(|t| fleet_img_s >= t).unwrap_or(true),
        per_replica,
    })
}

/// Search replica counts `1..=max_replicas` for the best fleet.
///
/// With a `target_img_s` SLO: the *largest* replica count whose modeled
/// replica-sum throughput still meets the target (more replicas = more
/// concurrent request capacity at the same SLO); if no count meets it,
/// the highest-throughput fleet is returned with `meets_target == false`
/// so the caller can degrade explicitly instead of silently. Without a
/// target: the count maximizing modeled fleet throughput (ties go to more
/// replicas). The scan stops at the first infeasible count — shards only
/// shrink as `r` grows, so feasibility is monotone.
pub fn plan_fleet(
    model: &Model,
    dev: &Device,
    clock_mhz: f64,
    policy: &Policy,
    target_img_s: Option<f64>,
    max_replicas: usize,
) -> Result<FleetPlan, PlanError> {
    let mut candidates: Vec<FleetPlan> = Vec::new();
    let mut first_err: Option<PlanError> = None;
    for r in 1..=max_replicas.max(1) {
        match plan_fixed_fleet(model, dev, clock_mhz, policy, r, target_img_s) {
            Ok(fp) => candidates.push(fp),
            Err(e) => {
                first_err = Some(e);
                break;
            }
        }
    }
    if candidates.is_empty() {
        return Err(first_err.expect("loop ran at least once"));
    }
    let fastest = candidates
        .iter()
        .max_by(|a, b| {
            (a.fleet_img_s, a.replicas)
                .partial_cmp(&(b.fleet_img_s, b.replicas))
                .expect("throughput is finite")
        })
        .expect("non-empty");
    let pick = match target_img_s {
        // SLO: the largest count still meeting it; none meets ⇒ the
        // fastest fleet, flagged `meets_target == false`.
        Some(_) => candidates.iter().rev().find(|fp| fp.meets_target).unwrap_or(fastest),
        // No SLO: maximize modeled fleet throughput (ties → more
        // replicas, i.e. more concurrent request capacity).
        None => fastest,
    };
    Ok(pick.clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::device::by_name;

    #[test]
    fn lenet_tiny_on_zcu104_replicates() {
        let m = Model::lenet_tiny();
        let dev = by_name("zcu104").unwrap();
        let fp =
            plan_fleet(&m, &dev, 200.0, &Policy::adaptive(), None, DEFAULT_MAX_REPLICAS).unwrap();
        // The acceptance bar: the default device carries at least two
        // replicas, and the fleet out-models a single whole-device plan.
        assert!(fp.replicas >= 2, "only {} replica(s)", fp.replicas);
        assert!(fp.total.fits(&dev), "fleet must fit the undivided device");
        let single = crate::planner::plan(&m, &dev, 200.0, &Policy::adaptive()).unwrap();
        assert!(
            fp.fleet_img_s >= single.images_per_sec,
            "fleet {} < single {}",
            fp.fleet_img_s,
            single.images_per_sec
        );
        assert!(fp.meets_target);
        let (d, l) = fp.pressure();
        assert!(d <= 1.0 && l <= 1.0);
    }

    #[test]
    fn slo_picks_largest_meeting_count() {
        let m = Model::lenet_tiny();
        let dev = by_name("zcu104").unwrap();
        let free = plan_fleet(&m, &dev, 200.0, &Policy::adaptive(), None, 4).unwrap();
        // An SLO below one replica's throughput is met by every count, so
        // the search must take the largest feasible one.
        let modest = free.per_replica.images_per_sec * 0.5;
        let fp = plan_fleet(&m, &dev, 200.0, &Policy::adaptive(), Some(modest), 4).unwrap();
        assert!(fp.meets_target);
        assert_eq!(fp.replicas, free.replicas.max(fp.replicas));
        // An absurd SLO is unmeetable: best effort, flagged.
        let fp = plan_fleet(&m, &dev, 200.0, &Policy::adaptive(), Some(1e15), 4).unwrap();
        assert!(!fp.meets_target);
        assert!(fp.fleet_img_s > 0.0);
    }

    #[test]
    fn no_slo_search_maximizes_fleet_throughput() {
        // Without an SLO the pick must dominate every feasible fixed
        // count — the search is argmax, not largest-feasible.
        let m = Model::lenet_tiny();
        for dev_name in ["zcu104", "zu2cg", "edge-nodsp"] {
            let dev = by_name(dev_name).unwrap();
            let Ok(best) = plan_fleet(&m, &dev, 200.0, &Policy::adaptive(), None, 6) else {
                continue;
            };
            for r in 1..=6usize {
                if let Ok(fp) = plan_fixed_fleet(&m, &dev, 200.0, &Policy::adaptive(), r, None) {
                    assert!(
                        best.fleet_img_s >= fp.fleet_img_s - 1e-6,
                        "{dev_name}: picked {} img/s @ r={}, but r={r} models {} img/s",
                        best.fleet_img_s,
                        best.replicas,
                        fp.fleet_img_s
                    );
                }
            }
        }
    }

    #[test]
    fn tiny_device_caps_replicas() {
        let m = Model::lenet_tiny();
        let dev = by_name("edge-nodsp").unwrap();
        // The starved part may fit 1..n replicas, but never an infeasible
        // shard; and the chosen fleet always fits the undivided device.
        if let Ok(fp) = plan_fleet(&m, &dev, 200.0, &Policy::adaptive(), None, 16) {
            assert!(fp.replicas >= 1);
            assert!(fp.total.fits(&dev));
        }
    }

    #[test]
    fn deploy_shares_weights_across_replicas() {
        let m = Model::lenet_tiny();
        let dev = by_name("zcu104").unwrap();
        let fp = plan_fixed_fleet(&m, &dev, 200.0, &Policy::adaptive(), 2, None).unwrap();
        let reps = fp.deploy(m, Weights::random(&Model::lenet_tiny(), 42));
        assert_eq!(reps.len(), 2);
        assert!(Arc::ptr_eq(&reps[0].weights, &reps[1].weights));
        assert!(Arc::ptr_eq(&reps[0].model, &reps[1].model));
        // Both pipelines are live and bit-identical.
        let img = vec![0i64; 256];
        assert_eq!(reps[0].infer_one(&img).unwrap(), reps[1].infer_one(&img).unwrap());
    }
}
