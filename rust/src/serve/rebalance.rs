//! Dynamic fleet rebalancing: a control loop that watches the live
//! [`super::metrics::FleetMetrics`] over a sliding window and grows or shrinks device
//! groups *without draining the server* — the run-time half of the
//! paper's adaptivity claim. PR 4 froze replica counts at plan time; a
//! traffic spike or lull wasted exactly the moldability the adaptive
//! IPs exist for. The rebalancer closes that gap at serve time.
//!
//! **Signals** (per control tick, one tick per `window`):
//!
//! * queue pressure — admitted-not-dispatched depth against the bounded
//!   queue's capacity, plus the rejection delta (load already shed);
//! * per-group utilization — busy-seconds delta over
//!   `tick × live replicas`;
//! * p99 drift — a group's in-window p99 blowing past 4× its own
//!   in-window median while work is queued (the early-warning signal
//!   before the queue actually fills).
//!
//! All signals come from atomic counters and the bounded sliding-window
//! pass — the controller never takes a full metrics snapshot, whose
//! all-time latency reservoirs grow with uptime.
//!
//! **Actions.** Scaling decisions index the memoized
//! [`FleetFrontier`] — the per-device count → plan frontiers built at
//! plan time — so *no planner run ever happens under traffic*; the
//! composition search is re-run incrementally by moving one group one
//! count step at a time under its still-attached device budget. If the
//! frontier's plan at the new count has the same engine signature as
//! the current one (the common case away from the resource ceiling),
//! replicas are simply added to or retired from the group. If the
//! signature differs (the shard shrank enough that the planner would
//! substitute IPs — the paper's Table III adaptations, now happening
//! live), the group *swaps* one-in-one-out: each new pipeline spins up
//! on the new frontier plan before one old replica retires (after its
//! in-flight micro-batches drain), so the group never goes dark and the
//! transient overcommit on the physical part is bounded to one extra
//! replica. Either way no admitted request is dropped and the scheduler
//! keeps dispatching throughout.
//!
//! **Stability.** Two mechanisms keep the loop from thrashing:
//! hysteresis (the scale-down watermark sits far below the scale-up
//! watermark, and shrinking additionally requires an empty queue and a
//! shed-free window) and a cooldown (after any action the controller
//! only observes for `cooldown`, letting the fleet settle before the
//! next decision). Forced (`name:count`) groups are never resized —
//! a pinned count is an operator statement, not a hint.
//!
//! **Observability.** Every action lands in the [`RebalanceEvent`]
//! timeline via [`super::metrics::FleetMetrics::note_rebalance`], which —
//! when the fleet was started with a live [`crate::trace::Tracer`]
//! (`acf serve --trace`) — also mirrors it as a `rebalance_grow` /
//! `rebalance_shrink` / `rebalance_swap` instant on the group's control
//! track, stamped by the same clock as the request span chains. A scale
//! action in the exported timeline therefore sits exactly where the
//! latency it caused (or cured) is visible; the add/retire/drain
//! lifecycle of each replica the action touched shows up as
//! `replica_add` / `replica_retire` / `replica_drained` instants on the
//! same track.

use super::fleet::{plan_signature, FleetFrontier, FleetPlan, GroupFrontier};
use super::metrics::{RebalanceAction, RebalanceEvent};
use super::scheduler::Server;
use crate::cnn::model::{Model, Weights};
use crate::coordinator::Deployment;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Control-loop knobs (`acf serve --rebalance --window-ms --headroom`).
#[derive(Debug, Clone)]
pub struct RebalanceConfig {
    /// Control period and signal window.
    pub window: Duration,
    /// Capacity headroom the fleet tries to keep: the scale-up watermark
    /// is `1 - headroom` group utilization.
    pub headroom: f64,
    /// Minimum quiet time after an action before the next one.
    pub cooldown: Duration,
    /// Per-group replica floor (unforced groups never shrink below it).
    pub min_replicas: usize,
}

impl Default for RebalanceConfig {
    fn default() -> RebalanceConfig {
        RebalanceConfig {
            window: Duration::from_millis(250),
            headroom: 0.25,
            cooldown: Duration::from_millis(500),
            min_replicas: 1,
        }
    }
}

impl RebalanceConfig {
    /// Scale-up utilization watermark.
    fn high_water(&self) -> f64 {
        (1.0 - self.headroom.clamp(0.0, 0.95)).max(0.05)
    }

    /// Scale-down utilization watermark — deliberately far below the
    /// scale-up mark (hysteresis).
    fn low_water(&self) -> f64 {
        self.high_water() * 0.35
    }
}

/// One managed device group: its frontier and the live count the
/// controller believes it has.
struct Managed {
    /// Server-side group index (metrics / dispatch).
    group: usize,
    frontier: GroupFrontier,
    count: usize,
}

/// The live rebalance controller. Owns a background thread; call
/// [`Rebalancer::stop`] before shutting the server down.
pub struct Rebalancer {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Rebalancer {
    /// Start rebalancing `server` (already serving `plan`) against the
    /// memoized `frontier`. `model`/`weights` are the fleet's shared
    /// network — new replicas deploy from them with frontier plans.
    /// Groups whose spec entry pinned a count are left alone.
    pub fn start(
        server: Arc<Server>,
        frontier: FleetFrontier,
        plan: &FleetPlan,
        model: Arc<Model>,
        weights: Arc<Weights>,
        cfg: RebalanceConfig,
    ) -> Rebalancer {
        // Map each server group back to its frontier entry. Groups the
        // composition search shed (under a target) are simply absent —
        // their budgets stay attached in `frontier` but they were never
        // deployed, so there is nothing to resize.
        let managed: Vec<Managed> = plan
            .groups
            .iter()
            .enumerate()
            .filter_map(|(gi, g)| {
                let f = frontier
                    .groups
                    .iter()
                    .find(|f| f.spec_entry == g.spec_entry)?
                    .clone();
                if f.forced.is_some() {
                    return None; // pinned counts are operator statements
                }
                Some(Managed { group: gi, frontier: f, count: g.replicas })
            })
            .collect();
        let stop = Arc::new(AtomicBool::new(false));
        let thread_stop = Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            control_loop(&server, managed, &model, &weights, &cfg, &thread_stop);
        });
        Rebalancer { stop, handle: Some(handle) }
    }

    /// Stop the control loop and join its thread. Always call this
    /// before `Server::shutdown` so no resize races the teardown.
    pub fn stop(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Rebalancer {
    fn drop(&mut self) {
        self.halt();
    }
}

/// Sleep `total` in small slices so a stop request is honored promptly.
fn interruptible_sleep(total: Duration, stop: &AtomicBool) {
    let slice = Duration::from_millis(20);
    let deadline = Instant::now() + total;
    while Instant::now() < deadline && !stop.load(Ordering::Relaxed) {
        std::thread::sleep(slice.min(deadline.saturating_duration_since(Instant::now())));
    }
}

fn control_loop(
    server: &Server,
    mut managed: Vec<Managed>,
    model: &Arc<Model>,
    weights: &Arc<Weights>,
    cfg: &RebalanceConfig,
    stop: &AtomicBool,
) {
    if managed.is_empty() {
        return; // every group pinned — nothing to control
    }
    // Floor the tick so a degenerate `--window-ms 0` cannot turn the
    // loop into a busy-spin contending every latency mutex.
    let tick = cfg.window.max(Duration::from_millis(10));
    // Signals come from atomic counters and the bounded window() pass —
    // never from FleetMetrics::snapshot(), whose all-time latency
    // reservoirs grow without bound over a long-running server.
    let mut prev_busy: Vec<f64> =
        server.metrics().window(tick).iter().map(|w| w.busy_secs).collect();
    let mut prev_rejected = server.metrics().rejected_total();
    let mut prev_at = Instant::now();
    let mut last_action: Option<Instant> = None; // free to act at once
    while !stop.load(Ordering::Relaxed) {
        interruptible_sleep(tick, stop);
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let now = Instant::now();
        let dt = now.duration_since(prev_at).as_secs_f64().max(1e-6);
        let win = server.metrics().window(tick);
        let queue_depth = server.metrics().queue_depth();
        let rejected = server.metrics().rejected_total();

        // Fleet-level pressure signals.
        let queue_ratio = queue_depth as f64 / server.queue_capacity().max(1) as f64;
        let shed = rejected.saturating_sub(prev_rejected);
        // p99 drift: some group's in-window tail blowing past 4x its own
        // in-window median while work is still queued — the early
        // warning before the queue actually fills.
        let drift = queue_depth > 0
            && win
                .iter()
                .any(|w| w.completed > 3 && w.p99_ms > 4.0 * w.p50_ms.max(0.01));

        // Per-group utilization over this tick (busy-seconds delta).
        let util: Vec<f64> = managed
            .iter()
            .map(|m| {
                let cur = win.get(m.group).map(|w| w.busy_secs).unwrap_or(0.0);
                let was = prev_busy.get(m.group).copied().unwrap_or(0.0);
                let live = win.get(m.group).map(|w| w.live.max(1)).unwrap_or(1);
                ((cur - was) / (dt * live as f64)).max(0.0)
            })
            .collect();

        if last_action.map_or(true, |t| now.duration_since(t) >= cfg.cooldown) {
            let hot = util.iter().any(|&u| u > cfg.high_water());
            let pressured = queue_ratio >= 0.5 || shed > 0 || hot || drift;
            let acted = if pressured {
                grow_step(server, &mut managed, &util, model, weights, queue_ratio, shed)
            } else if queue_depth == 0 && shed == 0 {
                shrink_step(server, &mut managed, &util, model, weights, cfg)
            } else {
                false
            };
            if acted {
                last_action = Some(now);
            }
        }
        prev_busy = win.iter().map(|w| w.busy_secs).collect();
        prev_rejected = rejected;
        prev_at = now;
    }
}

/// Grow the group with the largest modeled marginal gain by one count
/// step. Returns whether anything changed.
fn grow_step(
    server: &Server,
    managed: &mut [Managed],
    util: &[f64],
    model: &Arc<Model>,
    weights: &Arc<Weights>,
    queue_ratio: f64,
    shed: u64,
) -> bool {
    let mut best: Option<(usize, f64)> = None; // (managed idx, marginal img/s)
    for (mi, m) in managed.iter().enumerate() {
        if m.count >= m.frontier.max_count() {
            continue;
        }
        let marginal =
            m.frontier.at(m.count + 1).group_img_s - m.frontier.at(m.count).group_img_s;
        if marginal < -1e-9 {
            // Past the group's modeled argmax: another replica would
            // *reduce* modeled capacity (smaller shards plan slower
            // engines). Growing here would make an overload worse, not
            // better. Zero-marginal steps stay allowed — equal modeled
            // throughput across more replicas still buys host-side
            // parallelism and request concurrency.
            continue;
        }
        if best.map(|(_, b)| marginal > b).unwrap_or(true) {
            best = Some((mi, marginal));
        }
    }
    let Some((mi, _)) = best else {
        return false; // every group at its frontier ceiling or past argmax
    };
    let reason = format!(
        "queue {:.0}% full, {} shed, util {:.0}%",
        queue_ratio * 100.0,
        shed,
        util[mi] * 100.0
    );
    let (group, from, to) = {
        let m = &managed[mi];
        (m.group, m.count, m.count + 1)
    };
    let acted =
        apply_resize(server, &managed[mi].frontier, group, from, to, &reason, model, weights);
    // Resync even on failure: an aborted swap may still have mutated the
    // fleet (adds that landed before an add raced shutdown, retires that
    // were refused).
    resync_count(server, &mut managed[mi], if acted { to } else { from });
    acted
}

/// After an action (attempted or applied), re-read the group's *actual*
/// live count (a retire can be refused, an add can race a shutdown) so
/// the controller never drifts from the fleet; clamp into the frontier's
/// valid range so a transiently over-committed group still indexes the
/// frontier safely.
fn resync_count(server: &Server, m: &mut Managed, intended: usize) {
    let live = server.live_counts().get(m.group).copied().unwrap_or(intended);
    m.count = live.clamp(m.frontier.min_count(), m.frontier.max_count());
}

/// Shrink the coldest eligible group by one count step. Returns whether
/// anything changed.
fn shrink_step(
    server: &Server,
    managed: &mut [Managed],
    util: &[f64],
    model: &Arc<Model>,
    weights: &Arc<Weights>,
    cfg: &RebalanceConfig,
) -> bool {
    let mut coldest: Option<(usize, f64)> = None;
    for (mi, m) in managed.iter().enumerate() {
        if m.count <= cfg.min_replicas.max(m.frontier.min_count()) {
            continue;
        }
        if util[mi] >= cfg.low_water() {
            continue;
        }
        if coldest.map(|(_, c)| util[mi] < c).unwrap_or(true) {
            coldest = Some((mi, util[mi]));
        }
    }
    let Some((mi, u)) = coldest else {
        return false;
    };
    let reason = format!(
        "idle: util {:.0}% < {:.0}% low water, queue empty",
        u * 100.0,
        cfg.low_water() * 100.0
    );
    let (group, from, to) = {
        let m = &managed[mi];
        (m.group, m.count, m.count - 1)
    };
    let acted =
        apply_resize(server, &managed[mi].frontier, group, from, to, &reason, model, weights);
    resync_count(server, &mut managed[mi], if acted { to } else { from });
    acted
}

/// Move one group from `from` to `to` replicas using the memoized
/// frontier: incremental add/retire when the engine signature is
/// unchanged, a full spin-up-then-drain swap when the new shard plans
/// differently. Logs the action in the rebalance timeline.
#[allow(clippy::too_many_arguments)]
fn apply_resize(
    server: &Server,
    frontier: &GroupFrontier,
    group: usize,
    from: usize,
    to: usize,
    reason: &str,
    model: &Arc<Model>,
    weights: &Arc<Weights>,
) -> bool {
    let new_plan = frontier.at(to);
    let same = plan_signature(&frontier.at(from).per_replica)
        == plan_signature(&new_plan.per_replica);
    let deploy = || {
        Arc::new(Deployment::with_plan(
            Arc::clone(model),
            Arc::clone(weights),
            new_plan.per_replica.clone(),
        ))
    };
    let action = if same && to > from {
        let mut ok = true;
        for _ in from..to {
            ok &= server.add_replica(deploy(), group).is_ok();
        }
        if !ok {
            return false; // shutting down — nothing to log
        }
        RebalanceAction::Grow
    } else if same {
        // Retire the least-loaded replicas first; their in-flight work
        // drains before teardown.
        let ids = server.replica_ids_of_group(group);
        let mut retired = 0usize;
        for &id in ids.iter().take(from.saturating_sub(to)) {
            if server.retire_replica(id).is_ok() {
                retired += 1;
            }
        }
        if retired == 0 {
            return false; // e.g. it was the last live replica fleet-wide
        }
        RebalanceAction::Shrink
    } else {
        // Rolling swap: the new shard plans differently (live IP
        // substitution). One-in-one-out so the group never goes dark
        // *and* the transient overcommit on the physical part is bounded
        // to a single extra replica (the reconfiguration-overlap cost of
        // a live transition, not `from + to` pipelines at once), then
        // add or retire the remainder to land on `to`.
        let old = server.replica_ids_of_group(group);
        let mut spawned = 0usize;
        for id in &old {
            if spawned < to {
                if server.add_replica(deploy(), group).is_err() {
                    return false;
                }
                spawned += 1;
            }
            let _ = server.retire_replica(*id);
        }
        while spawned < to {
            if server.add_replica(deploy(), group).is_err() {
                return false;
            }
            spawned += 1;
        }
        RebalanceAction::Swap
    };
    server.metrics().note_rebalance(RebalanceEvent {
        at_secs: 0.0, // stamped by the metrics clock
        group,
        label: frontier.device.name.clone(),
        action,
        from,
        to,
        reason: reason.to_string(),
    });
    true
}

/// The fleet's health just before a fault: the bar recovery is measured
/// against. Captured by the scenario engine one event before the
/// injection fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryEnvelope {
    /// Admitted-not-dispatched depth at capture time.
    pub queue_depth: u64,
    /// Fleet p99 over the recovery tail at capture time (ms).
    pub p99_ms: f64,
    /// Absolute p99 slack (ms) added to the envelope. The caller sets
    /// it to its service granularity — the scenario engine uses a
    /// couple of worst-case batch times, so a fleet whose quantiles are
    /// inherently steppy (p99 over a short tail is the max sample, and
    /// every latency is a whole number of batch quanta) is not asked to
    /// land on an unreachable sub-quantum bar.
    pub p99_slack_ms: f64,
}

/// Measures recovery time for one injected fault: the time from the
/// fault instant until queue pressure AND windowed p99 are both back
/// under their pre-fault envelope (with a small tolerance — see
/// [`RecoveryTracker::observe`]). A fault the fleet absorbs without
/// ever breaching its envelope recovers in zero time; a fault the fleet
/// never re-absorbs yields `None`, which the scenario verdict turns
/// into a failed recovery assertion.
#[derive(Debug, Clone)]
pub struct RecoveryTracker {
    fault_nanos: u64,
    env: RecoveryEnvelope,
    breached: bool,
    recovered_nanos: Option<u64>,
}

impl RecoveryTracker {
    /// Start tracking a fault injected at `fault_nanos` (on the metrics
    /// clock) against the pre-fault `envelope`.
    pub fn new(fault_nanos: u64, envelope: RecoveryEnvelope) -> RecoveryTracker {
        RecoveryTracker {
            fault_nanos,
            env: envelope,
            breached: false,
            recovered_nanos: None,
        }
    }

    /// Whether `queue_depth` / `p99_ms` are back under the envelope.
    /// Tolerances: the queue bar is at least 1 (an envelope captured at
    /// an idle instant must not demand a permanently empty queue), and
    /// the p99 bar is the envelope +25% or + the envelope's absolute
    /// slack, whichever is larger (quantiles over small tails are
    /// steppy — see [`RecoveryEnvelope::p99_slack_ms`]).
    fn under(&self, queue_depth: u64, p99_ms: f64) -> bool {
        let q_bar = self.env.queue_depth.max(1);
        let p_bar = (self.env.p99_ms * 1.25).max(self.env.p99_ms + self.env.p99_slack_ms);
        queue_depth <= q_bar && p99_ms <= p_bar
    }

    /// Feed one observation (after any simulation event / control tick).
    /// The first observation *over* the envelope marks a breach; the
    /// first observation back under it after a breach marks recovery.
    /// Observations after recovery are ignored — recovery time is the
    /// first return to the envelope, not the last.
    pub fn observe(&mut self, now_nanos: u64, queue_depth: u64, p99_ms: f64) {
        if self.recovered_nanos.is_some() || now_nanos < self.fault_nanos {
            return;
        }
        if self.under(queue_depth, p99_ms) {
            if self.breached {
                self.recovered_nanos = Some(now_nanos);
            }
        } else {
            self.breached = true;
        }
    }

    /// End of run: a fault whose envelope was never breached was
    /// absorbed outright — recovery time zero. A breached-and-never-
    /// recovered fault stays `None`.
    pub fn finish(&mut self) {
        if !self.breached && self.recovered_nanos.is_none() {
            self.recovered_nanos = Some(self.fault_nanos);
        }
    }

    /// Milliseconds from the fault instant to recovery, if recovered.
    pub fn recovery_ms(&self) -> Option<f64> {
        self.recovered_nanos.map(|n| n.saturating_sub(self.fault_nanos) as f64 / 1e6)
    }
}

#[cfg(test)]
mod recovery_tests {
    use super::*;

    const MS: u64 = 1_000_000;

    #[test]
    fn breach_then_recover_measures_the_gap() {
        let env = RecoveryEnvelope { queue_depth: 2, p99_ms: 4.0, p99_slack_ms: 0.25 };
        let mut t = RecoveryTracker::new(100 * MS, env);
        // Pre-fault observations are ignored.
        t.observe(50 * MS, 60, 50.0);
        // Queue blows past the envelope after the fault.
        t.observe(110 * MS, 40, 4.0);
        // Still over (p99 this time).
        t.observe(120 * MS, 1, 9.0);
        // Back under both bars: recovered at 150 ms.
        t.observe(150 * MS, 2, 4.9);
        // Later wobble does not move the recovery point.
        t.observe(200 * MS, 50, 50.0);
        t.finish();
        assert_eq!(t.recovery_ms(), Some(50.0));
    }

    #[test]
    fn absorbed_fault_recovers_in_zero_time() {
        let env = RecoveryEnvelope { queue_depth: 3, p99_ms: 5.0, p99_slack_ms: 0.25 };
        let mut t = RecoveryTracker::new(100 * MS, env);
        // Never over the envelope (tolerances included).
        t.observe(110 * MS, 3, 6.0); // 6.0 <= 5.0 * 1.25
        t.observe(150 * MS, 1, 4.0);
        t.finish();
        assert_eq!(t.recovery_ms(), Some(0.0));
    }

    #[test]
    fn unrecovered_fault_stays_none() {
        let env = RecoveryEnvelope { queue_depth: 1, p99_ms: 2.0, p99_slack_ms: 0.25 };
        let mut t = RecoveryTracker::new(100 * MS, env);
        t.observe(110 * MS, 64, 80.0);
        t.observe(400 * MS, 64, 120.0);
        t.finish();
        assert_eq!(t.recovery_ms(), None);
    }

    #[test]
    fn idle_envelope_tolerates_one_queued_request() {
        // Envelope captured at a perfectly idle instant: queue bar
        // floors at 1 so a single in-queue request is not a breach.
        let env = RecoveryEnvelope { queue_depth: 0, p99_ms: 0.0, p99_slack_ms: 0.25 };
        let mut t = RecoveryTracker::new(0, env);
        t.observe(10 * MS, 1, 0.2); // within both floors
        t.finish();
        assert_eq!(t.recovery_ms(), Some(0.0));
    }
}
