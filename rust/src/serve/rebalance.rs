//! Dynamic fleet rebalancing: a control loop that watches the live
//! [`super::metrics::FleetMetrics`] over a sliding window and grows or shrinks device
//! groups *without draining the server* — the run-time half of the
//! paper's adaptivity claim. PR 4 froze replica counts at plan time; a
//! traffic spike or lull wasted exactly the moldability the adaptive
//! IPs exist for. The rebalancer closes that gap at serve time.
//!
//! **Signals** (per control tick, one tick per `window`):
//!
//! * queue pressure — admitted-not-dispatched depth against the bounded
//!   queue's capacity, plus the rejection delta (load already shed);
//! * per-group utilization — busy-seconds delta over
//!   `tick × live replicas`;
//! * p99 drift — a group's in-window p99 blowing past 4× its own
//!   in-window median while work is queued (the early-warning signal
//!   before the queue actually fills).
//!
//! All signals come from atomic counters and the bounded sliding-window
//! pass — the controller never takes a full metrics snapshot, whose
//! all-time latency reservoirs grow with uptime.
//!
//! **Actions.** Scaling decisions index the memoized
//! [`FleetFrontier`] — the per-device count → plan frontiers built at
//! plan time — so *no planner run ever happens under traffic*; the
//! composition search is re-run incrementally by moving one group one
//! count step at a time under its still-attached device budget. If the
//! frontier's plan at the new count has the same engine signature as
//! the current one (the common case away from the resource ceiling),
//! replicas are simply added to or retired from the group. If the
//! signature differs (the shard shrank enough that the planner would
//! substitute IPs — the paper's Table III adaptations, now happening
//! live), the group *swaps* one-in-one-out: each new pipeline spins up
//! on the new frontier plan before one old replica retires (after its
//! in-flight micro-batches drain), so the group never goes dark and the
//! transient overcommit on the physical part is bounded to one extra
//! replica. Either way no admitted request is dropped and the scheduler
//! keeps dispatching throughout.
//!
//! **Cross-model shifts.** On a multi-model fleet the frontier holds a
//! row per `(device, model)` pair, and the controller watches *per-model*
//! pressure through the tenant counters: when one model's tenants shed
//! while another model's groups idle, the coldest donor group *shifts* —
//! a rolling swap onto the recipient model's frontier plan for the same
//! physical part (new-model replicas spin up before old-model replicas
//! retire, so neither model's service goes dark). The decision rule is
//! the pure function [`shift_decision`]; the donor always keeps at least
//! one group per model, so a shift can rebalance a drifted traffic mix
//! but never evict a model from the fleet.
//!
//! **Stability.** Two mechanisms keep the loop from thrashing:
//! hysteresis (the scale-down watermark sits far below the scale-up
//! watermark, and shrinking additionally requires an empty queue and a
//! shed-free window) and a cooldown (after any action the controller
//! only observes for `cooldown`, letting the fleet settle before the
//! next decision). Forced (`name:count`) groups are never resized —
//! a pinned count is an operator statement, not a hint.
//!
//! **Observability.** Every action lands in the [`RebalanceEvent`]
//! timeline via [`super::metrics::FleetMetrics::note_rebalance`], which —
//! when the fleet was started with a live [`crate::trace::Tracer`]
//! (`acf serve --trace`) — also mirrors it as a `rebalance_grow` /
//! `rebalance_shrink` / `rebalance_swap` instant on the group's control
//! track, stamped by the same clock as the request span chains. A scale
//! action in the exported timeline therefore sits exactly where the
//! latency it caused (or cured) is visible; the add/retire/drain
//! lifecycle of each replica the action touched shows up as
//! `replica_add` / `replica_retire` / `replica_drained` instants on the
//! same track.

use super::fleet::{plan_signature, FleetFrontier, FleetPlan, GroupFrontier};
use super::metrics::{RebalanceAction, RebalanceEvent};
use super::scheduler::Server;
use crate::cnn::model::{Model, Weights};
use crate::coordinator::Deployment;
use std::cmp::Ordering as CmpOrdering;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Control-loop knobs (`acf serve --rebalance --window-ms --headroom`).
#[derive(Debug, Clone)]
pub struct RebalanceConfig {
    /// Control period and signal window.
    pub window: Duration,
    /// Capacity headroom the fleet tries to keep: the scale-up watermark
    /// is `1 - headroom` group utilization.
    pub headroom: f64,
    /// Minimum quiet time after an action before the next one.
    pub cooldown: Duration,
    /// Per-group replica floor (unforced groups never shrink below it).
    pub min_replicas: usize,
}

impl Default for RebalanceConfig {
    fn default() -> RebalanceConfig {
        RebalanceConfig {
            window: Duration::from_millis(250),
            headroom: 0.25,
            cooldown: Duration::from_millis(500),
            min_replicas: 1,
        }
    }
}

impl RebalanceConfig {
    /// Scale-up utilization watermark.
    fn high_water(&self) -> f64 {
        (1.0 - self.headroom.clamp(0.0, 0.95)).max(0.05)
    }

    /// Scale-down utilization watermark — deliberately far below the
    /// scale-up mark (hysteresis).
    fn low_water(&self) -> f64 {
        self.high_water() * 0.35
    }
}

/// One managed device group: its frontier row (keyed by
/// `(spec_entry, model)` — the model×device memo), the model it
/// currently serves, and the live count the controller believes it has.
struct Managed {
    /// Server-side group index (metrics / dispatch).
    group: usize,
    frontier: GroupFrontier,
    /// Index into the frontier's model list; changes on a shift.
    model_id: usize,
    count: usize,
}

/// The live rebalance controller. Owns a background thread; call
/// [`Rebalancer::stop`] before shutting the server down.
pub struct Rebalancer {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Rebalancer {
    /// Start rebalancing `server` (already serving `plan`) against the
    /// memoized `frontier`. `weights` is one weight set per frontier
    /// model (parallel to [`FleetFrontier::models`]) — new replicas
    /// deploy from their group's model with frontier plans, and a
    /// cross-model shift deploys the recipient model's. Groups whose
    /// spec entry pinned a count are left alone.
    pub fn start(
        server: Arc<Server>,
        frontier: FleetFrontier,
        plan: &FleetPlan,
        weights: Vec<Arc<Weights>>,
        cfg: RebalanceConfig,
    ) -> Rebalancer {
        assert_eq!(
            weights.len(),
            frontier.models.len(),
            "one weight set per frontier model"
        );
        // Map each server group back to its frontier row — keyed by
        // (spec entry, model), the memo key of the model×device
        // frontier. Groups the composition search shed (under a target)
        // are simply absent — their budgets stay attached in `frontier`
        // but they were never deployed, so there is nothing to resize.
        let managed: Vec<Managed> = plan
            .groups
            .iter()
            .enumerate()
            .filter_map(|(gi, g)| {
                let f = frontier
                    .groups
                    .iter()
                    .find(|f| f.spec_entry == g.spec_entry && f.model_id == g.model_id)?
                    .clone();
                if f.forced.is_some() {
                    return None; // pinned counts are operator statements
                }
                Some(Managed { group: gi, frontier: f, model_id: g.model_id, count: g.replicas })
            })
            .collect();
        let stop = Arc::new(AtomicBool::new(false));
        let thread_stop = Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            control_loop(&server, managed, &frontier, &weights, &cfg, &thread_stop);
        });
        Rebalancer { stop, handle: Some(handle) }
    }

    /// Stop the control loop and join its thread. Always call this
    /// before `Server::shutdown` so no resize races the teardown.
    pub fn stop(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Rebalancer {
    fn drop(&mut self) {
        self.halt();
    }
}

/// Sleep `total` in small slices so a stop request is honored promptly.
fn interruptible_sleep(total: Duration, stop: &AtomicBool) {
    let slice = Duration::from_millis(20);
    let deadline = Instant::now() + total;
    while Instant::now() < deadline && !stop.load(Ordering::Relaxed) {
        std::thread::sleep(slice.min(deadline.saturating_duration_since(Instant::now())));
    }
}

fn control_loop(
    server: &Server,
    mut managed: Vec<Managed>,
    frontier: &FleetFrontier,
    weights: &[Arc<Weights>],
    cfg: &RebalanceConfig,
    stop: &AtomicBool,
) {
    if managed.is_empty() {
        return; // every group pinned — nothing to control
    }
    let n_models = frontier.models.len();
    // Tenant → frontier-model mapping for per-model shed attribution
    // (tenants route by model name; frontier rows key by model id).
    let tenant_model: Vec<Option<usize>> = (0..server.n_tenants())
        .map(|t| {
            let name = &server.model_of_tenant(t).name;
            frontier.models.iter().position(|m| m.name == *name)
        })
        .collect();
    // Floor the tick so a degenerate `--window-ms 0` cannot turn the
    // loop into a busy-spin contending every latency mutex.
    let tick = cfg.window.max(Duration::from_millis(10));
    // Signals come from atomic counters and the bounded window() pass —
    // never from FleetMetrics::snapshot(), whose all-time latency
    // reservoirs grow without bound over a long-running server.
    let mut prev_busy: Vec<f64> =
        server.metrics().window(tick).iter().map(|w| w.busy_secs).collect();
    let mut prev_rejected = server.metrics().rejected_total();
    let mut prev_tenant_rej: Vec<u64> =
        (0..server.n_tenants()).map(|t| server.metrics().tenant_counts(t).1).collect();
    let mut prev_at = Instant::now();
    let mut last_action: Option<Instant> = None; // free to act at once
    while !stop.load(Ordering::Relaxed) {
        interruptible_sleep(tick, stop);
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let now = Instant::now();
        let dt = now.duration_since(prev_at).as_secs_f64().max(1e-6);
        let win = server.metrics().window(tick);
        let queue_depth = server.metrics().queue_depth();
        let rejected = server.metrics().rejected_total();

        // Fleet-level pressure signals.
        let queue_ratio = queue_depth as f64 / server.queue_capacity().max(1) as f64;
        let shed = rejected.saturating_sub(prev_rejected);
        // p99 drift: some group's in-window tail blowing past 4x its own
        // in-window median while work is still queued — the early
        // warning before the queue actually fills.
        let drift = queue_depth > 0
            && win
                .iter()
                .any(|w| w.completed > 3 && w.p99_ms > 4.0 * w.p50_ms.max(0.01));

        // Per-group utilization over this tick (busy-seconds delta).
        let util: Vec<f64> = managed
            .iter()
            .map(|m| {
                let cur = win.get(m.group).map(|w| w.busy_secs).unwrap_or(0.0);
                let was = prev_busy.get(m.group).copied().unwrap_or(0.0);
                let live = win.get(m.group).map(|w| w.live.max(1)).unwrap_or(1);
                ((cur - was) / (dt * live as f64)).max(0.0)
            })
            .collect();

        // Per-model pressure: shed attributed through the tenant
        // counters, utilization averaged over each model's groups.
        let mut model_shed = vec![0u64; n_models];
        for (t, &m) in tenant_model.iter().enumerate() {
            let rej = server.metrics().tenant_counts(t).1;
            if let Some(m) = m {
                model_shed[m] += rej.saturating_sub(prev_tenant_rej[t]);
            }
            prev_tenant_rej[t] = rej;
        }
        let mut model_groups = vec![0usize; n_models];
        let mut model_util_sum = vec![0.0f64; n_models];
        for (mi, m) in managed.iter().enumerate() {
            model_groups[m.model_id] += 1;
            model_util_sum[m.model_id] += util[mi];
        }
        let model_util: Vec<f64> = (0..n_models)
            .map(|m| {
                if model_groups[m] > 0 { model_util_sum[m] / model_groups[m] as f64 } else { 0.0 }
            })
            .collect();

        if last_action.map_or(true, |t| now.duration_since(t) >= cfg.cooldown) {
            let hot = util.iter().any(|&u| u > cfg.high_water());
            let pressured = queue_ratio >= 0.5 || shed > 0 || hot || drift;
            let acted = if pressured {
                // A drifted traffic mix (one model shedding while
                // another idles) is fixed by moving a whole group
                // between models, not by growing the hot model past its
                // budget — try the shift first.
                let shifted = shift_decision(&model_groups, &model_shed, &model_util, cfg.low_water())
                    .map(|(donor, recipient)| {
                        shift_step(
                            server, &mut managed, frontier, weights, &util, donor, recipient,
                        )
                    })
                    .unwrap_or(false);
                shifted
                    || grow_step(
                        server, &mut managed, &util, frontier, weights, queue_ratio, shed,
                    )
            } else if queue_depth == 0 && shed == 0 {
                shrink_step(server, &mut managed, &util, frontier, weights, cfg)
            } else {
                false
            };
            if acted {
                last_action = Some(now);
            }
        }
        prev_busy = win.iter().map(|w| w.busy_secs).collect();
        prev_rejected = rejected;
        prev_at = now;
    }
}

/// The cross-model shift rule, pure so it is directly testable: given
/// per-model group counts, per-model shed deltas over the window, and
/// per-model mean utilization, pick a `(donor, recipient)` pair — the
/// recipient is the model shedding the most, the donor the idlest
/// *quiet* model (no shed, mean utilization under `low_water`) that
/// would still keep at least one group after donating. `None` when the
/// mix is balanced (nobody sheds, or no model can safely donate).
pub fn shift_decision(
    groups_per_model: &[usize],
    shed_per_model: &[u64],
    util_per_model: &[f64],
    low_water: f64,
) -> Option<(usize, usize)> {
    let n = groups_per_model.len();
    if n < 2 {
        return None;
    }
    let recipient = (0..n)
        .filter(|&m| shed_per_model[m] > 0)
        .max_by_key(|&m| (shed_per_model[m], std::cmp::Reverse(m)))?;
    let donor = (0..n)
        .filter(|&m| {
            m != recipient
                && shed_per_model[m] == 0
                && groups_per_model[m] >= 2
                && util_per_model[m] < low_water
        })
        .min_by(|&a, &b| {
            util_per_model[a].partial_cmp(&util_per_model[b]).unwrap_or(CmpOrdering::Equal)
        })?;
    Some((donor, recipient))
}

/// Apply a [`shift_decision`]: roll the donor model's coldest group onto
/// the recipient model's frontier plan for the same physical part (the
/// `(spec entry, recipient)` row must exist — a board that cannot carry
/// the recipient model is never a shift target). Returns whether a
/// shift happened.
fn shift_step(
    server: &Server,
    managed: &mut [Managed],
    frontier: &FleetFrontier,
    weights: &[Arc<Weights>],
    util: &[f64],
    donor: usize,
    recipient: usize,
) -> bool {
    // Coldest donor group whose device also has a recipient-model row.
    let mut cand: Option<(usize, f64)> = None;
    for (mi, m) in managed.iter().enumerate() {
        if m.model_id != donor {
            continue;
        }
        let has_row = frontier.groups.iter().any(|r| {
            r.spec_entry == m.frontier.spec_entry && r.model_id == recipient && r.forced.is_none()
        });
        if !has_row {
            continue;
        }
        if cand.map(|(_, u)| util[mi] < u).unwrap_or(true) {
            cand = Some((mi, util[mi]));
        }
    }
    let Some((mi, _)) = cand else {
        return false;
    };
    let row = frontier
        .groups
        .iter()
        .find(|r| r.spec_entry == managed[mi].frontier.spec_entry && r.model_id == recipient)
        .expect("candidate filter checked the row exists")
        .clone();
    let to = row.argmax().replicas;
    let (group, from) = (managed[mi].group, managed[mi].count);
    let model = &frontier.models[recipient];
    let wts = &weights[recipient];
    let deploy = || {
        Arc::new(Deployment::with_plan(
            Arc::clone(model),
            Arc::clone(wts),
            row.at(to).per_replica.clone(),
        ))
    };
    // Rolling swap across the model axis: recipient-model replicas spin
    // up before donor-model replicas retire, so neither model's service
    // goes dark and the transient overcommit is bounded to one replica.
    let old = server.replica_ids_of_group(group);
    let mut spawned = 0usize;
    for id in &old {
        if spawned < to {
            if server.add_replica(deploy(), group).is_err() {
                return false;
            }
            spawned += 1;
        }
        let _ = server.retire_replica(*id);
    }
    while spawned < to {
        if server.add_replica(deploy(), group).is_err() {
            return false;
        }
        spawned += 1;
    }
    server.metrics().note_rebalance(RebalanceEvent {
        at_secs: 0.0, // stamped by the metrics clock
        group,
        label: row.device.name.clone(),
        action: RebalanceAction::Shift,
        from,
        to,
        reason: format!(
            "mix drift: '{}' shedding while '{}' idle",
            frontier.models[recipient].name, frontier.models[donor].name
        ),
    });
    managed[mi].frontier = row;
    managed[mi].model_id = recipient;
    resync_count(server, &mut managed[mi], to);
    true
}

/// Grow the group with the largest modeled marginal gain by one count
/// step. Returns whether anything changed.
fn grow_step(
    server: &Server,
    managed: &mut [Managed],
    util: &[f64],
    frontier: &FleetFrontier,
    weights: &[Arc<Weights>],
    queue_ratio: f64,
    shed: u64,
) -> bool {
    let mut best: Option<(usize, f64)> = None; // (managed idx, marginal img/s)
    for (mi, m) in managed.iter().enumerate() {
        if m.count >= m.frontier.max_count() {
            continue;
        }
        let marginal =
            m.frontier.at(m.count + 1).group_img_s - m.frontier.at(m.count).group_img_s;
        if marginal < -1e-9 {
            // Past the group's modeled argmax: another replica would
            // *reduce* modeled capacity (smaller shards plan slower
            // engines). Growing here would make an overload worse, not
            // better. Zero-marginal steps stay allowed — equal modeled
            // throughput across more replicas still buys host-side
            // parallelism and request concurrency.
            continue;
        }
        if best.map(|(_, b)| marginal > b).unwrap_or(true) {
            best = Some((mi, marginal));
        }
    }
    let Some((mi, _)) = best else {
        return false; // every group at its frontier ceiling or past argmax
    };
    let reason = format!(
        "queue {:.0}% full, {} shed, util {:.0}%",
        queue_ratio * 100.0,
        shed,
        util[mi] * 100.0
    );
    let (group, from, to, model_id) = {
        let m = &managed[mi];
        (m.group, m.count, m.count + 1, m.model_id)
    };
    let acted = apply_resize(
        server,
        &managed[mi].frontier,
        group,
        from,
        to,
        &reason,
        &frontier.models[model_id],
        &weights[model_id],
    );
    // Resync even on failure: an aborted swap may still have mutated the
    // fleet (adds that landed before an add raced shutdown, retires that
    // were refused).
    resync_count(server, &mut managed[mi], if acted { to } else { from });
    acted
}

/// After an action (attempted or applied), re-read the group's *actual*
/// live count (a retire can be refused, an add can race a shutdown) so
/// the controller never drifts from the fleet; clamp into the frontier's
/// valid range so a transiently over-committed group still indexes the
/// frontier safely.
fn resync_count(server: &Server, m: &mut Managed, intended: usize) {
    let live = server.live_counts().get(m.group).copied().unwrap_or(intended);
    m.count = live.clamp(m.frontier.min_count(), m.frontier.max_count());
}

/// Shrink the coldest eligible group by one count step. Returns whether
/// anything changed.
fn shrink_step(
    server: &Server,
    managed: &mut [Managed],
    util: &[f64],
    frontier: &FleetFrontier,
    weights: &[Arc<Weights>],
    cfg: &RebalanceConfig,
) -> bool {
    let mut coldest: Option<(usize, f64)> = None;
    for (mi, m) in managed.iter().enumerate() {
        if m.count <= cfg.min_replicas.max(m.frontier.min_count()) {
            continue;
        }
        if util[mi] >= cfg.low_water() {
            continue;
        }
        if coldest.map(|(_, c)| util[mi] < c).unwrap_or(true) {
            coldest = Some((mi, util[mi]));
        }
    }
    let Some((mi, u)) = coldest else {
        return false;
    };
    let reason = format!(
        "idle: util {:.0}% < {:.0}% low water, queue empty",
        u * 100.0,
        cfg.low_water() * 100.0
    );
    let (group, from, to, model_id) = {
        let m = &managed[mi];
        (m.group, m.count, m.count - 1, m.model_id)
    };
    let acted = apply_resize(
        server,
        &managed[mi].frontier,
        group,
        from,
        to,
        &reason,
        &frontier.models[model_id],
        &weights[model_id],
    );
    resync_count(server, &mut managed[mi], if acted { to } else { from });
    acted
}

/// Move one group from `from` to `to` replicas using the memoized
/// frontier: incremental add/retire when the engine signature is
/// unchanged, a full spin-up-then-drain swap when the new shard plans
/// differently. Logs the action in the rebalance timeline.
#[allow(clippy::too_many_arguments)]
fn apply_resize(
    server: &Server,
    frontier: &GroupFrontier,
    group: usize,
    from: usize,
    to: usize,
    reason: &str,
    model: &Arc<Model>,
    weights: &Arc<Weights>,
) -> bool {
    let new_plan = frontier.at(to);
    let same = plan_signature(&frontier.at(from).per_replica)
        == plan_signature(&new_plan.per_replica);
    let deploy = || {
        Arc::new(Deployment::with_plan(
            Arc::clone(model),
            Arc::clone(weights),
            new_plan.per_replica.clone(),
        ))
    };
    let action = if same && to > from {
        let mut ok = true;
        for _ in from..to {
            ok &= server.add_replica(deploy(), group).is_ok();
        }
        if !ok {
            return false; // shutting down — nothing to log
        }
        RebalanceAction::Grow
    } else if same {
        // Retire the least-loaded replicas first; their in-flight work
        // drains before teardown.
        let ids = server.replica_ids_of_group(group);
        let mut retired = 0usize;
        for &id in ids.iter().take(from.saturating_sub(to)) {
            if server.retire_replica(id).is_ok() {
                retired += 1;
            }
        }
        if retired == 0 {
            return false; // e.g. it was the last live replica fleet-wide
        }
        RebalanceAction::Shrink
    } else {
        // Rolling swap: the new shard plans differently (live IP
        // substitution). One-in-one-out so the group never goes dark
        // *and* the transient overcommit on the physical part is bounded
        // to a single extra replica (the reconfiguration-overlap cost of
        // a live transition, not `from + to` pipelines at once), then
        // add or retire the remainder to land on `to`.
        let old = server.replica_ids_of_group(group);
        let mut spawned = 0usize;
        for id in &old {
            if spawned < to {
                if server.add_replica(deploy(), group).is_err() {
                    return false;
                }
                spawned += 1;
            }
            let _ = server.retire_replica(*id);
        }
        while spawned < to {
            if server.add_replica(deploy(), group).is_err() {
                return false;
            }
            spawned += 1;
        }
        RebalanceAction::Swap
    };
    server.metrics().note_rebalance(RebalanceEvent {
        at_secs: 0.0, // stamped by the metrics clock
        group,
        label: frontier.device.name.clone(),
        action,
        from,
        to,
        reason: reason.to_string(),
    });
    true
}

/// The fleet's health just before a fault: the bar recovery is measured
/// against. Captured by the scenario engine one event before the
/// injection fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryEnvelope {
    /// Admitted-not-dispatched depth at capture time.
    pub queue_depth: u64,
    /// Fleet p99 over the recovery tail at capture time (ms).
    pub p99_ms: f64,
    /// Absolute p99 slack (ms) added to the envelope. The caller sets
    /// it to its service granularity — the scenario engine uses a
    /// couple of worst-case batch times, so a fleet whose quantiles are
    /// inherently steppy (p99 over a short tail is the max sample, and
    /// every latency is a whole number of batch quanta) is not asked to
    /// land on an unreachable sub-quantum bar.
    pub p99_slack_ms: f64,
}

/// Measures recovery time for one injected fault: the time from the
/// fault instant until queue pressure AND windowed p99 are both back
/// under their pre-fault envelope (with a small tolerance — see
/// [`RecoveryTracker::observe`]). A fault the fleet absorbs without
/// ever breaching its envelope recovers in zero time; a fault the fleet
/// never re-absorbs yields `None`, which the scenario verdict turns
/// into a failed recovery assertion.
#[derive(Debug, Clone)]
pub struct RecoveryTracker {
    fault_nanos: u64,
    env: RecoveryEnvelope,
    breached: bool,
    recovered_nanos: Option<u64>,
}

impl RecoveryTracker {
    /// Start tracking a fault injected at `fault_nanos` (on the metrics
    /// clock) against the pre-fault `envelope`.
    pub fn new(fault_nanos: u64, envelope: RecoveryEnvelope) -> RecoveryTracker {
        RecoveryTracker {
            fault_nanos,
            env: envelope,
            breached: false,
            recovered_nanos: None,
        }
    }

    /// Whether `queue_depth` / `p99_ms` are back under the envelope.
    /// Tolerances: the queue bar is at least 1 (an envelope captured at
    /// an idle instant must not demand a permanently empty queue), and
    /// the p99 bar is the envelope +25% or + the envelope's absolute
    /// slack, whichever is larger (quantiles over small tails are
    /// steppy — see [`RecoveryEnvelope::p99_slack_ms`]).
    fn under(&self, queue_depth: u64, p99_ms: f64) -> bool {
        let q_bar = self.env.queue_depth.max(1);
        let p_bar = (self.env.p99_ms * 1.25).max(self.env.p99_ms + self.env.p99_slack_ms);
        queue_depth <= q_bar && p99_ms <= p_bar
    }

    /// Feed one observation (after any simulation event / control tick).
    /// The first observation *over* the envelope marks a breach; the
    /// first observation back under it after a breach marks recovery.
    /// Observations after recovery are ignored — recovery time is the
    /// first return to the envelope, not the last.
    pub fn observe(&mut self, now_nanos: u64, queue_depth: u64, p99_ms: f64) {
        if self.recovered_nanos.is_some() || now_nanos < self.fault_nanos {
            return;
        }
        if self.under(queue_depth, p99_ms) {
            if self.breached {
                self.recovered_nanos = Some(now_nanos);
            }
        } else {
            self.breached = true;
        }
    }

    /// End of run: a fault whose envelope was never breached was
    /// absorbed outright — recovery time zero. A breached-and-never-
    /// recovered fault stays `None`.
    pub fn finish(&mut self) {
        if !self.breached && self.recovered_nanos.is_none() {
            self.recovered_nanos = Some(self.fault_nanos);
        }
    }

    /// Milliseconds from the fault instant to recovery, if recovered.
    pub fn recovery_ms(&self) -> Option<f64> {
        self.recovered_nanos.map(|n| n.saturating_sub(self.fault_nanos) as f64 / 1e6)
    }
}

#[cfg(test)]
mod shift_tests {
    use super::shift_decision;

    #[test]
    fn shed_plus_idle_donor_yields_a_shift() {
        // Model 1 sheds; model 0 has two idle groups — donate one.
        let pick = shift_decision(&[2, 1], &[0, 12], &[0.05, 0.9], 0.25);
        assert_eq!(pick, Some((0, 1)));
        // Busiest shedding model wins the recipient slot.
        let pick = shift_decision(&[3, 1, 1], &[0, 4, 9], &[0.02, 0.8, 0.9], 0.25);
        assert_eq!(pick, Some((0, 2)));
    }

    #[test]
    fn no_shift_without_shed_or_without_a_safe_donor() {
        // Nobody sheds: balanced mix, nothing to fix.
        assert_eq!(shift_decision(&[2, 2], &[0, 0], &[0.1, 0.1], 0.25), None);
        // The only quiet model has a single group — it never donates its
        // last one (a shift must not evict a model from the fleet).
        assert_eq!(shift_decision(&[1, 1], &[0, 5], &[0.05, 0.9], 0.25), None);
        // Quiet model is itself busy (util over low water): no donor.
        assert_eq!(shift_decision(&[2, 1], &[0, 5], &[0.5, 0.9], 0.25), None);
        // A model that is itself shedding never donates.
        assert_eq!(shift_decision(&[2, 2], &[3, 5], &[0.05, 0.9], 0.25), None);
        // Single-model fleets have no shift axis at all.
        assert_eq!(shift_decision(&[4], &[7], &[0.9], 0.25), None);
    }
}

#[cfg(test)]
mod recovery_tests {
    use super::*;

    const MS: u64 = 1_000_000;

    #[test]
    fn breach_then_recover_measures_the_gap() {
        let env = RecoveryEnvelope { queue_depth: 2, p99_ms: 4.0, p99_slack_ms: 0.25 };
        let mut t = RecoveryTracker::new(100 * MS, env);
        // Pre-fault observations are ignored.
        t.observe(50 * MS, 60, 50.0);
        // Queue blows past the envelope after the fault.
        t.observe(110 * MS, 40, 4.0);
        // Still over (p99 this time).
        t.observe(120 * MS, 1, 9.0);
        // Back under both bars: recovered at 150 ms.
        t.observe(150 * MS, 2, 4.9);
        // Later wobble does not move the recovery point.
        t.observe(200 * MS, 50, 50.0);
        t.finish();
        assert_eq!(t.recovery_ms(), Some(50.0));
    }

    #[test]
    fn absorbed_fault_recovers_in_zero_time() {
        let env = RecoveryEnvelope { queue_depth: 3, p99_ms: 5.0, p99_slack_ms: 0.25 };
        let mut t = RecoveryTracker::new(100 * MS, env);
        // Never over the envelope (tolerances included).
        t.observe(110 * MS, 3, 6.0); // 6.0 <= 5.0 * 1.25
        t.observe(150 * MS, 1, 4.0);
        t.finish();
        assert_eq!(t.recovery_ms(), Some(0.0));
    }

    #[test]
    fn unrecovered_fault_stays_none() {
        let env = RecoveryEnvelope { queue_depth: 1, p99_ms: 2.0, p99_slack_ms: 0.25 };
        let mut t = RecoveryTracker::new(100 * MS, env);
        t.observe(110 * MS, 64, 80.0);
        t.observe(400 * MS, 64, 120.0);
        t.finish();
        assert_eq!(t.recovery_ms(), None);
    }

    #[test]
    fn idle_envelope_tolerates_one_queued_request() {
        // Envelope captured at a perfectly idle instant: queue bar
        // floors at 1 so a single in-queue request is not a breach.
        let env = RecoveryEnvelope { queue_depth: 0, p99_ms: 0.0, p99_slack_ms: 0.25 };
        let mut t = RecoveryTracker::new(0, env);
        t.observe(10 * MS, 1, 0.2); // within both floors
        t.finish();
        assert_eq!(t.recovery_ms(), Some(0.0));
    }
}
