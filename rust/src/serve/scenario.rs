//! The deterministic scenario harness (`acf serve --scenario`).
//!
//! A *scenario* is a JSON-described sequence of phases, each combining a
//! [`LoadSpec`] (diurnal ramps, flash-crowd spikes, adversarial
//! micro-bursts — lowered to [`LoadProfile`]s), scheduled [`FaultSpec`]
//! injections (replica death, whole-group loss, latency degradation),
//! and per-phase pass/fail assertions (max shed %, p99 ceiling,
//! recovery time, zero admitted-request drops). The CLI runs one with
//! `acf serve --scenario scenarios/flash_crowd.json --seed 7`.
//!
//! **Why a virtual-time engine.** The acceptance contract is *byte-
//! identical verdict reports* for the same scenario file + seed —
//! across runs and across machines. Wall-clock threads cannot give that
//! (dispatch interleavings and measured latencies jitter), so the
//! engine is a single-threaded discrete-event simulation over the
//! *modeled* fleet: replicas serve at their plan's `images_per_sec`
//! (the planner's figure, derived from cycle-exact layer IPs), time is
//! a [`Clock::manual`], and arrivals come from the same
//! [`profile_schedule`] a real serve would draw. This mirrors the
//! repo's modeled-vs-measured bench split: modeled numbers gate CI,
//! measured numbers ride along as report-only. The *real*
//! [`super::Server`] carries the same fault surface
//! ([`super::Server::kill_replica`], [`super::Server::kill_group`],
//! [`super::Server::inject_latency`]) and is exercised qualitatively by
//! the integration tests; the scenario verdict is the deterministic,
//! machine-independent artifact.
//!
//! **Scale-free assertions.** Load is written in multiples of the
//! fleet's modeled throughput, and the recovery signal is the p99 over
//! the last `recovery_tail` *completions* (not a time window) with a
//! couple of worst-case batch times of absolute slack folded into the
//! envelope — so one scenario file means the same thing on a fleet
//! serving 100 img/s and one serving 100 000, and quick mode shrinks
//! request counts without distorting what "recovered" means.
//!
//! Everything downstream of the event loop reuses the production
//! types: [`FleetMetrics`] (latency reservoirs, tail/range cuts, fault
//! timeline), [`RecoveryTracker`] (the recovery-time definition), and
//! the [`crate::trace`] tracks — so a failing scenario exports a Chrome
//! trace whose fault instants sit on the same control tracks a live
//! serve would use.
//!
//! **Multi-tenant scenarios.** An optional top-level `tenants` list
//! turns on the same (tenant, model) routing the live scheduler uses:
//! per-tenant admission caps carved out of `queue_depth` by quota,
//! weighted-fair dequeue (lowest served/quota first), and model-affine
//! dispatch — a tenant's requests only ride replicas whose group serves
//! its model. Each arrival is assigned a tenant by deterministic
//! weighted round-robin over the phase's `mix` weights (equal by
//! default), so verdicts stay byte-identical for a given scenario +
//! seed. Phase verdicts then carry a per-tenant block (offered /
//! accepted / shed / completed / p99) and an optional
//! `tenant_p99_ms_max` assertion bounds the *worst* tenant's p99 —
//! the "no tenant starves" bar. Scenarios without a `tenants` key are
//! untouched: one implicit tenant, full-depth queue, no model filter,
//! and a verdict without the per-tenant block.

use super::fault::{FaultEvent, FaultEventKind, FaultKind, FaultSpec};
use super::metrics::{FleetMetrics, TenantInfo};
use super::rebalance::{RecoveryEnvelope, RecoveryTracker};
use super::{phase_seed, profile_schedule, FleetPlan, LoadProfile};
use crate::trace::{self, Clock, Tracer};
use crate::util::json::Json;
use std::collections::VecDeque;
use std::time::Duration;

/// One phase's load shape, in multiples of the fleet's *modeled*
/// throughput — scenarios are written against capacity, not absolute
/// rates, so one file exercises any fleet composition the same way.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LoadSpec {
    Constant { rate_x: f64 },
    Ramp { from_x: f64, to_x: f64 },
    Spike { base_x: f64, spike_x: f64, start_frac: f64, end_frac: f64 },
    Bursts { base_x: f64, burst_x: f64, every: usize, len: usize },
}

impl LoadSpec {
    /// Resolve the relative shape against a concrete modeled fleet rate.
    pub fn resolve(&self, fleet_img_s: f64) -> LoadProfile {
        let r = fleet_img_s;
        match *self {
            LoadSpec::Constant { rate_x } => LoadProfile::Constant { img_s: rate_x * r },
            LoadSpec::Ramp { from_x, to_x } => {
                LoadProfile::Ramp { from_img_s: from_x * r, to_img_s: to_x * r }
            }
            LoadSpec::Spike { base_x, spike_x, start_frac, end_frac } => LoadProfile::Spike {
                base_img_s: base_x * r,
                spike_img_s: spike_x * r,
                start_frac,
                end_frac,
            },
            LoadSpec::Bursts { base_x, burst_x, every, len } => LoadProfile::Bursts {
                base_img_s: base_x * r,
                burst_img_s: burst_x * r,
                every,
                len,
            },
        }
    }
}

/// A phase's pass/fail bars. Absent bars are not checked; `zero_drops`
/// defaults to *on* — an admitted request silently vanishing is the one
/// failure mode no scenario should ever tolerate implicitly.
#[derive(Debug, Clone, Copy)]
pub struct PhaseAsserts {
    /// Max % of this phase's arrivals shed at admission.
    pub max_shed_pct: Option<f64>,
    /// Max fleet p99 (ms) over completions inside the phase's window.
    pub p99_ms_max: Option<f64>,
    /// Max per-tenant p99 (ms): the *worst* tenant's p99 over the
    /// phase's window must sit under this bar (falls back to the fleet
    /// p99 in untenanted scenarios). The "no tenant starves" check.
    pub tenant_p99_ms_max: Option<f64>,
    /// Max recovery time (ms) for every fault injected in this phase.
    pub recovery_ms_max: Option<f64>,
    /// Admitted requests of this phase must all complete (default true).
    pub zero_drops: bool,
}

/// One scenario phase: a load profile, scheduled faults, assertions.
#[derive(Debug, Clone)]
pub struct ScenarioPhase {
    pub name: String,
    pub requests: usize,
    /// Optional explicit start (seconds from run start). Must not fall
    /// before the previous phase's arrivals end; omitted = back-to-back.
    pub start_s: Option<f64>,
    pub load: LoadSpec,
    /// Tenant traffic mix for this phase: one positive weight per
    /// tenant, driving the deterministic weighted round-robin that
    /// assigns arrivals to tenants. `None` = equal shares. Only valid
    /// when the scenario declares tenants.
    pub mix: Option<Vec<f64>>,
    pub faults: Vec<FaultSpec>,
    pub asserts: PhaseAsserts,
}

/// One tenant of a multi-tenant scenario: a name, the model its
/// requests target, and its weighted-fair admission/service quota —
/// mirroring the live scheduler's `(tenant, model)` routing table.
#[derive(Debug, Clone)]
pub struct ScenarioTenant {
    pub name: String,
    /// Model name; defaults to the scenario-level `model`.
    pub model: String,
    /// Relative quota (> 0). Admission caps and dequeue shares are
    /// proportional to quota, exactly as in the live scheduler.
    pub quota: f64,
    /// Advisory p99 SLO carried into the metrics roster (reports only).
    pub p99_slo_ms: Option<f64>,
}

/// A parsed scenario file.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub description: String,
    /// Fleet spec string (`"zcu104:2,zu5ev"`) resolved by the CLI
    /// against the device catalog.
    pub devices: String,
    /// Model name (resolved by the CLI against the model registry).
    /// Multi-model scenarios list per-tenant models in `tenants`; this
    /// stays the default for tenants that omit one.
    pub model: String,
    /// Tenant roster; empty = classic single-tenant scenario.
    pub tenants: Vec<ScenarioTenant>,
    pub queue_depth: usize,
    pub max_batch: usize,
    /// Completion-count tail the recovery envelope and the recovery
    /// p99 observations are measured over. Counting completions (not
    /// wall time) keeps the signal identical across fleet speeds and
    /// quick-mode request scaling. Default 64.
    pub recovery_tail: usize,
    pub phases: Vec<ScenarioPhase>,
}

fn bad(msg: impl Into<String>) -> String {
    msg.into()
}

impl Scenario {
    /// Parse a scenario from JSON source (see DESIGN.md §Fault model &
    /// scenario schema for the grammar). Errors name the offending
    /// field; a malformed document fails with the JSON parser's
    /// byte-position error.
    #[allow(clippy::should_implement_trait)] // inherent for call-site clarity
    pub fn from_str(src: &str) -> Result<Scenario, String> {
        let v = Json::parse(src).map_err(|e| format!("scenario JSON: {e}"))?;
        Scenario::parse(&v)
    }

    /// Parse a scenario from an already-parsed JSON document.
    pub fn parse(v: &Json) -> Result<Scenario, String> {
        let name = v.get("name").and_then(Json::as_str).map_err(|e| bad(format!("name: {e}")))?;
        let description =
            v.get_str_or("description", "").map_err(|e| bad(format!("description: {e}")))?;
        let devices =
            v.get("devices").and_then(Json::as_str).map_err(|e| bad(format!("devices: {e}")))?;
        let model = v.get_str_or("model", "lenet-tiny").map_err(|e| bad(format!("model: {e}")))?;
        let tenants = match v.get_opt("tenants").map_err(|e| bad(format!("tenants: {e}")))? {
            None => Vec::new(),
            Some(tv) => {
                let arr = tv.as_arr().map_err(|e| bad(format!("tenants: {e}")))?;
                if arr.is_empty() {
                    return Err(bad("tenants, when given, needs at least one entry"));
                }
                let mut out: Vec<ScenarioTenant> = Vec::with_capacity(arr.len());
                for (i, t) in arr.iter().enumerate() {
                    let tname = t
                        .get("name")
                        .and_then(Json::as_str)
                        .map_err(|e| bad(format!("tenants[{i}] name: {e}")))?;
                    let tmodel = t
                        .get_str_or("model", &model)
                        .map_err(|e| bad(format!("tenants[{i}] model: {e}")))?;
                    let quota = t
                        .get_f64_or("quota", 1.0)
                        .map_err(|e| bad(format!("tenants[{i}] quota: {e}")))?;
                    if !(quota > 0.0) {
                        return Err(bad(format!(
                            "tenants[{i}] '{tname}': quota must be positive"
                        )));
                    }
                    let p99_slo_ms = opt_f64(t, "p99_slo_ms")
                        .map_err(|e| bad(format!("tenants[{i}] p99_slo_ms: {e}")))?;
                    if out.iter().any(|o| o.name == tname) {
                        return Err(bad(format!(
                            "tenants[{i}]: duplicate tenant name '{tname}'"
                        )));
                    }
                    out.push(ScenarioTenant {
                        name: tname.to_string(),
                        model: tmodel,
                        quota,
                        p99_slo_ms,
                    });
                }
                out
            }
        };
        let queue_depth =
            v.get_usize_or("queue_depth", 64).map_err(|e| bad(format!("queue_depth: {e}")))?;
        let max_batch =
            v.get_usize_or("max_batch", 8).map_err(|e| bad(format!("max_batch: {e}")))?;
        let recovery_tail =
            v.get_usize_or("recovery_tail", 64).map_err(|e| bad(format!("recovery_tail: {e}")))?;
        if recovery_tail == 0 {
            return Err(bad("recovery_tail must be at least 1"));
        }
        let phases_v =
            v.get("phases").and_then(Json::as_arr).map_err(|e| bad(format!("phases: {e}")))?;
        if phases_v.is_empty() {
            return Err(bad("a scenario needs at least one phase"));
        }
        let mut phases = Vec::with_capacity(phases_v.len());
        let mut last_start: Option<f64> = None;
        for (i, pv) in phases_v.iter().enumerate() {
            let phase = parse_phase(pv, i, tenants.len())?;
            if let (Some(prev), Some(cur)) = (last_start, phase.start_s) {
                if cur <= prev {
                    return Err(bad(format!(
                        "phase '{}': overlapping phases — start_s {cur} is not after the \
                         previous phase's start_s {prev}",
                        phase.name
                    )));
                }
            }
            if phase.start_s.is_some() {
                last_start = phase.start_s;
            }
            phases.push(phase);
        }
        Ok(Scenario {
            name: name.to_string(),
            description,
            devices: devices.to_string(),
            model,
            tenants,
            queue_depth: queue_depth.max(1),
            max_batch: max_batch.max(1),
            recovery_tail,
            phases,
        })
    }
}

fn parse_phase(v: &Json, idx: usize, n_tenants: usize) -> Result<ScenarioPhase, String> {
    let name = v.get_str_or("name", &format!("phase{idx}")).map_err(|e| bad(e.to_string()))?;
    let ctx = |e: &dyn std::fmt::Display, field: &str| format!("phase '{name}' {field}: {e}");
    let requests = v.get("requests").and_then(Json::as_usize).map_err(|e| ctx(&e, "requests"))?;
    if requests == 0 {
        return Err(bad(format!("phase '{name}': zero requests")));
    }
    let start_s = match v.get_opt("start_s").map_err(|e| ctx(&e, "start_s"))? {
        Some(j) => Some(j.as_f64().map_err(|e| ctx(&e, "start_s"))?),
        None => None,
    };
    let load = parse_load(v.get("load").map_err(|e| ctx(&e, "load"))?, &name)?;
    let mix = match v.get_opt("mix").map_err(|e| ctx(&e, "mix"))? {
        None => None,
        Some(mv) => {
            let arr = mv.as_arr().map_err(|e| ctx(&e, "mix"))?;
            if n_tenants == 0 {
                return Err(bad(format!(
                    "phase '{name}': mix requires a top-level tenants list"
                )));
            }
            if arr.len() != n_tenants {
                return Err(bad(format!(
                    "phase '{name}': mix has {} weights for {n_tenants} tenants",
                    arr.len()
                )));
            }
            let mut ws = Vec::with_capacity(arr.len());
            for w in arr {
                let w = w.as_f64().map_err(|e| ctx(&e, "mix"))?;
                if !(w > 0.0) {
                    return Err(bad(format!("phase '{name}': mix weights must be positive")));
                }
                ws.push(w);
            }
            Some(ws)
        }
    };
    let mut faults = Vec::new();
    if let Some(fv) = v.get_opt("faults").map_err(|e| ctx(&e, "faults"))? {
        for f in fv.as_arr().map_err(|e| ctx(&e, "faults"))? {
            faults.push(parse_fault(f, &name)?);
        }
    }
    let asserts = match v.get_opt("asserts").map_err(|e| ctx(&e, "asserts"))? {
        Some(a) => PhaseAsserts {
            max_shed_pct: opt_f64(a, "max_shed_pct").map_err(|e| ctx(&e, "asserts"))?,
            p99_ms_max: opt_f64(a, "p99_ms_max").map_err(|e| ctx(&e, "asserts"))?,
            tenant_p99_ms_max: opt_f64(a, "tenant_p99_ms_max")
                .map_err(|e| ctx(&e, "asserts"))?,
            recovery_ms_max: opt_f64(a, "recovery_ms_max").map_err(|e| ctx(&e, "asserts"))?,
            zero_drops: a.get_bool_or("zero_drops", true).map_err(|e| ctx(&e, "asserts"))?,
        },
        None => PhaseAsserts {
            max_shed_pct: None,
            p99_ms_max: None,
            tenant_p99_ms_max: None,
            recovery_ms_max: None,
            zero_drops: true,
        },
    };
    Ok(ScenarioPhase { name, requests, start_s, load, mix, faults, asserts })
}

fn opt_f64(v: &Json, key: &str) -> Result<Option<f64>, crate::util::json::JsonError> {
    v.get_opt(key)?.map(Json::as_f64).transpose()
}

fn parse_load(v: &Json, phase: &str) -> Result<LoadSpec, String> {
    let ctx = |e: &dyn std::fmt::Display| format!("phase '{phase}' load: {e}");
    let profile = v.get("profile").and_then(Json::as_str).map_err(|e| ctx(&e))?;
    let spec = match profile {
        "constant" => LoadSpec::Constant {
            rate_x: v.get("rate_x").and_then(Json::as_f64).map_err(|e| ctx(&e))?,
        },
        "ramp" => LoadSpec::Ramp {
            from_x: v.get("from_x").and_then(Json::as_f64).map_err(|e| ctx(&e))?,
            to_x: v.get("to_x").and_then(Json::as_f64).map_err(|e| ctx(&e))?,
        },
        "spike" => LoadSpec::Spike {
            base_x: v.get("base_x").and_then(Json::as_f64).map_err(|e| ctx(&e))?,
            spike_x: v.get("spike_x").and_then(Json::as_f64).map_err(|e| ctx(&e))?,
            start_frac: v.get_f64_or("start_frac", 0.4).map_err(|e| ctx(&e))?,
            end_frac: v.get_f64_or("end_frac", 0.6).map_err(|e| ctx(&e))?,
        },
        "bursts" => LoadSpec::Bursts {
            base_x: v.get("base_x").and_then(Json::as_f64).map_err(|e| ctx(&e))?,
            burst_x: v.get("burst_x").and_then(Json::as_f64).map_err(|e| ctx(&e))?,
            every: v.get_usize_or("every", 32).map_err(|e| ctx(&e))?,
            len: v.get_usize_or("len", 8).map_err(|e| ctx(&e))?,
        },
        other => {
            return Err(bad(format!(
                "phase '{phase}' load: unknown load profile '{other}' \
                 (expected constant|ramp|spike|bursts)"
            )))
        }
    };
    let rates_ok = match spec {
        LoadSpec::Constant { rate_x } => rate_x > 0.0,
        LoadSpec::Ramp { from_x, to_x } => from_x > 0.0 && to_x > 0.0,
        LoadSpec::Spike { base_x, spike_x, start_frac, end_frac } => {
            base_x > 0.0
                && spike_x > 0.0
                && (0.0..=1.0).contains(&start_frac)
                && end_frac > start_frac
        }
        LoadSpec::Bursts { base_x, burst_x, every, .. } => {
            base_x > 0.0 && burst_x > 0.0 && every > 0
        }
    };
    if !rates_ok {
        return Err(bad(format!(
            "phase '{phase}' load: rates must be positive (and spike window well-formed)"
        )));
    }
    Ok(spec)
}

fn parse_fault(v: &Json, phase: &str) -> Result<FaultSpec, String> {
    let ctx = |e: &dyn std::fmt::Display| format!("phase '{phase}' fault: {e}");
    let at_frac = v.get("at_frac").and_then(Json::as_f64).map_err(|e| ctx(&e))?;
    if !(0.0..=1.0).contains(&at_frac) {
        return Err(bad(format!("phase '{phase}' fault: at_frac {at_frac} outside [0, 1]")));
    }
    let kind_s = v.get("kind").and_then(Json::as_str).map_err(|e| ctx(&e))?;
    let group = v.get_usize_or("group", 0).map_err(|e| ctx(&e))?;
    let kind = match kind_s {
        "replica_death" => FaultKind::ReplicaDeath { group },
        "group_loss" => FaultKind::GroupLoss { group },
        "latency_degrade" => {
            let factor = v.get_f64_or("factor", 4.0).map_err(|e| ctx(&e))?;
            let duration_ms = v.get_f64_or("duration_ms", 200.0).map_err(|e| ctx(&e))?;
            let well_formed = factor > 1.0 && duration_ms > 0.0;
            if !well_formed {
                return Err(bad(format!(
                    "phase '{phase}' fault: latency_degrade needs factor > 1 and \
                     duration_ms > 0"
                )));
            }
            FaultKind::LatencyDegrade {
                group,
                factor,
                duration: Duration::from_secs_f64(duration_ms / 1e3),
            }
        }
        other => {
            return Err(bad(format!(
                "phase '{phase}' fault: unknown fault kind '{other}' \
                 (expected replica_death|group_loss|latency_degrade)"
            )))
        }
    };
    Ok(FaultSpec { at_frac, kind })
}

// ---------------------------------------------------------------------------
// The virtual-time engine.

/// Engine options.
#[derive(Debug, Clone)]
pub struct ScenarioOpts {
    pub seed: u64,
    /// Quick mode (CI): scale every phase's request count down (shape
    /// preserved — profiles are functions of arrival *fraction*).
    pub quick: bool,
    /// Trace sink; phase spans land on [`trace::PID_SCENARIO`], fault
    /// instants on the group control tracks.
    pub tracer: Tracer,
}

impl Default for ScenarioOpts {
    fn default() -> ScenarioOpts {
        ScenarioOpts { seed: 7, quick: false, tracer: Tracer::off() }
    }
}

/// One assertion's outcome inside a phase verdict.
#[derive(Debug, Clone)]
pub struct CheckResult {
    pub name: String,
    /// The configured bar.
    pub limit: f64,
    /// The observed value (`-1` for a never-recovered recovery check).
    pub actual: f64,
    pub passed: bool,
}

/// One phase's verdict.
#[derive(Debug, Clone)]
pub struct PhaseVerdict {
    pub name: String,
    /// Arrivals offered in this phase (after quick-mode scaling).
    pub requests: usize,
    pub accepted: u64,
    pub shed: u64,
    pub shed_pct: f64,
    /// Admitted-in-phase requests that never completed (fleet loss).
    pub drops: u64,
    /// Completions inside the phase's time window (admissions from a
    /// previous phase completing here count here — completion-time
    /// attribution, matching the latency reservoir's view).
    pub completed: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Per-tenant cut of this phase (empty for untenanted scenarios).
    pub tenants: Vec<TenantPhaseVerdict>,
    pub checks: Vec<CheckResult>,
    pub passed: bool,
}

/// One tenant's slice of a phase verdict.
#[derive(Debug, Clone)]
pub struct TenantPhaseVerdict {
    pub name: String,
    pub model: String,
    /// Arrivals assigned to this tenant in the phase.
    pub offered: u64,
    pub accepted: u64,
    pub shed: u64,
    pub shed_pct: f64,
    /// This tenant's completions inside the phase's time window.
    pub completed: u64,
    pub p99_ms: f64,
}

/// One injected fault's outcome.
#[derive(Debug, Clone)]
pub struct FaultOutcome {
    /// Injection instant, ms from run start.
    pub at_ms: f64,
    /// Phase index the fault belonged to.
    pub phase: usize,
    /// Fault kind name (`replica_death` | `group_loss` | `latency_degrade`).
    pub kind: String,
    /// Target device group.
    pub group: usize,
    pub detail: String,
    /// Recovery time in ms ([`RecoveryTracker`] semantics); `None` if
    /// the fleet never returned under its pre-fault envelope.
    pub recovery_ms: Option<f64>,
    pub recovered: bool,
}

/// The full scenario verdict — what `acf serve --scenario` prints and
/// what [`ScenarioReport::to_json`] serializes byte-identically for a
/// given scenario + seed.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    pub name: String,
    pub seed: u64,
    pub quick: bool,
    /// Modeled fleet throughput the load multipliers resolved against.
    pub fleet_img_s: f64,
    pub phases: Vec<PhaseVerdict>,
    pub faults: Vec<FaultOutcome>,
    /// Total admitted-but-never-completed requests.
    pub drops: u64,
    /// Whether the fleet lost its last live replica at any point.
    pub fleet_lost: bool,
    pub passed: bool,
}

/// Round for the verdict JSON: three decimals is far above the noise
/// floor of any modeled quantity and keeps the report byte-stable.
fn r3(v: f64) -> f64 {
    (v * 1e3).round() / 1e3
}

impl ScenarioReport {
    /// Deterministic JSON (sorted keys via [`Json::dump`], all floats
    /// rounded to 3 decimals): same scenario + seed ⇒ identical bytes.
    pub fn to_json(&self) -> Json {
        let phases: Vec<Json> = self
            .phases
            .iter()
            .map(|p| {
                let checks: Vec<Json> = p
                    .checks
                    .iter()
                    .map(|c| {
                        crate::util::json::obj([
                            ("name", Json::Str(c.name.clone())),
                            ("limit", Json::Num(r3(c.limit))),
                            ("actual", Json::Num(r3(c.actual))),
                            ("passed", Json::Bool(c.passed)),
                        ])
                    })
                    .collect();
                let mut pj = crate::util::json::obj([
                    ("name", Json::Str(p.name.clone())),
                    ("requests", Json::Num(p.requests as f64)),
                    ("accepted", Json::Num(p.accepted as f64)),
                    ("shed", Json::Num(p.shed as f64)),
                    ("shed_pct", Json::Num(r3(p.shed_pct))),
                    ("drops", Json::Num(p.drops as f64)),
                    ("completed", Json::Num(p.completed as f64)),
                    ("p50_ms", Json::Num(r3(p.p50_ms))),
                    ("p99_ms", Json::Num(r3(p.p99_ms))),
                    ("checks", Json::Arr(checks)),
                    ("passed", Json::Bool(p.passed)),
                ]);
                // The per-tenant block only exists for tenanted
                // scenarios — untenanted reports keep their exact
                // pre-multi-tenant byte layout.
                if !p.tenants.is_empty() {
                    let tv: Vec<Json> = p
                        .tenants
                        .iter()
                        .map(|t| {
                            crate::util::json::obj([
                                ("name", Json::Str(t.name.clone())),
                                ("model", Json::Str(t.model.clone())),
                                ("offered", Json::Num(t.offered as f64)),
                                ("accepted", Json::Num(t.accepted as f64)),
                                ("shed", Json::Num(t.shed as f64)),
                                ("shed_pct", Json::Num(r3(t.shed_pct))),
                                ("completed", Json::Num(t.completed as f64)),
                                ("p99_ms", Json::Num(r3(t.p99_ms))),
                            ])
                        })
                        .collect();
                    if let Json::Obj(m) = &mut pj {
                        m.insert("tenants".to_string(), Json::Arr(tv));
                    }
                }
                pj
            })
            .collect();
        let faults: Vec<Json> = self
            .faults
            .iter()
            .map(|f| {
                crate::util::json::obj([
                    ("at_ms", Json::Num(r3(f.at_ms))),
                    ("phase", Json::Num(f.phase as f64)),
                    ("kind", Json::Str(f.kind.clone())),
                    ("group", Json::Num(f.group as f64)),
                    ("detail", Json::Str(f.detail.clone())),
                    (
                        "recovery_ms",
                        f.recovery_ms.map(|v| Json::Num(r3(v))).unwrap_or(Json::Null),
                    ),
                    ("recovered", Json::Bool(f.recovered)),
                ])
            })
            .collect();
        crate::util::json::obj([
            ("name", Json::Str(self.name.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("quick", Json::Bool(self.quick)),
            ("fleet_img_s", Json::Num(r3(self.fleet_img_s))),
            ("phases", Json::Arr(phases)),
            ("faults", Json::Arr(faults)),
            ("drops", Json::Num(self.drops as f64)),
            ("fleet_lost", Json::Bool(self.fleet_lost)),
            ("passed", Json::Bool(self.passed)),
        ])
    }
}

/// Quick-mode request scaling: a quarter of the configured count,
/// floored so tiny phases keep enough arrivals to exercise their shape.
pub fn quick_requests(requests: usize) -> usize {
    (requests / 4).max(24).min(requests)
}

/// Run `scenario` against the modeled `fleet` plan. Deterministic for a
/// given (scenario, fleet, seed): the engine is a single-threaded
/// discrete-event simulation in virtual time — see the module docs.
pub fn run_scenario(
    scenario: &Scenario,
    fleet: &FleetPlan,
    opts: &ScenarioOpts,
) -> Result<ScenarioReport, String> {
    let groups: Vec<SimGroup> = fleet
        .groups
        .iter()
        .map(|g| SimGroup {
            label: g.device.name.clone(),
            replicas: g.replicas,
            rate: g.per_replica.images_per_sec,
            model: fleet
                .models
                .get(g.model_id)
                .map(|m| m.name.clone())
                .unwrap_or_default(),
        })
        .collect();
    run_modeled(scenario, &groups, fleet.fleet_img_s, opts)
}

/// One device group of the modeled fleet (decoupled from [`FleetPlan`]
/// so the engine is unit-testable without running the planner).
#[derive(Debug, Clone)]
pub struct SimGroup {
    pub label: String,
    pub replicas: usize,
    /// Modeled per-replica service rate (img/s).
    pub rate: f64,
    /// Name of the model this group's replicas serve. Matched against
    /// tenant routes in multi-tenant scenarios; ignored otherwise.
    pub model: String,
}

/// A replica of the simulated fleet.
struct SimReplica {
    group: usize,
    /// Modeled service rate (img/s).
    rate: f64,
    /// Per-dispatch micro-batch clamp (scheduler scaling rule).
    clamp: usize,
    /// Index into the engine's route table of the model this replica
    /// serves (0 for untenanted scenarios' single implicit model).
    model: usize,
    alive: bool,
    /// When the in-flight batch completes (`None` = idle).
    busy_until: Option<u64>,
    /// `(admission timestamp, tenant)` of the in-flight batch's requests.
    batch: Vec<(u64, usize)>,
    /// When the in-flight batch was dispatched.
    batch_start: u64,
    /// Latency-degradation state: service times multiply by
    /// `degrade_factor` until `degrade_until`.
    degrade_factor: f64,
    degrade_until: Option<u64>,
}

/// Event classes, in tie-break priority order at equal timestamps:
/// completions free capacity before new work lands; restores and faults
/// apply before the arrival that observes them.
const EV_COMPLETE: u8 = 0;
const EV_RESTORE: u8 = 1;
const EV_FAULT: u8 = 2;
const EV_ARRIVAL: u8 = 3;

struct ScheduledFault {
    at_nanos: u64,
    phase: usize,
    kind: FaultKind,
}

fn secs_to_nanos(s: f64) -> u64 {
    (s * 1e9).round() as u64
}

/// The next event as `(time, class, key)` — the minimum over pending
/// completions, degrade expiries, faults, and arrivals, with the class
/// ordering breaking timestamp ties. `None` when the run is over.
fn next_event(
    reps: &[SimReplica],
    faults: &[ScheduledFault],
    next_fault: usize,
    arrivals: &[(u64, usize, usize)],
    next_arrival: usize,
) -> Option<(u64, u8, usize)> {
    let mut next: Option<(u64, u8, usize)> = None;
    let mut consider = |cand: (u64, u8, usize)| {
        if next.map(|n| cand < n).unwrap_or(true) {
            next = Some(cand);
        }
    };
    for (ri, r) in reps.iter().enumerate() {
        if let Some(t) = r.busy_until {
            consider((t, EV_COMPLETE, ri));
        }
        if r.alive {
            if let Some(t) = r.degrade_until {
                consider((t, EV_RESTORE, ri));
            }
        }
    }
    if next_fault < faults.len() {
        consider((faults[next_fault].at_nanos, EV_FAULT, next_fault));
    }
    if next_arrival < arrivals.len() {
        consider((arrivals[next_arrival].0, EV_ARRIVAL, next_arrival));
    }
    next
}

/// One (tenant, model) route of the simulated scheduler — the same
/// shape the live routing table carves out of the serve config.
struct SimRoute {
    name: String,
    model_name: String,
    /// Index into the engine's model table.
    model: usize,
    quota: f64,
    /// Admission cap: this tenant's quota-share of `queue_depth`.
    cap: usize,
}

/// Fill every idle live replica from the per-tenant queues, mirroring
/// the real scheduler: weighted-fair tenant pick (lowest served/quota
/// first, ties to the lower id), fastest model-compatible replica
/// (ties to the lowest id), batch filled fairly from same-model queues
/// up to the replica's clamp. With one tenant and `model_affine` off
/// this degenerates to the classic single-queue fastest-first fill.
fn dispatch(
    now: u64,
    queues: &mut [VecDeque<(u64, usize)>],
    served: &mut [u64],
    routes: &[SimRoute],
    model_affine: bool,
    reps: &mut [SimReplica],
    metrics: &FleetMetrics,
) {
    loop {
        // Weighted-fair pick among tenants with queued work and a
        // compatible idle replica. `served[a]/quota[a] < served[b]/quota[b]`
        // compared cross-multiplied to stay exact.
        let mut pick: Option<usize> = None;
        for (t, q) in queues.iter().enumerate() {
            if q.is_empty() {
                continue;
            }
            let has_idle = reps.iter().any(|r| {
                r.alive && r.busy_until.is_none() && (!model_affine || r.model == routes[t].model)
            });
            if !has_idle {
                continue;
            }
            let better = match pick {
                None => true,
                Some(p) => {
                    (served[t] as f64) * routes[p].quota < (served[p] as f64) * routes[t].quota
                }
            };
            if better {
                pick = Some(t);
            }
        }
        let Some(t) = pick else { return };
        // Fastest compatible idle replica (ties broken by lowest id).
        let mut best: Option<usize> = None;
        for (ri, r) in reps.iter().enumerate() {
            if !r.alive || r.busy_until.is_some() {
                continue;
            }
            if model_affine && r.model != routes[t].model {
                continue;
            }
            if best.map(|b| r.rate > reps[b].rate).unwrap_or(true) {
                best = Some(ri);
            }
        }
        let Some(ri) = best else { return };
        // Fill the batch weighted-fairly across this model's queues.
        let clamp = reps[ri].clamp;
        let mut batch: Vec<(u64, usize)> = Vec::new();
        while batch.len() < clamp {
            let mut src: Option<usize> = None;
            for (u, q) in queues.iter().enumerate() {
                if q.is_empty() {
                    continue;
                }
                if model_affine && routes[u].model != routes[t].model {
                    continue;
                }
                let better = match src {
                    None => true,
                    Some(p) => {
                        (served[u] as f64) * routes[p].quota
                            < (served[p] as f64) * routes[u].quota
                    }
                };
                if better {
                    src = Some(u);
                }
            }
            let Some(u) = src else { break };
            let (admit, _phase) = queues[u].pop_front().expect("picked queue is non-empty");
            served[u] += 1;
            batch.push((admit, u));
        }
        metrics.note_dispatched(ri, batch.len() as u64);
        let eff_rate = reps[ri].rate / reps[ri].degrade_factor;
        let service_s = batch.len() as f64 / eff_rate;
        reps[ri].busy_until = Some(now + secs_to_nanos(service_s));
        reps[ri].batch = batch;
        reps[ri].batch_start = now;
    }
}

/// Feed one observation to every active recovery tracker: current queue
/// pressure plus the p99 over the last `tail` completions.
fn observe_trackers(
    now: u64,
    queue_len: usize,
    trackers: &mut [(usize, RecoveryTracker)],
    metrics: &FleetMetrics,
    tail: usize,
) {
    if trackers.is_empty() {
        return;
    }
    let p99 = metrics.tail_stats(tail).p99_ms;
    for (_, t) in trackers.iter_mut() {
        t.observe(now, queue_len as u64, p99);
    }
}

/// The engine proper, over synthetic groups (see [`run_scenario`]).
pub fn run_modeled(
    scenario: &Scenario,
    groups: &[SimGroup],
    fleet_img_s: f64,
    opts: &ScenarioOpts,
) -> Result<ScenarioReport, String> {
    if groups.iter().map(|g| g.replicas).sum::<usize>() == 0 {
        return Err("the fleet plan has no replicas".into());
    }
    let has_throughput = fleet_img_s > 0.0; // NaN-safe: NaN fails too
    if !has_throughput {
        return Err("the fleet plan has no modeled throughput".into());
    }
    for ph in &scenario.phases {
        for f in &ph.faults {
            if f.kind.group() >= groups.len() {
                return Err(format!(
                    "phase '{}': fault targets group {} but the fleet has {} groups",
                    ph.name,
                    f.kind.group(),
                    groups.len()
                ));
            }
        }
    }

    // (tenant, model) routing table. Untenanted scenarios get one
    // implicit full-depth route and skip model affinity entirely, which
    // reproduces the classic single-queue engine exactly.
    let multi = !scenario.tenants.is_empty();
    let mut model_names: Vec<String> = Vec::new();
    for g in groups {
        if !model_names.contains(&g.model) {
            model_names.push(g.model.clone());
        }
    }
    let routes: Vec<SimRoute> = if multi {
        let total: f64 = scenario.tenants.iter().map(|t| t.quota).sum();
        let mut routes = Vec::with_capacity(scenario.tenants.len());
        for t in &scenario.tenants {
            let Some(model) = model_names.iter().position(|m| *m == t.model) else {
                return Err(format!(
                    "tenant '{}' routes to model '{}' but no fleet group serves it",
                    t.name, t.model
                ));
            };
            routes.push(SimRoute {
                name: t.name.clone(),
                model_name: t.model.clone(),
                model,
                quota: t.quota,
                cap: ((scenario.queue_depth as f64 * t.quota / total).round() as usize).max(1),
            });
        }
        routes
    } else {
        vec![SimRoute {
            name: "default".into(),
            model_name: scenario.model.clone(),
            model: 0,
            quota: 1.0,
            cap: scenario.queue_depth,
        }]
    };
    let roster: Vec<TenantInfo> = if multi {
        scenario
            .tenants
            .iter()
            .map(|t| TenantInfo {
                name: t.name.clone(),
                model: t.model.clone(),
                quota: t.quota,
                p99_slo_ms: t.p99_slo_ms,
            })
            .collect()
    } else {
        Vec::new()
    };

    let clock = Clock::manual();
    let labels: Vec<String> = groups.iter().map(|g| g.label.clone()).collect();
    let mut replica_groups = Vec::new();
    for (gi, g) in groups.iter().enumerate() {
        replica_groups.extend(std::iter::repeat(gi).take(g.replicas));
    }
    let metrics = FleetMetrics::grouped_with(
        replica_groups.clone(),
        labels,
        clock.clone(),
        opts.tracer.clone(),
    )
    .with_tenants(roster);

    // Replica table, scheduler batch-clamp rule included.
    let global_batch = scenario.max_batch.clamp(1, crate::netlist::sim::LANES);
    let top_rate =
        groups.iter().filter(|g| g.replicas > 0).map(|g| g.rate).fold(f64::MIN, f64::max);
    let mut reps: Vec<SimReplica> = replica_groups
        .iter()
        .map(|&gi| {
            let rate = groups[gi].rate.max(1e-9);
            let clamp =
                ((global_batch as f64 * rate / top_rate).ceil() as usize).clamp(1, global_batch);
            SimReplica {
                group: gi,
                rate,
                clamp,
                model: model_names
                    .iter()
                    .position(|m| *m == groups[gi].model)
                    .expect("every group's model is in the model table"),
                alive: true,
                busy_until: None,
                batch: Vec::new(),
                batch_start: 0,
                degrade_factor: 1.0,
                degrade_until: None,
            }
        })
        .collect();
    // Absolute p99 slack for recovery envelopes: a couple of worst-case
    // batch times on the slowest replica. The modeled fleet's latency
    // quantiles move in whole batch quanta, so a recovered fleet's tail
    // p99 can legitimately sit a few quanta above an envelope captured
    // at a calm instant — the slack keeps that from reading as
    // "never recovered".
    let min_rate = reps.iter().map(|r| r.rate).fold(f64::MAX, f64::min);
    let p99_slack_ms = (2.0 * global_batch as f64 + 4.0) / min_rate * 1e3;

    // Build the arrival timeline and the fault schedule, phase by phase.
    // Each arrival is assigned a tenant by deterministic weighted
    // round-robin over the phase's mix (equal weights by default) —
    // a separate pass that leaves the schedule's rng stream untouched,
    // so untenanted scenarios keep their exact arrival times.
    let n_tenants = routes.len();
    let mut arrivals: Vec<(u64, usize, usize)> = Vec::new(); // (due_nanos, phase, tenant)
    let mut faults: Vec<ScheduledFault> = Vec::new();
    let mut phase_start = Vec::with_capacity(scenario.phases.len());
    let mut phase_requests = Vec::with_capacity(scenario.phases.len());
    let mut base_s = 0.0f64;
    for (k, ph) in scenario.phases.iter().enumerate() {
        if let Some(s) = ph.start_s {
            if s < base_s {
                return Err(format!(
                    "phase '{}': overlapping phases — start_s {s:.3} falls before the \
                     previous phase's arrivals end at {base_s:.3}s",
                    ph.name
                ));
            }
            base_s = s;
        }
        let requests = if opts.quick { quick_requests(ph.requests) } else { ph.requests };
        let profile = ph.load.resolve(fleet_img_s);
        let schedule = profile_schedule(1, requests, &profile, phase_seed(opts.seed, k));
        let span_s = schedule.last().map(|&(at, _)| at).unwrap_or(0.0);
        phase_start.push(secs_to_nanos(base_s));
        phase_requests.push(requests);
        let weights: Vec<f64> =
            ph.mix.clone().unwrap_or_else(|| vec![1.0; n_tenants]);
        let total_w: f64 = weights.iter().sum();
        let mut credits = vec![0.0f64; n_tenants];
        for &(at, _) in &schedule {
            let mut tn = 0usize;
            for t in 0..n_tenants {
                credits[t] += weights[t];
            }
            for t in 1..n_tenants {
                if credits[t] > credits[tn] {
                    tn = t;
                }
            }
            credits[tn] -= total_w;
            arrivals.push((secs_to_nanos(base_s + at), k, tn));
        }
        for f in &ph.faults {
            faults.push(ScheduledFault {
                at_nanos: secs_to_nanos(base_s + f.at_frac * span_s),
                phase: k,
                kind: f.kind.clone(),
            });
        }
        base_s += span_s;
    }
    faults.sort_by_key(|f| f.at_nanos);

    // Per-phase, per-tenant books.
    let n_phases = scenario.phases.len();
    let mut accepted = vec![vec![0u64; n_tenants]; n_phases];
    let mut shed = vec![vec![0u64; n_tenants]; n_phases];
    let mut drops = vec![vec![0u64; n_tenants]; n_phases];

    // Engine state: one queue per tenant (untenanted = one queue), plus
    // the weighted-fair served counters the dequeue order feeds on.
    let mut queues: Vec<VecDeque<(u64, usize)>> = // (admit_nanos, phase)
        (0..n_tenants).map(|_| VecDeque::new()).collect();
    let mut served = vec![0u64; n_tenants];
    let mut next_arrival = 0usize;
    let mut next_fault = 0usize;
    let mut trackers: Vec<(usize, RecoveryTracker)> = Vec::new(); // (outcome idx, tracker)
    let mut outcomes: Vec<FaultOutcome> = Vec::new();

    while let Some((t, class, key)) =
        next_event(&reps, &faults, next_fault, &arrivals, next_arrival)
    {
        let now = clock.now_nanos();
        if t > now {
            clock.advance(Duration::from_nanos(t - now));
        }
        let now = clock.now_nanos();

        match class {
            EV_COMPLETE => {
                let ri = key;
                let n = reps[ri].batch.len() as u64;
                let batch = std::mem::take(&mut reps[ri].batch);
                for (admit, tenant) in batch {
                    metrics.note_completed_t(
                        ri,
                        tenant,
                        Duration::from_nanos(now.saturating_sub(admit)),
                    );
                }
                metrics
                    .note_replica_batch(ri, n, Duration::from_nanos(now - reps[ri].batch_start));
                reps[ri].busy_until = None;
                if !reps[ri].alive {
                    // A killed replica's in-flight batch just finished:
                    // its drain is complete.
                    metrics.note_drained(reps[ri].group);
                } else {
                    dispatch(now, &mut queues, &mut served, &routes, multi, &mut reps, &metrics);
                }
            }
            EV_RESTORE => {
                let ri = key;
                reps[ri].degrade_until = None;
                reps[ri].degrade_factor = 1.0;
                metrics.note_fault(FaultEvent {
                    at_secs: 0.0,
                    kind: FaultEventKind::LatencyRestore,
                    group: Some(reps[ri].group),
                    replica: Some(ri),
                    detail: "degradation lifted".into(),
                });
            }
            EV_FAULT => {
                next_fault += 1;
                // Pre-fault envelope, captured immediately before the
                // injection mutates the fleet.
                let env = RecoveryEnvelope {
                    queue_depth: queues.iter().map(|q| q.len() as u64).sum(),
                    p99_ms: metrics.tail_stats(scenario.recovery_tail).p99_ms,
                    p99_slack_ms,
                };
                let f = &faults[key];
                let detail = apply_fault(now, f, &mut reps, &metrics);
                trackers.push((outcomes.len(), RecoveryTracker::new(now, env)));
                outcomes.push(FaultOutcome {
                    at_ms: now as f64 / 1e6,
                    phase: f.phase,
                    kind: f.kind.name().to_string(),
                    group: f.kind.group(),
                    detail,
                    recovery_ms: None,
                    recovered: false,
                });
                // No dispatch here: a fault only ever removes or slows
                // capacity — it cannot free an idle slot.
            }
            EV_ARRIVAL => {
                let (admit, ph, tn) = arrivals[key];
                next_arrival += 1;
                if queues[tn].len() >= routes[tn].cap {
                    metrics.note_rejected_t(tn);
                    shed[ph][tn] += 1;
                } else {
                    metrics.note_accepted_t(tn);
                    accepted[ph][tn] += 1;
                    queues[tn].push_back((admit, ph));
                    dispatch(now, &mut queues, &mut served, &routes, multi, &mut reps, &metrics);
                }
            }
            _ => unreachable!(),
        }
        let queued: usize = queues.iter().map(|q| q.len()).sum();
        observe_trackers(now, queued, &mut trackers, &metrics, scenario.recovery_tail);

        // No live replicas and nothing in flight: the queue can never
        // drain again. Resolve the rest of the arrival schedule through
        // the admission books (the frozen queue still sheds once full)
        // and stop simulating.
        if reps.iter().all(|r| !r.alive && r.busy_until.is_none()) {
            while next_arrival < arrivals.len() {
                let (admit, ph, tn) = arrivals[next_arrival];
                next_arrival += 1;
                if queues[tn].len() >= routes[tn].cap {
                    metrics.note_rejected_t(tn);
                    shed[ph][tn] += 1;
                } else {
                    metrics.note_accepted_t(tn);
                    accepted[ph][tn] += 1;
                    queues[tn].push_back((admit, ph));
                }
            }
            next_fault = faults.len();
            break;
        }
    }

    // End of run: whatever is still queued was admitted and will never
    // complete — a drop, the cardinal sin. Attribute by arrival phase
    // and tenant.
    let leftover: u64 = queues.iter().map(|q| q.len() as u64).sum();
    for (tn, q) in queues.iter_mut().enumerate() {
        for (_, ph) in q.drain(..) {
            drops[ph][tn] += 1;
            metrics.note_failed();
        }
    }
    if leftover > 0 {
        metrics.note_abandoned(leftover);
    }
    for (oi, tr) in trackers.iter_mut() {
        tr.finish();
        outcomes[*oi].recovery_ms = tr.recovery_ms();
        outcomes[*oi].recovered = tr.recovery_ms().is_some();
    }

    // Phase spans on the scenario track (arrival windows).
    if opts.tracer.on() {
        for (k, ph) in scenario.phases.iter().enumerate() {
            let end = phase_start.get(k + 1).copied().unwrap_or_else(|| clock.now_nanos());
            opts.tracer.span(
                ph.name.clone(),
                "scenario",
                trace::PID_SCENARIO,
                0,
                phase_start[k],
                end,
                Vec::new(),
            );
        }
    }

    // Verdicts.
    let end_nanos = clock.now_nanos();
    let mut verdicts = Vec::with_capacity(n_phases);
    let mut all_passed = true;
    for (k, ph) in scenario.phases.iter().enumerate() {
        let from = phase_start[k];
        let to = phase_start.get(k + 1).copied().unwrap_or(end_nanos.saturating_add(1));
        let stats = metrics.range_stats(from, to);
        let accepted_k: u64 = accepted[k].iter().sum();
        let shed_k: u64 = shed[k].iter().sum();
        let drops_k: u64 = drops[k].iter().sum();
        let offered = phase_requests[k] as u64;
        let shed_pct = if offered > 0 { shed_k as f64 / offered as f64 * 100.0 } else { 0.0 };
        let tenant_cuts: Vec<TenantPhaseVerdict> = if multi {
            routes
                .iter()
                .enumerate()
                .map(|(tn, r)| {
                    let ts = metrics.tenant_range_stats(tn, from, to);
                    let t_offered = accepted[k][tn] + shed[k][tn];
                    let t_shed_pct = if t_offered > 0 {
                        shed[k][tn] as f64 / t_offered as f64 * 100.0
                    } else {
                        0.0
                    };
                    TenantPhaseVerdict {
                        name: r.name.clone(),
                        model: r.model_name.clone(),
                        offered: t_offered,
                        accepted: accepted[k][tn],
                        shed: shed[k][tn],
                        shed_pct: t_shed_pct,
                        completed: ts.completed,
                        p99_ms: ts.p99_ms,
                    }
                })
                .collect()
        } else {
            Vec::new()
        };
        let mut checks = Vec::new();
        if let Some(bar) = ph.asserts.max_shed_pct {
            checks.push(CheckResult {
                name: "max_shed_pct".into(),
                limit: bar,
                actual: shed_pct,
                passed: shed_pct <= bar,
            });
        }
        if let Some(bar) = ph.asserts.p99_ms_max {
            checks.push(CheckResult {
                name: "p99_ms_max".into(),
                limit: bar,
                actual: stats.p99_ms,
                passed: stats.p99_ms <= bar,
            });
        }
        if let Some(bar) = ph.asserts.tenant_p99_ms_max {
            // The *worst* tenant's p99 — the "no tenant starves" bar.
            let actual = if multi {
                tenant_cuts.iter().map(|t| t.p99_ms).fold(0.0f64, f64::max)
            } else {
                stats.p99_ms
            };
            checks.push(CheckResult {
                name: "tenant_p99_ms_max".into(),
                limit: bar,
                actual,
                passed: actual <= bar,
            });
        }
        if let Some(bar) = ph.asserts.recovery_ms_max {
            let unrecovered = outcomes.iter().any(|o| o.phase == k && !o.recovered);
            let worst = outcomes
                .iter()
                .filter(|o| o.phase == k)
                .filter_map(|o| o.recovery_ms)
                .fold(0.0f64, f64::max);
            checks.push(CheckResult {
                name: "recovery_ms_max".into(),
                limit: bar,
                actual: if unrecovered { -1.0 } else { worst },
                passed: !unrecovered && worst <= bar,
            });
        }
        if ph.asserts.zero_drops {
            checks.push(CheckResult {
                name: "zero_drops".into(),
                limit: 0.0,
                actual: drops_k as f64,
                passed: drops_k == 0,
            });
        }
        let passed = checks.iter().all(|c| c.passed);
        all_passed &= passed;
        verdicts.push(PhaseVerdict {
            name: ph.name.clone(),
            requests: phase_requests[k],
            accepted: accepted_k,
            shed: shed_k,
            shed_pct,
            drops: drops_k,
            completed: stats.completed,
            p50_ms: stats.p50_ms,
            p99_ms: stats.p99_ms,
            tenants: tenant_cuts,
            checks,
            passed,
        });
    }
    let fleet_lost = metrics.fleet_lost();
    // Losing the whole fleet is a failed scenario even if every
    // configured bar happens to pass (e.g. all drops attributed to a
    // phase with zero_drops disabled).
    let passed = all_passed && !fleet_lost;
    Ok(ScenarioReport {
        name: scenario.name.clone(),
        seed: opts.seed,
        quick: opts.quick,
        fleet_img_s,
        phases: verdicts,
        faults: outcomes,
        drops: drops.iter().flatten().sum(),
        fleet_lost,
        passed,
    })
}

/// Apply one fault to the simulated fleet, recording its event(s) in
/// the metrics fault timeline. Returns the human-readable detail for
/// the [`FaultOutcome`].
fn apply_fault(
    now: u64,
    f: &ScheduledFault,
    reps: &mut [SimReplica],
    metrics: &FleetMetrics,
) -> String {
    let group = f.kind.group();
    // Deterministic victim: the highest-id live replica of the group.
    let victim = |reps: &[SimReplica]| {
        reps.iter()
            .enumerate()
            .rev()
            .find(|(_, r)| r.alive && r.group == group)
            .map(|(ri, _)| ri)
    };
    let kill = |ri: usize, reps: &mut [SimReplica], metrics: &FleetMetrics| {
        reps[ri].alive = false;
        reps[ri].degrade_until = None;
        reps[ri].degrade_factor = 1.0;
        metrics.note_retiring(ri);
        metrics.note_fault(FaultEvent {
            at_secs: 0.0,
            kind: FaultEventKind::ReplicaDeath,
            group: Some(group),
            replica: Some(ri),
            detail: "injected kill (no drain)".into(),
        });
        if reps[ri].busy_until.is_none() {
            // Idle at death: nothing in flight, drain is trivially done.
            metrics.note_drained(group);
        }
    };
    let post_loss = |reps: &[SimReplica], metrics: &FleetMetrics| {
        let survivors = reps.iter().filter(|r| r.alive).count();
        if !reps.iter().any(|r| r.alive && r.group == group) {
            metrics.note_fault(FaultEvent {
                at_secs: 0.0,
                kind: FaultEventKind::GroupLost,
                group: Some(group),
                replica: None,
                detail: format!("group empty; {survivors} fleet survivors"),
            });
        }
        if survivors == 0 {
            metrics.note_fault(FaultEvent {
                at_secs: 0.0,
                kind: FaultEventKind::FleetLost,
                group: None,
                replica: None,
                detail: "no live replicas remain".into(),
            });
        }
        survivors
    };
    match f.kind {
        FaultKind::ReplicaDeath { .. } => match victim(reps) {
            Some(ri) => {
                kill(ri, reps, metrics);
                let survivors = post_loss(reps, metrics);
                format!("killed replica {ri}; {survivors} fleet survivors")
            }
            None => "target group already empty; no-op".to_string(),
        },
        FaultKind::GroupLoss { .. } => {
            let victims: Vec<usize> = reps
                .iter()
                .enumerate()
                .filter(|(_, r)| r.alive && r.group == group)
                .map(|(ri, _)| ri)
                .collect();
            if victims.is_empty() {
                return "target group already empty; no-op".to_string();
            }
            metrics.note_fault(FaultEvent {
                at_secs: 0.0,
                kind: FaultEventKind::GroupLoss,
                group: Some(group),
                replica: None,
                detail: format!("killing {} replicas", victims.len()),
            });
            let n = victims.len();
            for ri in victims {
                kill(ri, reps, metrics);
            }
            let survivors = post_loss(reps, metrics);
            format!("killed {n} replicas; {survivors} fleet survivors")
        }
        FaultKind::LatencyDegrade { factor, duration, .. } => match victim(reps) {
            Some(ri) => {
                reps[ri].degrade_factor = factor;
                reps[ri].degrade_until = Some(now + duration.as_nanos() as u64);
                metrics.note_fault(FaultEvent {
                    at_secs: 0.0,
                    kind: FaultEventKind::LatencyDegrade,
                    group: Some(group),
                    replica: Some(ri),
                    detail: format!(
                        "{factor:.1}x slower for {:.0}ms",
                        duration.as_secs_f64() * 1e3
                    ),
                });
                format!(
                    "replica {ri} degraded {factor:.1}x for {:.0}ms",
                    duration.as_secs_f64() * 1e3
                )
            }
            None => "target group already empty; no-op".to_string(),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SC: &str = r#"{
        "name": "t",
        "devices": "zcu104:2",
        "model": "lenet-tiny",
        "queue_depth": 16,
        "max_batch": 4,
        "recovery_tail": 16,
        "phases": [
            {"name": "warm", "requests": 64,
             "load": {"profile": "constant", "rate_x": 0.4},
             "asserts": {"max_shed_pct": 50.0}},
            {"name": "crunch", "requests": 64,
             "load": {"profile": "spike", "base_x": 0.3, "spike_x": 2.0,
                      "start_frac": 0.3, "end_frac": 0.7},
             "faults": [{"at_frac": 0.5, "kind": "replica_death", "group": 0}],
             "asserts": {"recovery_ms_max": 60000.0}}
        ]
    }"#;

    #[test]
    fn parses_a_full_scenario() {
        let sc = Scenario::from_str(SC).unwrap();
        assert_eq!(sc.name, "t");
        assert_eq!(sc.devices, "zcu104:2");
        assert_eq!(sc.queue_depth, 16);
        assert_eq!(sc.recovery_tail, 16);
        assert_eq!(sc.phases.len(), 2);
        assert_eq!(sc.phases[0].load, LoadSpec::Constant { rate_x: 0.4 });
        assert!(sc.phases[0].asserts.zero_drops, "zero_drops defaults on");
        assert_eq!(sc.phases[0].asserts.max_shed_pct, Some(50.0));
        assert_eq!(sc.phases[1].faults.len(), 1);
        assert_eq!(sc.phases[1].faults[0].kind, FaultKind::ReplicaDeath { group: 0 });
        assert_eq!(sc.phases[1].asserts.recovery_ms_max, Some(60000.0));
    }

    #[test]
    fn parser_rejects_malformed_documents() {
        // Malformed JSON surfaces the parser's error.
        let e = Scenario::from_str("{not json").unwrap_err();
        assert!(e.contains("scenario JSON"), "{e}");
        // Unknown fault kind.
        let e = Scenario::from_str(
            r#"{"name":"x","devices":"zcu104","phases":[
                {"name":"p","requests":8,"load":{"profile":"constant","rate_x":0.5},
                 "faults":[{"at_frac":0.5,"kind":"meteor_strike"}]}]}"#,
        )
        .unwrap_err();
        assert!(e.contains("unknown fault kind 'meteor_strike'"), "{e}");
        // Unknown load profile.
        let e = Scenario::from_str(
            r#"{"name":"x","devices":"zcu104","phases":[
                {"name":"p","requests":8,"load":{"profile":"sawtooth","rate_x":0.5}}]}"#,
        )
        .unwrap_err();
        assert!(e.contains("unknown load profile 'sawtooth'"), "{e}");
        // at_frac out of range.
        let e = Scenario::from_str(
            r#"{"name":"x","devices":"zcu104","phases":[
                {"name":"p","requests":8,"load":{"profile":"constant","rate_x":0.5},
                 "faults":[{"at_frac":1.5,"kind":"replica_death"}]}]}"#,
        )
        .unwrap_err();
        assert!(e.contains("at_frac 1.5 outside"), "{e}");
        // Zero requests.
        let e = Scenario::from_str(
            r#"{"name":"x","devices":"zcu104","phases":[
                {"name":"p","requests":0,"load":{"profile":"constant","rate_x":0.5}}]}"#,
        )
        .unwrap_err();
        assert!(e.contains("zero requests"), "{e}");
        // Overlapping explicit phase starts.
        let e = Scenario::from_str(
            r#"{"name":"x","devices":"zcu104","phases":[
                {"name":"a","requests":8,"start_s":2.0,
                 "load":{"profile":"constant","rate_x":0.5}},
                {"name":"b","requests":8,"start_s":1.0,
                 "load":{"profile":"constant","rate_x":0.5}}]}"#,
        )
        .unwrap_err();
        assert!(e.contains("overlapping phases"), "{e}");
        // Zero recovery tail.
        let e = Scenario::from_str(
            r#"{"name":"x","devices":"zcu104","recovery_tail":0,"phases":[
                {"name":"p","requests":8,"load":{"profile":"constant","rate_x":0.5}}]}"#,
        )
        .unwrap_err();
        assert!(e.contains("recovery_tail"), "{e}");
        // No phases at all.
        let e = Scenario::from_str(r#"{"name":"x","devices":"zcu104","phases":[]}"#).unwrap_err();
        assert!(e.contains("at least one phase"), "{e}");
    }

    fn two_group_fleet() -> Vec<SimGroup> {
        vec![
            SimGroup { label: "fast".into(), replicas: 2, rate: 2000.0, model: String::new() },
            SimGroup { label: "slow".into(), replicas: 1, rate: 800.0, model: String::new() },
        ]
    }

    #[test]
    fn engine_is_bit_deterministic() {
        let sc = Scenario::from_str(SC).unwrap();
        let groups = two_group_fleet();
        let opts = ScenarioOpts { seed: 7, quick: false, tracer: Tracer::off() };
        let a = run_modeled(&sc, &groups, 4800.0, &opts).unwrap();
        let b = run_modeled(&sc, &groups, 4800.0, &opts).unwrap();
        assert_eq!(a.to_json().dump(), b.to_json().dump());
        // A different seed draws a different schedule (almost surely a
        // different report — at minimum the fault lands elsewhere).
        let c = run_modeled(
            &sc,
            &groups,
            4800.0,
            &ScenarioOpts { seed: 8, quick: false, tracer: Tracer::off() },
        )
        .unwrap();
        assert_ne!(a.to_json().dump(), c.to_json().dump());
    }

    #[test]
    fn underloaded_phase_completes_everything() {
        let sc = Scenario::from_str(
            r#"{"name":"x","devices":"d","queue_depth":32,"phases":[
                {"name":"p","requests":200,
                 "load":{"profile":"constant","rate_x":0.5},
                 "asserts":{"max_shed_pct":0.0,"p99_ms_max":100.0}}]}"#,
        )
        .unwrap();
        let groups = vec![SimGroup { label: "g".into(), replicas: 2, rate: 1000.0, model: String::new() }];
        let r = run_modeled(&sc, &groups, 2000.0, &ScenarioOpts::default()).unwrap();
        assert!(r.passed, "{:?}", r.phases[0].checks);
        assert_eq!(r.phases[0].accepted, 200);
        assert_eq!(r.phases[0].shed, 0);
        assert_eq!(r.phases[0].completed, 200);
        assert_eq!(r.drops, 0);
        assert!(!r.fleet_lost);
        assert!(r.phases[0].p99_ms > 0.0);
    }

    #[test]
    fn overload_sheds_instead_of_dropping() {
        // 3x modeled capacity into a shallow queue: plenty of shed, but
        // zero drops — admission control holds the line.
        let sc = Scenario::from_str(
            r#"{"name":"x","devices":"d","queue_depth":8,"phases":[
                {"name":"p","requests":300,
                 "load":{"profile":"constant","rate_x":3.0},
                 "asserts":{"max_shed_pct":90.0}}]}"#,
        )
        .unwrap();
        let groups = vec![SimGroup { label: "g".into(), replicas: 1, rate: 1000.0, model: String::new() }];
        let r = run_modeled(&sc, &groups, 1000.0, &ScenarioOpts::default()).unwrap();
        assert!(r.phases[0].shed > 0, "3x load must shed");
        assert_eq!(r.drops, 0);
        assert_eq!(r.phases[0].accepted + r.phases[0].shed, r.phases[0].requests as u64);
        assert_eq!(r.phases[0].completed, r.phases[0].accepted);
    }

    #[test]
    fn fleet_loss_fails_with_drops_not_a_panic() {
        let sc = Scenario::from_str(
            r#"{"name":"x","devices":"d","queue_depth":16,"phases":[
                {"name":"p","requests":200,
                 "load":{"profile":"constant","rate_x":0.8},
                 "faults":[{"at_frac":0.5,"kind":"group_loss","group":0}]}]}"#,
        )
        .unwrap();
        let groups = vec![SimGroup { label: "g".into(), replicas: 2, rate: 1000.0, model: String::new() }];
        let r = run_modeled(&sc, &groups, 2000.0, &ScenarioOpts::default()).unwrap();
        assert!(!r.passed, "fleet loss must fail the scenario");
        assert!(r.fleet_lost);
        assert!(r.drops > 0, "queued work at fleet loss becomes drops");
        // One injection recorded; the loss cascade (group_lost,
        // fleet_lost) lives on the metrics fault timeline.
        let kinds: Vec<&str> = r.faults.iter().map(|f| f.kind.as_str()).collect();
        assert_eq!(kinds, vec!["group_loss"]);
        assert!(!r.faults[0].recovered, "a dead fleet never recovers");
        // The zero_drops check (default on) is the failing assertion.
        let zd = r.phases[0].checks.iter().find(|c| c.name == "zero_drops").unwrap();
        assert!(!zd.passed);
    }

    #[test]
    fn replica_death_with_headroom_recovers() {
        // Two replicas at 35% fleet load: killing one leaves the
        // survivor at ~70% — the transient drains and the tail p99
        // settles inside the envelope's batch-quantum slack.
        let sc = Scenario::from_str(
            r#"{"name":"x","devices":"d","queue_depth":64,
                "recovery_tail":16,"phases":[
                {"name":"p","requests":400,
                 "load":{"profile":"constant","rate_x":0.35},
                 "faults":[{"at_frac":0.5,"kind":"replica_death","group":0}],
                 "asserts":{"recovery_ms_max":60000.0}}]}"#,
        )
        .unwrap();
        let groups = vec![SimGroup { label: "g".into(), replicas: 2, rate: 1000.0, model: String::new() }];
        let r = run_modeled(&sc, &groups, 2000.0, &ScenarioOpts::default()).unwrap();
        assert!(r.passed, "{:?} {:?}", r.phases[0].checks, r.faults);
        assert_eq!(r.drops, 0);
        assert!(r.faults[0].recovered);
        assert!(r.faults[0].recovery_ms.unwrap() >= 0.0);
        assert!(!r.fleet_lost);
    }

    #[test]
    fn latency_degrade_restores_on_schedule() {
        let sc = Scenario::from_str(
            r#"{"name":"x","devices":"d","queue_depth":64,"phases":[
                {"name":"p","requests":300,
                 "load":{"profile":"constant","rate_x":0.5},
                 "faults":[{"at_frac":0.3,"kind":"latency_degrade","group":0,
                            "factor":6.0,"duration_ms":50.0}]}]}"#,
        )
        .unwrap();
        let groups = vec![SimGroup { label: "g".into(), replicas: 2, rate: 1000.0, model: String::new() }];
        let r = run_modeled(&sc, &groups, 2000.0, &ScenarioOpts::default()).unwrap();
        assert_eq!(r.faults.len(), 1);
        assert_eq!(r.faults[0].kind, "latency_degrade");
        assert_eq!(r.drops, 0);
        assert!(!r.fleet_lost);
    }

    #[test]
    fn quick_mode_scales_requests_down() {
        assert_eq!(quick_requests(400), 100);
        assert_eq!(quick_requests(100), 25);
        assert_eq!(quick_requests(40), 24);
        assert_eq!(quick_requests(10), 10, "never scales up");
        let sc = Scenario::from_str(SC).unwrap();
        let groups = two_group_fleet();
        let r = run_modeled(
            &sc,
            &groups,
            4800.0,
            &ScenarioOpts { seed: 7, quick: true, tracer: Tracer::off() },
        )
        .unwrap();
        assert!(r.quick);
        assert_eq!(r.phases[0].requests, 24);
    }

    #[test]
    fn fault_group_out_of_range_is_a_runtime_error() {
        let sc = Scenario::from_str(
            r#"{"name":"x","devices":"d","phases":[
                {"name":"p","requests":8,"load":{"profile":"constant","rate_x":0.5},
                 "faults":[{"at_frac":0.5,"kind":"replica_death","group":9}]}]}"#,
        )
        .unwrap();
        let groups = vec![SimGroup { label: "g".into(), replicas: 1, rate: 1000.0, model: String::new() }];
        let e = run_modeled(&sc, &groups, 1000.0, &ScenarioOpts::default()).unwrap_err();
        assert!(e.contains("targets group 9"), "{e}");
    }

    #[test]
    fn parses_tenants_mix_and_tenant_p99_assert() {
        let sc = Scenario::from_str(
            r#"{"name":"mt","devices":"zcu104:2","model":"lenet-tiny",
                "tenants":[
                    {"name":"gold","model":"lenet-tiny","quota":3.0,"p99_slo_ms":50.0},
                    {"name":"bronze","quota":1.0}],
                "phases":[{"name":"p","requests":32,"mix":[3.0,1.0],
                           "load":{"profile":"constant","rate_x":0.5},
                           "asserts":{"tenant_p99_ms_max":80.0}}]}"#,
        )
        .unwrap();
        assert_eq!(sc.tenants.len(), 2);
        assert_eq!(sc.tenants[0].name, "gold");
        assert_eq!(sc.tenants[0].quota, 3.0);
        assert_eq!(sc.tenants[0].p99_slo_ms, Some(50.0));
        assert_eq!(sc.tenants[1].model, "lenet-tiny", "tenant model defaults to scenario model");
        assert_eq!(sc.tenants[1].p99_slo_ms, None);
        assert_eq!(sc.phases[0].mix, Some(vec![3.0, 1.0]));
        assert_eq!(sc.phases[0].asserts.tenant_p99_ms_max, Some(80.0));
    }

    #[test]
    fn tenant_parse_rejects_bad_documents() {
        // Non-positive quota.
        let e = Scenario::from_str(
            r#"{"name":"x","devices":"d","tenants":[{"name":"a","quota":0.0}],"phases":[
                {"name":"p","requests":8,"load":{"profile":"constant","rate_x":0.5}}]}"#,
        )
        .unwrap_err();
        assert!(e.contains("quota must be positive"), "{e}");
        // Duplicate tenant names.
        let e = Scenario::from_str(
            r#"{"name":"x","devices":"d",
                "tenants":[{"name":"a"},{"name":"a"}],"phases":[
                {"name":"p","requests":8,"load":{"profile":"constant","rate_x":0.5}}]}"#,
        )
        .unwrap_err();
        assert!(e.contains("duplicate tenant name 'a'"), "{e}");
        // Empty tenants list.
        let e = Scenario::from_str(
            r#"{"name":"x","devices":"d","tenants":[],"phases":[
                {"name":"p","requests":8,"load":{"profile":"constant","rate_x":0.5}}]}"#,
        )
        .unwrap_err();
        assert!(e.contains("at least one entry"), "{e}");
        // Mix without tenants.
        let e = Scenario::from_str(
            r#"{"name":"x","devices":"d","phases":[
                {"name":"p","requests":8,"mix":[1.0],
                 "load":{"profile":"constant","rate_x":0.5}}]}"#,
        )
        .unwrap_err();
        assert!(e.contains("mix requires a top-level tenants list"), "{e}");
        // Mix length mismatch.
        let e = Scenario::from_str(
            r#"{"name":"x","devices":"d","tenants":[{"name":"a"},{"name":"b"}],"phases":[
                {"name":"p","requests":8,"mix":[1.0],
                 "load":{"profile":"constant","rate_x":0.5}}]}"#,
        )
        .unwrap_err();
        assert!(e.contains("mix has 1 weights for 2 tenants"), "{e}");
    }

    #[test]
    fn quota_weighted_admission_sheds_the_small_tenant_harder() {
        // Two tenants at 3:1 quota on one model, equal offered traffic,
        // 3x fleet capacity: weighted-fair service admits ~3:1 and the
        // small tenant sheds a much larger share of its offers.
        let sc = Scenario::from_str(
            r#"{"name":"mt","devices":"d","queue_depth":16,"model":"m0",
                "tenants":[{"name":"gold","quota":3.0},{"name":"bronze","quota":1.0}],
                "phases":[{"name":"crunch","requests":600,
                           "load":{"profile":"constant","rate_x":3.0}}]}"#,
        )
        .unwrap();
        let groups =
            vec![SimGroup { label: "g".into(), replicas: 1, rate: 1000.0, model: "m0".into() }];
        let r = run_modeled(&sc, &groups, 1000.0, &ScenarioOpts::default()).unwrap();
        assert!(r.passed, "{:?}", r.phases[0].checks);
        let t = &r.phases[0].tenants;
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].name, "gold");
        let ratio = t[0].accepted as f64 / t[1].accepted.max(1) as f64;
        assert!(
            (2.2..=3.8).contains(&ratio),
            "accepted ratio {ratio} should track the 3:1 quota ({} vs {})",
            t[0].accepted,
            t[1].accepted
        );
        assert!(
            t[1].shed_pct > t[0].shed_pct,
            "the small tenant sheds harder: {} vs {}",
            t[1].shed_pct,
            t[0].shed_pct
        );
        assert_eq!(r.drops, 0, "quota shed is admission-time, never a drop");
        // Byte-determinism holds with tenants on.
        let r2 = run_modeled(&sc, &groups, 1000.0, &ScenarioOpts::default()).unwrap();
        assert_eq!(r.to_json().dump(), r2.to_json().dump());
        assert!(r.to_json().dump().contains("\"tenants\""));
    }

    #[test]
    fn tenants_only_ride_their_models_groups() {
        // Two models on disjoint groups: the fast group must not absorb
        // the slow model's overload — tenant b sheds while tenant a
        // rides clean, and nothing admitted is dropped.
        let sc = Scenario::from_str(
            r#"{"name":"mm","devices":"d","queue_depth":32,
                "tenants":[{"name":"a","model":"m0","quota":1.0},
                           {"name":"b","model":"m1","quota":1.0}],
                "phases":[{"name":"p","requests":400,
                           "load":{"profile":"constant","rate_x":0.8}}]}"#,
        )
        .unwrap();
        let groups = vec![
            SimGroup { label: "g0".into(), replicas: 1, rate: 1000.0, model: "m0".into() },
            SimGroup { label: "g1".into(), replicas: 1, rate: 100.0, model: "m1".into() },
        ];
        let r = run_modeled(&sc, &groups, 1100.0, &ScenarioOpts::default()).unwrap();
        assert!(r.passed, "{:?}", r.phases[0].checks);
        let t = &r.phases[0].tenants;
        assert_eq!(t[0].shed, 0, "the fast model has 2x headroom for its tenant");
        assert!(t[1].shed > 0, "the slow model drowns under its tenant's half");
        assert_eq!(r.drops, 0);
        assert_eq!(t[0].completed + t[1].completed, t[0].accepted + t[1].accepted);
    }

    #[test]
    fn unserved_tenant_model_is_a_runtime_error() {
        let sc = Scenario::from_str(
            r#"{"name":"x","devices":"d",
                "tenants":[{"name":"a","model":"ghost"}],"phases":[
                {"name":"p","requests":8,"load":{"profile":"constant","rate_x":0.5}}]}"#,
        )
        .unwrap();
        let groups =
            vec![SimGroup { label: "g".into(), replicas: 1, rate: 1000.0, model: "m0".into() }];
        let e = run_modeled(&sc, &groups, 1000.0, &ScenarioOpts::default()).unwrap_err();
        assert!(e.contains("no fleet group serves it"), "{e}");
    }

    #[test]
    fn untenanted_reports_have_no_tenants_key() {
        // The pre-multi-tenant report layout is load-bearing: shipped
        // scenario verdicts must stay byte-identical.
        let sc = Scenario::from_str(SC).unwrap();
        let groups = two_group_fleet();
        let r = run_modeled(&sc, &groups, 4800.0, &ScenarioOpts::default()).unwrap();
        assert!(r.phases.iter().all(|p| p.tenants.is_empty()));
        assert!(!r.to_json().dump().contains("\"tenants\""));
    }

    #[test]
    fn runtime_overlap_check_catches_early_start_s() {
        // Parses fine (start_s values increase) but phase b's explicit
        // start lands inside phase a's arrival window at run time.
        let sc = Scenario::from_str(
            r#"{"name":"x","devices":"d","phases":[
                {"name":"a","requests":2000,
                 "load":{"profile":"constant","rate_x":0.1}},
                {"name":"b","requests":8,"start_s":0.001,
                 "load":{"profile":"constant","rate_x":0.1}}]}"#,
        )
        .unwrap();
        let groups = vec![SimGroup { label: "g".into(), replicas: 1, rate: 1000.0, model: String::new() }];
        let e = run_modeled(&sc, &groups, 1000.0, &ScenarioOpts::default()).unwrap_err();
        assert!(e.contains("overlapping phases"), "{e}");
    }
}
