//! Fleet-level serving metrics: request counters, queue pressure,
//! end-to-end latency quantiles, and per-replica / per-device-group
//! utilization.
//!
//! Latency is measured from *admission* (the request entering the bounded
//! submission queue) to *completion* (logits handed back), so queue wait
//! and micro-batch formation are inside the number — the figure an SLO
//! actually constrains. Counters are atomics; the latency reservoirs are
//! mutex-protected vectors sampled only at snapshot time, which is fine
//! at synthetic-load scale and keeps the hot path to two locks per
//! completed request (fleet + device group).
//!
//! Heterogeneous fleets make the *group* axis the interesting one: a
//! DSP-starved part serves slower than the paper's board, so fleet-wide
//! quantiles hide which silicon is falling behind. Every replica is
//! assigned to a device group at construction
//! ([`FleetMetrics::grouped`]); latency, utilization, and dispatch
//! pressure (in-flight images) are broken out per group in
//! [`FleetSnapshot::groups`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Live counters for one replica of the fleet.
#[derive(Debug, Default)]
pub struct ReplicaMetrics {
    /// Images dispatched to (but not yet completed by) this replica —
    /// the dispatch-load key (divided by the replica's modeled rate for
    /// throughput-weighted selection).
    in_flight: AtomicU64,
    images: AtomicU64,
    batches: AtomicU64,
    busy_nanos: AtomicU64,
}

/// Live counters for one device group (all replicas on one physical
/// part).
#[derive(Debug)]
struct GroupMetrics {
    label: String,
    replicas: usize,
    images: AtomicU64,
    batches: AtomicU64,
    busy_nanos: AtomicU64,
    /// Images dispatched to the group and not yet retired — the group's
    /// share of queue pressure.
    in_flight: AtomicU64,
    in_flight_peak: AtomicU64,
    latencies_nanos: Mutex<Vec<u64>>,
}

impl GroupMetrics {
    fn new(label: String, replicas: usize) -> GroupMetrics {
        GroupMetrics {
            label,
            replicas,
            images: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            busy_nanos: AtomicU64::new(0),
            in_flight: AtomicU64::new(0),
            in_flight_peak: AtomicU64::new(0),
            latencies_nanos: Mutex::new(Vec::new()),
        }
    }
}

/// Live fleet metrics shared by the scheduler, the runners, and callers.
#[derive(Debug)]
pub struct FleetMetrics {
    started: Instant,
    accepted: AtomicU64,
    rejected: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    /// Total requests dequeued by the dispatcher. Queue depth is derived
    /// as `accepted - dispatched`: two monotonic counters cannot drift
    /// the way a racy increment/decrement gauge can (the dispatcher may
    /// observe a request before its submitter finishes accounting).
    dispatched: AtomicU64,
    queue_peak: AtomicU64,
    /// Completion-time offsets from `started` (nanos) bounding the
    /// sustained-throughput window.
    first_done_nanos: AtomicU64,
    last_done_nanos: AtomicU64,
    latencies_nanos: Mutex<Vec<u64>>,
    replicas: Vec<ReplicaMetrics>,
    /// Device-group index per replica (same length as `replicas`).
    replica_group: Vec<usize>,
    groups: Vec<GroupMetrics>,
}

impl FleetMetrics {
    /// A single-group fleet (`n_replicas` replicas of one device) — the
    /// PR 2 surface.
    pub fn new(n_replicas: usize) -> FleetMetrics {
        FleetMetrics::grouped(vec![0; n_replicas], vec!["fleet".to_string()])
    }

    /// A heterogeneous fleet: `replica_group[i]` is the device-group
    /// index of replica `i`, `labels[g]` its display name (one entry per
    /// group; every index in `replica_group` must be covered).
    pub fn grouped(replica_group: Vec<usize>, labels: Vec<String>) -> FleetMetrics {
        assert!(!labels.is_empty(), "a fleet has at least one device group");
        assert!(
            replica_group.iter().all(|&g| g < labels.len()),
            "replica group index out of range"
        );
        let groups = labels
            .into_iter()
            .enumerate()
            .map(|(gi, label)| {
                GroupMetrics::new(label, replica_group.iter().filter(|&&g| g == gi).count())
            })
            .collect();
        FleetMetrics {
            started: Instant::now(),
            accepted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            dispatched: AtomicU64::new(0),
            queue_peak: AtomicU64::new(0),
            first_done_nanos: AtomicU64::new(u64::MAX),
            last_done_nanos: AtomicU64::new(0),
            latencies_nanos: Mutex::new(Vec::new()),
            replicas: replica_group.iter().map(|_| ReplicaMetrics::default()).collect(),
            replica_group,
            groups,
        }
    }

    fn group_of(&self, replica: usize) -> Option<&GroupMetrics> {
        self.replica_group.get(replica).and_then(|&g| self.groups.get(g))
    }

    /// A request entered the submission queue.
    pub fn note_accepted(&self) {
        let accepted = self.accepted.fetch_add(1, Ordering::Relaxed) + 1;
        let depth = accepted.saturating_sub(self.dispatched.load(Ordering::Relaxed));
        self.queue_peak.fetch_max(depth, Ordering::Relaxed);
    }

    /// A request bounced off the full queue (`ServeError::Overloaded`).
    pub fn note_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// `n` requests left the queue as one micro-batch bound for `replica`.
    pub fn note_dispatched(&self, replica: usize, n: u64) {
        self.dispatched.fetch_add(n, Ordering::Relaxed);
        if let Some(r) = self.replicas.get(replica) {
            r.in_flight.fetch_add(n, Ordering::Relaxed);
        }
        if let Some(g) = self.group_of(replica) {
            let now = g.in_flight.fetch_add(n, Ordering::Relaxed) + n;
            g.in_flight_peak.fetch_max(now, Ordering::Relaxed);
        }
    }

    /// One request on `replica` finished successfully after `latency`
    /// (admission → reply).
    pub fn note_completed(&self, replica: usize, latency: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        let now = self.started.elapsed().as_nanos() as u64;
        self.first_done_nanos.fetch_min(now, Ordering::Relaxed);
        self.last_done_nanos.fetch_max(now, Ordering::Relaxed);
        let nanos = latency.as_nanos() as u64;
        self.latencies_nanos.lock().unwrap().push(nanos);
        if let Some(g) = self.group_of(replica) {
            g.latencies_nanos.lock().unwrap().push(nanos);
        }
    }

    /// One request failed inside a replica.
    pub fn note_failed(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    /// `replica` retired a micro-batch of `n` images in `busy` wall time.
    pub fn note_replica_batch(&self, replica: usize, n: u64, busy: Duration) {
        let busy_nanos = busy.as_nanos() as u64;
        if let Some(r) = self.replicas.get(replica) {
            r.images.fetch_add(n, Ordering::Relaxed);
            r.batches.fetch_add(1, Ordering::Relaxed);
            r.busy_nanos.fetch_add(busy_nanos, Ordering::Relaxed);
            saturating_dec(&r.in_flight, n);
        }
        if let Some(g) = self.group_of(replica) {
            g.images.fetch_add(n, Ordering::Relaxed);
            g.batches.fetch_add(1, Ordering::Relaxed);
            g.busy_nanos.fetch_add(busy_nanos, Ordering::Relaxed);
            saturating_dec(&g.in_flight, n);
        }
    }

    /// Current dispatched-not-done load per replica (the numerator of the
    /// throughput-weighted dispatch key).
    pub fn load_of(&self, replica: usize) -> u64 {
        self.replicas.get(replica).map(|r| r.in_flight.load(Ordering::Relaxed)).unwrap_or(0)
    }

    /// Point-in-time aggregate view.
    pub fn snapshot(&self) -> FleetSnapshot {
        let mut lat: Vec<u64> = self.latencies_nanos.lock().unwrap().clone();
        lat.sort_unstable();
        let completed = self.completed.load(Ordering::Relaxed);
        let first = self.first_done_nanos.load(Ordering::Relaxed);
        let last = self.last_done_nanos.load(Ordering::Relaxed);
        // Sustained window: first completion → last completion. One
        // completion (or none) has no window; fall back to wall time.
        let wall_secs = self.started.elapsed().as_secs_f64();
        let window_secs = if last > first && first != u64::MAX {
            (last - first) as f64 / 1e9
        } else {
            wall_secs
        };
        let mean_ms = if lat.is_empty() {
            0.0
        } else {
            lat.iter().map(|&n| n as f64).sum::<f64>() / lat.len() as f64 / 1e6
        };
        let accepted = self.accepted.load(Ordering::Relaxed);
        FleetSnapshot {
            accepted,
            rejected: self.rejected.load(Ordering::Relaxed),
            completed,
            failed: self.failed.load(Ordering::Relaxed),
            queue_depth: accepted.saturating_sub(self.dispatched.load(Ordering::Relaxed)),
            queue_peak: self.queue_peak.load(Ordering::Relaxed),
            wall_secs,
            sustained_img_s: if window_secs > 0.0 { completed as f64 / window_secs } else { 0.0 },
            p50_ms: percentile_ms(&lat, 0.50),
            p95_ms: percentile_ms(&lat, 0.95),
            p99_ms: percentile_ms(&lat, 0.99),
            mean_ms,
            replicas: self
                .replicas
                .iter()
                .zip(&self.replica_group)
                .map(|(r, &group)| {
                    let busy_secs = r.busy_nanos.load(Ordering::Relaxed) as f64 / 1e9;
                    ReplicaSnapshot {
                        group,
                        images: r.images.load(Ordering::Relaxed),
                        batches: r.batches.load(Ordering::Relaxed),
                        busy_secs,
                        utilization: if wall_secs > 0.0 { busy_secs / wall_secs } else { 0.0 },
                    }
                })
                .collect(),
            groups: self
                .groups
                .iter()
                .map(|g| {
                    let mut glat: Vec<u64> = g.latencies_nanos.lock().unwrap().clone();
                    glat.sort_unstable();
                    let busy_secs = g.busy_nanos.load(Ordering::Relaxed) as f64 / 1e9;
                    // A group's capacity-seconds is wall time × replicas.
                    let cap_secs = wall_secs * g.replicas.max(1) as f64;
                    GroupSnapshot {
                        label: g.label.clone(),
                        replicas: g.replicas,
                        images: g.images.load(Ordering::Relaxed),
                        batches: g.batches.load(Ordering::Relaxed),
                        busy_secs,
                        utilization: if cap_secs > 0.0 { busy_secs / cap_secs } else { 0.0 },
                        completed: glat.len() as u64,
                        p50_ms: percentile_ms(&glat, 0.50),
                        p95_ms: percentile_ms(&glat, 0.95),
                        p99_ms: percentile_ms(&glat, 0.99),
                        in_flight: g.in_flight.load(Ordering::Relaxed),
                        in_flight_peak: g.in_flight_peak.load(Ordering::Relaxed),
                    }
                })
                .collect(),
        }
    }
}

/// Gauge decrement that floors at zero (a metrics type should degrade to
/// slightly-off numbers, never wrap to 2^64 on a reordered update).
fn saturating_dec(cell: &AtomicU64, n: u64) {
    let _ = cell.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_sub(n)));
}

/// Nearest-rank percentile over an already-sorted nanosecond reservoir,
/// reported in milliseconds.
fn percentile_ms(sorted_nanos: &[u64], q: f64) -> f64 {
    if sorted_nanos.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_nanos.len() - 1) as f64 * q).round() as usize;
    sorted_nanos[idx.min(sorted_nanos.len() - 1)] as f64 / 1e6
}

/// Frozen fleet statistics (what `acf serve` prints and tests assert on).
#[derive(Debug, Clone)]
pub struct FleetSnapshot {
    pub accepted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub queue_depth: u64,
    pub queue_peak: u64,
    pub wall_secs: f64,
    /// Completed images per second over the first→last completion window.
    pub sustained_img_s: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub replicas: Vec<ReplicaSnapshot>,
    /// Per-device-group breakdown (one entry per physical part).
    pub groups: Vec<GroupSnapshot>,
}

/// Frozen per-replica statistics.
#[derive(Debug, Clone)]
pub struct ReplicaSnapshot {
    /// Index into [`FleetSnapshot::groups`].
    pub group: usize,
    pub images: u64,
    pub batches: u64,
    pub busy_secs: f64,
    /// Fraction of fleet wall time this replica spent inferring.
    pub utilization: f64,
}

/// Frozen per-device-group statistics.
#[derive(Debug, Clone)]
pub struct GroupSnapshot {
    pub label: String,
    pub replicas: usize,
    pub images: u64,
    pub batches: u64,
    pub busy_secs: f64,
    /// Busy time over the group's capacity (wall time × replicas).
    pub utilization: f64,
    /// Requests completed by this group.
    pub completed: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Images dispatched to the group and not yet retired (its share of
    /// queue pressure at snapshot time).
    pub in_flight: u64,
    pub in_flight_peak: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_quantiles() {
        let m = FleetMetrics::new(2);
        for _ in 0..10 {
            m.note_accepted();
        }
        m.note_rejected();
        m.note_dispatched(0, 6);
        m.note_dispatched(1, 4);
        assert_eq!(m.load_of(0), 6);
        assert_eq!(m.load_of(1), 4);
        for i in 0..10u64 {
            m.note_completed((i % 2) as usize, Duration::from_millis(i + 1));
        }
        m.note_replica_batch(0, 6, Duration::from_millis(30));
        m.note_replica_batch(1, 4, Duration::from_millis(20));
        let s = m.snapshot();
        assert_eq!(s.accepted, 10);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.completed, 10);
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.queue_peak, 10);
        assert!(s.p50_ms <= s.p95_ms && s.p95_ms <= s.p99_ms);
        assert!((s.p99_ms - 10.0).abs() < 1e-6, "p99 {}", s.p99_ms);
        assert!(s.mean_ms > 5.0 && s.mean_ms < 6.0);
        assert_eq!(s.replicas[0].images, 6);
        assert_eq!(s.replicas[1].batches, 1);
        assert_eq!(m.load_of(0), 0);
        assert!(s.replicas[0].busy_secs > 0.0);
        // Both replicas belong to the single default group, which sees
        // every image and every latency sample.
        assert_eq!(s.groups.len(), 1);
        let g = &s.groups[0];
        assert_eq!(g.label, "fleet");
        assert_eq!(g.replicas, 2);
        assert_eq!(g.images, 10);
        assert_eq!(g.batches, 2);
        assert_eq!(g.completed, 10);
        assert_eq!(g.in_flight, 0);
        assert_eq!(g.in_flight_peak, 10);
        assert!((g.p99_ms - s.p99_ms).abs() < 1e-9);
        // Group utilization averages over both replicas' capacity.
        assert!(g.utilization <= s.replicas[0].utilization + s.replicas[1].utilization);
    }

    #[test]
    fn grouped_breakdown_attributes_per_device() {
        // Replicas 0,1 on group 0 ("zcu104"), replica 2 on group 1
        // ("edge-nodsp").
        let m = FleetMetrics::grouped(
            vec![0, 0, 1],
            vec!["zcu104".to_string(), "edge-nodsp".to_string()],
        );
        m.note_dispatched(0, 2);
        m.note_dispatched(1, 2);
        m.note_dispatched(2, 3);
        m.note_completed(0, Duration::from_millis(2));
        m.note_completed(1, Duration::from_millis(4));
        m.note_completed(2, Duration::from_millis(40));
        m.note_replica_batch(0, 2, Duration::from_millis(2));
        m.note_replica_batch(2, 3, Duration::from_millis(40));
        let s = m.snapshot();
        assert_eq!(s.groups.len(), 2);
        let (g0, g1) = (&s.groups[0], &s.groups[1]);
        assert_eq!(g0.label, "zcu104");
        assert_eq!(g0.replicas, 2);
        assert_eq!(g1.replicas, 1);
        assert_eq!(g0.completed, 2);
        assert_eq!(g1.completed, 1);
        // The slow part's latency stays in ITS group's quantiles.
        assert!(g1.p99_ms > g0.p99_ms * 5.0, "g0 {} g1 {}", g0.p99_ms, g1.p99_ms);
        // Queue pressure: group 0 retired one of two batches (2 of 4
        // images), group 1 retired everything.
        assert_eq!(g0.in_flight, 2);
        assert_eq!(g0.in_flight_peak, 4);
        assert_eq!(g1.in_flight, 0);
        assert_eq!(g1.in_flight_peak, 3);
        // Replica snapshots point back at their groups.
        assert_eq!(s.replicas[0].group, 0);
        assert_eq!(s.replicas[2].group, 1);
    }

    #[test]
    fn empty_snapshot_is_quiet() {
        let m = FleetMetrics::new(1);
        let s = m.snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p99_ms, 0.0);
        assert_eq!(s.sustained_img_s, 0.0);
        assert_eq!(s.replicas.len(), 1);
        assert_eq!(s.replicas[0].utilization, 0.0);
        assert_eq!(s.groups.len(), 1);
        assert_eq!(s.groups[0].utilization, 0.0);
        assert_eq!(s.groups[0].completed, 0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<u64> = (1..=100).map(|i| i * 1_000_000).collect();
        assert!((percentile_ms(&v, 0.50) - 50.0).abs() < 1.01);
        assert!((percentile_ms(&v, 0.99) - 99.0).abs() < 1.01);
        assert_eq!(percentile_ms(&[], 0.5), 0.0);
    }
}
