//! Fleet-level serving metrics: request counters, queue pressure,
//! end-to-end latency quantiles, per-replica / per-device-group
//! utilization, windowed control signals, and the rebalance event log.
//!
//! Latency is measured from *admission* (the request entering the bounded
//! submission queue) to *completion* (logits handed back), so queue wait
//! and micro-batch formation are inside the number — the figure an SLO
//! actually constrains. Counters are atomics; the latency reservoirs are
//! mutex-protected vectors sampled only at snapshot time, which is fine
//! at synthetic-load scale and keeps the hot path to two locks per
//! completed request (fleet + device group).
//!
//! Heterogeneous fleets make the *group* axis the interesting one: a
//! DSP-starved part serves slower than the paper's board, so fleet-wide
//! quantiles hide which silicon is falling behind. Every replica is
//! assigned to a device group at registration; latency, utilization, and
//! dispatch pressure (in-flight images) are broken out per group in
//! [`FleetSnapshot::groups`].
//!
//! Since the rebalancing tier (PR 5) the replica set is *dynamic*: the
//! registry is an append-only `RwLock<Vec<_>>` — replica ids are stable
//! for the life of the server, retired replicas keep their history (and
//! show up flagged in the snapshot), and group "replicas" counts track
//! the *live* membership. Latency samples carry their completion offset
//! so [`FleetMetrics::window`] can answer "what happened in the last
//! control period" without a second reservoir, and every scale action is
//! recorded in the [`RebalanceEvent`] log the timeline report prints.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};
use std::time::Duration;

use crate::serve::fault::{FaultEvent, FaultEventKind};
use crate::trace::{self, ArgValue, Clock, Tracer};
use crate::util::sync::{lock_ok, read_ok, write_ok};

/// Live counters for one replica of the fleet.
#[derive(Debug, Default)]
pub struct ReplicaMetrics {
    /// Images dispatched to (but not yet completed by) this replica —
    /// the dispatch-load key (divided by the replica's modeled rate for
    /// throughput-weighted selection) and the drain signal the retire
    /// path waits on.
    in_flight: AtomicU64,
    images: AtomicU64,
    batches: AtomicU64,
    busy_nanos: AtomicU64,
    /// Set when the replica is marked for retirement (no new dispatches);
    /// history is kept so the final report still shows its work.
    retired: AtomicBool,
}

/// Registry entry: a replica and the device group it belongs to.
#[derive(Debug)]
struct ReplicaEntry {
    group: usize,
    m: ReplicaMetrics,
}

/// Live counters for one device group (all replicas on one physical
/// part).
#[derive(Debug)]
struct GroupMetrics {
    label: String,
    /// Replicas currently serving (registered minus retiring/retired).
    live: AtomicU64,
    /// Replicas ever registered to this group (rebalance churn included).
    spawned: AtomicU64,
    images: AtomicU64,
    batches: AtomicU64,
    busy_nanos: AtomicU64,
    /// Images dispatched to the group and not yet retired — the group's
    /// share of queue pressure.
    in_flight: AtomicU64,
    in_flight_peak: AtomicU64,
    /// `(completion offset from start, latency)` in nanos — the offset is
    /// what lets [`FleetMetrics::window`] cut a sliding window out of the
    /// same reservoir the all-time quantiles use.
    latencies_nanos: Mutex<Vec<(u64, u64)>>,
    /// Drain outcomes: replicas retired after a clean drain vs replicas
    /// that missed their drain deadline (and how many images they still
    /// held when it expired). Shutdown and live retirement both report
    /// here — a replica that fails to drain is surfaced, never silently
    /// dropped.
    drained: AtomicU64,
    drain_failed: AtomicU64,
    drain_leftover_images: AtomicU64,
}

impl GroupMetrics {
    fn new(label: String) -> GroupMetrics {
        GroupMetrics {
            label,
            live: AtomicU64::new(0),
            spawned: AtomicU64::new(0),
            images: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            busy_nanos: AtomicU64::new(0),
            in_flight: AtomicU64::new(0),
            in_flight_peak: AtomicU64::new(0),
            latencies_nanos: Mutex::new(Vec::new()),
            drained: AtomicU64::new(0),
            drain_failed: AtomicU64::new(0),
            drain_leftover_images: AtomicU64::new(0),
        }
    }
}

/// Identity and contract of one tenant, registered at fleet start
/// ([`FleetMetrics::with_tenants`]).
#[derive(Debug, Clone)]
pub struct TenantInfo {
    pub name: String,
    /// Name of the model the tenant's requests route to.
    pub model: String,
    /// Weighted-fair quota (admission share and dispatch priority).
    pub quota: f64,
    /// Declared p99 SLO class in ms (reported, not enforced).
    pub p99_slo_ms: Option<f64>,
}

/// Live counters for one tenant: admission outcomes and its own latency
/// reservoir, so per-customer p99 and shed rate never hide inside the
/// fleet aggregate.
#[derive(Debug)]
struct TenantMetrics {
    info: TenantInfo,
    accepted: AtomicU64,
    rejected: AtomicU64,
    completed: AtomicU64,
    latencies_nanos: Mutex<Vec<(u64, u64)>>,
}

impl TenantMetrics {
    fn new(info: TenantInfo) -> TenantMetrics {
        TenantMetrics {
            info,
            accepted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            latencies_nanos: Mutex::new(Vec::new()),
        }
    }
}

/// What a rebalance action did to one device group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RebalanceAction {
    /// Replicas added on the group's existing per-replica plan.
    Grow,
    /// Replicas retired (after drain) from the group's existing plan.
    Shrink,
    /// The whole group rolled onto a different frontier plan: new
    /// replicas spun up first, old ones retired after their in-flight
    /// micro-batches drained.
    Swap,
    /// The whole group moved to a *different model's* frontier plan as
    /// the traffic mix drifted (a rolling swap across the model axis).
    Shift,
}

impl std::fmt::Display for RebalanceAction {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RebalanceAction::Grow => write!(f, "grow"),
            RebalanceAction::Shrink => write!(f, "shrink"),
            RebalanceAction::Swap => write!(f, "swap"),
            RebalanceAction::Shift => write!(f, "shift"),
        }
    }
}

/// One entry of the rebalance timeline (what `report::rebalance_table`
/// prints and the step-load integration test asserts on).
#[derive(Debug, Clone)]
pub struct RebalanceEvent {
    /// Seconds since the metrics clock started.
    pub at_secs: f64,
    /// Device-group index the action applied to.
    pub group: usize,
    pub label: String,
    pub action: RebalanceAction,
    /// Replica count before / after the action.
    pub from: usize,
    pub to: usize,
    /// The signal that triggered it (human-readable).
    pub reason: String,
}

/// One group's sliding-window control signals (what the rebalancer reads
/// each tick — deliberately cheap: only the window's own latency samples
/// are sorted, never the all-time reservoirs).
#[derive(Debug, Clone)]
pub struct GroupWindow {
    pub group: usize,
    pub label: String,
    /// Replicas currently live in the group.
    pub live: usize,
    /// Requests completed inside the window.
    pub completed: u64,
    /// Completion rate over the window.
    pub img_s: f64,
    /// p50 latency over the window's completions (0 when idle).
    pub p50_ms: f64,
    /// p99 latency over the window's completions (0 when idle).
    pub p99_ms: f64,
    /// Images dispatched to the group and not yet retired, now.
    pub in_flight: u64,
    /// Cumulative busy seconds (the controller differences consecutive
    /// ticks for windowed utilization — one atomic load here).
    pub busy_secs: f64,
}

/// Live fleet metrics shared by the scheduler, the runners, the
/// rebalancer, and callers.
#[derive(Debug)]
pub struct FleetMetrics {
    /// Time source for latency reservoirs, windows, AND trace spans —
    /// one clock, so the request timeline and the rebalance timeline are
    /// directly comparable (and deterministic under `Clock::manual`).
    clock: Clock,
    /// Trace handle shared by every component holding the registry.
    /// `Tracer::off()` (the default) keeps every instrumentation site to
    /// a single branch.
    tracer: Tracer,
    accepted: AtomicU64,
    rejected: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    /// Total requests dequeued by the dispatcher. Queue depth is derived
    /// as `accepted - dispatched`: two monotonic counters cannot drift
    /// the way a racy increment/decrement gauge can (the dispatcher may
    /// observe a request before its submitter finishes accounting).
    dispatched: AtomicU64,
    queue_peak: AtomicU64,
    /// Completion-time offsets from `started` (nanos) bounding the
    /// sustained-throughput window.
    first_done_nanos: AtomicU64,
    last_done_nanos: AtomicU64,
    latencies_nanos: Mutex<Vec<(u64, u64)>>,
    /// Append-only replica registry; ids are indices and stay valid after
    /// retirement.
    replicas: RwLock<Vec<ReplicaEntry>>,
    groups: Vec<GroupMetrics>,
    events: Mutex<Vec<RebalanceEvent>>,
    /// The fault timeline (injections and their outcomes) — what the
    /// scenario harness asserts on and the fault tables print.
    faults: Mutex<Vec<FaultEvent>>,
    /// Per-tenant admission counters and latency reservoirs. Empty when
    /// the fleet is single-tenant (the PR 2 surface); fixed at start via
    /// [`FleetMetrics::with_tenants`].
    tenants: Vec<TenantMetrics>,
}

impl FleetMetrics {
    /// A single-group fleet (`n_replicas` replicas of one device) — the
    /// PR 2 surface.
    pub fn new(n_replicas: usize) -> FleetMetrics {
        FleetMetrics::grouped(vec![0; n_replicas], vec!["fleet".to_string()])
    }

    /// A heterogeneous fleet: `replica_group[i]` is the device-group
    /// index of replica `i`, `labels[g]` its display name (one entry per
    /// group). More replicas can be registered later with
    /// [`FleetMetrics::register_replica`]; the group set is fixed.
    pub fn grouped(replica_group: Vec<usize>, labels: Vec<String>) -> FleetMetrics {
        FleetMetrics::grouped_with(replica_group, labels, Clock::wall(), Tracer::off())
    }

    /// [`FleetMetrics::grouped`] with an explicit time source and trace
    /// handle. Tests inject `Clock::manual()` for deterministic windows;
    /// `acf serve --trace` injects a ring-buffer [`Tracer`] here so every
    /// component that can see the registry shares one sink and one clock.
    pub fn grouped_with(
        replica_group: Vec<usize>,
        labels: Vec<String>,
        clock: Clock,
        tracer: Tracer,
    ) -> FleetMetrics {
        assert!(!labels.is_empty(), "a fleet has at least one device group");
        assert!(
            replica_group.iter().all(|&g| g < labels.len()),
            "replica group index out of range"
        );
        let m = FleetMetrics {
            clock,
            tracer,
            accepted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            dispatched: AtomicU64::new(0),
            queue_peak: AtomicU64::new(0),
            first_done_nanos: AtomicU64::new(u64::MAX),
            last_done_nanos: AtomicU64::new(0),
            latencies_nanos: Mutex::new(Vec::new()),
            replicas: RwLock::new(Vec::new()),
            groups: labels.into_iter().map(GroupMetrics::new).collect(),
            events: Mutex::new(Vec::new()),
            faults: Mutex::new(Vec::new()),
            tenants: Vec::new(),
        };
        for g in replica_group {
            m.register_replica(g);
        }
        m
    }

    /// Attach the tenant roster (consumes `self` before it is shared).
    /// Each [`TenantInfo`] gets its own admission counters and latency
    /// reservoir; the tenant-suffixed hooks (`note_accepted_t`, …) index
    /// into this roster. An empty roster keeps the fleet single-tenant.
    pub fn with_tenants(mut self, roster: Vec<TenantInfo>) -> FleetMetrics {
        self.tenants = roster.into_iter().map(TenantMetrics::new).collect();
        self
    }

    /// Number of registered tenants (0 for a single-tenant fleet).
    pub fn n_tenants(&self) -> usize {
        self.tenants.len()
    }

    /// Identity/contract of tenant `t` as registered at start.
    pub fn tenant_info(&self, t: usize) -> &TenantInfo {
        &self.tenants[t].info
    }

    /// The shared time source. Span timestamps taken from this clock are
    /// directly comparable with the latency reservoirs and window cuts.
    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    /// The shared trace handle (off unless the fleet was built with
    /// [`FleetMetrics::grouped_with`] and a live sink).
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// Register a new replica in device group `group`, returning its
    /// stable replica id. Ids are never reused; a retired replica keeps
    /// its slot (and its history) in the registry.
    pub fn register_replica(&self, group: usize) -> usize {
        assert!(group < self.groups.len(), "replica group index out of range");
        let mut reg = write_ok(&self.replicas);
        let id = reg.len();
        reg.push(ReplicaEntry { group, m: ReplicaMetrics::default() });
        self.groups[group].live.fetch_add(1, Ordering::Relaxed);
        self.groups[group].spawned.fetch_add(1, Ordering::Relaxed);
        if self.tracer.on() {
            self.tracer.instant(
                "replica_add",
                "fleet",
                trace::pid_of_group(group),
                trace::TID_CONTROL,
                self.clock.now_nanos(),
                vec![("replica", ArgValue::U(id as u64))],
            );
        }
        id
    }

    /// Mark `replica` as retiring: the dispatcher has stopped feeding it
    /// and its group's live count drops now (drain outcome is reported
    /// separately via [`FleetMetrics::note_drained`] /
    /// [`FleetMetrics::note_drain_timeout`]).
    pub fn note_retiring(&self, replica: usize) {
        let reg = read_ok(&self.replicas);
        if let Some(e) = reg.get(replica) {
            if !e.m.retired.swap(true, Ordering::Relaxed) {
                saturating_dec(&self.groups[e.group].live, 1);
                if self.tracer.on() {
                    self.tracer.instant(
                        "replica_retire",
                        "fleet",
                        trace::pid_of_group(e.group),
                        trace::TID_CONTROL,
                        self.clock.now_nanos(),
                        vec![("replica", ArgValue::U(replica as u64))],
                    );
                }
            }
        }
    }

    /// A retiring replica of `group` drained cleanly (in-flight reached
    /// zero before the deadline).
    pub fn note_drained(&self, group: usize) {
        if let Some(g) = self.groups.get(group) {
            g.drained.fetch_add(1, Ordering::Relaxed);
            if self.tracer.on() {
                self.tracer.instant(
                    "replica_drained",
                    "fleet",
                    trace::pid_of_group(group),
                    trace::TID_CONTROL,
                    self.clock.now_nanos(),
                    Vec::new(),
                );
            }
        }
    }

    /// A retiring replica of `group` missed its drain deadline while
    /// still holding `leftover` images. The replica is detached and
    /// reported — never silently dropped.
    pub fn note_drain_timeout(&self, group: usize, leftover: u64) {
        if let Some(g) = self.groups.get(group) {
            g.drain_failed.fetch_add(1, Ordering::Relaxed);
            g.drain_leftover_images.fetch_add(leftover, Ordering::Relaxed);
            if self.tracer.on() {
                self.tracer.instant(
                    "drain_timeout",
                    "fleet",
                    trace::pid_of_group(group),
                    trace::TID_CONTROL,
                    self.clock.now_nanos(),
                    vec![("leftover_images", ArgValue::U(leftover))],
                );
            }
        }
    }

    /// Record one rebalance action in the timeline (and, when tracing,
    /// as an instant on the group's control track — same clock, so the
    /// action lines up against the request spans it displaced).
    pub fn note_rebalance(&self, mut event: RebalanceEvent) {
        event.at_secs = self.clock.now_secs();
        if self.tracer.on() {
            self.tracer.instant(
                format!("rebalance_{}", event.action),
                "fleet",
                trace::pid_of_group(event.group),
                trace::TID_CONTROL,
                self.clock.now_nanos(),
                vec![
                    ("from", ArgValue::U(event.from as u64)),
                    ("to", ArgValue::U(event.to as u64)),
                    ("reason", ArgValue::S(event.reason.clone())),
                ],
            );
        }
        lock_ok(&self.events).push(event);
    }

    /// The rebalance timeline so far.
    pub fn events(&self) -> Vec<RebalanceEvent> {
        lock_ok(&self.events).clone()
    }

    /// Record one fault-timeline entry, stamping it with the metrics
    /// clock and mirroring it on the trace control tracks: group-scoped
    /// faults land on their group's control track, fleet-wide ones on
    /// the requests process's control track — the same timeline the
    /// request chains and rebalance actions live on, so a Chrome trace
    /// of a failing scenario shows exactly what happened and when.
    pub fn note_fault(&self, mut event: FaultEvent) {
        event.at_secs = self.clock.now_secs();
        if self.tracer.on() {
            let (pid, tid) = match event.group {
                Some(g) => (trace::pid_of_group(g), trace::TID_CONTROL),
                None => (trace::PID_REQUESTS, 0),
            };
            let mut args = vec![("detail", ArgValue::S(event.detail.clone()))];
            if let Some(r) = event.replica {
                args.push(("replica", ArgValue::U(r as u64)));
            }
            self.tracer.instant(
                format!("fault_{}", event.kind),
                "fault",
                pid,
                tid,
                self.clock.now_nanos(),
                args,
            );
        }
        lock_ok(&self.faults).push(event);
    }

    /// The fault timeline so far (injections and derived outcomes, in
    /// record order).
    pub fn faults(&self) -> Vec<FaultEvent> {
        lock_ok(&self.faults).clone()
    }

    /// Whether a [`FaultEventKind::FleetLost`] outcome has been recorded
    /// — the scenario engine turns this into a failed verdict.
    pub fn fleet_lost(&self) -> bool {
        lock_ok(&self.faults).iter().any(|e| e.kind == FaultEventKind::FleetLost)
    }

    fn with_group_of<T>(&self, replica: usize, f: impl FnOnce(&GroupMetrics) -> T) -> Option<T> {
        let reg = read_ok(&self.replicas);
        reg.get(replica).and_then(|e| self.groups.get(e.group)).map(f)
    }

    /// A request entered the submission queue.
    pub fn note_accepted(&self) {
        let accepted = self.accepted.fetch_add(1, Ordering::Relaxed) + 1;
        let depth = accepted.saturating_sub(self.dispatched.load(Ordering::Relaxed));
        self.queue_peak.fetch_max(depth, Ordering::Relaxed);
    }

    /// A request bounced off the full queue (`ServeError::Overloaded`).
    /// Shed decisions are traced on the requests process's control track
    /// (tid 0 — request ids start at 1) so overload shows up in the same
    /// timeline as the chains it thinned.
    pub fn note_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
        if self.tracer.on() {
            self.tracer.instant(
                "shed",
                "fleet",
                trace::PID_REQUESTS,
                0,
                self.clock.now_nanos(),
                vec![("queue_depth", ArgValue::U(self.queue_depth()))],
            );
        }
    }

    /// `n` requests left the queue as one micro-batch bound for `replica`.
    pub fn note_dispatched(&self, replica: usize, n: u64) {
        self.dispatched.fetch_add(n, Ordering::Relaxed);
        let reg = read_ok(&self.replicas);
        if let Some(e) = reg.get(replica) {
            e.m.in_flight.fetch_add(n, Ordering::Relaxed);
            if let Some(g) = self.groups.get(e.group) {
                let now = g.in_flight.fetch_add(n, Ordering::Relaxed) + n;
                g.in_flight_peak.fetch_max(now, Ordering::Relaxed);
            }
        }
    }

    /// A dispatch to `replica` bounced (its feed closed mid-handoff
    /// during retirement) and the batch is being re-dispatched: undo the
    /// dispatch accounting so queue depth and in-flight stay honest.
    pub fn note_requeued(&self, replica: usize, n: u64) {
        saturating_dec(&self.dispatched, n);
        let reg = read_ok(&self.replicas);
        if let Some(e) = reg.get(replica) {
            saturating_dec(&e.m.in_flight, n);
            if let Some(g) = self.groups.get(e.group) {
                saturating_dec(&g.in_flight, n);
            }
        }
    }

    /// One request on `replica` finished successfully after `latency`
    /// (admission → reply).
    pub fn note_completed(&self, replica: usize, latency: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        let now = self.clock.now_nanos();
        self.first_done_nanos.fetch_min(now, Ordering::Relaxed);
        self.last_done_nanos.fetch_max(now, Ordering::Relaxed);
        let nanos = latency.as_nanos() as u64;
        lock_ok(&self.latencies_nanos).push((now, nanos));
        let _ = self.with_group_of(replica, |g| {
            lock_ok(&g.latencies_nanos).push((now, nanos));
        });
    }

    /// [`FleetMetrics::note_accepted`] plus the tenant-axis counter.
    pub fn note_accepted_t(&self, tenant: usize) {
        self.note_accepted();
        if let Some(t) = self.tenants.get(tenant) {
            t.accepted.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// [`FleetMetrics::note_rejected`] plus the tenant-axis shed counter
    /// — which tenant got shed is the whole point of quota admission.
    pub fn note_rejected_t(&self, tenant: usize) {
        self.note_rejected();
        if let Some(t) = self.tenants.get(tenant) {
            t.rejected.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// [`FleetMetrics::note_completed`] plus the tenant's own latency
    /// reservoir, so per-tenant p99 is computed from that tenant's
    /// requests only.
    pub fn note_completed_t(&self, replica: usize, tenant: usize, latency: Duration) {
        self.note_completed(replica, latency);
        if let Some(t) = self.tenants.get(tenant) {
            t.completed.fetch_add(1, Ordering::Relaxed);
            let now = self.clock.now_nanos();
            lock_ok(&t.latencies_nanos).push((now, latency.as_nanos() as u64));
        }
    }

    /// Lifetime `(accepted, rejected, completed)` for tenant `t` — the
    /// scenario harness differences these across phase boundaries.
    pub fn tenant_counts(&self, t: usize) -> (u64, u64, u64) {
        let tm = &self.tenants[t];
        (
            tm.accepted.load(Ordering::Relaxed),
            tm.rejected.load(Ordering::Relaxed),
            tm.completed.load(Ordering::Relaxed),
        )
    }

    /// Latency stats for tenant `t` over completions recorded in
    /// `[from_nanos, to_nanos)` offsets — the per-tenant analog of
    /// [`FleetMetrics::range_stats`] for phase verdicts.
    pub fn tenant_range_stats(&self, t: usize, from_nanos: u64, to_nanos: u64) -> RangeStats {
        let mut lat: Vec<u64> = lock_ok(&self.tenants[t].latencies_nanos)
            .iter()
            .filter(|&&(at, _)| at >= from_nanos && at < to_nanos)
            .map(|&(_, l)| l)
            .collect();
        lat.sort_unstable();
        RangeStats {
            completed: lat.len() as u64,
            p50_ms: percentile_ms(&lat, 0.50),
            p95_ms: percentile_ms(&lat, 0.95),
            p99_ms: percentile_ms(&lat, 0.99),
        }
    }

    /// One request failed inside a replica.
    pub fn note_failed(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    /// `n` admitted requests left the queue permanently without reaching
    /// any replica (every slot gone — each request was also failed
    /// individually). Keeps the `accepted - dispatched` queue-depth
    /// derivation honest.
    pub fn note_abandoned(&self, n: u64) {
        self.dispatched.fetch_add(n, Ordering::Relaxed);
    }

    /// `replica`'s runner died with work still dispatched to it (its
    /// feed channel dropped, taking any queued micro-batches along).
    /// Zero its in-flight gauge, release the group's share, and count
    /// the trapped images as failed — their reply channels dropped with
    /// the batches, so the callers already see errors; the books must
    /// agree. Returns how many images were lost.
    pub fn note_dead_replica(&self, replica: usize) -> u64 {
        let reg = read_ok(&self.replicas);
        let Some(e) = reg.get(replica) else {
            return 0;
        };
        let lost = e.m.in_flight.swap(0, Ordering::Relaxed);
        if lost > 0 {
            if let Some(g) = self.groups.get(e.group) {
                saturating_dec(&g.in_flight, lost);
            }
            self.failed.fetch_add(lost, Ordering::Relaxed);
        }
        lost
    }

    /// `replica` retired a micro-batch of `n` images in `busy` wall time.
    pub fn note_replica_batch(&self, replica: usize, n: u64, busy: Duration) {
        let busy_nanos = busy.as_nanos() as u64;
        let reg = read_ok(&self.replicas);
        if let Some(e) = reg.get(replica) {
            e.m.images.fetch_add(n, Ordering::Relaxed);
            e.m.batches.fetch_add(1, Ordering::Relaxed);
            e.m.busy_nanos.fetch_add(busy_nanos, Ordering::Relaxed);
            saturating_dec(&e.m.in_flight, n);
            if let Some(g) = self.groups.get(e.group) {
                g.images.fetch_add(n, Ordering::Relaxed);
                g.batches.fetch_add(1, Ordering::Relaxed);
                g.busy_nanos.fetch_add(busy_nanos, Ordering::Relaxed);
                saturating_dec(&g.in_flight, n);
            }
        }
    }

    /// Current dispatched-not-done load per replica (the numerator of the
    /// throughput-weighted dispatch key, and the retire path's drain
    /// signal).
    pub fn load_of(&self, replica: usize) -> u64 {
        read_ok(&self.replicas)
            .get(replica)
            .map(|e| e.m.in_flight.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Live replicas per device group, now (index = group id). The fault
    /// hooks use this to tell a replica death from a group loss from a
    /// fleet loss.
    pub fn live_counts(&self) -> Vec<usize> {
        self.groups.iter().map(|g| g.live.load(Ordering::Relaxed) as usize).collect()
    }

    /// Number of device groups (fixed for the life of the fleet).
    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    /// Requests admitted but not yet dispatched, now.
    pub fn queue_depth(&self) -> u64 {
        self.accepted
            .load(Ordering::Relaxed)
            .saturating_sub(self.dispatched.load(Ordering::Relaxed))
    }

    /// Total requests shed at admission so far (one atomic load — the
    /// controller differences consecutive ticks).
    pub fn rejected_total(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Sliding-window control signals per device group: completions,
    /// rate, and p99 over the last `window`, plus the live replica count
    /// and current in-flight pressure. This is what the rebalancer's
    /// control loop reads each tick.
    pub fn window(&self, window: Duration) -> Vec<GroupWindow> {
        let now = self.clock.now_nanos();
        let cut = now.saturating_sub(window.as_nanos() as u64);
        let secs = window.as_secs_f64().max(1e-9);
        self.groups
            .iter()
            .enumerate()
            .map(|(gi, g)| {
                // The reservoir is appended in (near-)monotonic
                // completion-offset order, so the window is a suffix:
                // walk backwards and stop at the first sample older
                // than the cut. Cost is O(window), not O(lifetime) —
                // the control loop ticks 4x/s on servers that may run
                // for days. Out-of-order jitter at the boundary is
                // microseconds against windows of ≥ tens of ms.
                let mut lat: Vec<u64> = lock_ok(&g.latencies_nanos)
                    .iter()
                    .rev()
                    .take_while(|(off, _)| *off >= cut)
                    .map(|(_, l)| *l)
                    .collect();
                lat.sort_unstable();
                GroupWindow {
                    group: gi,
                    label: g.label.clone(),
                    live: g.live.load(Ordering::Relaxed) as usize,
                    completed: lat.len() as u64,
                    img_s: lat.len() as f64 / secs,
                    p50_ms: percentile_ms(&lat, 0.50),
                    p99_ms: percentile_ms(&lat, 0.99),
                    in_flight: g.in_flight.load(Ordering::Relaxed),
                    busy_secs: g.busy_nanos.load(Ordering::Relaxed) as f64 / 1e9,
                }
            })
            .collect()
    }

    /// Fleet-wide sliding-window signals over the last `window`: the
    /// recovery tracker's view of "is the fleet back under its pre-fault
    /// envelope". Same suffix walk as [`FleetMetrics::window`], over the
    /// fleet reservoir.
    pub fn window_fleet(&self, window: Duration) -> FleetWindow {
        let now = self.clock.now_nanos();
        let cut = now.saturating_sub(window.as_nanos() as u64);
        let mut lat: Vec<u64> = lock_ok(&self.latencies_nanos)
            .iter()
            .rev()
            .take_while(|(off, _)| *off >= cut)
            .map(|(_, l)| *l)
            .collect();
        lat.sort_unstable();
        FleetWindow {
            completed: lat.len() as u64,
            p50_ms: percentile_ms(&lat, 0.50),
            p99_ms: percentile_ms(&lat, 0.99),
        }
    }

    /// Fleet-wide quantiles over the last `n` completions (or fewer,
    /// early on). The scenario engine's recovery signal: unlike a time
    /// window, a completion-count tail is *scale-free* — the same
    /// scenario file probes the same number of samples whether the
    /// modeled fleet serves 100 or 100 000 img/s, so verdicts stay
    /// machine- and model-independent.
    pub fn tail_stats(&self, n: usize) -> FleetWindow {
        let mut lat: Vec<u64> = {
            let res = lock_ok(&self.latencies_nanos);
            res[res.len().saturating_sub(n)..].iter().map(|&(_, l)| l).collect()
        };
        lat.sort_unstable();
        FleetWindow {
            completed: lat.len() as u64,
            p50_ms: percentile_ms(&lat, 0.50),
            p99_ms: percentile_ms(&lat, 0.99),
        }
    }

    /// Fleet latency quantiles over completions whose completion offset
    /// falls in `[from_nanos, to_nanos)` — the phase-scoped view the
    /// scenario verdict table prints (a phase's stats are a range query
    /// on the same reservoir the all-time quantiles use, so no second
    /// accounting path exists to drift).
    pub fn range_stats(&self, from_nanos: u64, to_nanos: u64) -> RangeStats {
        let mut lat: Vec<u64> = lock_ok(&self.latencies_nanos)
            .iter()
            .filter(|(off, _)| *off >= from_nanos && *off < to_nanos)
            .map(|(_, l)| *l)
            .collect();
        lat.sort_unstable();
        RangeStats {
            completed: lat.len() as u64,
            p50_ms: percentile_ms(&lat, 0.50),
            p95_ms: percentile_ms(&lat, 0.95),
            p99_ms: percentile_ms(&lat, 0.99),
        }
    }

    /// The five request counters in one read (accepted, rejected,
    /// completed, failed, dispatched) — what the scenario engine
    /// differences at phase boundaries.
    pub fn totals(&self) -> Totals {
        Totals {
            accepted: self.accepted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            dispatched: self.dispatched.load(Ordering::Relaxed),
        }
    }

    /// Point-in-time aggregate view.
    pub fn snapshot(&self) -> FleetSnapshot {
        let mut lat: Vec<u64> = lock_ok(&self.latencies_nanos).iter().map(|&(_, l)| l).collect();
        lat.sort_unstable();
        let completed = self.completed.load(Ordering::Relaxed);
        let first = self.first_done_nanos.load(Ordering::Relaxed);
        let last = self.last_done_nanos.load(Ordering::Relaxed);
        // Sustained window: first completion → last completion. One
        // completion (or none) has no window; fall back to wall time.
        let wall_secs = self.clock.now_secs();
        let window_secs = if last > first && first != u64::MAX {
            (last - first) as f64 / 1e9
        } else {
            wall_secs
        };
        let mean_ms = if lat.is_empty() {
            0.0
        } else {
            lat.iter().map(|&n| n as f64).sum::<f64>() / lat.len() as f64 / 1e6
        };
        let accepted = self.accepted.load(Ordering::Relaxed);
        FleetSnapshot {
            accepted,
            rejected: self.rejected.load(Ordering::Relaxed),
            completed,
            failed: self.failed.load(Ordering::Relaxed),
            queue_depth: accepted.saturating_sub(self.dispatched.load(Ordering::Relaxed)),
            queue_peak: self.queue_peak.load(Ordering::Relaxed),
            wall_secs,
            sustained_img_s: if window_secs > 0.0 { completed as f64 / window_secs } else { 0.0 },
            p50_ms: percentile_ms(&lat, 0.50),
            p95_ms: percentile_ms(&lat, 0.95),
            p99_ms: percentile_ms(&lat, 0.99),
            mean_ms,
            replicas: read_ok(&self.replicas)
                .iter()
                .map(|e| {
                    let busy_secs = e.m.busy_nanos.load(Ordering::Relaxed) as f64 / 1e9;
                    ReplicaSnapshot {
                        group: e.group,
                        retired: e.m.retired.load(Ordering::Relaxed),
                        images: e.m.images.load(Ordering::Relaxed),
                        batches: e.m.batches.load(Ordering::Relaxed),
                        busy_secs,
                        utilization: if wall_secs > 0.0 { busy_secs / wall_secs } else { 0.0 },
                    }
                })
                .collect(),
            groups: self
                .groups
                .iter()
                .map(|g| {
                    let mut glat: Vec<u64> =
                        lock_ok(&g.latencies_nanos).iter().map(|&(_, l)| l).collect();
                    glat.sort_unstable();
                    let busy_secs = g.busy_nanos.load(Ordering::Relaxed) as f64 / 1e9;
                    let live = g.live.load(Ordering::Relaxed) as usize;
                    // A group's capacity-seconds is wall time × live
                    // replicas — an approximation once rebalancing varies
                    // the count mid-run, but exact for static fleets.
                    let cap_secs = wall_secs * live.max(1) as f64;
                    GroupSnapshot {
                        label: g.label.clone(),
                        replicas: live,
                        spawned: g.spawned.load(Ordering::Relaxed),
                        images: g.images.load(Ordering::Relaxed),
                        batches: g.batches.load(Ordering::Relaxed),
                        busy_secs,
                        utilization: if cap_secs > 0.0 { busy_secs / cap_secs } else { 0.0 },
                        completed: glat.len() as u64,
                        p50_ms: percentile_ms(&glat, 0.50),
                        p95_ms: percentile_ms(&glat, 0.95),
                        p99_ms: percentile_ms(&glat, 0.99),
                        in_flight: g.in_flight.load(Ordering::Relaxed),
                        in_flight_peak: g.in_flight_peak.load(Ordering::Relaxed),
                        drained: g.drained.load(Ordering::Relaxed),
                        drain_failed: g.drain_failed.load(Ordering::Relaxed),
                        drain_leftover_images: g.drain_leftover_images.load(Ordering::Relaxed),
                    }
                })
                .collect(),
            events: self.events(),
            faults: self.faults(),
            tenants: self
                .tenants
                .iter()
                .map(|t| {
                    let mut tlat: Vec<u64> =
                        lock_ok(&t.latencies_nanos).iter().map(|&(_, l)| l).collect();
                    tlat.sort_unstable();
                    let accepted = t.accepted.load(Ordering::Relaxed);
                    let rejected = t.rejected.load(Ordering::Relaxed);
                    let offered = accepted + rejected;
                    TenantSnapshot {
                        name: t.info.name.clone(),
                        model: t.info.model.clone(),
                        quota: t.info.quota,
                        p99_slo_ms: t.info.p99_slo_ms,
                        accepted,
                        rejected,
                        completed: t.completed.load(Ordering::Relaxed),
                        shed_pct: if offered > 0 {
                            rejected as f64 / offered as f64 * 100.0
                        } else {
                            0.0
                        },
                        p50_ms: percentile_ms(&tlat, 0.50),
                        p95_ms: percentile_ms(&tlat, 0.95),
                        p99_ms: percentile_ms(&tlat, 0.99),
                    }
                })
                .collect(),
        }
    }
}

/// Gauge decrement that floors at zero (a metrics type should degrade to
/// slightly-off numbers, never wrap to 2^64 on a reordered update).
fn saturating_dec(cell: &AtomicU64, n: u64) {
    let _ = cell.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_sub(n)));
}

/// Nearest-rank percentile over an already-sorted nanosecond reservoir,
/// reported in milliseconds.
fn percentile_ms(sorted_nanos: &[u64], q: f64) -> f64 {
    if sorted_nanos.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_nanos.len() - 1) as f64 * q).round() as usize;
    sorted_nanos[idx.min(sorted_nanos.len() - 1)] as f64 / 1e6
}

/// Frozen fleet statistics (what `acf serve` prints and tests assert on).
#[derive(Debug, Clone)]
pub struct FleetSnapshot {
    pub accepted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub queue_depth: u64,
    pub queue_peak: u64,
    pub wall_secs: f64,
    /// Completed images per second over the first→last completion window.
    pub sustained_img_s: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub replicas: Vec<ReplicaSnapshot>,
    /// Per-device-group breakdown (one entry per physical part).
    pub groups: Vec<GroupSnapshot>,
    /// The rebalance timeline (empty for static fleets).
    pub events: Vec<RebalanceEvent>,
    /// The fault timeline (empty unless faults were injected).
    pub faults: Vec<FaultEvent>,
    /// Per-tenant breakdown (empty for single-tenant fleets).
    pub tenants: Vec<TenantSnapshot>,
}

/// Frozen per-tenant statistics — admission outcomes, shed rate, and
/// latency quantiles computed from that tenant's requests only.
#[derive(Debug, Clone)]
pub struct TenantSnapshot {
    pub name: String,
    /// Model the tenant routes to.
    pub model: String,
    pub quota: f64,
    pub p99_slo_ms: Option<f64>,
    pub accepted: u64,
    pub rejected: u64,
    pub completed: u64,
    /// `rejected / (accepted + rejected)` × 100 — the shed rate quota
    /// admission is supposed to apportion.
    pub shed_pct: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

/// Fleet-wide sliding-window signals ([`FleetMetrics::window_fleet`]).
#[derive(Debug, Clone, Copy)]
pub struct FleetWindow {
    pub completed: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

/// Fleet latency quantiles over a completion-offset range
/// ([`FleetMetrics::range_stats`]) — one scenario phase's view.
#[derive(Debug, Clone, Copy)]
pub struct RangeStats {
    pub completed: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

/// One consistent read of the request counters ([`FleetMetrics::totals`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Totals {
    pub accepted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub dispatched: u64,
}

/// Frozen per-replica statistics.
#[derive(Debug, Clone)]
pub struct ReplicaSnapshot {
    /// Index into [`FleetSnapshot::groups`].
    pub group: usize,
    /// Whether the replica had been retired by snapshot time.
    pub retired: bool,
    pub images: u64,
    pub batches: u64,
    pub busy_secs: f64,
    /// Fraction of fleet wall time this replica spent inferring.
    pub utilization: f64,
}

/// Frozen per-device-group statistics.
#[derive(Debug, Clone)]
pub struct GroupSnapshot {
    pub label: String,
    /// Replicas live at snapshot time.
    pub replicas: usize,
    /// Replicas ever spawned into this group (rebalance churn included).
    pub spawned: u64,
    pub images: u64,
    pub batches: u64,
    pub busy_secs: f64,
    /// Busy time over the group's capacity (wall time × live replicas).
    pub utilization: f64,
    /// Requests completed by this group.
    pub completed: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Images dispatched to the group and not yet retired (its share of
    /// queue pressure at snapshot time).
    pub in_flight: u64,
    pub in_flight_peak: u64,
    /// Replicas retired after a clean drain.
    pub drained: u64,
    /// Replicas that missed their drain deadline (reported, not hidden).
    pub drain_failed: u64,
    /// Images those replicas still held when their deadlines expired.
    pub drain_leftover_images: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_quantiles() {
        let m = FleetMetrics::new(2);
        for _ in 0..10 {
            m.note_accepted();
        }
        m.note_rejected();
        m.note_dispatched(0, 6);
        m.note_dispatched(1, 4);
        assert_eq!(m.load_of(0), 6);
        assert_eq!(m.load_of(1), 4);
        for i in 0..10u64 {
            m.note_completed((i % 2) as usize, Duration::from_millis(i + 1));
        }
        m.note_replica_batch(0, 6, Duration::from_millis(30));
        m.note_replica_batch(1, 4, Duration::from_millis(20));
        let s = m.snapshot();
        assert_eq!(s.accepted, 10);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.completed, 10);
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.queue_peak, 10);
        assert!(s.p50_ms <= s.p95_ms && s.p95_ms <= s.p99_ms);
        assert!((s.p99_ms - 10.0).abs() < 1e-6, "p99 {}", s.p99_ms);
        assert!(s.mean_ms > 5.0 && s.mean_ms < 6.0);
        assert_eq!(s.replicas[0].images, 6);
        assert_eq!(s.replicas[1].batches, 1);
        assert_eq!(m.load_of(0), 0);
        assert!(s.replicas[0].busy_secs > 0.0);
        assert!(!s.replicas[0].retired);
        // Both replicas belong to the single default group, which sees
        // every image and every latency sample.
        assert_eq!(s.groups.len(), 1);
        let g = &s.groups[0];
        assert_eq!(g.label, "fleet");
        assert_eq!(g.replicas, 2);
        assert_eq!(g.spawned, 2);
        assert_eq!(g.images, 10);
        assert_eq!(g.batches, 2);
        assert_eq!(g.completed, 10);
        assert_eq!(g.in_flight, 0);
        assert_eq!(g.in_flight_peak, 10);
        assert!((g.p99_ms - s.p99_ms).abs() < 1e-9);
        // Group utilization averages over both replicas' capacity.
        assert!(g.utilization <= s.replicas[0].utilization + s.replicas[1].utilization);
        // No rebalancing happened: an empty timeline and clean drains.
        assert!(s.events.is_empty());
        assert_eq!(g.drained, 0);
        assert_eq!(g.drain_failed, 0);
    }

    #[test]
    fn tenant_axis_tracks_per_tenant_shed_and_latency() {
        let m = FleetMetrics::new(2).with_tenants(vec![
            TenantInfo {
                name: "tenantA".to_string(),
                model: "lenet-tiny".to_string(),
                quota: 3.0,
                p99_slo_ms: Some(50.0),
            },
            TenantInfo {
                name: "tenantB".to_string(),
                model: "lenet-wide-2x".to_string(),
                quota: 1.0,
                p99_slo_ms: None,
            },
        ]);
        assert_eq!(m.n_tenants(), 2);
        assert_eq!(m.tenant_info(1).model, "lenet-wide-2x");
        // 4 accepts + 1 shed for A, 2 accepts + 3 sheds for B.
        for _ in 0..4 {
            m.note_accepted_t(0);
        }
        m.note_rejected_t(0);
        for _ in 0..2 {
            m.note_accepted_t(1);
        }
        for _ in 0..3 {
            m.note_rejected_t(1);
        }
        m.note_dispatched(0, 6);
        for i in 0..4u64 {
            m.note_completed_t(0, 0, Duration::from_millis(i + 1));
        }
        m.note_completed_t(1, 1, Duration::from_millis(40));
        m.note_completed_t(1, 1, Duration::from_millis(60));
        assert_eq!(m.tenant_counts(0), (4, 1, 4));
        assert_eq!(m.tenant_counts(1), (2, 3, 2));
        let s = m.snapshot();
        // Fleet aggregates see every request; tenant rows partition them.
        assert_eq!(s.accepted, 6);
        assert_eq!(s.rejected, 4);
        assert_eq!(s.tenants.len(), 2);
        let (a, b) = (&s.tenants[0], &s.tenants[1]);
        assert_eq!(a.name, "tenantA");
        assert_eq!((a.accepted, a.rejected, a.completed), (4, 1, 4));
        assert!((a.shed_pct - 20.0).abs() < 1e-9);
        assert_eq!(a.p99_slo_ms, Some(50.0));
        assert!((a.p99_ms - 4.0).abs() < 1e-6, "A p99 {}", a.p99_ms);
        assert!((b.shed_pct - 60.0).abs() < 1e-9);
        assert!((b.p99_ms - 60.0).abs() < 1e-6, "B p99 {}", b.p99_ms);
        // Per-tenant range query slices B's reservoir like range_stats.
        let rs = m.tenant_range_stats(1, 0, u64::MAX);
        assert_eq!(rs.completed, 2);
        assert!((rs.p50_ms - 40.0).abs() < 1e-6);
        // Untenanted fleets report an empty tenant table.
        let plain = FleetMetrics::new(1);
        plain.note_accepted_t(0); // out-of-roster index is a no-op tenant-wise
        assert_eq!(plain.snapshot().tenants.len(), 0);
        assert_eq!(plain.snapshot().accepted, 1);
    }

    #[test]
    fn grouped_breakdown_attributes_per_device() {
        // Replicas 0,1 on group 0 ("zcu104"), replica 2 on group 1
        // ("edge-nodsp").
        let m = FleetMetrics::grouped(
            vec![0, 0, 1],
            vec!["zcu104".to_string(), "edge-nodsp".to_string()],
        );
        m.note_dispatched(0, 2);
        m.note_dispatched(1, 2);
        m.note_dispatched(2, 3);
        m.note_completed(0, Duration::from_millis(2));
        m.note_completed(1, Duration::from_millis(4));
        m.note_completed(2, Duration::from_millis(40));
        m.note_replica_batch(0, 2, Duration::from_millis(2));
        m.note_replica_batch(2, 3, Duration::from_millis(40));
        let s = m.snapshot();
        assert_eq!(s.groups.len(), 2);
        let (g0, g1) = (&s.groups[0], &s.groups[1]);
        assert_eq!(g0.label, "zcu104");
        assert_eq!(g0.replicas, 2);
        assert_eq!(g1.replicas, 1);
        assert_eq!(g0.completed, 2);
        assert_eq!(g1.completed, 1);
        // The slow part's latency stays in ITS group's quantiles.
        assert!(g1.p99_ms > g0.p99_ms * 5.0, "g0 {} g1 {}", g0.p99_ms, g1.p99_ms);
        // Queue pressure: group 0 retired one of two batches (2 of 4
        // images), group 1 retired everything.
        assert_eq!(g0.in_flight, 2);
        assert_eq!(g0.in_flight_peak, 4);
        assert_eq!(g1.in_flight, 0);
        assert_eq!(g1.in_flight_peak, 3);
        // Replica snapshots point back at their groups.
        assert_eq!(s.replicas[0].group, 0);
        assert_eq!(s.replicas[2].group, 1);
    }

    #[test]
    fn empty_snapshot_is_quiet() {
        let m = FleetMetrics::new(1);
        let s = m.snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p99_ms, 0.0);
        assert_eq!(s.sustained_img_s, 0.0);
        assert_eq!(s.replicas.len(), 1);
        assert_eq!(s.replicas[0].utilization, 0.0);
        assert_eq!(s.groups.len(), 1);
        assert_eq!(s.groups[0].utilization, 0.0);
        assert_eq!(s.groups[0].completed, 0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<u64> = (1..=100).map(|i| i * 1_000_000).collect();
        assert!((percentile_ms(&v, 0.50) - 50.0).abs() < 1.01);
        assert!((percentile_ms(&v, 0.99) - 99.0).abs() < 1.01);
        assert_eq!(percentile_ms(&[], 0.5), 0.0);
    }

    #[test]
    fn dynamic_registration_and_retirement() {
        let m = FleetMetrics::grouped(vec![0], vec!["zcu104".into(), "zu5ev".into()]);
        // Register into the second (initially empty) group.
        let r1 = m.register_replica(1);
        let r2 = m.register_replica(1);
        assert_eq!((r1, r2), (1, 2));
        m.note_dispatched(r1, 3);
        m.note_replica_batch(r1, 3, Duration::from_millis(5));
        // Retire r1: live drops immediately, history survives.
        m.note_retiring(r1);
        m.note_retiring(r1); // idempotent — live only drops once
        m.note_drained(1);
        let s = m.snapshot();
        assert_eq!(s.groups[1].replicas, 1);
        assert_eq!(s.groups[1].spawned, 2);
        assert_eq!(s.groups[1].drained, 1);
        assert_eq!(s.groups[1].drain_failed, 0);
        assert!(s.replicas[r1].retired);
        assert_eq!(s.replicas[r1].images, 3);
        assert!(!s.replicas[r2].retired);
        // A timed-out drain is reported with its leftover images.
        m.note_drain_timeout(1, 7);
        let s = m.snapshot();
        assert_eq!(s.groups[1].drain_failed, 1);
        assert_eq!(s.groups[1].drain_leftover_images, 7);
    }

    #[test]
    fn dead_replica_releases_gauges_and_counts_failures() {
        let m = FleetMetrics::new(2);
        m.note_accepted();
        m.note_accepted();
        m.note_accepted();
        m.note_dispatched(0, 3);
        assert_eq!(m.load_of(0), 3);
        // Runner 0 dies with 3 images trapped in its feed.
        let lost = m.note_dead_replica(0);
        assert_eq!(lost, 3);
        assert_eq!(m.load_of(0), 0);
        let s = m.snapshot();
        assert_eq!(s.failed, 3);
        assert_eq!(s.groups[0].in_flight, 0);
        assert_eq!(s.queue_depth, 0);
        // Idempotent-ish: nothing left to lose.
        assert_eq!(m.note_dead_replica(0), 0);
        assert_eq!(m.note_dead_replica(99), 0);
    }

    #[test]
    fn requeue_undoes_dispatch_accounting() {
        let m = FleetMetrics::new(2);
        m.note_accepted();
        m.note_accepted();
        m.note_dispatched(0, 2);
        assert_eq!(m.queue_depth(), 0);
        assert_eq!(m.load_of(0), 2);
        // The handoff bounced (replica retiring): the batch goes back to
        // the dispatcher's hand and the books rewind.
        m.note_requeued(0, 2);
        assert_eq!(m.queue_depth(), 2);
        assert_eq!(m.load_of(0), 0);
        m.note_dispatched(1, 2);
        assert_eq!(m.queue_depth(), 0);
        assert_eq!(m.load_of(1), 2);
    }

    #[test]
    fn windowed_signals_cut_by_completion_time() {
        // Deterministic: the manual clock replaces the real sleep this
        // test used before the Clock abstraction existed.
        let clock = Clock::manual();
        let m = FleetMetrics::grouped_with(
            vec![0, 1],
            vec!["a".into(), "b".into()],
            clock.clone(),
            Tracer::off(),
        );
        m.note_dispatched(0, 1);
        m.note_completed(0, Duration::from_millis(3));
        clock.advance(Duration::from_millis(60));
        m.note_dispatched(1, 2);
        m.note_completed(1, Duration::from_millis(9));
        // A 40 ms window sees only the recent completion on group 1.
        let w = m.window(Duration::from_millis(40));
        assert_eq!(w.len(), 2);
        assert_eq!(w[0].completed, 0);
        assert_eq!(w[0].p99_ms, 0.0);
        assert_eq!(w[1].completed, 1);
        assert!((w[1].p99_ms - 9.0).abs() < 1e-6);
        assert!(w[1].img_s > 0.0);
        assert_eq!(w[1].in_flight, 2); // dispatched 2, completed reply for 1, batch not retired
        // A generous window sees both.
        let w = m.window(Duration::from_secs(10));
        assert_eq!(w[0].completed, 1);
        assert_eq!(w[1].completed, 1);
        assert_eq!(w[0].live, 1);
    }

    #[test]
    fn rebalance_events_are_timestamped_in_order() {
        let clock = Clock::manual();
        let m = FleetMetrics::grouped_with(
            vec![0],
            vec!["fleet".to_string()],
            clock.clone(),
            Tracer::off(),
        );
        m.note_rebalance(RebalanceEvent {
            at_secs: -1.0, // overwritten by the metrics clock
            group: 0,
            label: "fleet".into(),
            action: RebalanceAction::Grow,
            from: 1,
            to: 2,
            reason: "queue 80% full".into(),
        });
        clock.advance(Duration::from_millis(5));
        m.note_rebalance(RebalanceEvent {
            at_secs: -1.0,
            group: 0,
            label: "fleet".into(),
            action: RebalanceAction::Shrink,
            from: 2,
            to: 1,
            reason: "idle".into(),
        });
        let ev = m.events();
        assert_eq!(ev.len(), 2);
        assert!(ev[0].at_secs >= 0.0);
        assert!(ev[1].at_secs > ev[0].at_secs);
        assert_eq!(ev[0].action, RebalanceAction::Grow);
        assert_eq!(ev[1].action, RebalanceAction::Shrink);
        assert_eq!(format!("{}", ev[0].action), "grow");
        let s = m.snapshot();
        assert_eq!(s.events.len(), 2);
    }

    #[test]
    fn fleet_lifecycle_events_are_traced_on_group_control_tracks() {
        let clock = Clock::manual();
        let tracer = Tracer::ring(64);
        let m = FleetMetrics::grouped_with(
            vec![0],
            vec!["zcu104".into(), "zu5ev".into()],
            clock.clone(),
            tracer.clone(),
        );
        clock.advance(Duration::from_millis(1));
        let r = m.register_replica(1);
        m.note_retiring(r);
        m.note_retiring(r); // idempotent: no second retire event
        m.note_drained(1);
        m.note_drain_timeout(1, 4);
        m.note_rejected();
        m.note_rebalance(RebalanceEvent {
            at_secs: -1.0,
            group: 1,
            label: "zu5ev".into(),
            action: RebalanceAction::Swap,
            from: 1,
            to: 2,
            reason: "p99 drift".into(),
        });
        let names: Vec<String> = tracer.drain().into_iter().map(|e| e.name).collect();
        assert_eq!(
            names,
            vec![
                "replica_add", // the constructor's replica 0
                "replica_add",
                "replica_retire",
                "replica_drained",
                "drain_timeout",
                "shed",
                "rebalance_swap",
            ]
        );
        // Same clock as the metrics timeline: events carry manual time.
        let tracer2 = Tracer::ring(8);
        let m2 = FleetMetrics::grouped_with(
            Vec::new(),
            vec!["g".into()],
            clock.clone(),
            tracer2.clone(),
        );
        m2.register_replica(0);
        let ev = &tracer2.drain()[0];
        assert_eq!(ev.ts_nanos, 1_000_000);
        assert_eq!(ev.pid, trace::pid_of_group(0));
        assert_eq!(ev.tid, trace::TID_CONTROL);
    }

    #[test]
    fn fault_timeline_is_stamped_and_traced() {
        let clock = Clock::manual();
        let tracer = Tracer::ring(16);
        let m = FleetMetrics::grouped_with(
            vec![0, 1],
            vec!["a".into(), "b".into()],
            clock.clone(),
            tracer.clone(),
        );
        clock.advance(Duration::from_millis(10));
        m.note_fault(FaultEvent {
            at_secs: -1.0, // overwritten by the metrics clock
            kind: FaultEventKind::ReplicaDeath,
            group: Some(1),
            replica: Some(1),
            detail: "injected".into(),
        });
        clock.advance(Duration::from_millis(10));
        m.note_fault(FaultEvent {
            at_secs: -1.0,
            kind: FaultEventKind::FleetLost,
            group: None,
            replica: None,
            detail: "no live replicas".into(),
        });
        let faults = m.faults();
        assert_eq!(faults.len(), 2);
        assert!((faults[0].at_secs - 0.010).abs() < 1e-9);
        assert!(faults[1].at_secs > faults[0].at_secs);
        assert!(m.fleet_lost());
        // Group-scoped fault on its group's control track; fleet-wide
        // one on the requests process's control track.
        let evs: Vec<_> = tracer
            .drain()
            .into_iter()
            .filter(|e| e.name.starts_with("fault_"))
            .collect();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].name, "fault_replica_death");
        assert_eq!(evs[0].pid, trace::pid_of_group(1));
        assert_eq!(evs[1].name, "fault_fleet_lost");
        assert_eq!(evs[1].pid, trace::PID_REQUESTS);
        assert_eq!(m.snapshot().faults.len(), 2);
    }

    #[test]
    fn range_stats_and_fleet_window_cut_the_shared_reservoir() {
        let clock = Clock::manual();
        let m = FleetMetrics::grouped_with(
            vec![0],
            vec!["g".into()],
            clock.clone(),
            Tracer::off(),
        );
        // Three completions at t = 10, 20, 30 ms with latencies 1/5/9 ms.
        for (t, l) in [(10u64, 1u64), (20, 5), (30, 9)] {
            clock.advance(Duration::from_millis(10));
            let _ = t;
            m.note_completed(0, Duration::from_millis(l));
        }
        // Range [15ms, 35ms) sees the 5 and 9 ms samples.
        let r = m.range_stats(15_000_000, 35_000_000);
        assert_eq!(r.completed, 2);
        assert!((r.p50_ms - 5.0).abs() < 1e-9);
        assert!((r.p99_ms - 9.0).abs() < 1e-9);
        // An empty range is quiet.
        let r = m.range_stats(40_000_000, 50_000_000);
        assert_eq!(r.completed, 0);
        assert_eq!(r.p99_ms, 0.0);
        // A 15 ms fleet window at t=30ms sees the last two samples.
        let w = m.window_fleet(Duration::from_millis(15));
        assert_eq!(w.completed, 2);
        assert!((w.p99_ms - 9.0).abs() < 1e-9);
        // A completion-count tail cuts by order, not time.
        let t2 = m.tail_stats(2);
        assert_eq!(t2.completed, 2);
        assert!((t2.p50_ms - 5.0).abs() < 1e-9);
        assert!((t2.p99_ms - 9.0).abs() < 1e-9);
        // Asking for more than exists returns everything.
        assert_eq!(m.tail_stats(100).completed, 3);
        // Totals reads match the individual counters.
        m.note_accepted();
        m.note_rejected();
        let t = m.totals();
        assert_eq!(t.accepted, 1);
        assert_eq!(t.rejected, 1);
        assert_eq!(t.completed, 3);
        assert_eq!(t.failed, 0);
        // live_counts reflects the registry.
        assert_eq!(m.live_counts(), vec![1]);
        m.note_retiring(0);
        assert_eq!(m.live_counts(), vec![0]);
    }
}
