//! Fleet-level serving metrics: request counters, queue pressure,
//! end-to-end latency quantiles, and per-replica utilization.
//!
//! Latency is measured from *admission* (the request entering the bounded
//! submission queue) to *completion* (logits handed back), so queue wait
//! and micro-batch formation are inside the number — the figure an SLO
//! actually constrains. Counters are atomics; the latency reservoir is a
//! mutex-protected vector sampled only at snapshot time, which is fine at
//! synthetic-load scale and keeps the hot path to one lock per completed
//! request.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Live counters for one replica of the fleet.
#[derive(Debug, Default)]
pub struct ReplicaMetrics {
    /// Images dispatched to (but not yet completed by) this replica —
    /// the least-loaded dispatch key.
    in_flight: AtomicU64,
    images: AtomicU64,
    batches: AtomicU64,
    busy_nanos: AtomicU64,
}

/// Live fleet metrics shared by the scheduler, the runners, and callers.
#[derive(Debug)]
pub struct FleetMetrics {
    started: Instant,
    accepted: AtomicU64,
    rejected: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    /// Total requests dequeued by the dispatcher. Queue depth is derived
    /// as `accepted - dispatched`: two monotonic counters cannot drift
    /// the way a racy increment/decrement gauge can (the dispatcher may
    /// observe a request before its submitter finishes accounting).
    dispatched: AtomicU64,
    queue_peak: AtomicU64,
    /// Completion-time offsets from `started` (nanos) bounding the
    /// sustained-throughput window.
    first_done_nanos: AtomicU64,
    last_done_nanos: AtomicU64,
    latencies_nanos: Mutex<Vec<u64>>,
    replicas: Vec<ReplicaMetrics>,
}

impl FleetMetrics {
    pub fn new(n_replicas: usize) -> FleetMetrics {
        FleetMetrics {
            started: Instant::now(),
            accepted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            dispatched: AtomicU64::new(0),
            queue_peak: AtomicU64::new(0),
            first_done_nanos: AtomicU64::new(u64::MAX),
            last_done_nanos: AtomicU64::new(0),
            latencies_nanos: Mutex::new(Vec::new()),
            replicas: (0..n_replicas).map(|_| ReplicaMetrics::default()).collect(),
        }
    }

    /// A request entered the submission queue.
    pub fn note_accepted(&self) {
        let accepted = self.accepted.fetch_add(1, Ordering::Relaxed) + 1;
        let depth = accepted.saturating_sub(self.dispatched.load(Ordering::Relaxed));
        self.queue_peak.fetch_max(depth, Ordering::Relaxed);
    }

    /// A request bounced off the full queue (`ServeError::Overloaded`).
    pub fn note_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// `n` requests left the queue as one micro-batch bound for `replica`.
    pub fn note_dispatched(&self, replica: usize, n: u64) {
        self.dispatched.fetch_add(n, Ordering::Relaxed);
        if let Some(r) = self.replicas.get(replica) {
            r.in_flight.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// One request finished successfully after `latency` (admission →
    /// reply).
    pub fn note_completed(&self, latency: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        let now = self.started.elapsed().as_nanos() as u64;
        self.first_done_nanos.fetch_min(now, Ordering::Relaxed);
        self.last_done_nanos.fetch_max(now, Ordering::Relaxed);
        self.latencies_nanos.lock().unwrap().push(latency.as_nanos() as u64);
    }

    /// One request failed inside a replica.
    pub fn note_failed(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    /// `replica` retired a micro-batch of `n` images in `busy` wall time.
    pub fn note_replica_batch(&self, replica: usize, n: u64, busy: Duration) {
        if let Some(r) = self.replicas.get(replica) {
            r.images.fetch_add(n, Ordering::Relaxed);
            r.batches.fetch_add(1, Ordering::Relaxed);
            r.busy_nanos.fetch_add(busy.as_nanos() as u64, Ordering::Relaxed);
            saturating_dec(&r.in_flight, n);
        }
    }

    /// Current dispatched-not-done load per replica (for least-loaded
    /// dispatch).
    pub fn load_of(&self, replica: usize) -> u64 {
        self.replicas.get(replica).map(|r| r.in_flight.load(Ordering::Relaxed)).unwrap_or(0)
    }

    /// Point-in-time aggregate view.
    pub fn snapshot(&self) -> FleetSnapshot {
        let mut lat: Vec<u64> = self.latencies_nanos.lock().unwrap().clone();
        lat.sort_unstable();
        let completed = self.completed.load(Ordering::Relaxed);
        let first = self.first_done_nanos.load(Ordering::Relaxed);
        let last = self.last_done_nanos.load(Ordering::Relaxed);
        // Sustained window: first completion → last completion. One
        // completion (or none) has no window; fall back to wall time.
        let wall_secs = self.started.elapsed().as_secs_f64();
        let window_secs = if last > first && first != u64::MAX {
            (last - first) as f64 / 1e9
        } else {
            wall_secs
        };
        let mean_ms = if lat.is_empty() {
            0.0
        } else {
            lat.iter().map(|&n| n as f64).sum::<f64>() / lat.len() as f64 / 1e6
        };
        let accepted = self.accepted.load(Ordering::Relaxed);
        FleetSnapshot {
            accepted,
            rejected: self.rejected.load(Ordering::Relaxed),
            completed,
            failed: self.failed.load(Ordering::Relaxed),
            queue_depth: accepted.saturating_sub(self.dispatched.load(Ordering::Relaxed)),
            queue_peak: self.queue_peak.load(Ordering::Relaxed),
            wall_secs,
            sustained_img_s: if window_secs > 0.0 { completed as f64 / window_secs } else { 0.0 },
            p50_ms: percentile_ms(&lat, 0.50),
            p95_ms: percentile_ms(&lat, 0.95),
            p99_ms: percentile_ms(&lat, 0.99),
            mean_ms,
            replicas: self
                .replicas
                .iter()
                .map(|r| {
                    let busy_secs = r.busy_nanos.load(Ordering::Relaxed) as f64 / 1e9;
                    ReplicaSnapshot {
                        images: r.images.load(Ordering::Relaxed),
                        batches: r.batches.load(Ordering::Relaxed),
                        busy_secs,
                        utilization: if wall_secs > 0.0 { busy_secs / wall_secs } else { 0.0 },
                    }
                })
                .collect(),
        }
    }
}

/// Gauge decrement that floors at zero (a metrics type should degrade to
/// slightly-off numbers, never wrap to 2^64 on a reordered update).
fn saturating_dec(cell: &AtomicU64, n: u64) {
    let _ = cell.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_sub(n)));
}

/// Nearest-rank percentile over an already-sorted nanosecond reservoir,
/// reported in milliseconds.
fn percentile_ms(sorted_nanos: &[u64], q: f64) -> f64 {
    if sorted_nanos.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_nanos.len() - 1) as f64 * q).round() as usize;
    sorted_nanos[idx.min(sorted_nanos.len() - 1)] as f64 / 1e6
}

/// Frozen fleet statistics (what `acf serve` prints and tests assert on).
#[derive(Debug, Clone)]
pub struct FleetSnapshot {
    pub accepted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub queue_depth: u64,
    pub queue_peak: u64,
    pub wall_secs: f64,
    /// Completed images per second over the first→last completion window.
    pub sustained_img_s: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub replicas: Vec<ReplicaSnapshot>,
}

/// Frozen per-replica statistics.
#[derive(Debug, Clone)]
pub struct ReplicaSnapshot {
    pub images: u64,
    pub batches: u64,
    pub busy_secs: f64,
    /// Fraction of fleet wall time this replica spent inferring.
    pub utilization: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_quantiles() {
        let m = FleetMetrics::new(2);
        for _ in 0..10 {
            m.note_accepted();
        }
        m.note_rejected();
        m.note_dispatched(0, 6);
        m.note_dispatched(1, 4);
        assert_eq!(m.load_of(0), 6);
        assert_eq!(m.load_of(1), 4);
        for i in 0..10u64 {
            m.note_completed(Duration::from_millis(i + 1));
        }
        m.note_replica_batch(0, 6, Duration::from_millis(30));
        m.note_replica_batch(1, 4, Duration::from_millis(20));
        let s = m.snapshot();
        assert_eq!(s.accepted, 10);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.completed, 10);
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.queue_peak, 10);
        assert!(s.p50_ms <= s.p95_ms && s.p95_ms <= s.p99_ms);
        assert!((s.p99_ms - 10.0).abs() < 1e-6, "p99 {}", s.p99_ms);
        assert!(s.mean_ms > 5.0 && s.mean_ms < 6.0);
        assert_eq!(s.replicas[0].images, 6);
        assert_eq!(s.replicas[1].batches, 1);
        assert_eq!(m.load_of(0), 0);
        assert!(s.replicas[0].busy_secs > 0.0);
    }

    #[test]
    fn empty_snapshot_is_quiet() {
        let m = FleetMetrics::new(1);
        let s = m.snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p99_ms, 0.0);
        assert_eq!(s.sustained_img_s, 0.0);
        assert_eq!(s.replicas.len(), 1);
        assert_eq!(s.replicas[0].utilization, 0.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<u64> = (1..=100).map(|i| i * 1_000_000).collect();
        assert!((percentile_ms(&v, 0.50) - 50.0).abs() < 1.01);
        assert!((percentile_ms(&v, 0.99) - 99.0).abs() < 1.01);
        assert_eq!(percentile_ms(&[], 0.5), 0.0);
    }
}
