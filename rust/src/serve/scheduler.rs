//! The request scheduler: per-tenant bounded admission queues drained in
//! weighted-fair order, a micro-batching dispatcher, throughput-weighted
//! replica selection, explicit admission control, and — since PR 5 — a
//! *dynamic* replica set that grows and shrinks while traffic flows.
//!
//! **Multi-tenant, multi-model routing.** One deployment hosts several
//! models, and several tenants share it under quota. Each tenant binds
//! to one model ([`super::TenantSpec`]); admission validates against
//! *that* model and lands the request in the tenant's own bounded queue,
//! whose capacity is the tenant's quota share of the configured
//! `queue_depth` (floored at one slot). Overload therefore sheds the
//! right tenant: a customer that exceeds its share bounces off its own
//! full queue while its neighbors' queues still admit. The dispatcher
//! drains the queues *weighted-fair*: it always serves the non-empty
//! tenant with the lowest normalized service count `served / quota`, so
//! over any busy interval tenant throughput tracks quota ratios without
//! any tenant being starved outright. A single-tenant fleet degenerates
//! to exactly the old one-queue behavior (one implicit route, full
//! queue depth, FIFO order).
//!
//! Heterogeneous fleets put replicas with very different modeled rates
//! behind one queue, so the PR 2 least-loaded rule (pick the fewest
//! in-flight images) is wrong: three images queued on a DSP-starved
//! edge part take far longer to drain than five on the paper's board.
//! Dispatch is therefore *throughput-weighted*: every replica advertises
//! its plan's modeled `images_per_sec`, and the dispatcher picks — among
//! the live replicas serving the request's model — the one with the
//! smallest expected drain time `(in_flight + 1) / images_per_sec`.
//! With equal weights this degrades to exactly the least-loaded rule.
//!
//! Micro-batches clamp *per replica*, not globally: each replica's
//! ceiling is the configured `max_batch` scaled by its rate relative to
//! the fastest live replica of its model (floored at 1, capped at the
//! execution tier's lane width [`crate::netlist::sim::LANES`]), so one
//! dispatch costs roughly equal wall time on every part and a slow group
//! never hoards a lane-wide batch while fast silicon idles. A batch is
//! always single-model (it runs on one pipeline) but may mix tenants —
//! the fill path pulls from same-model tenant queues in weighted-fair
//! order.
//!
//! **Replica lifecycle.** PR 2–4 assumed plan-once/run-forever: the
//! dispatcher captured a fixed replica list at startup. The dispatcher
//! now reads a shared slot table on every pick, so
//! [`Server::add_replica`] can bring a freshly planned pipeline into
//! rotation mid-flight and [`Server::retire_replica`] can take one out:
//! the slot is unlisted first (no new dispatches), its feed closes, its
//! already-queued micro-batches drain (the *weighted-drain handoff* —
//! remaining load rebalances onto the surviving replicas by the same
//! expected-drain-time rule), and only then is its pipeline torn down.
//! A replica that misses the drain deadline is detached and *reported*
//! in the per-group drain summary — never silently dropped, and never
//! able to wedge a shutdown.
//!
//! Topology (all threads long-lived until retired or shutdown):
//!
//! ```text
//! submit_as(t,·) --push--> [tenant t queue] --\
//! submit_as(u,·) --push--> [tenant u queue] ---+--> dispatcher --+--> runner 0 -> replica 0
//!    |  tenant's share full =>                 |  (WFQ tenant    |--> runner 1 -> replica 1
//!    |  ServeError::Overloaded                 |   pick, then    +--> ... (slots added and
//!    +--> Pending (per-request reply)          |   weighted pick      retired live; dispatch
//!                                              |   over that          filtered to the
//!                                              |   model's slots)     request's model)
//! ```
//!
//! Backpressure story: the *only* unbounded buffers are per-request reply
//! channels (capacity one message each). The tenant queues are bounded
//! and non-blocking at admission — a full share is an `Overloaded` error
//! the caller sees immediately, never invisible queueing. Replica work
//! queues are bounded too; when every replica is busy the dispatcher
//! blocks, the tenant queues fill, and overload surfaces at the edge —
//! per tenant — which is the admission-control design the real-time
//! serving literature asks for.

use super::fault::{FaultEvent, FaultEventKind, LatencyShim};
use super::fleet::FleetHandle;
use super::metrics::{FleetMetrics, FleetSnapshot, TenantInfo};
use super::{ServeConfig, ServeError};
use crate::cnn::model::Model;
use crate::coordinator::{validate_image, Deployment};
use crate::trace::{self, ArgValue};
use crate::util::sync::lock_ok;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One admitted request traveling from its tenant queue to a replica
/// runner.
///
/// The `*_nanos` fields are lifecycle timestamps on the fleet's shared
/// [`crate::trace::Clock`], stamped where the request crosses each stage
/// boundary (admission, enqueue, dispatcher pull, runner handoff). The
/// runner turns them into the request's span chain at completion —
/// adjacent spans *share* their boundary timestamp, so the chain is
/// contiguous and non-overlapping by construction. Latency accounting
/// uses `admitted_nanos` on the same clock.
struct Request {
    /// Trace thread id within [`trace::PID_REQUESTS`] (ids start at 1;
    /// tid 0 is the shed/control track).
    id: u64,
    /// Index into the tenant routing table (0 for untenanted fleets).
    tenant: usize,
    image: Vec<i64>,
    admitted_nanos: u64,
    enqueued_nanos: u64,
    /// Stamped by the dispatcher on first pull (0 = not yet pulled;
    /// preserved across bounce re-dispatches).
    dequeued_nanos: u64,
    /// Stamped at every handoff attempt; the successful one wins.
    handoff_nanos: u64,
    reply: mpsc::Sender<Result<Vec<i64>, ServeError>>,
}

/// A handle to one in-flight request; resolves to its logits.
pub struct Pending {
    rx: mpsc::Receiver<Result<Vec<i64>, ServeError>>,
}

impl Pending {
    /// Block until the request completes.
    pub fn wait(self) -> Result<Vec<i64>, ServeError> {
        self.rx.recv().unwrap_or(Err(ServeError::ShuttingDown))
    }
}

/// One tenant's routing entry, fixed at startup.
#[derive(Debug, Clone)]
struct TenantRoute {
    /// Index into the fleet's deployed-model list.
    model_id: usize,
    /// Weighted-fair share (positive).
    quota: f64,
    /// This tenant's bounded queue capacity: its quota share of the
    /// configured queue depth, floored at one slot so no tenant is
    /// locked out entirely.
    cap: usize,
}

/// The multi-tenant ingress: one bounded FIFO per tenant plus the
/// weighted-fair service counters, under one lock with one condvar
/// (submitters wait for space, the dispatcher waits for work).
struct Ingress {
    state: Mutex<QueueState>,
    ready: Condvar,
}

struct QueueState {
    /// False once shutdown begins — the single source of truth for
    /// "still admitting" (same convention as the coordinator pipeline).
    open: bool,
    /// One bounded FIFO per tenant (parallel to the routing table).
    queues: Vec<VecDeque<Request>>,
    /// Requests handed to the dispatcher per tenant; the WFQ pick
    /// minimizes `served / quota`.
    served: Vec<u64>,
}

impl Ingress {
    /// Block until a request is available, returning the weighted-fair
    /// next `(tenant, request)`; `None` once the ingress is closed AND
    /// every queue is empty (the dispatcher's exit condition — queued
    /// work always drains before shutdown completes).
    fn pop_next(&self, routes: &[TenantRoute]) -> Option<(usize, Request)> {
        let mut st = lock_ok(&self.state);
        loop {
            if let Some(t) = wfq_pick(&st, routes, None) {
                let req = st.queues[t].pop_front().expect("picked tenant queue is non-empty");
                st.served[t] += 1;
                // Space freed: wake any submit_wait blocked on this share.
                self.ready.notify_all();
                return Some((t, req));
            }
            if !st.open {
                return None;
            }
            st = self.ready.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Non-blocking weighted-fair pop restricted to tenants routing to
    /// `model_id` — the dispatcher's batch-fill path (a micro-batch runs
    /// on one pipeline, so it is single-model by construction).
    fn try_pop_model(&self, routes: &[TenantRoute], model_id: usize) -> Option<Request> {
        let mut st = lock_ok(&self.state);
        let t = wfq_pick(&st, routes, Some(model_id))?;
        let req = st.queues[t].pop_front()?;
        st.served[t] += 1;
        self.ready.notify_all();
        Some(req)
    }
}

/// Weighted-fair pick: among non-empty tenant queues (optionally
/// restricted to one model), the tenant with the lowest normalized
/// service count `served / quota`. Ties break to the lower tenant id,
/// so the order is deterministic.
fn wfq_pick(st: &QueueState, routes: &[TenantRoute], model: Option<usize>) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (t, route) in routes.iter().enumerate() {
        if st.queues[t].is_empty() {
            continue;
        }
        if let Some(m) = model {
            if m != route.model_id {
                continue;
            }
        }
        let v = st.served[t] as f64 / route.quota;
        if best.map_or(true, |(_, bv)| v < bv) {
            best = Some((t, v));
        }
    }
    best.map(|(t, _)| t)
}

/// One live, dispatchable replica.
struct Slot {
    /// Stable replica id (index into the metrics registry; never reused).
    id: usize,
    group: usize,
    /// Index into the fleet's deployed-model list — dispatch only routes
    /// a request to a slot serving its model.
    model_id: usize,
    /// Modeled `images_per_sec` — the dispatch weight.
    weight: f64,
    tx: mpsc::SyncSender<Vec<Request>>,
}

/// A runner thread and the deployment it drives (kept so retirement can
/// tear the pipeline down *after* the drain, off the dispatch path).
struct Runner {
    id: usize,
    dep: Arc<Deployment>,
    handle: std::thread::JoinHandle<()>,
}

/// Outcome of retiring one replica.
#[derive(Debug, Clone)]
pub struct DrainReport {
    pub replica: usize,
    pub group: usize,
    /// Whether in-flight work reached zero before the deadline.
    pub drained: bool,
    /// Images still dispatched-not-done when the deadline expired.
    pub leftover: u64,
}

/// A running serving fleet: replicas with persistent pipelines, a
/// dispatcher, and per-replica runner threads. The replica set is
/// dynamic — see the module docs for the lifecycle.
pub struct Server {
    ingress: Arc<Ingress>,
    /// Tenant routing table (never empty: untenanted fleets get one
    /// implicit route with the full queue depth).
    routes: Arc<Vec<TenantRoute>>,
    metrics: Arc<FleetMetrics>,
    /// The fleet's deployed models, deduplicated by name — admission
    /// validates against the *tenant's* model, so rebalancing can swap
    /// every replica out without ever closing the front door.
    models: Vec<Arc<Model>>,
    /// Live dispatch targets (shared with the dispatcher thread).
    slots: Arc<Mutex<Vec<Slot>>>,
    /// Runners for live and draining replicas.
    runners: Mutex<Vec<Runner>>,
    dispatcher: Mutex<Option<std::thread::JoinHandle<()>>>,
    /// Set once shutdown has completed (idempotence + final snapshot).
    finished: Mutex<Option<FleetSnapshot>>,
    queue_depth: usize,
    drain_deadline: Duration,
    /// Next request id (trace tid). Starts at 1 — tid 0 of the requests
    /// process is the control track shed instants land on.
    next_req: AtomicU64,
    /// Per-replica synthetic latency injections (scenario faults),
    /// consulted by every runner at the dispatch boundary.
    degrade: Arc<LatencyShim>,
}

impl Server {
    /// Start serving a fleet. THE one serving entry point: the
    /// [`FleetHandle`] says what runs where (replicas, their device
    /// groups, and each group's model — what
    /// [`super::fleet::FleetPlan::deploy`] and friends produce, or
    /// [`FleetHandle::solo`] for a hand-built single-group fleet), and
    /// the [`ServeConfig`] says how to admit and dispatch (queue depth,
    /// batching, tenants).
    pub fn start(fleet: FleetHandle, cfg: &ServeConfig) -> Server {
        let FleetHandle { replicas, groups, labels, models: group_models } = fleet;
        assert!(!replicas.is_empty(), "a fleet needs at least one replica");
        assert_eq!(groups.len(), replicas.len(), "one group index per replica");
        let queue_depth = cfg.admission.queue_depth.max(1);
        // Per-replica micro-batch ceiling: at most one simulator lane
        // word (a wider batch would split into multiple lane groups and
        // only add queueing delay); per-slot scaling happens at dispatch
        // time against the *current* fastest live replica of the model.
        let global_batch = cfg.dispatch.max_batch.clamp(1, crate::netlist::sim::LANES);

        // The deployed-model list: per-group models deduplicated by name
        // (two boards serving the same model are one routing target).
        let mut models: Vec<Arc<Model>> = Vec::new();
        for m in &group_models {
            if !models.iter().any(|z| z.name == m.name) {
                models.push(Arc::clone(m));
            }
        }
        if models.is_empty() {
            models.push(Arc::clone(&replicas[0].model));
        }

        // Tenant routing table + metrics roster. No tenants configured =
        // one implicit route owning the whole queue (and no tenant axis
        // in the metrics, keeping single-tenant snapshots unchanged).
        let specs = &cfg.tenants.tenants;
        let total_quota: f64 = specs.iter().map(|t| t.quota).sum();
        let mut routes = Vec::with_capacity(specs.len().max(1));
        let mut roster = Vec::with_capacity(specs.len());
        for t in specs {
            assert!(t.quota > 0.0, "tenant '{}': quota must be positive", t.name);
            let model_id = if t.model.is_empty() {
                0
            } else {
                models.iter().position(|m| m.name == t.model).unwrap_or_else(|| {
                    panic!(
                        "tenant '{}' routes to model '{}', which is not deployed on this fleet",
                        t.name, t.model
                    )
                })
            };
            let cap = ((queue_depth as f64 * t.quota / total_quota).round() as usize).max(1);
            routes.push(TenantRoute { model_id, quota: t.quota, cap });
            roster.push(TenantInfo {
                name: t.name.clone(),
                model: models[model_id].name.clone(),
                quota: t.quota,
                p99_slo_ms: t.p99_slo_ms,
            });
        }
        if routes.is_empty() {
            routes.push(TenantRoute { model_id: 0, quota: 1.0, cap: queue_depth });
        }
        let routes = Arc::new(routes);

        let metrics = Arc::new(
            FleetMetrics::grouped_with(Vec::new(), labels, cfg.clock.clone(), cfg.tracer.clone())
                .with_tenants(roster),
        );
        let ingress = Arc::new(Ingress {
            state: Mutex::new(QueueState {
                open: true,
                queues: routes.iter().map(|_| VecDeque::new()).collect(),
                served: vec![0; routes.len()],
            }),
            ready: Condvar::new(),
        });
        let server = Server {
            ingress,
            routes: Arc::clone(&routes),
            metrics,
            models,
            slots: Arc::new(Mutex::new(Vec::new())),
            runners: Mutex::new(Vec::new()),
            dispatcher: Mutex::new(None),
            finished: Mutex::new(None),
            queue_depth,
            drain_deadline: cfg.dispatch.drain_deadline,
            next_req: AtomicU64::new(1),
            degrade: Arc::new(LatencyShim::new()),
        };
        for (dep, group) in replicas.into_iter().zip(groups) {
            server.add_slot(dep, group);
        }

        // Dispatcher: pull the weighted-fair next request, pick the live
        // replica of ITS model with the least expected drain time,
        // micro-batch up to that slot's clamp from same-model tenant
        // queues. A handoff that bounces (slot retired between pick and
        // send) is re-dispatched, so no admitted request is ever dropped.
        let slots = Arc::clone(&server.slots);
        let metrics = Arc::clone(&server.metrics);
        let ingress = Arc::clone(&server.ingress);
        let handle = std::thread::spawn(move || {
            let clock = metrics.clock().clone();
            // The tracer is fixed at construction, so stage-boundary
            // stamping (a clock read per pull/handoff) can be skipped for
            // the life of the server when tracing is off.
            let tracing = metrics.tracer().on();
            while let Some((tenant, mut first)) = ingress.pop_next(&routes) {
                if tracing && first.dequeued_nanos == 0 {
                    first.dequeued_nanos = clock.now_nanos();
                }
                let model_id = routes[tenant].model_id;
                let mut batch = vec![first];
                // Work in hand must land somewhere within this grace
                // period. Normally a pick succeeds instantly; the
                // deadline only matters if every runner serving this
                // model died (the batch is then failed loudly instead of
                // spinning forever and wedging shutdown's dispatcher
                // join).
                let give_up = Instant::now() + Duration::from_millis(50);
                while !batch.is_empty() {
                    let Some((id, tx, cap)) = pick_slot(&slots, &metrics, global_batch, model_id)
                    else {
                        if Instant::now() >= give_up {
                            metrics.note_abandoned(batch.len() as u64);
                            for req in batch.drain(..) {
                                metrics.note_failed();
                                let _ = req.reply.send(Err(ServeError::ReplicaFailed(
                                    "no live replicas serve this model".into(),
                                )));
                            }
                            break;
                        }
                        // Mid-swap instant with no live slot: adds always
                        // precede retires, so this resolves immediately.
                        std::thread::sleep(Duration::from_micros(200));
                        continue;
                    };
                    while batch.len() < cap {
                        match ingress.try_pop_model(&routes, model_id) {
                            Some(mut r) => {
                                if tracing && r.dequeued_nanos == 0 {
                                    r.dequeued_nanos = clock.now_nanos();
                                }
                                batch.push(r);
                            }
                            None => break,
                        }
                    }
                    // Work carried over from a bounce may exceed THIS
                    // slot's clamp (a slow part must never receive a
                    // fast part's batch whole); the tail re-dispatches
                    // on the next pick.
                    let rest = if batch.len() > cap { batch.split_off(cap) } else { Vec::new() };
                    if tracing {
                        let t_handoff = clock.now_nanos();
                        for r in &mut batch {
                            r.handoff_nanos = t_handoff;
                        }
                    }
                    metrics.note_dispatched(id, batch.len() as u64);
                    match tx.send(batch) {
                        Ok(()) => batch = rest,
                        Err(mpsc::SendError(mut bounced)) => {
                            // The runner's feed closed under us: rewind
                            // the books and pick again. If the slot is
                            // still listed the runner *died* (a retire
                            // already unlists) — unlist it and account
                            // the death (its channel-trapped images
                            // included) so live counts, in-flight
                            // gauges, and the drain summary stay honest.
                            metrics.note_requeued(id, bounced.len() as u64);
                            let dead = {
                                let mut slots = lock_ok(&slots);
                                let pos = slots.iter().position(|s| s.id == id);
                                pos.map(|p| slots.remove(p))
                            };
                            if let Some(slot) = dead {
                                metrics.note_retiring(slot.id);
                                let lost = metrics.note_dead_replica(slot.id);
                                metrics.note_drain_timeout(slot.group, lost);
                            }
                            bounced.extend(rest);
                            batch = bounced;
                        }
                    }
                }
            }
            // Ingress closed and drained; slot feeds stay open for the
            // shutdown path to close after this thread is joined.
        });
        *lock_ok(&server.dispatcher) = Some(handle);
        server
    }

    /// Start serving a heterogeneous fleet from parallel arrays.
    #[deprecated(note = "use Server::start(FleetHandle, &ServeConfig) — \
                         FleetPlan::deploy* returns the handle directly")]
    pub fn start_grouped(
        replicas: Vec<Arc<Deployment>>,
        groups: Vec<usize>,
        labels: Vec<String>,
        cfg: &ServeConfig,
    ) -> Server {
        assert!(!replicas.is_empty(), "a fleet needs at least one replica");
        // Reconstruct each group's model from its first replica (the old
        // entry points were single-model, but this keeps mixed handles
        // honest too).
        let models: Vec<Arc<Model>> = (0..labels.len())
            .map(|g| {
                groups
                    .iter()
                    .position(|&gi| gi == g)
                    .map(|i| Arc::clone(&replicas[i].model))
                    .unwrap_or_else(|| Arc::clone(&replicas[0].model))
            })
            .collect();
        Server::start(FleetHandle::new(replicas, groups, labels, models), cfg)
    }

    /// Register a replica and bring it into dispatch rotation
    /// (infallible — shared by startup and live adds).
    fn add_slot(&self, dep: Arc<Deployment>, group: usize) -> usize {
        let id = self.metrics.register_replica(group);
        let weight = dep.plan.images_per_sec.max(1e-9);
        let model_id = self
            .models
            .iter()
            .position(|m| m.name == dep.model.name)
            .expect("replica's model is not among the fleet's deployed models");
        // Route the replica's pipeline-worker layer spans onto its trace
        // track (the id only exists now, post-registration). Re-attaching
        // is fine: a deployment reused by a later server just moves to
        // that server's sink and track.
        if self.metrics.tracer().on() {
            dep.attach_trace(
                self.metrics.tracer().clone(),
                self.metrics.clock().clone(),
                trace::pid_of_group(group),
                trace::tid_of_replica(id),
            );
        } else {
            dep.detach_trace();
        }
        // Depth 2: one batch inferring, one staged (double buffering,
        // same rationale as the pipeline's CHANNEL_DEPTH).
        let (btx, brx) = mpsc::sync_channel::<Vec<Request>>(2);
        let runner_dep = Arc::clone(&dep);
        let metrics = Arc::clone(&self.metrics);
        let shim = Arc::clone(&self.degrade);
        let handle =
            std::thread::spawn(move || run_replica(id, group, &runner_dep, &brx, &metrics, &shim));
        lock_ok(&self.runners).push(Runner { id, dep, handle });
        lock_ok(&self.slots).push(Slot { id, group, model_id, weight, tx: btx });
        id
    }

    /// Bring a freshly deployed replica into dispatch rotation while the
    /// server keeps admitting (its model must be one of the fleet's
    /// deployed models — a cross-model shift deploys the *other* model's
    /// plan into the group). Returns its stable replica id.
    pub fn add_replica(&self, dep: Arc<Deployment>, group: usize) -> Result<usize, ServeError> {
        if !lock_ok(&self.ingress.state).open {
            return Err(ServeError::ShuttingDown);
        }
        Ok(self.add_slot(dep, group))
    }

    /// Retire one replica without draining the server: unlist it (no new
    /// dispatches — its share of load immediately rebalances onto the
    /// surviving replicas by expected drain time), close its feed, wait
    /// up to the configured drain deadline for its in-flight micro-
    /// batches to finish, then tear its pipeline down off-thread. The
    /// last live replica cannot be retired.
    pub fn retire_replica(&self, replica: usize) -> Result<DrainReport, ServeError> {
        let slot = {
            let mut slots = lock_ok(&self.slots);
            if slots.len() <= 1 {
                return Err(ServeError::Rebalance(
                    "cannot retire the last live replica".into(),
                ));
            }
            let Some(pos) = slots.iter().position(|s| s.id == replica) else {
                return Err(ServeError::Rebalance(format!(
                    "replica {replica} is not in dispatch rotation"
                )));
            };
            slots.remove(pos)
        };
        let group = slot.group;
        self.metrics.note_retiring(replica);
        drop(slot); // closes the runner's feed once queued batches drain
        let deadline = Instant::now() + self.drain_deadline;
        let report = self.reap(replica, group, deadline);
        Ok(report)
    }

    /// Fault injection: kill one replica *without* a drain wait. The slot
    /// is unlisted exactly as in [`Server::retire_replica`] — queued
    /// micro-batches still finish (admitted requests are never dropped by
    /// a kill; what cannot be served reroutes or sheds at admission) —
    /// but the caller gets control back immediately and a background
    /// reaper absorbs the teardown. Unlike retirement, killing a group's
    /// (or the fleet's) last replica is allowed: the outcome is recorded
    /// as a [`FaultEventKind::GroupLost`] / [`FaultEventKind::FleetLost`]
    /// event, traffic reroutes to any survivors, and a fleet with no
    /// survivors degrades to the dispatcher's abandon path — a failed
    /// scenario verdict, never a process abort.
    pub fn kill_replica(&self, replica: usize) -> Result<(), ServeError> {
        let slot = {
            let mut slots = lock_ok(&self.slots);
            let Some(pos) = slots.iter().position(|s| s.id == replica) else {
                return Err(ServeError::Fault(format!(
                    "replica {replica} is not in dispatch rotation"
                )));
            };
            slots.remove(pos)
        };
        let group = slot.group;
        self.metrics.note_retiring(replica);
        self.degrade.clear(replica);
        drop(slot); // closes the runner's feed once queued batches drain
        self.metrics.note_fault(FaultEvent {
            at_secs: 0.0,
            kind: FaultEventKind::ReplicaDeath,
            group: Some(group),
            replica: Some(replica),
            detail: "injected kill (no drain)".into(),
        });
        let live = self.live_counts();
        let survivors: usize = live.iter().sum();
        if live.get(group).copied() == Some(0) {
            self.metrics.note_fault(FaultEvent {
                at_secs: 0.0,
                kind: FaultEventKind::GroupLost,
                group: Some(group),
                replica: None,
                detail: format!("group empty; {survivors} fleet survivors"),
            });
        }
        if survivors == 0 {
            self.metrics.note_fault(FaultEvent {
                at_secs: 0.0,
                kind: FaultEventKind::FleetLost,
                group: None,
                replica: None,
                detail: "no live replicas remain".into(),
            });
        }
        // Reap off-thread: wait out the in-flight drain and tear the
        // pipeline down without blocking the injector.
        let runner = {
            let mut runners = lock_ok(&self.runners);
            runners.iter().position(|r| r.id == replica).map(|pos| runners.remove(pos))
        };
        let metrics = Arc::clone(&self.metrics);
        let deadline = Instant::now() + self.drain_deadline;
        std::thread::spawn(move || {
            reap_runner(&metrics, runner, replica, group, deadline);
        });
        Ok(())
    }

    /// Fault injection: kill every live replica of `group` at once (a
    /// board falling off the fabric). Returns how many replicas died.
    pub fn kill_group(&self, group: usize) -> Result<usize, ServeError> {
        let ids = self.replica_ids_of_group(group);
        if ids.is_empty() {
            return Err(ServeError::Fault(format!("group {group} has no live replicas")));
        }
        self.metrics.note_fault(FaultEvent {
            at_secs: 0.0,
            kind: FaultEventKind::GroupLoss,
            group: Some(group),
            replica: None,
            detail: format!("killing {} replicas", ids.len()),
        });
        let n = ids.len();
        for id in ids {
            self.kill_replica(id)?;
        }
        Ok(n)
    }

    /// Fault injection: add `extra` synthetic delay per micro-batch on
    /// `replica`, applied by its runner at the dispatch boundary — the
    /// slowdown is visible to latency reservoirs, utilization windows,
    /// and rebalance signals exactly as genuinely slow silicon would be.
    pub fn inject_latency(&self, replica: usize, extra: Duration) -> Result<(), ServeError> {
        let group = lock_ok(&self.slots).iter().find(|s| s.id == replica).map(|s| s.group);
        let Some(group) = group else {
            return Err(ServeError::Fault(format!(
                "replica {replica} is not in dispatch rotation"
            )));
        };
        self.degrade.inject(replica, extra);
        self.metrics.note_fault(FaultEvent {
            at_secs: 0.0,
            kind: FaultEventKind::LatencyDegrade,
            group: Some(group),
            replica: Some(replica),
            detail: format!("+{:.1}ms per batch", extra.as_secs_f64() * 1e3),
        });
        Ok(())
    }

    /// Lift a latency injection; a no-op if none is active on `replica`.
    pub fn clear_latency(&self, replica: usize) {
        if self.degrade.clear(replica) {
            let group = lock_ok(&self.slots).iter().find(|s| s.id == replica).map(|s| s.group);
            self.metrics.note_fault(FaultEvent {
                at_secs: 0.0,
                kind: FaultEventKind::LatencyRestore,
                group,
                replica: Some(replica),
                detail: "degradation lifted".into(),
            });
        }
    }

    /// Wait (until `deadline`) for `replica`'s in-flight work to drain,
    /// record the outcome in the per-group drain summary, and join or
    /// detach its runner. Shared by live retirement and shutdown. The
    /// drain condition covers both the scheduler's own dispatch counters
    /// AND the pipeline's job gauge ([`Deployment::in_flight`]), so a
    /// one-shot `infer_batch` caller sharing the replica outside the
    /// server holds the drain open too.
    fn reap(&self, replica: usize, group: usize, deadline: Instant) -> DrainReport {
        let runner = {
            let mut runners = lock_ok(&self.runners);
            runners.iter().position(|r| r.id == replica).map(|pos| runners.remove(pos))
        };
        reap_runner(&self.metrics, runner, replica, group, deadline)
    }

    /// Live replicas per device group (dispatch rotation view).
    pub fn live_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.metrics.n_groups()];
        for s in lock_ok(&self.slots).iter() {
            if let Some(c) = counts.get_mut(s.group) {
                *c += 1;
            }
        }
        counts
    }

    /// Replica ids currently in dispatch rotation for `group`, least
    /// loaded first (the retirement-candidate order).
    pub fn replica_ids_of_group(&self, group: usize) -> Vec<usize> {
        let mut ids: Vec<usize> = lock_ok(&self.slots)
            .iter()
            .filter(|s| s.group == group)
            .map(|s| s.id)
            .collect();
        ids.sort_by_key(|&id| self.metrics.load_of(id));
        ids
    }

    /// The fleet's deployed models (deduplicated; what tenant routes
    /// resolve against).
    pub fn models(&self) -> &[Arc<Model>] {
        &self.models
    }

    /// The model tenant `t`'s requests are validated against and routed
    /// to (tenant 0 of an untenanted fleet is the implicit default).
    pub fn model_of_tenant(&self, tenant: usize) -> &Arc<Model> {
        &self.models[self.routes[tenant].model_id]
    }

    /// Number of tenant routes (1 for untenanted fleets — the implicit
    /// default route).
    pub fn n_tenants(&self) -> usize {
        self.routes.len()
    }

    /// Admission-controlled submission as the default tenant: validates
    /// the image, then tries to enqueue without blocking. A full queue
    /// rejects with [`ServeError::Overloaded`] — the caller decides
    /// whether to retry, shed, or propagate.
    pub fn submit(&self, image: Vec<i64>) -> Result<Pending, ServeError> {
        self.submit_as(0, image)
    }

    /// Admission-controlled submission on behalf of `tenant` (an index
    /// into the configured tenant list). Validates against the tenant's
    /// model; a full *tenant share* rejects with
    /// [`ServeError::Overloaded`] while other tenants' shares still
    /// admit — overload sheds the tenant that exceeded its quota.
    pub fn submit_as(&self, tenant: usize, image: Vec<i64>) -> Result<Pending, ServeError> {
        self.admit(tenant, image, false)
    }

    /// Blocking submission for closed-loop callers (benches, tests):
    /// waits for queue space instead of rejecting.
    pub fn submit_wait(&self, image: Vec<i64>) -> Result<Pending, ServeError> {
        self.admit(0, image, true)
    }

    /// [`Server::submit_wait`] on behalf of `tenant`.
    pub fn submit_wait_as(&self, tenant: usize, image: Vec<i64>) -> Result<Pending, ServeError> {
        self.admit(tenant, image, true)
    }

    /// Shared admission path: validate against the tenant's model, build
    /// the request, enqueue in the tenant's bounded share (rejecting or
    /// waiting when full per `wait`), account on acceptance.
    fn admit(&self, tenant: usize, image: Vec<i64>, wait: bool) -> Result<Pending, ServeError> {
        let route = self
            .routes
            .get(tenant)
            .unwrap_or_else(|| panic!("tenant {tenant} is not in the routing table"));
        let clock = self.metrics.clock();
        let admitted_nanos = clock.now_nanos();
        validate_image(&self.models[route.model_id], &image).map_err(ServeError::BadRequest)?;
        // The admit span covers validation; with tracing off, skip the
        // second clock read (the boundary is never rendered).
        let enqueued_nanos =
            if self.metrics.tracer().on() { clock.now_nanos() } else { admitted_nanos };
        let (rtx, rrx) = mpsc::channel();
        let req = Request {
            id: self.next_req.fetch_add(1, Ordering::Relaxed),
            tenant,
            image,
            admitted_nanos,
            enqueued_nanos,
            dequeued_nanos: 0,
            handoff_nanos: 0,
            reply: rtx,
        };
        {
            let mut st = lock_ok(&self.ingress.state);
            loop {
                if !st.open {
                    return Err(ServeError::ShuttingDown);
                }
                if st.queues[tenant].len() < route.cap {
                    break;
                }
                if !wait {
                    drop(st);
                    self.metrics.note_rejected_t(tenant);
                    return Err(ServeError::Overloaded { queue_depth: route.cap });
                }
                st = self.ingress.ready.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            st.queues[tenant].push_back(req);
        }
        self.metrics.note_accepted_t(tenant);
        self.ingress.ready.notify_all();
        Ok(Pending { rx: rrx })
    }

    /// The shared live metrics (snapshot any time).
    pub fn metrics(&self) -> &FleetMetrics {
        &self.metrics
    }

    /// The total admission capacity across tenant shares (the
    /// denominator of the rebalancer's queue-pressure signal).
    pub fn queue_capacity(&self) -> usize {
        self.queue_depth
    }

    /// Stop admitting, drain everything in flight (reporting any replica
    /// that misses the drain deadline in the per-group drain summary),
    /// join all threads, and return the final fleet statistics.
    /// Idempotent — later calls return the same snapshot.
    pub fn shutdown(&self) -> FleetSnapshot {
        let mut finished = lock_ok(&self.finished);
        if let Some(snap) = finished.as_ref() {
            return snap.clone();
        }
        self.degrade.clear_all();
        // Closing the ingress lets the dispatcher drain every tenant
        // queue and exit.
        lock_ok(&self.ingress.state).open = false;
        self.ingress.ready.notify_all();
        if let Some(h) = lock_ok(&self.dispatcher).take() {
            let _ = h.join();
        }
        // Close every live feed, then hold all replicas to one shared
        // drain deadline. Outcomes land in the per-group drain summary
        // (`GroupSnapshot::{drained, drain_failed, drain_leftover_images}`)
        // — a replica that cannot finish is reported, not silently
        // dropped, and cannot wedge the shutdown.
        let closing: Vec<(usize, usize)> = {
            let mut slots = lock_ok(&self.slots);
            slots.drain(..).map(|s| (s.id, s.group)).collect()
        };
        let deadline = Instant::now() + self.drain_deadline;
        for (id, group) in closing {
            self.reap(id, group, deadline);
        }
        // Anything left in `runners` had no slot — runners whose death
        // the dispatcher already accounted, or kill-reaped replicas whose
        // background reaper already removed them. Join the finished ones
        // (they are done or nearly done), detach the rest to reaper
        // threads.
        for r in lock_ok(&self.runners).drain(..) {
            if r.handle.is_finished() {
                let _ = r.handle.join();
                drop(r.dep);
            } else {
                std::thread::spawn(move || {
                    let _ = r.handle.join();
                    drop(r.dep);
                });
            }
        }
        let snap = self.metrics.snapshot();
        *finished = Some(snap.clone());
        snap
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Pick — among live replicas serving `model_id` — the one with the
/// least expected drain time `(in_flight + 1) / weight`, returning its
/// id, a feed handle, and its per-dispatch micro-batch clamp (scaled by
/// its weight relative to the fastest live replica of that model).
fn pick_slot(
    slots: &Mutex<Vec<Slot>>,
    metrics: &FleetMetrics,
    global_batch: usize,
    model_id: usize,
) -> Option<(usize, mpsc::SyncSender<Vec<Request>>, usize)> {
    let slots = lock_ok(slots);
    let best = slots.iter().filter(|s| s.model_id == model_id).min_by(|a, b| {
        let da = (metrics.load_of(a.id) + 1) as f64 / a.weight;
        let db = (metrics.load_of(b.id) + 1) as f64 / b.weight;
        // Weights are clamped positive at registration, so drain times
        // are finite; an Equal fallback keeps a hypothetical NaN from
        // aborting the dispatcher mid-run.
        da.partial_cmp(&db).unwrap_or(std::cmp::Ordering::Equal)
    })?;
    let top = slots
        .iter()
        .filter(|s| s.model_id == model_id)
        .map(|s| s.weight)
        .fold(f64::MIN, f64::max);
    let cap = ((global_batch as f64 * best.weight / top).ceil() as usize).clamp(1, global_batch);
    Some((best.id, best.tx.clone(), cap))
}

/// Wait (until `deadline`) for `replica`'s in-flight work to drain,
/// record the outcome in the per-group drain summary, and join or
/// detach its runner. Shared by live retirement, shutdown, and the
/// kill-path's background reaper (which is why this is a free function
/// over the metrics handle, not a `Server` method). The drain condition
/// covers both the scheduler's own dispatch counters AND the pipeline's
/// job gauge ([`Deployment::in_flight`]), so a one-shot `infer_batch`
/// caller sharing the replica outside the server holds the drain open
/// too.
fn reap_runner(
    metrics: &FleetMetrics,
    runner: Option<Runner>,
    replica: usize,
    group: usize,
    deadline: Instant,
) -> DrainReport {
    let pipeline_busy =
        |r: &Option<Runner>| r.as_ref().map(|r| r.dep.in_flight() > 0).unwrap_or(false);
    let mut leftover = metrics.load_of(replica);
    while (leftover > 0 || pipeline_busy(&runner)) && Instant::now() < deadline {
        std::thread::sleep(Duration::from_micros(500));
        leftover = metrics.load_of(replica);
    }
    // Also give the runner thread itself (and any one-shot pipeline
    // work) until the deadline to wind down, so join below cannot
    // block past it.
    let finished = loop {
        match &runner {
            Some(r) if !r.handle.is_finished() => {
                if Instant::now() >= deadline {
                    break false;
                }
                std::thread::sleep(Duration::from_micros(500));
            }
            _ => break true,
        }
    };
    let drained = leftover == 0 && finished && !pipeline_busy(&runner);
    if drained {
        metrics.note_drained(group);
        if let Some(r) = runner {
            let _ = r.handle.join();
            drop(r.dep); // pipeline teardown, after the drain
        }
    } else {
        metrics.note_drain_timeout(group, leftover);
        if let Some(r) = runner {
            // Report-and-detach: a reaper thread absorbs the eventual
            // teardown so a wedged replica cannot block the caller.
            std::thread::spawn(move || {
                let _ = r.handle.join();
                drop(r.dep);
            });
        }
    }
    DrainReport { replica, group, drained, leftover }
}

/// What the runner keeps of a request while its image is inferring: the
/// stage-boundary timestamps that become its span chain, the tenant for
/// per-tenant latency accounting, and the reply.
struct ReqMeta {
    id: u64,
    tenant: usize,
    admitted_nanos: u64,
    enqueued_nanos: u64,
    dequeued_nanos: u64,
    handoff_nanos: u64,
    reply: mpsc::Sender<Result<Vec<i64>, ServeError>>,
}

/// One replica runner: pull a micro-batch, run it through the replica's
/// persistent pipeline, reply per request, account per replica (and
/// therefore per device group) and per tenant. When tracing, each
/// completed request's full span chain is recorded here — the only point
/// that has every boundary timestamp in hand — and the batch itself gets
/// a span on the replica's own track.
fn run_replica(
    ri: usize,
    group: usize,
    dep: &Deployment,
    brx: &mpsc::Receiver<Vec<Request>>,
    metrics: &FleetMetrics,
    shim: &LatencyShim,
) {
    let clock = metrics.clock().clone();
    let tracer = metrics.tracer().clone();
    let (rpid, rtid) = (trace::pid_of_group(group), trace::tid_of_replica(ri));
    while let Ok(batch) = brx.recv() {
        // Degradation shim at the dispatch boundary: an injected fault
        // slows this replica down *before* the batch enters its
        // pipeline, so the extra time lands in every request's measured
        // latency and stretches the replica's effective service rate
        // (fewer batches per second) — exactly how throttled silicon
        // would present.
        if let Some(extra) = shim.delay_of(ri) {
            std::thread::sleep(extra);
        }
        let n = batch.len() as u64;
        let mut images = Vec::with_capacity(batch.len());
        let mut meta = Vec::with_capacity(batch.len());
        for req in batch {
            images.push(req.image);
            meta.push(ReqMeta {
                id: req.id,
                tenant: req.tenant,
                admitted_nanos: req.admitted_nanos,
                enqueued_nanos: req.enqueued_nanos,
                dequeued_nanos: req.dequeued_nanos,
                handoff_nanos: req.handoff_nanos,
                reply: req.reply,
            });
        }
        let t_start = clock.now_nanos();
        match dep.infer_batch(&images) {
            Ok(outs) => {
                let t_infer_done = clock.now_nanos();
                for (slot, (m, logits)) in meta.into_iter().zip(outs).enumerate() {
                    let t_done = clock.now_nanos();
                    metrics.note_completed_t(
                        ri,
                        m.tenant,
                        Duration::from_nanos(t_done.saturating_sub(m.admitted_nanos)),
                    );
                    let _ = m.reply.send(Ok(logits));
                    if tracer.on() {
                        let t_replied = clock.now_nanos();
                        let tid = m.id;
                        let pid = trace::PID_REQUESTS;
                        tracer.span("admit", "request", pid, tid, m.admitted_nanos, m.enqueued_nanos, Vec::new());
                        tracer.span("queue_wait", "request", pid, tid, m.enqueued_nanos, m.dequeued_nanos, Vec::new());
                        tracer.span("batch_form", "request", pid, tid, m.dequeued_nanos, m.handoff_nanos, Vec::new());
                        tracer.span(
                            "dispatch",
                            "request",
                            pid,
                            tid,
                            m.handoff_nanos,
                            t_start,
                            vec![
                                ("replica", ArgValue::U(ri as u64)),
                                ("group", ArgValue::U(group as u64)),
                                ("lane_slot", ArgValue::U(slot as u64)),
                            ],
                        );
                        tracer.span("sim", "request", pid, tid, t_start, t_infer_done, Vec::new());
                        tracer.span("reply", "request", pid, tid, t_infer_done, t_replied, Vec::new());
                    }
                }
                if tracer.on() {
                    tracer.span(
                        "infer_batch",
                        "replica",
                        rpid,
                        rtid,
                        t_start,
                        t_infer_done,
                        vec![("images", ArgValue::U(n))],
                    );
                }
            }
            Err(e) => {
                // Inputs were validated at admission, so this is a replica
                // fault; fail the whole micro-batch loudly.
                let msg = e.to_string();
                for m in meta {
                    metrics.note_failed();
                    let _ = m.reply.send(Err(ServeError::ReplicaFailed(msg.clone())));
                }
            }
        }
        metrics.note_replica_batch(
            ri,
            n,
            Duration::from_nanos(clock.now_nanos().saturating_sub(t_start)),
        );
    }
}
