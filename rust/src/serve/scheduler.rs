//! The request scheduler: a bounded submission queue, a micro-batching
//! dispatcher, throughput-weighted replica selection, and explicit
//! admission control.
//!
//! Heterogeneous fleets put replicas with very different modeled rates
//! behind one queue, so the PR 2 least-loaded rule (pick the fewest
//! in-flight images) is wrong: three images queued on a DSP-starved
//! edge part take far longer to drain than five on the paper's board.
//! Dispatch is therefore *throughput-weighted*: every replica advertises
//! its plan's modeled `images_per_sec`, and the dispatcher picks the
//! replica with the smallest expected drain time
//! `(in_flight + 1) / images_per_sec`. With equal weights this degrades
//! to exactly the least-loaded rule.
//!
//! Micro-batches clamp *per replica*, not globally: each replica's
//! ceiling is the configured `max_batch` scaled by its rate relative to
//! the fastest replica (floored at 1, capped at the execution tier's
//! lane width [`crate::netlist::sim::LANES`]), so one dispatch costs
//! roughly equal wall time on every part and a slow group never hoards
//! a lane-wide batch while fast silicon idles.
//!
//! Topology (all threads long-lived, torn down on [`Server::shutdown`]):
//!
//! ```text
//! submit() --try_send--> [bounded queue] --> dispatcher --+--> runner 0 -> replica 0 pipeline
//!    |  full => ServeError::Overloaded    (weighted pick, |--> runner 1 -> replica 1 pipeline
//!    +--> Pending (per-request reply)      per-replica    +--> ...
//!                                          micro-batch)
//! ```
//!
//! Backpressure story: the *only* unbounded buffers are per-request reply
//! channels (capacity one message each). The submission queue is bounded
//! and non-blocking at admission — a full queue is an `Overloaded` error
//! the caller sees immediately, never invisible queueing. Replica work
//! queues are bounded too; when every replica is busy the dispatcher
//! blocks, the submission queue fills, and overload surfaces at the edge
//! — the admission-control design the real-time serving literature asks
//! for.

use super::metrics::{FleetMetrics, FleetSnapshot};
use super::{ServeConfig, ServeError};
use crate::coordinator::Deployment;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// One admitted request traveling from the queue to a replica runner.
struct Request {
    image: Vec<i64>,
    admitted: Instant,
    reply: mpsc::Sender<Result<Vec<i64>, ServeError>>,
}

/// A handle to one in-flight request; resolves to its logits.
pub struct Pending {
    rx: mpsc::Receiver<Result<Vec<i64>, ServeError>>,
}

impl Pending {
    /// Block until the request completes.
    pub fn wait(self) -> Result<Vec<i64>, ServeError> {
        self.rx.recv().unwrap_or(Err(ServeError::ShuttingDown))
    }
}

/// A running serving fleet: replicas with persistent pipelines, a
/// dispatcher, and per-replica runner threads.
pub struct Server {
    /// `None` once shutdown begins — the single source of truth for
    /// "still admitting" (same convention as the coordinator pipeline).
    ingress: Mutex<Option<mpsc::SyncSender<Request>>>,
    metrics: Arc<FleetMetrics>,
    replicas: Vec<Arc<Deployment>>,
    threads: Vec<std::thread::JoinHandle<()>>,
    queue_depth: usize,
}

impl Server {
    /// Start serving a single-device fleet (every replica in one metrics
    /// group). Dispatch is still throughput-weighted — identical plans
    /// just make the weights equal.
    pub fn start(replicas: Vec<Arc<Deployment>>, cfg: &ServeConfig) -> Server {
        let groups = vec![0; replicas.len()];
        Server::start_grouped(replicas, groups, vec!["fleet".to_string()], cfg)
    }

    /// Start serving a heterogeneous fleet: `groups[i]` is the device-
    /// group index of `replicas[i]` and `labels[g]` names group `g`
    /// (what [`super::fleet::FleetPlan::replica_groups`] /
    /// [`super::fleet::FleetPlan::group_labels`] produce).
    pub fn start_grouped(
        replicas: Vec<Arc<Deployment>>,
        groups: Vec<usize>,
        labels: Vec<String>,
        cfg: &ServeConfig,
    ) -> Server {
        assert!(!replicas.is_empty(), "a fleet needs at least one replica");
        assert_eq!(groups.len(), replicas.len(), "one group index per replica");
        let queue_depth = cfg.queue_depth.max(1);
        // Each replica advertises its plan's modeled throughput as its
        // dispatch weight.
        let weights: Vec<f64> =
            replicas.iter().map(|d| d.plan.images_per_sec.max(1e-9)).collect();
        let top_weight = weights.iter().copied().fold(f64::MIN, f64::max);
        // Per-replica micro-batch ceiling: at most one simulator lane
        // word (a wider batch would split into multiple lane groups and
        // only add queueing delay), scaled down for replicas modeled
        // slower than the fastest so a dispatch costs roughly equal wall
        // time on every part.
        let global_batch = cfg.max_batch.clamp(1, crate::netlist::sim::LANES);
        let max_batch: Vec<usize> = weights
            .iter()
            .map(|w| ((global_batch as f64 * w / top_weight).ceil() as usize).clamp(1, global_batch))
            .collect();
        let metrics = Arc::new(FleetMetrics::grouped(groups, labels));
        let (tx, rx) = mpsc::sync_channel::<Request>(queue_depth);
        let mut threads = Vec::with_capacity(replicas.len() + 1);

        // Replica runners: one thread per replica, fed micro-batches.
        let mut batch_txs = Vec::with_capacity(replicas.len());
        for (ri, dep) in replicas.iter().enumerate() {
            // Depth 2: one batch inferring, one staged (double buffering,
            // same rationale as the pipeline's CHANNEL_DEPTH).
            let (btx, brx) = mpsc::sync_channel::<Vec<Request>>(2);
            batch_txs.push(btx);
            let dep = Arc::clone(dep);
            let metrics = Arc::clone(&metrics);
            threads.push(std::thread::spawn(move || run_replica(ri, &dep, &brx, &metrics)));
        }

        // Dispatcher: drain the queue, pick the replica with the least
        // expected drain time, micro-batch up to ITS clamp.
        {
            let metrics = Arc::clone(&metrics);
            threads.push(std::thread::spawn(move || {
                while let Ok(first) = rx.recv() {
                    let target = (0..batch_txs.len())
                        .min_by(|&a, &b| {
                            let da = (metrics.load_of(a) + 1) as f64 / weights[a];
                            let db = (metrics.load_of(b) + 1) as f64 / weights[b];
                            da.partial_cmp(&db).expect("drain time is finite")
                        })
                        .expect("at least one replica");
                    let mut batch = vec![first];
                    while batch.len() < max_batch[target] {
                        match rx.try_recv() {
                            Ok(r) => batch.push(r),
                            Err(_) => break,
                        }
                    }
                    metrics.note_dispatched(target, batch.len() as u64);
                    if batch_txs[target].send(batch).is_err() {
                        return; // runner died; Overloaded backpressure takes over
                    }
                }
                // Queue disconnected and drained; batch_txs drop here and
                // the runner feeds close.
            }));
        }

        Server { ingress: Mutex::new(Some(tx)), metrics, replicas, threads, queue_depth }
    }

    /// Admission-controlled submission: validates the image, then tries
    /// to enqueue without blocking. A full queue rejects with
    /// [`ServeError::Overloaded`] — the caller decides whether to retry,
    /// shed, or propagate.
    pub fn submit(&self, image: Vec<i64>) -> Result<Pending, ServeError> {
        self.admit(image, |tx, req| match tx.try_send(req) {
            Ok(()) => Ok(()),
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.note_rejected();
                Err(ServeError::Overloaded { queue_depth: self.queue_depth })
            }
            Err(mpsc::TrySendError::Disconnected(_)) => Err(ServeError::ShuttingDown),
        })
    }

    /// Blocking submission for closed-loop callers (benches, tests):
    /// waits for queue space instead of rejecting.
    pub fn submit_wait(&self, image: Vec<i64>) -> Result<Pending, ServeError> {
        self.admit(image, |tx, req| tx.send(req).map_err(|_| ServeError::ShuttingDown))
    }

    /// Shared admission path: validate, build the request, enqueue via
    /// `send` (the try_send/send strategy), account on acceptance.
    fn admit(
        &self,
        image: Vec<i64>,
        send: impl FnOnce(&mpsc::SyncSender<Request>, Request) -> Result<(), ServeError>,
    ) -> Result<Pending, ServeError> {
        let tx = self.sender()?;
        self.replicas[0].validate_image(&image).map_err(ServeError::BadRequest)?;
        let (rtx, rrx) = mpsc::channel();
        send(&tx, Request { image, admitted: Instant::now(), reply: rtx })?;
        self.metrics.note_accepted();
        Ok(Pending { rx: rrx })
    }

    fn sender(&self) -> Result<mpsc::SyncSender<Request>, ServeError> {
        self.ingress.lock().unwrap().clone().ok_or(ServeError::ShuttingDown)
    }

    /// The shared live metrics (snapshot any time).
    pub fn metrics(&self) -> &FleetMetrics {
        &self.metrics
    }

    /// The replica deployments (for modeled-vs-measured reports).
    pub fn replicas(&self) -> &[Arc<Deployment>] {
        &self.replicas
    }

    /// Stop admitting, drain everything in flight, join all threads, and
    /// return the final fleet statistics.
    pub fn shutdown(mut self) -> FleetSnapshot {
        self.stop();
        self.metrics.snapshot()
    }

    fn stop(&mut self) {
        // Dropping the ingress sender lets the dispatcher drain the queue
        // and then unwind the runners.
        *self.ingress.lock().unwrap() = None;
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// One replica runner: pull a micro-batch, run it through the replica's
/// persistent pipeline, reply per request, account per replica (and
/// therefore per device group).
fn run_replica(
    ri: usize,
    dep: &Deployment,
    brx: &mpsc::Receiver<Vec<Request>>,
    metrics: &FleetMetrics,
) {
    while let Ok(batch) = brx.recv() {
        let n = batch.len() as u64;
        let mut images = Vec::with_capacity(batch.len());
        let mut meta = Vec::with_capacity(batch.len());
        for req in batch {
            images.push(req.image);
            meta.push((req.admitted, req.reply));
        }
        let t0 = Instant::now();
        match dep.infer_batch(&images) {
            Ok(outs) => {
                for ((admitted, reply), logits) in meta.into_iter().zip(outs) {
                    metrics.note_completed(ri, admitted.elapsed());
                    let _ = reply.send(Ok(logits));
                }
            }
            Err(e) => {
                // Inputs were validated at admission, so this is a replica
                // fault; fail the whole micro-batch loudly.
                let msg = e.to_string();
                for (_, reply) in meta {
                    metrics.note_failed();
                    let _ = reply.send(Err(ServeError::ReplicaFailed(msg.clone())));
                }
            }
        }
        metrics.note_replica_batch(ri, n, t0.elapsed());
    }
}
