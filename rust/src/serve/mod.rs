//! The traffic-scale serving tier (`acf serve`).
//!
//! Everything below the planner treats one device budget as one network;
//! this module turns a *catalog of device budgets* into a fleet:
//!
//! * [`fleet`] — the fleet planner: takes a [`FleetSpec`] of
//!   `(device, count?)` entries (one per physical part), builds each
//!   device's memoized replica-count frontier ([`FleetFrontier`]) by
//!   running [`crate::planner::plan`] under divided budgets
//!   ([`crate::fabric::device::Device::shard`], with per-replica
//!   coefficient BRAM charged off the top), and composes the groups
//!   across devices — maximizing modeled fleet throughput, or minimizing
//!   modeled static power under a target SLO. Replicas on different
//!   parts run *different* plans (the paper's IP substitutions, live
//!   inside one fleet).
//! * [`scheduler`] — the request scheduler: a bounded submission queue
//!   with explicit admission control ([`ServeError::Overloaded`] instead
//!   of unbounded queueing), per-replica micro-batch clamps,
//!   throughput-weighted replica dispatch (expected drain time, not raw
//!   queue length) onto the coordinator's persistent pipelines, and a
//!   dynamic replica set (add/retire with weighted-drain handoff).
//! * [`rebalance`] — the live controller: watches windowed fleet
//!   signals (queue pressure, per-group utilization, p99 drift) and
//!   grows or shrinks device groups from the memoized frontier without
//!   draining the server.
//! * [`metrics`] — fleet statistics: p50/p95/p99 end-to-end latency,
//!   sustained throughput, queue pressure, utilization, per-group drain
//!   summaries, and the rebalance event log, broken out per replica and
//!   per device group.
//! * [`open_loop`] / [`step_load`] — deterministic open-loop synthetic
//!   load (Poisson arrivals via a reproducible [`arrival_schedule`])
//!   driving the above; the `acf serve` CLI prints its
//!   modeled-vs-measured comparison.

pub mod fleet;
pub mod metrics;
pub mod rebalance;
pub mod scheduler;

pub use fleet::{
    compose_frontier, plan_fixed_fleet, plan_fleet, plan_fleet_spec, plan_signature, FleetEntry,
    FleetFrontier, FleetPlan, FleetSpec, GroupFrontier, GroupPlan, DEFAULT_MAX_REPLICAS,
};
pub use metrics::{
    FleetMetrics, FleetSnapshot, GroupSnapshot, GroupWindow, RebalanceAction, RebalanceEvent,
    ReplicaSnapshot,
};
pub use rebalance::{RebalanceConfig, Rebalancer};
pub use scheduler::{DrainReport, Pending, Server};

use crate::coordinator::DeployError;
use crate::trace::{Clock, Tracer};
use crate::util::rng::Rng;
use std::time::{Duration, Instant};

/// Serving-path errors (the request-level counterpart of
/// [`crate::coordinator::DeployError`]).
#[derive(Debug)]
pub enum ServeError {
    /// The bounded submission queue is full: the fleet is saturated and
    /// this request was shed at admission.
    Overloaded { queue_depth: usize },
    /// The image failed ingress validation.
    BadRequest(DeployError),
    /// The server is draining; no new requests are admitted.
    ShuttingDown,
    /// A replica failed while the request was in flight.
    ReplicaFailed(String),
    /// A fleet-resize operation could not be applied (e.g. retiring the
    /// last live replica, or a replica id no longer in rotation).
    Rebalance(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded { queue_depth } => {
                write!(f, "overloaded: submission queue (depth {queue_depth}) is full")
            }
            ServeError::BadRequest(e) => write!(f, "bad request: {e}"),
            ServeError::ShuttingDown => write!(f, "server is shutting down"),
            ServeError::ReplicaFailed(msg) => write!(f, "replica failed: {msg}"),
            ServeError::Rebalance(msg) => write!(f, "rebalance rejected: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ServeError::BadRequest(e) => Some(e),
            _ => None,
        }
    }
}

/// Scheduler knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bounded submission-queue depth; a full queue rejects with
    /// [`ServeError::Overloaded`].
    pub queue_depth: usize,
    /// Largest micro-batch the dispatcher forms per replica handoff.
    /// Clamped to the execution tier's lane width
    /// ([`crate::netlist::sim::LANES`]) so each dispatch maps onto whole
    /// lane-packed pipeline jobs, then scaled *per replica* by modeled
    /// throughput relative to the fleet's fastest live replica — slow
    /// parts take proportionally smaller batches (see [`scheduler`]).
    pub max_batch: usize,
    /// How long a retiring replica (live rebalance or shutdown) gets to
    /// finish its in-flight micro-batches before it is detached and
    /// *reported* in the per-group drain summary.
    pub drain_deadline: Duration,
    /// Time source for metrics windows, latency reservoirs, and trace
    /// spans. Injected (rather than created inside the server) so spans
    /// recorded *outside* the server — e.g. the CLI's per-engine settle
    /// attribution — line up on the same timeline.
    pub clock: Clock,
    /// Trace handle. [`crate::trace::Tracer::off`] (the default) records
    /// nothing and costs one branch per instrumentation site; pass
    /// `Tracer::ring(cap)` to collect spans for `acf serve --trace`.
    pub tracer: Tracer,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            queue_depth: 64,
            max_batch: 8,
            drain_deadline: Duration::from_secs(5),
            clock: Clock::wall(),
            tracer: Tracer::off(),
        }
    }
}

/// Outcome of one open-loop request: which corpus image was sent and what
/// came back (rejections appear as `Err(Overloaded)`).
#[derive(Debug)]
pub struct LoadOutcome {
    pub image_idx: usize,
    pub result: Result<Vec<i64>, ServeError>,
}

/// One phase of a step-load profile: `requests` Poisson arrivals at
/// `offered_img_s`.
#[derive(Debug, Clone, Copy)]
pub struct LoadPhase {
    pub requests: usize,
    pub offered_img_s: f64,
}

/// The deterministic open-loop arrival schedule: for each of `requests`
/// arrivals, its absolute due time (seconds from the run's start) and
/// its corpus index. Exponential inter-arrival gaps with mean
/// `1/offered_img_s` drawn from `seed` — the same seed, rate, corpus
/// size, and request count reproduce the *identical* sequence on every
/// run and every machine, which is what pins the serve benches and the
/// CI step-load tests.
pub fn arrival_schedule(
    corpus_len: usize,
    requests: usize,
    offered_img_s: f64,
    seed: u64,
) -> Vec<(f64, usize)> {
    assert!(corpus_len > 0, "load generator needs at least one image");
    assert!(offered_img_s > 0.0, "offered rate must be positive");
    let mut rng = Rng::new(seed);
    let mut at = 0.0f64;
    (0..requests)
        .map(|_| {
            // Exponential inter-arrival with mean 1/rate; (1 - u) avoids
            // ln(0).
            at += -(1.0 - rng.unit_f64()).ln() / offered_img_s;
            (at, rng.index(corpus_len))
        })
        .collect()
}

/// Drive `server` with an open-loop synthetic workload: `requests`
/// arrivals at `offered_img_s` (Poisson — see [`arrival_schedule`]),
/// each a uniformly chosen image from `corpus`. Open loop means arrivals
/// never wait for responses: if the fleet falls behind, the queue fills
/// and admission control sheds load, exactly like production ingress.
/// Responses are collected after the last arrival.
pub fn open_loop(
    server: &Server,
    corpus: &[Vec<i64>],
    requests: usize,
    offered_img_s: f64,
    seed: u64,
) -> Vec<LoadOutcome> {
    step_load(server, corpus, &[LoadPhase { requests, offered_img_s }], seed)
}

/// Drive `server` with a multi-phase open-loop profile (e.g. the
/// low → spike → low shape the rebalancer is tested under). Phase `k`
/// draws its arrivals from a seed forked off `seed` by `k`, so adding
/// or resizing a phase never perturbs the others. Arrival timing stays
/// open-loop *across* phases: the schedule is absolute from the start
/// of the run, and responses are only collected after the last arrival
/// of the last phase.
pub fn step_load(
    server: &Server,
    corpus: &[Vec<i64>],
    phases: &[LoadPhase],
    seed: u64,
) -> Vec<LoadOutcome> {
    assert!(!corpus.is_empty(), "load generator needs at least one image");
    let start = Instant::now();
    let mut base = 0.0f64; // absolute end of the previous phase
    let mut submitted: Vec<(usize, Result<Pending, ServeError>)> = Vec::new();
    for (k, phase) in phases.iter().enumerate() {
        let schedule = arrival_schedule(
            corpus.len(),
            phase.requests,
            phase.offered_img_s,
            seed.wrapping_add((k as u64).wrapping_mul(0x9E3779B97F4A7C15)),
        );
        let mut last = base;
        for (at, idx) in schedule {
            let due = Duration::from_secs_f64(base + at);
            last = base + at;
            let elapsed = start.elapsed();
            if due > elapsed {
                std::thread::sleep(due - elapsed);
            }
            submitted.push((idx, server.submit(corpus[idx].clone())));
        }
        base = last;
    }
    submitted
        .into_iter()
        .map(|(image_idx, sub)| LoadOutcome {
            image_idx,
            result: match sub {
                Ok(p) => p.wait(),
                Err(e) => Err(e),
            },
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_schedule_is_deterministic() {
        // Same seed + rate + corpus + count ⇒ the identical sequence —
        // the reproducibility contract CI serve tests rely on.
        let a = arrival_schedule(16, 200, 1500.0, 0xBE7C);
        let b = arrival_schedule(16, 200, 1500.0, 0xBE7C);
        assert_eq!(a.len(), 200);
        for (x, y) in a.iter().zip(&b) {
            assert!(x.0.to_bits() == y.0.to_bits() && x.1 == y.1, "{x:?} != {y:?}");
        }
        // A different seed produces a different sequence.
        let c = arrival_schedule(16, 200, 1500.0, 0xBE7D);
        assert!(a.iter().zip(&c).any(|(x, y)| x.0 != y.0 || x.1 != y.1));
        // A different rate rescales time but draws the same images.
        let d = arrival_schedule(16, 200, 150.0, 0xBE7C);
        assert!(a.iter().zip(&d).all(|(x, y)| x.1 == y.1));
        assert!(d.last().unwrap().0 > a.last().unwrap().0);
    }

    #[test]
    fn arrival_schedule_statistics_match_the_offered_rate() {
        // 2000 arrivals at 1000 img/s should span ~2 s; the sample mean
        // of an exponential at n=2000 is within a loose 15% band.
        let s = arrival_schedule(8, 2000, 1000.0, 7);
        let span = s.last().unwrap().0;
        assert!((1.7..2.3).contains(&span), "span {span}");
        // Monotone non-decreasing due times; indices stay in range.
        for w in s.windows(2) {
            assert!(w[1].0 >= w[0].0);
        }
        assert!(s.iter().all(|&(_, i)| i < 8));
    }
}
