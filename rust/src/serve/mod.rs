//! The traffic-scale serving tier (`acf serve`).
//!
//! Everything below the planner treats one device budget as one network;
//! this module turns a *catalog of device budgets* into a fleet:
//!
//! * [`fleet`] — the fleet planner: takes a [`FleetSpec`] of
//!   `(device, count?)` entries (one per physical part), builds each
//!   device's replica-count frontier by running [`crate::planner::plan`]
//!   under divided budgets ([`crate::fabric::device::Device::shard`],
//!   with per-replica coefficient BRAM charged off the top), and
//!   composes the groups across devices — maximizing modeled fleet
//!   throughput, or minimizing modeled static power under a target SLO.
//!   Replicas on different parts run *different* plans (the paper's IP
//!   substitutions, live inside one fleet).
//! * [`scheduler`] — the request scheduler: a bounded submission queue
//!   with explicit admission control ([`ServeError::Overloaded`] instead
//!   of unbounded queueing), per-replica micro-batch clamps, and
//!   throughput-weighted replica dispatch (expected drain time, not raw
//!   queue length) onto the coordinator's persistent pipelines.
//! * [`metrics`] — fleet statistics: p50/p95/p99 end-to-end latency,
//!   sustained throughput, queue pressure, and utilization, broken out
//!   per replica and per device group.
//! * [`open_loop`] — a deterministic open-loop synthetic load generator
//!   (Poisson arrivals via [`crate::util::rng`]) driving the above; the
//!   `acf serve` CLI prints its modeled-vs-measured comparison.

pub mod fleet;
pub mod metrics;
pub mod scheduler;

pub use fleet::{
    plan_fixed_fleet, plan_fleet, plan_fleet_spec, FleetEntry, FleetPlan, FleetSpec, GroupPlan,
    DEFAULT_MAX_REPLICAS,
};
pub use metrics::{FleetMetrics, FleetSnapshot, GroupSnapshot, ReplicaSnapshot};
pub use scheduler::{Pending, Server};

use crate::coordinator::DeployError;
use crate::util::rng::Rng;
use std::time::{Duration, Instant};

/// Serving-path errors (the request-level counterpart of
/// [`crate::coordinator::DeployError`]).
#[derive(Debug)]
pub enum ServeError {
    /// The bounded submission queue is full: the fleet is saturated and
    /// this request was shed at admission.
    Overloaded { queue_depth: usize },
    /// The image failed ingress validation.
    BadRequest(DeployError),
    /// The server is draining; no new requests are admitted.
    ShuttingDown,
    /// A replica failed while the request was in flight.
    ReplicaFailed(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded { queue_depth } => {
                write!(f, "overloaded: submission queue (depth {queue_depth}) is full")
            }
            ServeError::BadRequest(e) => write!(f, "bad request: {e}"),
            ServeError::ShuttingDown => write!(f, "server is shutting down"),
            ServeError::ReplicaFailed(msg) => write!(f, "replica failed: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ServeError::BadRequest(e) => Some(e),
            _ => None,
        }
    }
}

/// Scheduler knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bounded submission-queue depth; a full queue rejects with
    /// [`ServeError::Overloaded`].
    pub queue_depth: usize,
    /// Largest micro-batch the dispatcher forms per replica handoff.
    /// Clamped to the execution tier's lane width
    /// ([`crate::netlist::sim::LANES`]) so each dispatch maps onto whole
    /// lane-packed pipeline jobs, then scaled *per replica* by modeled
    /// throughput relative to the fleet's fastest replica — slow parts
    /// take proportionally smaller batches (see [`scheduler`]).
    pub max_batch: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig { queue_depth: 64, max_batch: 8 }
    }
}

/// Outcome of one open-loop request: which corpus image was sent and what
/// came back (rejections appear as `Err(Overloaded)`).
#[derive(Debug)]
pub struct LoadOutcome {
    pub image_idx: usize,
    pub result: Result<Vec<i64>, ServeError>,
}

/// Drive `server` with an open-loop synthetic workload: `requests`
/// arrivals at `offered_img_s` (Poisson — exponential inter-arrival gaps
/// drawn from `seed`), each a uniformly chosen image from `corpus`. Open
/// loop means arrivals never wait for responses: if the fleet falls
/// behind, the queue fills and admission control sheds load, exactly like
/// production ingress. Responses are collected after the last arrival.
pub fn open_loop(
    server: &Server,
    corpus: &[Vec<i64>],
    requests: usize,
    offered_img_s: f64,
    seed: u64,
) -> Vec<LoadOutcome> {
    assert!(!corpus.is_empty(), "load generator needs at least one image");
    assert!(offered_img_s > 0.0, "offered rate must be positive");
    let mut rng = Rng::new(seed);
    let start = Instant::now();
    let mut next_arrival = 0.0f64; // seconds since start
    let mut submitted: Vec<(usize, Result<Pending, ServeError>)> = Vec::with_capacity(requests);
    for _ in 0..requests {
        // Exponential inter-arrival with mean 1/rate; (1 - u) avoids ln(0).
        let gap = -(1.0 - rng.unit_f64()).ln() / offered_img_s;
        next_arrival += gap;
        let due = Duration::from_secs_f64(next_arrival);
        let elapsed = start.elapsed();
        if due > elapsed {
            std::thread::sleep(due - elapsed);
        }
        let idx = rng.index(corpus.len());
        submitted.push((idx, server.submit(corpus[idx].clone())));
    }
    submitted
        .into_iter()
        .map(|(image_idx, sub)| LoadOutcome {
            image_idx,
            result: match sub {
                Ok(p) => p.wait(),
                Err(e) => Err(e),
            },
        })
        .collect()
}
