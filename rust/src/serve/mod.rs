//! The traffic-scale serving tier (`acf serve`).
//!
//! Everything below the planner treats one device budget as one network;
//! this module turns a *catalog of device budgets* into a fleet:
//!
//! * [`fleet`] — the fleet planner: takes a [`FleetSpec`] of
//!   `(device, count?)` entries (one per physical part), builds each
//!   device's memoized replica-count frontier ([`FleetFrontier`]) by
//!   running [`crate::planner::plan`] under divided budgets
//!   ([`crate::fabric::device::Device::shard`], with per-replica
//!   coefficient BRAM charged off the top), and composes the groups
//!   across devices — maximizing modeled fleet throughput, or minimizing
//!   modeled static power under a target SLO. Replicas on different
//!   parts run *different* plans (the paper's IP substitutions, live
//!   inside one fleet).
//! * [`scheduler`] — the request scheduler: a bounded submission queue
//!   with explicit admission control ([`ServeError::Overloaded`] instead
//!   of unbounded queueing), per-replica micro-batch clamps,
//!   throughput-weighted replica dispatch (expected drain time, not raw
//!   queue length) onto the coordinator's persistent pipelines, and a
//!   dynamic replica set (add/retire with weighted-drain handoff).
//! * [`rebalance`] — the live controller: watches windowed fleet
//!   signals (queue pressure, per-group utilization, p99 drift) and
//!   grows or shrinks device groups from the memoized frontier without
//!   draining the server.
//! * [`metrics`] — fleet statistics: p50/p95/p99 end-to-end latency,
//!   sustained throughput, queue pressure, utilization, per-group drain
//!   summaries, and the rebalance event log, broken out per replica and
//!   per device group.
//! * [`open_loop`] / [`step_load`] — deterministic open-loop synthetic
//!   load (Poisson arrivals via a reproducible [`arrival_schedule`])
//!   driving the above; the `acf serve` CLI prints its
//!   modeled-vs-measured comparison.
//!
//! ## Multi-model, multi-tenant serving
//!
//! One deployment hosts several CNNs at once. The [`FleetSpec::plan`]
//! builder composes a fleet over a **model×device** frontier (each
//! physical board is assigned one model's bitstream), [`FleetPlan`]'s
//! deploy methods return a [`FleetHandle`] describing which groups carry
//! which models, and the one serving entry point —
//! [`Server::start`]`(fleet, &config)` — routes requests by
//! `(tenant, model)`: each [`TenantSpec`] binds a named tenant to a
//! model with an admission quota, admission runs per-tenant bounded
//! queues sized by quota share (the over-quota tenant sheds, others are
//! unaffected), and dispatch drains tenants weighted-fair (lowest
//! served/quota first) onto the replicas serving their model. Per-tenant
//! p99 and shed rate land in [`FleetSnapshot::tenants`] and
//! `report::tenant_table`.

pub mod fault;
pub mod fleet;
pub mod metrics;
pub mod rebalance;
pub mod scenario;
pub mod scheduler;

pub use fault::{FaultEvent, FaultEventKind, FaultKind, FaultSpec, LatencyShim};
pub use fleet::{
    compose_frontier, plan_signature, FleetEntry, FleetFrontier, FleetHandle, FleetPlan,
    FleetPlanner, FleetSpec, GroupFrontier, GroupPlan, DEFAULT_MAX_REPLICAS,
};
#[allow(deprecated)]
pub use fleet::{plan_fixed_fleet, plan_fleet, plan_fleet_spec};
pub use metrics::{
    FleetMetrics, FleetSnapshot, FleetWindow, GroupSnapshot, GroupWindow, RangeStats,
    RebalanceAction, RebalanceEvent, ReplicaSnapshot, TenantInfo, TenantSnapshot, Totals,
};
pub use rebalance::{
    shift_decision, RebalanceConfig, Rebalancer, RecoveryEnvelope, RecoveryTracker,
};
pub use scenario::{
    run_scenario, FaultOutcome, PhaseVerdict, Scenario, ScenarioOpts, ScenarioReport,
    ScenarioTenant, TenantPhaseVerdict,
};
pub use scheduler::{DrainReport, Pending, Server};

use crate::coordinator::DeployError;
use crate::trace::{Clock, Tracer};
use crate::util::rng::Rng;
use std::time::{Duration, Instant};

/// Serving-path errors (the request-level counterpart of
/// [`crate::coordinator::DeployError`]).
#[derive(Debug)]
pub enum ServeError {
    /// The bounded submission queue is full: the fleet is saturated and
    /// this request was shed at admission.
    Overloaded { queue_depth: usize },
    /// The image failed ingress validation.
    BadRequest(DeployError),
    /// The server is draining; no new requests are admitted.
    ShuttingDown,
    /// A replica failed while the request was in flight.
    ReplicaFailed(String),
    /// A fleet-resize operation could not be applied (e.g. retiring the
    /// last live replica, or a replica id no longer in rotation).
    Rebalance(String),
    /// A fault injection could not be applied (e.g. targeting a group
    /// with no live replicas). Distinct from [`ServeError::Rebalance`]
    /// because the scenario engine treats it as a scenario-authoring
    /// error, not a fleet condition.
    Fault(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded { queue_depth } => {
                write!(f, "overloaded: submission queue (depth {queue_depth}) is full")
            }
            ServeError::BadRequest(e) => write!(f, "bad request: {e}"),
            ServeError::ShuttingDown => write!(f, "server is shutting down"),
            ServeError::ReplicaFailed(msg) => write!(f, "replica failed: {msg}"),
            ServeError::Rebalance(msg) => write!(f, "rebalance rejected: {msg}"),
            ServeError::Fault(msg) => write!(f, "fault injection rejected: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ServeError::BadRequest(e) => Some(e),
            _ => None,
        }
    }
}

/// Admission-control knobs (the ingress side of the scheduler).
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Bounded submission-queue depth, split across tenants by quota
    /// share; a tenant whose share is full rejects with
    /// [`ServeError::Overloaded`].
    pub queue_depth: usize,
}

impl Default for AdmissionConfig {
    fn default() -> AdmissionConfig {
        AdmissionConfig { queue_depth: 64 }
    }
}

/// Dispatch-side knobs (queue → replica handoff).
#[derive(Debug, Clone)]
pub struct DispatchConfig {
    /// Largest micro-batch the dispatcher forms per replica handoff.
    /// Clamped to the execution tier's lane width
    /// ([`crate::netlist::sim::LANES`]) so each dispatch maps onto whole
    /// lane-packed pipeline jobs, then scaled *per replica* by modeled
    /// throughput relative to the fleet's fastest live replica — slow
    /// parts take proportionally smaller batches (see [`scheduler`]).
    pub max_batch: usize,
    /// How long a retiring replica (live rebalance or shutdown) gets to
    /// finish its in-flight micro-batches before it is detached and
    /// *reported* in the per-group drain summary.
    pub drain_deadline: Duration,
}

impl Default for DispatchConfig {
    fn default() -> DispatchConfig {
        DispatchConfig { max_batch: 8, drain_deadline: Duration::from_secs(5) }
    }
}

/// One tenant's admission contract: a name, the model its requests run
/// on, its weighted-fair quota, and an optional p99 SLO class (reported
/// against, never enforced by dropping completed work).
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub name: String,
    /// Model name the tenant's requests route to. An empty string binds
    /// to the fleet's first model (the single-model default).
    pub model: String,
    /// Weighted-fair share: admission capacity and dispatch service are
    /// proportional to `quota / Σ quotas`. Must be positive.
    pub quota: f64,
    /// Declared p99 SLO in ms, reported in the tenant table.
    pub p99_slo_ms: Option<f64>,
}

impl TenantSpec {
    pub fn new(name: &str, model: &str, quota: f64) -> TenantSpec {
        TenantSpec { name: name.into(), model: model.into(), quota, p99_slo_ms: None }
    }
}

/// The tenant roster. Empty (the default) means one implicit tenant
/// named `default` with quota 1 bound to the fleet's first model —
/// exactly the pre-multi-tenant behavior.
#[derive(Debug, Clone, Default)]
pub struct TenantConfig {
    pub tenants: Vec<TenantSpec>,
}

/// Scheduler configuration, in nested sections so scenario files, the
/// `--serve-config` JSON, and CLI flags share one field list:
/// [`AdmissionConfig`] (ingress), [`DispatchConfig`] (queue → replica),
/// [`TenantConfig`] (who may ask for what).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub admission: AdmissionConfig,
    pub dispatch: DispatchConfig,
    pub tenants: TenantConfig,
    /// Time source for metrics windows, latency reservoirs, and trace
    /// spans. Injected (rather than created inside the server) so spans
    /// recorded *outside* the server — e.g. the CLI's per-engine settle
    /// attribution — line up on the same timeline.
    pub clock: Clock,
    /// Trace handle. [`crate::trace::Tracer::off`] (the default) records
    /// nothing and costs one branch per instrumentation site; pass
    /// `Tracer::ring(cap)` to collect spans for `acf serve --trace`.
    pub tracer: Tracer,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            admission: AdmissionConfig::default(),
            dispatch: DispatchConfig::default(),
            tenants: TenantConfig::default(),
            clock: Clock::wall(),
            tracer: Tracer::off(),
        }
    }
}

impl ServeConfig {
    /// The common test/bench shape: a queue depth and a batch clamp,
    /// everything else default.
    pub fn sized(queue_depth: usize, max_batch: usize) -> ServeConfig {
        ServeConfig {
            admission: AdmissionConfig { queue_depth },
            dispatch: DispatchConfig { max_batch, ..DispatchConfig::default() },
            ..ServeConfig::default()
        }
    }

    /// Load the serializable sections (`admission` / `dispatch` /
    /// `tenants`) from `--serve-config` JSON. Absent keys keep their
    /// defaults; `clock` and `tracer` are runtime handles and not
    /// configurable from a file.
    ///
    /// ```json
    /// {
    ///   "admission": {"queue_depth": 128},
    ///   "dispatch": {"max_batch": 8, "drain_deadline_ms": 5000},
    ///   "tenants": [
    ///     {"name": "tenantA", "model": "lenet-tiny", "quota": 3.0, "p99_slo_ms": 50.0},
    ///     {"name": "tenantB", "model": "lenet-wide-2x", "quota": 1.0}
    ///   ]
    /// }
    /// ```
    pub fn from_json(v: &crate::util::json::Json) -> Result<ServeConfig, crate::util::json::JsonError> {
        use crate::util::json::JsonError;
        let mut cfg = ServeConfig::default();
        if let Some(a) = v.get_opt("admission") {
            cfg.admission.queue_depth = a.get_usize_or("queue_depth", cfg.admission.queue_depth)?;
        }
        if let Some(d) = v.get_opt("dispatch") {
            cfg.dispatch.max_batch = d.get_usize_or("max_batch", cfg.dispatch.max_batch)?;
            let ms = d.get_f64_or(
                "drain_deadline_ms",
                cfg.dispatch.drain_deadline.as_secs_f64() * 1e3,
            )?;
            cfg.dispatch.drain_deadline = Duration::from_secs_f64(ms / 1e3);
        }
        if let Some(t) = v.get_opt("tenants") {
            let mut tenants = Vec::new();
            for item in t.as_arr()? {
                let quota = item.get_f64_or("quota", 1.0)?;
                if !(quota > 0.0) {
                    return Err(JsonError::Access("tenant quota must be positive".into()));
                }
                tenants.push(TenantSpec {
                    name: item.get("name")?.as_str()?.to_string(),
                    model: item.get_str_or("model", "")?.to_string(),
                    quota,
                    p99_slo_ms: match item.get_opt("p99_slo_ms") {
                        Some(s) => Some(s.as_f64()?),
                        None => None,
                    },
                });
            }
            cfg.tenants = TenantConfig { tenants };
        }
        Ok(cfg)
    }

    /// The serializable sections, mirror of [`ServeConfig::from_json`].
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{obj, Json};
        let tenants: Vec<Json> = self
            .tenants
            .tenants
            .iter()
            .map(|t| {
                let mut fields = vec![
                    ("name", t.name.as_str().into()),
                    ("model", t.model.as_str().into()),
                    ("quota", t.quota.into()),
                ];
                if let Some(slo) = t.p99_slo_ms {
                    fields.push(("p99_slo_ms", slo.into()));
                }
                obj_from(fields)
            })
            .collect();
        obj([
            ("admission", obj([("queue_depth", self.admission.queue_depth.into())])),
            (
                "dispatch",
                obj([
                    ("max_batch", self.dispatch.max_batch.into()),
                    (
                        "drain_deadline_ms",
                        (self.dispatch.drain_deadline.as_secs_f64() * 1e3).into(),
                    ),
                ]),
            ),
            ("tenants", Json::Arr(tenants)),
        ])
    }
}

/// [`crate::util::json::obj`] for a runtime-sized field list.
fn obj_from(fields: Vec<(&str, crate::util::json::Json)>) -> crate::util::json::Json {
    crate::util::json::Json::Obj(
        fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
    )
}

/// Outcome of one open-loop request: which corpus image was sent and what
/// came back (rejections appear as `Err(Overloaded)`).
#[derive(Debug)]
pub struct LoadOutcome {
    pub image_idx: usize,
    pub result: Result<Vec<i64>, ServeError>,
}

/// One phase of a step-load profile: `requests` Poisson arrivals at
/// `offered_img_s`.
#[derive(Debug, Clone, Copy)]
pub struct LoadPhase {
    pub requests: usize,
    pub offered_img_s: f64,
}

/// The deterministic open-loop arrival schedule: for each of `requests`
/// arrivals, its absolute due time (seconds from the run's start) and
/// its corpus index. Exponential inter-arrival gaps with mean
/// `1/offered_img_s` drawn from `seed` — the same seed, rate, corpus
/// size, and request count reproduce the *identical* sequence on every
/// run and every machine, which is what pins the serve benches and the
/// CI step-load tests.
pub fn arrival_schedule(
    corpus_len: usize,
    requests: usize,
    offered_img_s: f64,
    seed: u64,
) -> Vec<(f64, usize)> {
    profile_schedule(corpus_len, requests, &LoadProfile::Constant { img_s: offered_img_s }, seed)
}

/// A time-varying offered-rate shape for one scenario phase. The rate is
/// a function of *arrival index* (fraction of the way through the
/// phase), so the same profile stretches or compresses with the request
/// count — quick mode scales a phase down without changing its shape.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LoadProfile {
    /// Flat offered rate (what [`arrival_schedule`] always produced).
    Constant { img_s: f64 },
    /// Linear ramp across the phase — half a diurnal cycle; chain a ramp
    /// up and a ramp down for the full curve.
    Ramp { from_img_s: f64, to_img_s: f64 },
    /// Flash crowd: `base_img_s` except between `start_frac` and
    /// `end_frac` of the phase, where the rate jumps to `spike_img_s`.
    Spike { base_img_s: f64, spike_img_s: f64, start_frac: f64, end_frac: f64 },
    /// Adversarial micro-bursts: every `every` arrivals, the next `len`
    /// arrive at `burst_img_s` instead of `base_img_s` — repeated
    /// short-lived queue slams that hunt for admission-control and
    /// rebalance-hysteresis edge cases.
    Bursts { base_img_s: f64, burst_img_s: f64, every: usize, len: usize },
}

impl LoadProfile {
    /// The offered rate for arrival `i` of `requests`.
    pub fn rate_at(&self, i: usize, requests: usize) -> f64 {
        let frac = if requests > 1 { i as f64 / (requests - 1) as f64 } else { 0.0 };
        match *self {
            LoadProfile::Constant { img_s } => img_s,
            LoadProfile::Ramp { from_img_s, to_img_s } => {
                from_img_s + (to_img_s - from_img_s) * frac
            }
            LoadProfile::Spike { base_img_s, spike_img_s, start_frac, end_frac } => {
                if frac >= start_frac && frac < end_frac {
                    spike_img_s
                } else {
                    base_img_s
                }
            }
            LoadProfile::Bursts { base_img_s, burst_img_s, every, len } => {
                if every > 0 && i % every < len {
                    burst_img_s
                } else {
                    base_img_s
                }
            }
        }
    }

    /// The peak rate anywhere in the profile (sanity checks / reports).
    pub fn peak_img_s(&self) -> f64 {
        match *self {
            LoadProfile::Constant { img_s } => img_s,
            LoadProfile::Ramp { from_img_s, to_img_s } => from_img_s.max(to_img_s),
            LoadProfile::Spike { base_img_s, spike_img_s, .. } => base_img_s.max(spike_img_s),
            LoadProfile::Bursts { base_img_s, burst_img_s, .. } => base_img_s.max(burst_img_s),
        }
    }
}

/// [`arrival_schedule`] generalized to a time-varying [`LoadProfile`]:
/// arrival `i`'s exponential inter-arrival gap uses the profile's rate
/// at `i`. Same seed + profile + corpus + count ⇒ the identical
/// sequence — the determinism contract the scenario harness is built on.
pub fn profile_schedule(
    corpus_len: usize,
    requests: usize,
    profile: &LoadProfile,
    seed: u64,
) -> Vec<(f64, usize)> {
    assert!(corpus_len > 0, "load generator needs at least one image");
    let mut rng = Rng::new(seed);
    let mut at = 0.0f64;
    (0..requests)
        .map(|i| {
            let rate = profile.rate_at(i, requests);
            assert!(rate > 0.0, "offered rate must be positive");
            // Exponential inter-arrival with mean 1/rate; (1 - u) avoids
            // ln(0).
            at += -(1.0 - rng.unit_f64()).ln() / rate;
            (at, rng.index(corpus_len))
        })
        .collect()
}

/// Drive `server` with an open-loop synthetic workload: `requests`
/// arrivals at `offered_img_s` (Poisson — see [`arrival_schedule`]),
/// each a uniformly chosen image from `corpus`. Open loop means arrivals
/// never wait for responses: if the fleet falls behind, the queue fills
/// and admission control sheds load, exactly like production ingress.
/// Responses are collected after the last arrival.
pub fn open_loop(
    server: &Server,
    corpus: &[Vec<i64>],
    requests: usize,
    offered_img_s: f64,
    seed: u64,
) -> Vec<LoadOutcome> {
    step_load(server, corpus, &[LoadPhase { requests, offered_img_s }], seed)
}

/// [`open_loop`] for a tenant mix: arrival `i` is submitted as tenant
/// `i % corpora.len()` with an image from that tenant's corpus, so every
/// tenant offers an equal share of the load (quota skew then shows up in
/// what gets *admitted*, which is the point). Returns `(tenant, outcome)`
/// per arrival.
pub fn open_loop_tenants(
    server: &Server,
    corpora: &[Vec<Vec<i64>>],
    requests: usize,
    offered_img_s: f64,
    seed: u64,
) -> Vec<(usize, LoadOutcome)> {
    assert!(!corpora.is_empty() && corpora.iter().all(|c| !c.is_empty()));
    let schedule = arrival_schedule(
        corpora.iter().map(|c| c.len()).min().unwrap(),
        requests,
        offered_img_s,
        seed,
    );
    let start = Instant::now();
    let mut submitted: Vec<(usize, usize, Result<Pending, ServeError>)> = Vec::new();
    for (i, (at, idx)) in schedule.into_iter().enumerate() {
        let tenant = i % corpora.len();
        let due = Duration::from_secs_f64(at);
        let elapsed = start.elapsed();
        if due > elapsed {
            std::thread::sleep(due - elapsed);
        }
        submitted.push((tenant, idx, server.submit_as(tenant, corpora[tenant][idx].clone())));
    }
    submitted
        .into_iter()
        .map(|(tenant, image_idx, sub)| {
            (
                tenant,
                LoadOutcome {
                    image_idx,
                    result: match sub {
                        Ok(p) => p.wait(),
                        Err(e) => Err(e),
                    },
                },
            )
        })
        .collect()
}

/// Drive `server` with a multi-phase open-loop profile (e.g. the
/// low → spike → low shape the rebalancer is tested under). Phase `k`
/// draws its arrivals from a seed forked off `seed` by `k`, so adding
/// or resizing a phase never perturbs the others. Arrival timing stays
/// open-loop *across* phases: the schedule is absolute from the start
/// of the run, and responses are only collected after the last arrival
/// of the last phase.
pub fn step_load(
    server: &Server,
    corpus: &[Vec<i64>],
    phases: &[LoadPhase],
    seed: u64,
) -> Vec<LoadOutcome> {
    let profiled: Vec<ProfilePhase> = phases
        .iter()
        .map(|p| ProfilePhase {
            requests: p.requests,
            profile: LoadProfile::Constant { img_s: p.offered_img_s },
        })
        .collect();
    profile_load(server, corpus, &profiled, seed)
}

/// One phase of a profiled load: `requests` arrivals shaped by
/// `profile`. The scenario DSL's phases lower to this.
#[derive(Debug, Clone, Copy)]
pub struct ProfilePhase {
    pub requests: usize,
    pub profile: LoadProfile,
}

/// Fork the arrival seed for phase `k` — adding or resizing a phase
/// never perturbs the others' schedules. Shared by [`profile_load`] and
/// the scenario engine's virtual-time driver so a scenario's modeled
/// run and a real serve of the same phases draw identical schedules.
pub fn phase_seed(seed: u64, k: usize) -> u64 {
    seed.wrapping_add((k as u64).wrapping_mul(0x9E3779B97F4A7C15))
}

/// [`step_load`] generalized to time-varying [`LoadProfile`] phases.
pub fn profile_load(
    server: &Server,
    corpus: &[Vec<i64>],
    phases: &[ProfilePhase],
    seed: u64,
) -> Vec<LoadOutcome> {
    assert!(!corpus.is_empty(), "load generator needs at least one image");
    let start = Instant::now();
    let mut base = 0.0f64; // absolute end of the previous phase
    let mut submitted: Vec<(usize, Result<Pending, ServeError>)> = Vec::new();
    for (k, phase) in phases.iter().enumerate() {
        let schedule =
            profile_schedule(corpus.len(), phase.requests, &phase.profile, phase_seed(seed, k));
        let mut last = base;
        for (at, idx) in schedule {
            let due = Duration::from_secs_f64(base + at);
            last = base + at;
            let elapsed = start.elapsed();
            if due > elapsed {
                std::thread::sleep(due - elapsed);
            }
            submitted.push((idx, server.submit(corpus[idx].clone())));
        }
        base = last;
    }
    submitted
        .into_iter()
        .map(|(image_idx, sub)| LoadOutcome {
            image_idx,
            result: match sub {
                Ok(p) => p.wait(),
                Err(e) => Err(e),
            },
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_config_json_roundtrip_and_defaults() {
        use crate::util::json::Json;
        // An empty object keeps every default.
        let cfg = ServeConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(cfg.admission.queue_depth, 64);
        assert_eq!(cfg.dispatch.max_batch, 8);
        assert_eq!(cfg.dispatch.drain_deadline, Duration::from_secs(5));
        assert!(cfg.tenants.tenants.is_empty());
        // Nested sections load independently; absent keys default.
        let text = r#"{
            "admission": {"queue_depth": 128},
            "tenants": [
                {"name": "tenantA", "model": "lenet-tiny", "quota": 3.0, "p99_slo_ms": 50.0},
                {"name": "tenantB", "model": "lenet-wide-2x"}
            ]
        }"#;
        let cfg = ServeConfig::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(cfg.admission.queue_depth, 128);
        assert_eq!(cfg.dispatch.max_batch, 8, "absent dispatch section keeps defaults");
        assert_eq!(cfg.tenants.tenants.len(), 2);
        assert_eq!(cfg.tenants.tenants[0].name, "tenantA");
        assert_eq!(cfg.tenants.tenants[0].quota, 3.0);
        assert_eq!(cfg.tenants.tenants[0].p99_slo_ms, Some(50.0));
        assert_eq!(cfg.tenants.tenants[1].quota, 1.0, "quota defaults to 1");
        assert_eq!(cfg.tenants.tenants[1].p99_slo_ms, None);
        // to_json → from_json is lossless for the serializable sections.
        let back = ServeConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.admission.queue_depth, cfg.admission.queue_depth);
        assert_eq!(back.dispatch.max_batch, cfg.dispatch.max_batch);
        assert_eq!(back.tenants.tenants.len(), cfg.tenants.tenants.len());
        assert_eq!(back.tenants.tenants[0].model, "lenet-tiny");
        // A non-positive quota is a config error, not a later panic.
        let bad = r#"{"tenants": [{"name": "x", "quota": 0.0}]}"#;
        assert!(ServeConfig::from_json(&Json::parse(bad).unwrap()).is_err());
        // The sized() shorthand fills the two hot fields.
        let s = ServeConfig::sized(2, 1);
        assert_eq!(s.admission.queue_depth, 2);
        assert_eq!(s.dispatch.max_batch, 1);
    }

    #[test]
    fn arrival_schedule_is_deterministic() {
        // Same seed + rate + corpus + count ⇒ the identical sequence —
        // the reproducibility contract CI serve tests rely on.
        let a = arrival_schedule(16, 200, 1500.0, 0xBE7C);
        let b = arrival_schedule(16, 200, 1500.0, 0xBE7C);
        assert_eq!(a.len(), 200);
        for (x, y) in a.iter().zip(&b) {
            assert!(x.0.to_bits() == y.0.to_bits() && x.1 == y.1, "{x:?} != {y:?}");
        }
        // A different seed produces a different sequence.
        let c = arrival_schedule(16, 200, 1500.0, 0xBE7D);
        assert!(a.iter().zip(&c).any(|(x, y)| x.0 != y.0 || x.1 != y.1));
        // A different rate rescales time but draws the same images.
        let d = arrival_schedule(16, 200, 150.0, 0xBE7C);
        assert!(a.iter().zip(&d).all(|(x, y)| x.1 == y.1));
        assert!(d.last().unwrap().0 > a.last().unwrap().0);
    }

    #[test]
    fn arrival_schedule_statistics_match_the_offered_rate() {
        // 2000 arrivals at 1000 img/s should span ~2 s; the sample mean
        // of an exponential at n=2000 is within a loose 15% band.
        let s = arrival_schedule(8, 2000, 1000.0, 7);
        let span = s.last().unwrap().0;
        assert!((1.7..2.3).contains(&span), "span {span}");
        // Monotone non-decreasing due times; indices stay in range.
        for w in s.windows(2) {
            assert!(w[1].0 >= w[0].0);
        }
        assert!(s.iter().all(|&(_, i)| i < 8));
    }

    #[test]
    fn load_profiles_shape_the_rate() {
        let ramp = LoadProfile::Ramp { from_img_s: 100.0, to_img_s: 300.0 };
        assert_eq!(ramp.rate_at(0, 101), 100.0);
        assert_eq!(ramp.rate_at(100, 101), 300.0);
        assert_eq!(ramp.rate_at(50, 101), 200.0);
        assert_eq!(ramp.peak_img_s(), 300.0);
        let spike = LoadProfile::Spike {
            base_img_s: 100.0,
            spike_img_s: 1000.0,
            start_frac: 0.4,
            end_frac: 0.6,
        };
        assert_eq!(spike.rate_at(0, 101), 100.0);
        assert_eq!(spike.rate_at(50, 101), 1000.0);
        assert_eq!(spike.rate_at(99, 101), 100.0);
        let bursts =
            LoadProfile::Bursts { base_img_s: 100.0, burst_img_s: 800.0, every: 10, len: 3 };
        assert_eq!(bursts.rate_at(0, 101), 800.0);
        assert_eq!(bursts.rate_at(2, 101), 800.0);
        assert_eq!(bursts.rate_at(3, 101), 100.0);
        assert_eq!(bursts.rate_at(12, 101), 800.0);
        assert_eq!(bursts.peak_img_s(), 800.0);
        // Degenerate single-arrival phase uses frac 0.
        assert_eq!(ramp.rate_at(0, 1), 100.0);
    }

    #[test]
    fn profile_schedule_is_deterministic_and_matches_constant() {
        // Constant profile reproduces arrival_schedule exactly (same rng
        // stream) — the serve benches' pinned schedules are unchanged.
        let a = arrival_schedule(16, 100, 500.0, 0xA1);
        let b = profile_schedule(16, 100, &LoadProfile::Constant { img_s: 500.0 }, 0xA1);
        for (x, y) in a.iter().zip(&b) {
            assert!(x.0.to_bits() == y.0.to_bits() && x.1 == y.1);
        }
        // A spike compresses arrivals inside its window: the spiked
        // schedule finishes earlier than the flat one at the base rate.
        let s = profile_schedule(
            16,
            100,
            &LoadProfile::Spike {
                base_img_s: 500.0,
                spike_img_s: 5000.0,
                start_frac: 0.2,
                end_frac: 0.8,
            },
            0xA1,
        );
        assert!(s.last().unwrap().0 < a.last().unwrap().0);
        // Bit-identical across runs.
        let s2 = profile_schedule(
            16,
            100,
            &LoadProfile::Spike {
                base_img_s: 500.0,
                spike_img_s: 5000.0,
                start_frac: 0.2,
                end_frac: 0.8,
            },
            0xA1,
        );
        for (x, y) in s.iter().zip(&s2) {
            assert!(x.0.to_bits() == y.0.to_bits() && x.1 == y.1);
        }
    }
}
