//! Fully-connected (dense) layer IP — future-work layer from the paper's
//! conclusion.
//!
//! A serial MAC engine in the `Conv_2` mold, generalized from K² window
//! taps to an arbitrary dot-product length `n`: activation and weight
//! stream in element by element (both from the enclosing engine's
//! memories), one DSP48E2 accumulates, and the requantized neuron output
//! is captured every `n` cycles.

use super::params::ConvParams;
use crate::fabric::dsp48::Config;
use crate::netlist::builder::{Builder, Bus};
use crate::netlist::{NetId, Netlist};

/// DSP pipeline depth (same MACC config as `Conv_2`).
pub const DSP_LATENCY: u32 = 3;

/// A generated FC IP.
#[derive(Debug, Clone)]
pub struct FcIp {
    /// Dot-product length (fan-in per neuron).
    pub n: u32,
    /// Arithmetic contract (widths/shift/rounding reused from ConvParams).
    pub params: ConvParams,
    pub netlist: Netlist,
    /// Cycles per neuron.
    pub ii: u32,
    /// Cycles from the last element to `valid`.
    pub out_latency: u32,
}

/// Behavioral reference for one neuron.
pub fn fc_ref(p: &ConvParams, x: &[i64], w: &[i64]) -> i64 {
    assert_eq!(x.len(), w.len());
    let acc: i64 = x.iter().zip(w).map(|(&a, &b)| a * b).sum::<i64>() + p.round_bias();
    crate::fixed::requantize(acc, p.shift, crate::fixed::Round::Truncate, p.out_bits)
}

/// Generate an FC IP with fan-in `n` under the arithmetic contract `p`
/// (`p.k` is ignored; widths/shift/round apply).
pub fn generate(p: &ConvParams, n: u32) -> Result<FcIp, String> {
    p.validate()?;
    if n < 2 {
        return Err("FC fan-in must be >= 2".into());
    }
    // Accumulator head-room check for n products.
    let acc_bits = crate::fixed::acc_bits(p.data_bits, p.coef_bits, n);
    if acc_bits > 46 {
        return Err(format!("FC fan-in {n} overflows the 48-bit accumulator"));
    }
    let mut nl = Netlist::new();
    let mut b = Builder::new(&mut nl);
    let en: NetId = b.input("en", 1).bit(0);
    let rst: NetId = b.input("rst", 1).bit(0);
    let x = b.input("x", p.data_bits as usize);
    let w = b.input("coef", p.coef_bits as usize);
    let (phase, wrap) = b.counter_mod(n as u64, en, rst);
    let first = b.eq_const(&phase, 0);
    b.output("phase", &phase);

    let bit0 = b.not(first);
    let bit1 = if p.round_bias() != 0 { first } else { b.zero() };
    let zmux = Bus(vec![bit0, bit1]);
    let cbus = b.const_bus(p.round_bias(), 48);
    let dbus = b.const_bus(0, 1);
    let pbus = b.dsp(Config::full_macc(false), &x, &w, &cbus, &dbus, &zmux, en);

    let dwrap = super::common::delay_flag(&mut b, wrap, DSP_LATENCY, en, rst);
    let acc_view = pbus.slice(0, (acc_bits as usize + 1).min(48));
    super::common::output_stage(&mut b, p, &acc_view, dwrap, en, rst, 0, true);

    Ok(FcIp { n, params: *p, netlist: nl, ii: n, out_latency: DSP_LATENCY + 1 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::sim::Sim;
    use crate::util::rng::Rng;

    /// Stream `neurons.len()` dot products through the engine.
    fn run(ip: &FcIp, xs: &[Vec<i64>], ws: &[Vec<i64>]) -> Vec<i64> {
        let p = &ip.params;
        let n = ip.n as usize;
        let mut sim = Sim::new(&ip.netlist).unwrap();
        sim.set_input("rst", 1);
        sim.set_input("en", 1);
        sim.set_input("x", 0);
        sim.set_input("coef", 0);
        sim.settle();
        sim.tick();
        sim.set_input("rst", 0);
        let dmask = (1u64 << p.data_bits) - 1;
        let cmask = (1u64 << p.coef_bits) - 1;
        let total = xs.len() * n + ip.out_latency as usize + 2;
        let mut out = Vec::new();
        for cycle in 0..total {
            let phase = cycle % n;
            let neuron = (cycle / n).min(xs.len() - 1);
            sim.set_input("x", (xs[neuron][phase] as u64) & dmask);
            sim.set_input("coef", (ws[neuron][phase] as u64) & cmask);
            sim.settle();
            if sim.output_unsigned("valid") == 1 {
                out.push(sim.output_signed("out0"));
                if out.len() == xs.len() {
                    break;
                }
            }
            sim.tick();
        }
        out
    }

    #[test]
    fn matches_reference() {
        let p = ConvParams::paper_8bit();
        let ip = generate(&p, 16).unwrap();
        ip.netlist.check().unwrap();
        let mut rng = Rng::new(3);
        let xs: Vec<Vec<i64>> = (0..5).map(|_| (0..16).map(|_| rng.signed_bits(8)).collect()).collect();
        let ws: Vec<Vec<i64>> = (0..5).map(|_| (0..16).map(|_| rng.signed_bits(8)).collect()).collect();
        let got = run(&ip, &xs, &ws);
        let want: Vec<i64> = (0..5).map(|i| fc_ref(&p, &xs[i], &ws[i])).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn large_fanin_guard() {
        let p = ConvParams::paper_8bit();
        assert!(generate(&p, 1).is_err());
        // 8x8-bit products: 2^31 fan-in would blow the 48-bit accumulator.
        assert!(generate(&p, 1 << 31).is_err());
        assert!(generate(&p, 1024).is_ok());
    }

    #[test]
    fn footprint_is_conv2_like() {
        let p = ConvParams::paper_8bit();
        let fc = generate(&p, 64).unwrap();
        let u = crate::synth::synthesize(&fc.netlist);
        assert_eq!(u.dsps, 1);
        // No window mux at all — even leaner than Conv_2.
        let c2 = crate::synth::synthesize(
            &super::super::conv2::generate(&p).unwrap().netlist,
        );
        assert!(u.luts <= c2.luts, "fc {} vs conv2 {}", u.luts, c2.luts);
        let t = crate::sta::analyze(&fc.netlist, 200.0, 1.0).unwrap();
        assert!(t.met());
    }
}
