//! Netlist-vs-behavioral equivalence driving.
//!
//! [`run_ip`] exercises a generated IP netlist through its streaming
//! protocol in the bit-exact simulator; [`expected`] computes the
//! behavioral reference via [`ConvParams::window_ref`]. The two must match
//! exactly for every IP, parameterization, and stimulus — this is the
//! correctness spine of the whole repository (the same `window_ref`
//! semantics are enforced against the Pallas kernels by pytest and against
//! the XLA artifacts by the runtime integration tests).
//!
//! Two lane axes meet here and must not be confused:
//! * **IP lanes** — `Conv_3`/`Conv_4`'s dual datapaths (`win0`/`win1`),
//!   part of the netlist itself.
//! * **Sim lanes** — up to [`crate::netlist::sim::LANES`] independent
//!   stimulus streams packed one-per-bit into the simulator's lane words.
//!   [`run_ip_lanes`] drives many window streams through ONE simulator
//!   pass structure: control (`en`/`rst`/`coef`/phase) is broadcast —
//!   every lane runs the same schedule with the same coefficients, which
//!   is exactly a micro-batch of images on one engine — while window data
//!   is set per lane.

use super::common::ConvIp;
use super::params::ConvParams;
use crate::netlist::sim::{SettleStats, Sim, LANES};
use crate::trace::{ArgValue, SettleTrace};
use crate::util::rng::Rng;

/// One pass's stimulus: a window per IP lane.
pub type PassStimulus = Vec<Vec<i64>>;

/// One sim lane's stimulus: its sequence of passes.
pub type LaneStimulus = Vec<PassStimulus>;

/// Pre-resolved port indices for a conv IP's streaming interface, so
/// per-cycle driving is allocation- and lookup-free. Shared by
/// [`run_ip_lanes`] (and through it [`run_ip`]) and the stall-injection
/// drivers.
pub struct IpPorts {
    pub rst: usize,
    pub en: usize,
    pub coef: usize,
    pub win: Vec<usize>,
    pub valid: usize,
    pub out: Vec<usize>,
    pub phase: usize,
}

impl IpPorts {
    /// Resolve every streaming bus of a `lanes`-lane IP once.
    pub fn resolve(sim: &Sim<'_>, lanes: usize) -> IpPorts {
        IpPorts {
            rst: sim.input_index("rst"),
            en: sim.input_index("en"),
            coef: sim.input_index("coef"),
            win: (0..lanes).map(|l| sim.input_index(&format!("win{l}"))).collect(),
            valid: sim.output_index("valid"),
            out: (0..lanes).map(|l| sim.output_index(&format!("out{l}"))).collect(),
            phase: sim.output_index("phase"),
        }
    }

    /// Apply the reset pulse with zeroed data/coefficient inputs, leaving
    /// the IP enabled and out of reset.
    pub fn reset(&self, sim: &mut Sim<'_>, p: &ConvParams) {
        let taps = p.taps() as usize;
        sim.set_input_at(self.rst, 1);
        sim.set_input_at(self.en, 1);
        sim.set_input_at(self.coef, 0);
        for &win in &self.win {
            for e in 0..taps {
                sim.set_input_field_at(win, e * p.data_bits as usize, p.data_bits as usize, 0);
            }
        }
        sim.settle();
        sim.tick();
        sim.set_input_at(self.rst, 0);
    }

    /// Present coefficient `phase` and every lane's window of `pass` in
    /// one call — the per-cycle driver the stall-injection tests use
    /// (idempotent, so re-driving a held cycle is safe).
    pub fn drive(
        &self,
        sim: &mut Sim<'_>,
        p: &ConvParams,
        windows: &[PassStimulus],
        pass: usize,
        coefs: &[i64],
        phase: usize,
    ) {
        let dmask = (1u64 << p.data_bits) - 1;
        let cmask = (1u64 << p.coef_bits) - 1;
        let taps = p.taps() as usize;
        sim.set_input_at(self.coef, (coefs[phase] as u64) & cmask);
        for (lane, &win) in self.win.iter().enumerate() {
            for e in 0..taps {
                sim.set_input_field_at(
                    win,
                    e * p.data_bits as usize,
                    p.data_bits as usize,
                    (windows[pass][lane][e] as u64) & dmask,
                );
            }
        }
    }

    /// After `settle`: if `valid` is high, capture one output row.
    pub fn capture(&self, sim: &Sim<'_>) -> Option<Vec<i64>> {
        if sim.output_unsigned_at(self.valid) == 1 {
            Some(self.out.iter().map(|&o| sim.output_signed_at(o)).collect())
        } else {
            None
        }
    }

    /// Broadcast coefficient `phase` to every sim lane (the only input
    /// that changes mid-pass).
    pub fn drive_coef(&self, sim: &mut Sim<'_>, p: &ConvParams, coefs: &[i64], phase: usize) {
        let cmask = (1u64 << p.coef_bits) - 1;
        sim.set_input_at(self.coef, (coefs[phase] as u64) & cmask);
    }

    /// Set every sim lane's windows of `pass`. Windows are stable for the
    /// K² cycles of a pass (the IP port contract), so call this at pass
    /// boundaries only — re-driving every cycle would put O(lanes·K²·W)
    /// serial bit writes in the lane-parallel hot loop.
    pub fn drive_windows_lanes(
        &self,
        sim: &mut Sim<'_>,
        p: &ConvParams,
        per_lane: &[LaneStimulus],
        pass: usize,
    ) {
        let dmask = (1u64 << p.data_bits) - 1;
        let taps = p.taps() as usize;
        for (sl, stim) in per_lane.iter().enumerate() {
            for (il, &win) in self.win.iter().enumerate() {
                for e in 0..taps {
                    sim.set_input_field_lane_at(
                        win,
                        sl,
                        e * p.data_bits as usize,
                        p.data_bits as usize,
                        (stim[pass][il][e] as u64) & dmask,
                    );
                }
            }
        }
    }

    /// Capture one sim lane's output row (the caller has already seen
    /// `valid` high — control is broadcast, so all lanes pulse together).
    pub fn capture_lane(&self, sim: &Sim<'_>, lane: usize) -> Vec<i64> {
        self.out.iter().map(|&o| sim.output_signed_lane_at(o, lane)).collect()
    }
}

/// Drive `ip` through `windows.len()` passes with the given coefficient
/// set and return the captured outputs per pass per lane. A thin wrapper
/// over [`run_ip_lanes`] at one sim lane (which still takes the scalar
/// LUT fast path), so there is exactly one copy of the pass schedule.
pub fn run_ip(ip: &ConvIp, windows: &[PassStimulus], coefs: &[i64]) -> Vec<Vec<i64>> {
    let lane: LaneStimulus = windows.to_vec();
    run_ip_lanes(ip, std::slice::from_ref(&lane), coefs).pop().expect("one sim lane")
}

/// Drive `ip` through one lane-batched run: `per_lane[l]` is sim lane
/// `l`'s pass sequence (all lanes share the pass count, schedule, and
/// coefficient set). Returns captured outputs per sim lane per pass per
/// IP lane — bit-identical to running [`run_ip`] once per sim lane, at a
/// fraction of the settle/tick cost.
pub fn run_ip_lanes(
    ip: &ConvIp,
    per_lane: &[LaneStimulus],
    coefs: &[i64],
) -> Vec<Vec<Vec<i64>>> {
    run_ip_lanes_report(ip, per_lane, coefs, false).outputs
}

/// One lane-batched run's outputs plus the simulator's settle-scheduler
/// accounting — what the layer checks and benches surface alongside the
/// values.
pub struct LaneRunReport {
    /// Captured outputs per sim lane per pass per IP lane.
    pub outputs: Vec<Vec<Vec<i64>>>,
    /// Cumulative scheduler activity over the whole run (event vs. dense
    /// settles, ops evaluated vs. the dense workload).
    pub activity: SettleStats,
    /// Total toggles charged across all nets and lanes — the power-model
    /// signal, exact regardless of which settle path ran.
    pub toggles: u64,
}

/// [`run_ip_lanes`] with the activity report kept. With `dense`, the
/// simulator is forced onto full sweeps for every settle (the PR 3
/// baseline the event scheduler is measured against); otherwise the
/// event-driven path applies. Outputs and toggles must be identical
/// either way — the differential tests below pin that.
pub fn run_ip_lanes_report(
    ip: &ConvIp,
    per_lane: &[LaneStimulus],
    coefs: &[i64],
    dense: bool,
) -> LaneRunReport {
    run_ip_lanes_report_traced(ip, per_lane, coefs, dense, None)
}

/// [`run_ip_lanes_report`] with per-pass settle attribution: when `trace`
/// carries a live tracer, each pipeline pass becomes a `"sim"`-category
/// span named `settle:{label}:pass{n}` on the given `(pid, tid)` track,
/// carrying the *interval's* [`SettleStats`] as span args. The stats
/// counters are cumulative over the simulator's lifetime, so each span
/// subtracts the snapshot taken at its pass boundary
/// ([`SettleStats::delta_since`]) — attributing only that pass's settles.
pub fn run_ip_lanes_report_traced(
    ip: &ConvIp,
    per_lane: &[LaneStimulus],
    coefs: &[i64],
    dense: bool,
    trace: Option<&SettleTrace<'_>>,
) -> LaneRunReport {
    let p = &ip.params;
    let ip_lanes = ip.kind.lanes() as usize;
    let taps = p.taps() as usize;
    let sim_lanes = per_lane.len();
    assert!((1..=LANES).contains(&sim_lanes), "{sim_lanes} sim lanes unsupported");
    let n_passes = per_lane[0].len();
    assert!(n_passes > 0, "need at least one pass");
    assert!(per_lane.iter().all(|stim| stim.len() == n_passes
        && stim.iter().all(|w| w.len() == ip_lanes && w.iter().all(|l| l.len() == taps))));
    assert_eq!(coefs.len(), taps);

    let mut sim = Sim::with_lanes(&ip.netlist, sim_lanes).expect("IP netlist must check");
    if dense {
        sim.set_force_dense(true);
    }
    let ports = IpPorts::resolve(&sim, ip_lanes);
    ports.reset(&mut sim, p);

    let total = n_passes * taps + ip.out_latency as usize + 4;
    let mut results: Vec<Vec<Vec<i64>>> = vec![Vec::new(); sim_lanes];
    // Pass-attribution state: (span start, stats snapshot at that start).
    let trace = trace.filter(|t| t.tracer.on());
    let mut open_span = trace.map(|t| (t.clock.now_nanos(), sim.settle_stats().clone()));
    let mut spans_done = 0usize;
    for cycle in 0..total {
        let phase = cycle % taps;
        let pass = (cycle / taps).min(n_passes - 1);
        // Windows are stable across a pass; only the coefficient streams.
        if phase == 0 {
            if cycle > 0 {
                if let (Some(t), Some(open)) = (trace, open_span.as_mut()) {
                    let now = t.clock.now_nanos();
                    let stats = sim.settle_stats().clone();
                    record_pass_span(t, spans_done, open.0, now, &stats.delta_since(&open.1));
                    *open = (now, stats);
                    spans_done += 1;
                }
            }
            ports.drive_windows_lanes(&mut sim, p, per_lane, pass);
        }
        ports.drive_coef(&mut sim, p, coefs, phase);
        sim.settle();
        debug_assert_eq!(sim.output_unsigned_at(ports.phase), phase as u64, "cycle {cycle}");
        if sim.output_unsigned_at(ports.valid) == 1 {
            for (lane, rows) in results.iter_mut().enumerate() {
                if rows.len() < n_passes {
                    rows.push(ports.capture_lane(&sim, lane));
                }
            }
            if results[0].len() == n_passes {
                break; // trailing margin cycles re-process the last window
            }
        }
        sim.tick();
    }
    // Final span: the last pass plus the pipeline's drain margin.
    if let (Some(t), Some((t0, prev))) = (trace, open_span) {
        let now = t.clock.now_nanos();
        record_pass_span(t, spans_done, t0, now, &sim.settle_stats().delta_since(&prev));
    }
    for (lane, rows) in results.iter().enumerate() {
        assert_eq!(
            rows.len(),
            n_passes,
            "{}: sim lane {lane} missed valid pulses",
            ip.kind.name()
        );
    }
    LaneRunReport {
        activity: sim.settle_stats().clone(),
        toggles: sim.toggle_total(),
        outputs: results,
    }
}

/// Emit one pass's settle-attribution span; `d` is already the interval
/// delta (see [`run_ip_lanes_report_traced`]).
fn record_pass_span(t: &SettleTrace<'_>, pass: usize, t0: u64, t1: u64, d: &SettleStats) {
    t.tracer.span(
        format!("settle:{}:pass{pass}", t.label),
        "sim",
        t.pid,
        t.tid,
        t0,
        t1,
        vec![
            ("settles", ArgValue::U(d.settles)),
            ("dense_settles", ArgValue::U(d.dense_settles)),
            ("event_settles", ArgValue::U(d.event_settles())),
            ("ops_evaluated", ArgValue::U(d.ops_evaluated)),
            ("ops_total", ArgValue::U(d.ops_total)),
            ("evaluated_fraction", ArgValue::F(d.evaluated_fraction())),
        ],
    );
}

/// Behavioral expectation for the same stimulus (lane-aware: includes the
/// `Conv_3` high-lane precision clamp).
pub fn expected(ip: &ConvIp, windows: &[PassStimulus], coefs: &[i64]) -> Vec<Vec<i64>> {
    windows
        .iter()
        .map(|pass| {
            pass.iter()
                .enumerate()
                .map(|(lane, win)| ip.expected_window(lane as u32, win, coefs))
                .collect()
        })
        .collect()
}

/// Random stimulus generator: `n_passes` windows (full operand range).
pub fn random_stimulus(
    ip: &ConvIp,
    rng: &mut Rng,
    n_passes: usize,
) -> (Vec<PassStimulus>, Vec<i64>) {
    let p = &ip.params;
    let taps = p.taps() as usize;
    let lanes = ip.kind.lanes() as usize;
    let windows: Vec<PassStimulus> = (0..n_passes)
        .map(|_| {
            (0..lanes)
                .map(|_| (0..taps).map(|_| rng.signed_bits(p.data_bits)).collect())
                .collect()
        })
        .collect();
    let coefs: Vec<i64> = (0..taps).map(|_| rng.signed_bits(p.coef_bits)).collect();
    (windows, coefs)
}

/// Random lane-batched stimulus: `sim_lanes` independent streams of
/// `passes_per_lane` passes each, plus one shared coefficient set.
pub fn random_stimulus_lanes(
    ip: &ConvIp,
    rng: &mut Rng,
    sim_lanes: usize,
    passes_per_lane: usize,
) -> (Vec<LaneStimulus>, Vec<i64>) {
    let p = &ip.params;
    let taps = p.taps() as usize;
    let ip_lanes = ip.kind.lanes() as usize;
    let per_lane: Vec<LaneStimulus> = (0..sim_lanes)
        .map(|_| {
            (0..passes_per_lane)
                .map(|_| {
                    (0..ip_lanes)
                        .map(|_| (0..taps).map(|_| rng.signed_bits(p.data_bits)).collect())
                        .collect()
                })
                .collect()
        })
        .collect();
    let coefs: Vec<i64> = (0..taps).map(|_| rng.signed_bits(p.coef_bits)).collect();
    (per_lane, coefs)
}

/// Assert netlist == behavioral over random stimulus. Returns the number
/// of windows checked.
pub fn check_equivalence(ip: &ConvIp, seed: u64, n_passes: usize) -> usize {
    let mut rng = Rng::new(seed);
    let (windows, coefs) = random_stimulus(ip, &mut rng, n_passes);
    let got = run_ip(ip, &windows, &coefs);
    let want = expected(ip, &windows, &coefs);
    assert_eq!(got, want, "{} netlist != behavioral", ip.kind.name());
    n_passes * ip.kind.lanes() as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ips::{generate, ConvKind};
    use crate::util::prop::forall;

    /// Lane-batched runs must be bit-identical to per-lane scalar runs
    /// AND to the behavioral reference, across IP kinds, widths, and
    /// occupancies.
    #[test]
    fn prop_lane_batched_run_matches_scalar_runs() {
        forall("run_ip_lanes == run_ip per lane", 10, |g| {
            let kind = *g.choose(&ConvKind::ALL);
            let bits = g.usize_in(4, 8) as u32;
            let p = ConvParams {
                k: g.usize_in(2, 3) as u32,
                data_bits: bits,
                coef_bits: bits,
                out_bits: bits,
                shift: bits - 1,
                round: crate::fixed::Round::Truncate,
            };
            // All four kinds generate for k<=3 at <=8 bits today; skip
            // defensively rather than fail the property if a kind ever
            // narrows its envelope.
            let Ok(ip) = generate(kind, &p) else { return Ok(()) };
            let sim_lanes = g.usize_in(2, 6);
            let passes = g.usize_in(1, 3);
            // Draw stimuli through the prop generator so failures shrink.
            let taps = p.taps() as usize;
            let ip_lanes = ip.kind.lanes() as usize;
            let per_lane: Vec<LaneStimulus> = (0..sim_lanes)
                .map(|_| {
                    (0..passes)
                        .map(|_| (0..ip_lanes).map(|_| g.signed_vec(bits, taps)).collect())
                        .collect()
                })
                .collect();
            let coefs = g.signed_vec(bits, taps);
            let got = run_ip_lanes(&ip, &per_lane, &coefs);
            for (lane, stim) in per_lane.iter().enumerate() {
                let scalar = run_ip(&ip, stim, &coefs);
                if got[lane] != scalar {
                    return Err(format!("{} lane {lane}: lane-run != scalar run", kind.name()));
                }
                let want = expected(&ip, stim, &coefs);
                if got[lane] != want {
                    return Err(format!("{} lane {lane}: lane-run != behavioral", kind.name()));
                }
            }
            Ok(())
        });
    }

    /// Differential suite on *real* IP layers: the event-driven settle
    /// must produce bit-exact outputs AND exact toggle totals versus the
    /// forced dense sweep, at 1, 8, and 64 sim lanes, for every IP kind.
    #[test]
    fn event_run_matches_dense_run_exactly_all_kinds() {
        let p = ConvParams::paper_8bit();
        for kind in ConvKind::ALL {
            let ip = generate(kind, &p).unwrap();
            for sim_lanes in [1usize, 8, LANES] {
                let mut rng = Rng::new(0xD1FF ^ ((kind as u64) << 8) ^ sim_lanes as u64);
                let (per_lane, coefs) = random_stimulus_lanes(&ip, &mut rng, sim_lanes, 1);
                let event = run_ip_lanes_report(&ip, &per_lane, &coefs, false);
                let dense = run_ip_lanes_report(&ip, &per_lane, &coefs, true);
                assert_eq!(
                    event.outputs,
                    dense.outputs,
                    "{} @ {sim_lanes} lanes: event != dense outputs",
                    kind.name()
                );
                assert_eq!(
                    event.toggles,
                    dense.toggles,
                    "{} @ {sim_lanes} lanes: toggle totals diverge",
                    kind.name()
                );
                // The event run must also match the behavioral reference
                // (not merely agree with dense on a shared wrong answer).
                for (lane, stim) in per_lane.iter().enumerate() {
                    let want = expected(&ip, stim, &coefs);
                    assert_eq!(event.outputs[lane], want, "{} lane {lane}", kind.name());
                }
                // Accounting invariants: the dense run swept every pass
                // densely; the event run never exceeds the dense workload.
                assert_eq!(dense.activity.dense_settles, dense.activity.settles);
                assert!(event.activity.ops_evaluated <= event.activity.ops_total);
            }
        }
    }

    #[test]
    fn traced_lane_run_attributes_every_settle_exactly_once() {
        use crate::trace::{pid_of_group, ArgValue, Clock, SettleTrace, Tracer, TID_CONTROL};
        let p = ConvParams::paper_8bit();
        let ip = generate(ConvKind::Conv2, &p).unwrap();
        let mut rng = Rng::new(0x7E57);
        let (per_lane, coefs) = random_stimulus_lanes(&ip, &mut rng, 4, 3);
        let plain = run_ip_lanes_report(&ip, &per_lane, &coefs, false);
        let tracer = Tracer::ring(1024);
        let clock = Clock::manual();
        let ctx = SettleTrace {
            tracer: &tracer,
            clock: &clock,
            pid: pid_of_group(0),
            tid: TID_CONTROL,
            label: "conv2 L0".to_string(),
        };
        let traced = run_ip_lanes_report_traced(&ip, &per_lane, &coefs, false, Some(&ctx));
        assert_eq!(traced.outputs, plain.outputs, "tracing must not perturb results");
        assert_eq!(traced.toggles, plain.toggles, "tracing must not perturb toggles");
        let evs = tracer.drain();
        assert!(evs.len() >= 3, "at least one span per pass, got {}", evs.len());
        assert!(evs
            .iter()
            .all(|e| e.cat == "sim" && e.name.starts_with("settle:conv2 L0:pass")));
        // The per-span deltas partition the run: settles attributed across
        // all spans equal the cumulative total minus whatever ran before
        // the first snapshot (the construction bootstrap + port reset) —
        // mirrored here on an identical fresh simulator.
        let attributed: u64 = evs
            .iter()
            .map(|e| match e.args.iter().find(|(k, _)| *k == "settles") {
                Some((_, ArgValue::U(v))) => *v,
                other => panic!("span lacks a settles arg: {other:?}"),
            })
            .sum();
        let mut pre_sim = Sim::with_lanes(&ip.netlist, per_lane.len()).unwrap();
        let pre_ports = IpPorts::resolve(&pre_sim, ip.kind.lanes() as usize);
        pre_ports.reset(&mut pre_sim, &p);
        let pre = pre_sim.settle_stats().settles;
        assert_eq!(attributed, traced.activity.settles - pre);
        // A context whose tracer is off records nothing.
        let off = Tracer::off();
        let ctx_off =
            SettleTrace { tracer: &off, clock: &clock, pid: 1, tid: 0, label: "x".to_string() };
        run_ip_lanes_report_traced(&ip, &per_lane, &coefs, false, Some(&ctx_off));
        assert!(off.drain().is_empty());
    }

    #[test]
    fn full_occupancy_lane_run_all_kinds() {
        // All 64 sim lanes at once, every IP kind, paper configuration.
        let p = ConvParams::paper_8bit();
        for kind in ConvKind::ALL {
            let ip = generate(kind, &p).unwrap();
            let mut rng = Rng::new(0xACE0 ^ kind as u64);
            let (per_lane, coefs) = random_stimulus_lanes(&ip, &mut rng, LANES, 2);
            let got = run_ip_lanes(&ip, &per_lane, &coefs);
            for (lane, stim) in per_lane.iter().enumerate() {
                let want = expected(&ip, stim, &coefs);
                assert_eq!(got[lane], want, "{} lane {lane}", kind.name());
            }
        }
    }
}
