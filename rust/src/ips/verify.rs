//! Netlist-vs-behavioral equivalence driving.
//!
//! [`run_ip`] exercises a generated IP netlist through its streaming
//! protocol in the bit-exact simulator; [`expected`] computes the
//! behavioral reference via [`ConvParams::window_ref`]. The two must match
//! exactly for every IP, parameterization, and stimulus — this is the
//! correctness spine of the whole repository (the same `window_ref`
//! semantics are enforced against the Pallas kernels by pytest and against
//! the XLA artifacts by the runtime integration tests).

use super::common::ConvIp;
use super::params::ConvParams;
use crate::netlist::sim::Sim;
use crate::util::rng::Rng;

/// One pass's stimulus: a window per lane.
pub type PassStimulus = Vec<Vec<i64>>;

/// Pre-resolved port indices for a conv IP's streaming interface, so
/// per-cycle driving is allocation- and lookup-free. Shared by [`run_ip`]
/// and the stall-injection drivers.
pub struct IpPorts {
    pub rst: usize,
    pub en: usize,
    pub coef: usize,
    pub win: Vec<usize>,
    pub valid: usize,
    pub out: Vec<usize>,
    pub phase: usize,
}

impl IpPorts {
    /// Resolve every streaming bus of a `lanes`-lane IP once.
    pub fn resolve(sim: &Sim<'_>, lanes: usize) -> IpPorts {
        IpPorts {
            rst: sim.input_index("rst"),
            en: sim.input_index("en"),
            coef: sim.input_index("coef"),
            win: (0..lanes).map(|l| sim.input_index(&format!("win{l}"))).collect(),
            valid: sim.output_index("valid"),
            out: (0..lanes).map(|l| sim.output_index(&format!("out{l}"))).collect(),
            phase: sim.output_index("phase"),
        }
    }

    /// Apply the reset pulse with zeroed data/coefficient inputs, leaving
    /// the IP enabled and out of reset.
    pub fn reset(&self, sim: &mut Sim<'_>, p: &ConvParams) {
        let taps = p.taps() as usize;
        sim.set_input_at(self.rst, 1);
        sim.set_input_at(self.en, 1);
        sim.set_input_at(self.coef, 0);
        for &win in &self.win {
            for e in 0..taps {
                sim.set_input_field_at(win, e * p.data_bits as usize, p.data_bits as usize, 0);
            }
        }
        sim.settle();
        sim.tick();
        sim.set_input_at(self.rst, 0);
    }

    /// Present coefficient `phase` and every lane's window of `pass`.
    pub fn drive(
        &self,
        sim: &mut Sim<'_>,
        p: &ConvParams,
        windows: &[PassStimulus],
        pass: usize,
        coefs: &[i64],
        phase: usize,
    ) {
        let dmask = (1u64 << p.data_bits) - 1;
        let cmask = (1u64 << p.coef_bits) - 1;
        let taps = p.taps() as usize;
        sim.set_input_at(self.coef, (coefs[phase] as u64) & cmask);
        for (lane, &win) in self.win.iter().enumerate() {
            for e in 0..taps {
                sim.set_input_field_at(
                    win,
                    e * p.data_bits as usize,
                    p.data_bits as usize,
                    (windows[pass][lane][e] as u64) & dmask,
                );
            }
        }
    }

    /// After `settle`: if `valid` is high, capture one output row.
    pub fn capture(&self, sim: &Sim<'_>) -> Option<Vec<i64>> {
        if sim.output_unsigned_at(self.valid) == 1 {
            Some(self.out.iter().map(|&o| sim.output_signed_at(o)).collect())
        } else {
            None
        }
    }
}

/// Drive `ip` through `windows.len()` passes with the given coefficient
/// set and return the captured outputs per pass per lane.
pub fn run_ip(ip: &ConvIp, windows: &[PassStimulus], coefs: &[i64]) -> Vec<Vec<i64>> {
    let p = &ip.params;
    let lanes = ip.kind.lanes() as usize;
    let taps = p.taps() as usize;
    assert!(windows.iter().all(|w| w.len() == lanes && w.iter().all(|l| l.len() == taps)));
    assert_eq!(coefs.len(), taps);

    let mut sim = Sim::new(&ip.netlist).expect("IP netlist must check");
    let ports = IpPorts::resolve(&sim, lanes);
    ports.reset(&mut sim, p);

    let total = windows.len() * taps + ip.out_latency as usize + 4;
    let mut results: Vec<Vec<i64>> = Vec::new();
    for cycle in 0..total {
        let phase = cycle % taps;
        let pass = (cycle / taps).min(windows.len() - 1);
        ports.drive(&mut sim, p, windows, pass, coefs, phase);
        sim.settle();
        // The IP's own view of the phase must agree with the driver's.
        debug_assert_eq!(sim.output_unsigned_at(ports.phase), phase as u64, "cycle {cycle}");
        if let Some(row) = ports.capture(&sim) {
            results.push(row);
            if results.len() == windows.len() {
                break; // trailing margin cycles re-process the last window
            }
        }
        sim.tick();
    }
    assert_eq!(
        results.len(),
        windows.len(),
        "{}: expected one valid pulse per pass",
        ip.kind.name()
    );
    results
}

/// Behavioral expectation for the same stimulus (lane-aware: includes the
/// `Conv_3` high-lane precision clamp).
pub fn expected(ip: &ConvIp, windows: &[PassStimulus], coefs: &[i64]) -> Vec<Vec<i64>> {
    windows
        .iter()
        .map(|pass| {
            pass.iter()
                .enumerate()
                .map(|(lane, win)| ip.expected_window(lane as u32, win, coefs))
                .collect()
        })
        .collect()
}

/// Random stimulus generator: `n_passes` windows (full operand range).
pub fn random_stimulus(
    ip: &ConvIp,
    rng: &mut Rng,
    n_passes: usize,
) -> (Vec<PassStimulus>, Vec<i64>) {
    let p = &ip.params;
    let taps = p.taps() as usize;
    let lanes = ip.kind.lanes() as usize;
    let windows: Vec<PassStimulus> = (0..n_passes)
        .map(|_| {
            (0..lanes)
                .map(|_| (0..taps).map(|_| rng.signed_bits(p.data_bits)).collect())
                .collect()
        })
        .collect();
    let coefs: Vec<i64> = (0..taps).map(|_| rng.signed_bits(p.coef_bits)).collect();
    (windows, coefs)
}

/// Assert netlist == behavioral over random stimulus. Returns the number
/// of windows checked.
pub fn check_equivalence(ip: &ConvIp, seed: u64, n_passes: usize) -> usize {
    let mut rng = Rng::new(seed);
    let (windows, coefs) = random_stimulus(ip, &mut rng, n_passes);
    let got = run_ip(ip, &windows, &coefs);
    let want = expected(ip, &windows, &coefs);
    assert_eq!(got, want, "{} netlist != behavioral", ip.kind.name());
    n_passes * ip.kind.lanes() as usize
}
