//! Max-pooling IP (2×2, stride 2) — future-work layer from the paper's
//! conclusion.
//!
//! Four window elements arrive in parallel; a tree of three signed
//! max-comparators (subtract → sign → mux) picks the maximum. Output is
//! registered: one pooled value per cycle, latency 1.

use crate::netlist::builder::{Builder, Bus};
use crate::netlist::{NetId, Netlist};

/// A generated max-pool IP.
#[derive(Debug, Clone)]
pub struct PoolIp {
    pub bits: u32,
    /// Window size (elements pooled per output).
    pub window: u32,
    pub netlist: Netlist,
    pub latency: u32,
}

/// Behavioral reference.
pub fn maxpool_ref(vals: &[i64]) -> i64 {
    *vals.iter().max().expect("nonempty window")
}

/// Signed max of two buses: `sel = (a < b)` via subtraction sign, then mux.
fn smax(b: &mut Builder, x: &Bus, y: &Bus) -> Bus {
    let diff = b.sub(x, y); // x - y, sign bit ⇒ x < y
    let lt: NetId = diff.msb();
    b.mux2(lt, x, y) // lt ? y : x
}

/// Generate a max-pool IP over `window` parallel elements of `bits` each.
pub fn generate(bits: u32, window: u32) -> PoolIp {
    assert!((2..=32).contains(&bits));
    assert!((2..=16).contains(&window));
    let mut nl = Netlist::new();
    let mut b = Builder::new(&mut nl);
    let en = b.input("en", 1).bit(0);
    let rst = b.input("rst", 1).bit(0);
    let win = b.input("win", (bits * window) as usize);
    let mut items: Vec<Bus> = (0..window as usize)
        .map(|e| win.slice(e * bits as usize, (e + 1) * bits as usize))
        .collect();
    while items.len() > 1 {
        let mut next = Vec::new();
        for pair in items.chunks(2) {
            next.push(if pair.len() == 2 { smax(&mut b, &pair[0], &pair[1]) } else { pair[0].clone() });
        }
        items = next;
    }
    let q = b.register(&items[0], en, rst);
    b.output("out", &q);
    PoolIp { bits, window, netlist: nl, latency: 1 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::sim::Sim;
    use crate::util::prop::forall;

    fn run(ip: &PoolIp, vals: &[i64]) -> i64 {
        let mut sim = Sim::new(&ip.netlist).unwrap();
        sim.set_input("en", 1);
        sim.set_input("rst", 0);
        for (e, &v) in vals.iter().enumerate() {
            sim.set_input_field("win", e * ip.bits as usize, ip.bits as usize, (v as u64) & ((1 << ip.bits) - 1));
        }
        sim.settle();
        sim.tick();
        sim.output_signed("out")
    }

    #[test]
    fn pool4_corners() {
        let ip = generate(8, 4);
        ip.netlist.check().unwrap();
        assert_eq!(run(&ip, &[1, 2, 3, 4]), 4);
        assert_eq!(run(&ip, &[-128, -1, -127, -2]), -1);
        assert_eq!(run(&ip, &[127, -128, 0, 5]), 127);
        assert_eq!(run(&ip, &[-5, -5, -5, -5]), -5);
    }

    #[test]
    fn prop_pool_matches_reference() {
        let ip = generate(8, 4);
        forall("maxpool == max", 120, |g| {
            let vals = g.signed_vec(8, 4);
            let got = run(&ip, &vals);
            let want = maxpool_ref(&vals);
            if got == want {
                Ok(())
            } else {
                Err(format!("{vals:?}: got {got} want {want}"))
            }
        });
    }

    #[test]
    fn odd_window() {
        let ip = generate(6, 3);
        assert_eq!(run(&ip, &[-32, 31, 0]), 31);
        assert_eq!(run(&ip, &[-32, -31, -30]), -30);
    }

    #[test]
    fn timing_and_resources() {
        let ip = generate(8, 4);
        let u = crate::synth::synthesize(&ip.netlist);
        assert_eq!(u.dsps, 0);
        assert!(u.luts < 80, "pool LUTs {}", u.luts);
        let t = crate::sta::analyze(&ip.netlist, 200.0, 1.0).unwrap();
        assert!(t.met(), "pool WNS {}", t.wns_ns);
    }
}
