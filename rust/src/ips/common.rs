//! Shared microarchitecture frame for the four convolution IPs.
//!
//! Every IP follows the same streaming contract (the paper: "kernel
//! coefficients are loaded serially ..., data inputs are loaded in
//! parallel"):
//!
//! * `win0` (and `win1` for the dual-lane IPs) — the K×K window presented
//!   in parallel, element e at bits `[e·W, (e+1)·W)`. Must be stable for
//!   the K² cycles of a pass; may change exactly at the pass boundary.
//! * `coef` — the *current* coefficient, streamed serially: the wrapper
//!   presents `C[phase]` every cycle (coefficients live outside the IP —
//!   in BRAM/ROM — which is what keeps the IPs this small).
//! * `en` — global clock-enable (backpressure); `rst` — sync reset.
//! * Outputs: `out0` (`out1`), `valid` (one-cycle pulse per completed
//!   pass), `phase` (the coefficient index the IP expects *this* cycle).
//!
//! One MAC retires per cycle per lane; a pass takes K² cycles (II = K²),
//! so "one convolution per cycle" in the paper's Table I reads as "one
//! MAC per cycle, fully pipelined" (see DESIGN.md §0).

use super::params::ConvParams;
use crate::netlist::builder::{Builder, Bus};
use crate::netlist::{NetId, Netlist};

/// Handles to the shared control/datapath nets of an IP under
/// construction.
pub struct Frame {
    pub en: NetId,
    pub rst: NetId,
    /// Phase counter (coefficient index), modulo K².
    pub phase: Bus,
    /// High during the last phase of a pass.
    pub wrap: NetId,
    /// High during phase 0.
    pub first: NetId,
    /// Streamed coefficient input.
    pub coef: Bus,
    /// Current window element per lane (muxed by phase).
    pub sel: Vec<Bus>,
}

/// Build the shared frame: ports, phase counter, per-lane window muxes.
pub fn build_frame(b: &mut Builder, p: &ConvParams, lanes: u32) -> Frame {
    let en_bus = b.input("en", 1);
    let rst_bus = b.input("rst", 1);
    let en = en_bus.bit(0);
    let rst = rst_bus.bit(0);
    let coef = b.input("coef", p.coef_bits as usize);
    let taps = p.taps() as usize;
    let mut sel = Vec::new();
    for lane in 0..lanes {
        let win = b.input(&format!("win{lane}"), taps * p.data_bits as usize);
        let elems: Vec<Bus> = (0..taps)
            .map(|e| win.slice(e * p.data_bits as usize, (e + 1) * p.data_bits as usize))
            .collect();
        sel.push(elems);
    }
    let (phase, wrap) = if taps >= 2 {
        b.counter_mod(taps as u64, en, rst)
    } else {
        // K=1 degenerates: phase is constantly 0 and every cycle wraps.
        let one = b.one();
        (Bus(vec![b.zero()]), one)
    };
    let first = if taps >= 2 { b.eq_const(&phase, 0) } else { b.one() };
    let sel = sel
        .into_iter()
        .map(|elems| if elems.len() == 1 { elems[0].clone() } else { b.mux_bus_tree(&elems, &phase) })
        .collect();
    b.output("phase", &phase);
    Frame { en, rst, phase, wrap, first, coef, sel }
}

/// Delay a 1-bit flag by `n` cycles through CE-gated FDREs (the flag
/// pipeline tracking datapath latency).
pub fn delay_flag(b: &mut Builder, flag: NetId, n: u32, ce: NetId, rst: NetId) -> NetId {
    let mut cur = flag;
    for _ in 0..n {
        cur = b.register(&Bus(vec![cur]), ce, rst).bit(0);
    }
    cur
}

/// Standard output stage: requantize `acc_full`, capture into an output
/// register on `capture & en`, and produce the shared `valid` pulse
/// register if `make_valid`. Returns the registered output bus.
pub fn output_stage(
    b: &mut Builder,
    p: &ConvParams,
    acc_full: &Bus,
    capture: NetId,
    en: NetId,
    rst: NetId,
    lane: u32,
    make_valid: bool,
) -> Bus {
    let q = b.requant(acc_full, p.shift, p.out_bits);
    let ce = b.and2(capture, en);
    let out = b.register(&q, ce, rst);
    b.output(&format!("out{lane}"), &out);
    if make_valid {
        let one = b.one();
        let valid = b.register(&Bus(vec![ce]), one, rst);
        b.output("valid", &valid);
    }
    out
}

/// A fully generated convolution IP: netlist plus schedule metadata the
/// coordinator's performance model consumes.
#[derive(Debug, Clone)]
pub struct ConvIp {
    pub kind: super::params::ConvKind,
    pub params: ConvParams,
    pub netlist: Netlist,
    /// Initiation interval in cycles per pass (= K²). Each pass produces
    /// `kind.lanes()` outputs.
    pub ii: u32,
    /// Cycles from the last phase cycle of a pass until `valid` is high.
    pub out_latency: u32,
    /// `Conv_3` at the packing boundary clamps the high-lane (lane 0)
    /// pixel `min → min+1` — the paper's "reduced precision" (see
    /// [`crate::fixed::pack::Packing::needs_high_clamp`]).
    pub high_lane_clamp: bool,
}

impl ConvIp {
    /// Windows per cycle at steady state.
    pub fn throughput_per_cycle(&self) -> f64 {
        self.kind.lanes() as f64 / self.ii as f64
    }

    /// Behavioral expectation for one window on one lane, including the
    /// lane-0 precision clamp where the IP applies it.
    pub fn expected_window(&self, lane: u32, win: &[i64], coefs: &[i64]) -> i64 {
        if lane == 0 && self.high_lane_clamp {
            let min = -(1i64 << (self.params.data_bits - 1));
            let clamped: Vec<i64> =
                win.iter().map(|&v| if v == min { min + 1 } else { v }).collect();
            self.params.window_ref(&clamped, coefs)
        } else {
            self.params.window_ref(win, coefs)
        }
    }
}
