//! The unified engine registry — one abstraction over every layer engine
//! the IP library can put on the fabric.
//!
//! The paper's conclusion promises expanding the adaptive IP library "to
//! include pooling and activation functions"; this module is where that
//! expansion becomes *uniform* instead of a pile of special cases. An
//! [`EngineKind`] names any deployable engine — the four convolution IPs,
//! the serial FC MAC, the max-pool comparator tree, and the ReLU gate —
//! and every kind answers the same three questions:
//!
//! 1. [`generate`] — netlist + steady-state rate for a parameterization,
//! 2. [`EngineKind::work_per_image`] — how many work units (windows, MACs,
//!    or elements) one image costs at a given layer,
//! 3. [`EngineKind::structural_cap`] — how many instances the streaming
//!    dataflow can actually feed.
//!
//! The planner ([`crate::planner`]) consumes exactly this surface, so a
//! new layer type (strided conv, avg-pool, ...) is one new registry entry
//! — not another planner special case.

use super::params::{ConvKind, ConvParams};
use crate::cnn::model::{Layer, Model, Shape};
use crate::fixed::Round;
use crate::netlist::Netlist;

/// Every engine the registry can deploy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum EngineKind {
    /// One of the paper's four convolution IPs.
    Conv(ConvKind),
    /// Serial MAC fully-connected engine (1 MAC/cycle).
    Fc,
    /// Max-pool comparator tree (1 pooled output/cycle).
    MaxPool,
    /// ReLU gate (1 element/cycle).
    Relu,
}

impl EngineKind {
    /// Display name (conv kinds keep their Table I names).
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Conv(k) => k.name(),
            EngineKind::Fc => "FC",
            EngineKind::MaxPool => "MaxPool",
            EngineKind::Relu => "ReLU",
        }
    }

    /// The conv kind, when this engine is one of the four conv IPs.
    pub fn conv_kind(&self) -> Option<ConvKind> {
        match self {
            EngineKind::Conv(k) => Some(*k),
            _ => None,
        }
    }

    /// Work units one image costs at layer `li`: conv counts window
    /// passes, FC counts MACs, pool/ReLU count elements. `shapes` is
    /// `model.shapes()`. Returns 0 for a kind that cannot serve the layer.
    pub fn work_per_image(&self, model: &Model, li: usize, shapes: &[Shape]) -> u64 {
        let s = shapes[li];
        match (self, &model.layers[li]) {
            (EngineKind::Conv(_), Layer::Conv { in_ch, out_ch, .. }) => {
                (s.h * s.w * out_ch * in_ch) as u64
            }
            (EngineKind::Fc, Layer::Fc { out_dim, .. }) => {
                (fc_in_dim(model, li, shapes) * out_dim) as u64
            }
            (EngineKind::MaxPool, Layer::MaxPool) => s.numel() as u64,
            // ReLU rides fused on a conv/fc layer's output stream.
            (EngineKind::Relu, Layer::Conv { .. } | Layer::Fc { .. }) => s.numel() as u64,
            _ => 0,
        }
    }

    /// Structural parallelism ceiling at layer `li`: finer splits would
    /// need broadcast bandwidth the streaming front-end doesn't have.
    pub fn structural_cap(&self, model: &Model, li: usize, shapes: &[Shape]) -> u64 {
        let s = shapes[li];
        match (self, &model.layers[li]) {
            // One conv engine per (in_ch, out_ch, output_row) tuple.
            (EngineKind::Conv(_), Layer::Conv { in_ch, out_ch, .. }) => {
                (*in_ch as u64) * (*out_ch as u64) * s.h as u64
            }
            // One FC engine per neuron.
            (EngineKind::Fc, Layer::Fc { out_dim, .. }) => *out_dim as u64,
            // One element-stream engine per (channel, output_row).
            (EngineKind::MaxPool, Layer::MaxPool)
            | (EngineKind::Relu, Layer::Conv { .. } | Layer::Fc { .. }) => {
                (s.ch * s.h) as u64
            }
            _ => 0,
        }
    }
}

/// Input fan-in of the FC layer at `li` (flattened predecessor shape).
/// `shapes` is `model.shapes()`.
pub fn fc_in_dim(model: &Model, li: usize, shapes: &[Shape]) -> usize {
    if li == 0 {
        model.in_h * model.in_w * model.in_ch
    } else {
        shapes[li - 1].numel()
    }
}

/// Uniform parameter block for any engine. Hash/Eq so profiles memoize.
///
/// `arith` always carries the operand/requant contract; `fanin` is only
/// meaningful for [`EngineKind::Fc`] and `window` only for
/// [`EngineKind::MaxPool`] — the constructors zero the irrelevant fields
/// so equal configurations compare (and therefore cache) equal.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EngineParams {
    pub arith: ConvParams,
    /// FC dot-product length (0 for non-FC engines).
    pub fanin: u32,
    /// Pool window elements (0 for non-pool engines).
    pub window: u32,
}

impl EngineParams {
    pub fn conv(p: ConvParams) -> EngineParams {
        EngineParams { arith: p, fanin: 0, window: 0 }
    }

    pub fn fc(p: ConvParams, fanin: u32) -> EngineParams {
        EngineParams { arith: p, fanin, window: 0 }
    }

    /// Canonical contract for element-stream engines (pool/ReLU): only
    /// the data width matters, so everything else is pinned.
    fn elem(bits: u32) -> ConvParams {
        ConvParams { k: 1, data_bits: bits, coef_bits: 2, out_bits: bits, shift: 0, round: Round::Truncate }
    }

    pub fn pool(bits: u32, window: u32) -> EngineParams {
        EngineParams { arith: Self::elem(bits), fanin: 0, window }
    }

    pub fn relu(bits: u32) -> EngineParams {
        EngineParams { arith: Self::elem(bits), fanin: 0, window: 0 }
    }
}

/// A generated engine: the netlist plus its steady-state schedule.
#[derive(Debug, Clone)]
pub struct EngineIp {
    pub kind: EngineKind,
    pub netlist: Netlist,
    /// Work units per cycle per instance (windows, MACs, or elements).
    pub rate: f64,
}

/// Generate any registry engine. Errors (never panics) when the kind
/// cannot implement the parameters — e.g. `Conv_3` above 8 bits, FC
/// fan-in overflowing the accumulator, or element widths outside the
/// comparator/gate generators' ranges.
pub fn generate(kind: EngineKind, p: &EngineParams) -> Result<EngineIp, String> {
    match kind {
        EngineKind::Conv(ck) => {
            let ip = super::generate(ck, &p.arith)?;
            Ok(EngineIp { kind, rate: ip.throughput_per_cycle(), netlist: ip.netlist })
        }
        EngineKind::Fc => {
            let mut ip = super::fc::generate(&p.arith, p.fanin)?;
            crate::netlist::opt::optimize(&mut ip.netlist);
            Ok(EngineIp { kind, rate: 1.0, netlist: ip.netlist })
        }
        EngineKind::MaxPool => {
            let bits = p.arith.data_bits;
            if !(2..=32).contains(&bits) {
                return Err(format!("MaxPool data width {bits} outside 2..=32"));
            }
            if !(2..=16).contains(&p.window) {
                return Err(format!("MaxPool window {} outside 2..=16", p.window));
            }
            let mut ip = super::pool::generate(bits, p.window);
            crate::netlist::opt::optimize(&mut ip.netlist);
            Ok(EngineIp { kind, rate: 1.0, netlist: ip.netlist })
        }
        EngineKind::Relu => {
            let bits = p.arith.data_bits;
            if !(2..=32).contains(&bits) {
                return Err(format!("ReLU data width {bits} outside 2..=32"));
            }
            let mut ip = super::relu::generate(bits);
            crate::netlist::opt::optimize(&mut ip.netlist);
            Ok(EngineIp { kind, rate: 1.0, netlist: ip.netlist })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::model::Model;

    #[test]
    fn names_and_conv_kind() {
        assert_eq!(EngineKind::Conv(ConvKind::Conv3).name(), "Conv_3");
        assert_eq!(EngineKind::Fc.name(), "FC");
        assert_eq!(EngineKind::MaxPool.name(), "MaxPool");
        assert_eq!(EngineKind::Relu.name(), "ReLU");
        assert_eq!(EngineKind::Fc.conv_kind(), None);
        assert_eq!(EngineKind::Conv(ConvKind::Conv1).conv_kind(), Some(ConvKind::Conv1));
    }

    #[test]
    fn work_and_caps_on_lenet() {
        let m = Model::lenet_tiny();
        let shapes = m.shapes().unwrap();
        let conv = EngineKind::Conv(ConvKind::Conv2);
        // Layer 0: conv 16x16x1 -> 14x14x4.
        assert_eq!(conv.work_per_image(&m, 0, &shapes), 14 * 14 * 4);
        assert_eq!(conv.structural_cap(&m, 0, &shapes), 4 * 14);
        assert_eq!(EngineKind::Relu.work_per_image(&m, 0, &shapes), 14 * 14 * 4);
        // Layer 1: pool -> 7x7x4.
        assert_eq!(EngineKind::MaxPool.work_per_image(&m, 1, &shapes), 7 * 7 * 4);
        assert_eq!(EngineKind::MaxPool.structural_cap(&m, 1, &shapes), 4 * 7);
        // Layer 4: fc 32 -> 10.
        assert_eq!(EngineKind::Fc.work_per_image(&m, 4, &shapes), 32 * 10);
        assert_eq!(EngineKind::Fc.structural_cap(&m, 4, &shapes), 10);
        // Mismatched kind/layer pairs are inert, not panics.
        assert_eq!(conv.work_per_image(&m, 1, &shapes), 0);
        assert_eq!(EngineKind::Fc.structural_cap(&m, 0, &shapes), 0);
    }

    #[test]
    fn generate_every_kind() {
        let p = ConvParams::paper_8bit();
        for (kind, ep) in [
            (EngineKind::Conv(ConvKind::Conv1), EngineParams::conv(p)),
            (EngineKind::Conv(ConvKind::Conv4), EngineParams::conv(p)),
            (EngineKind::Fc, EngineParams::fc(p, 32)),
            (EngineKind::MaxPool, EngineParams::pool(8, 4)),
            (EngineKind::Relu, EngineParams::relu(8)),
        ] {
            let ip = generate(kind, &ep).unwrap_or_else(|e| panic!("{}: {e}", kind.name()));
            ip.netlist.check().unwrap();
            assert!(ip.rate > 0.0, "{}", kind.name());
            let u = crate::synth::synthesize(&ip.netlist);
            assert!(u.luts + u.dsps > 0, "{} must cost something", kind.name());
        }
    }

    #[test]
    fn generate_rejects_bad_params() {
        let p = ConvParams::paper_8bit();
        // Conv_3 above its packing ceiling.
        let mut wide = p;
        wide.data_bits = 12;
        wide.coef_bits = 12;
        wide.shift = 11;
        assert!(generate(EngineKind::Conv(ConvKind::Conv3), &EngineParams::conv(wide)).is_err());
        // FC fan-in below the serial minimum.
        assert!(generate(EngineKind::Fc, &EngineParams::fc(p, 1)).is_err());
        // Pool window / widths outside the comparator generator's range.
        assert!(generate(EngineKind::MaxPool, &EngineParams::pool(8, 1)).is_err());
        assert!(generate(EngineKind::MaxPool, &EngineParams::pool(40, 4)).is_err());
        assert!(generate(EngineKind::Relu, &EngineParams::relu(1)).is_err());
    }
}
