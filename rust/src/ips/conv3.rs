//! `Conv_3` — dual-pixel packed single-DSP convolution IP.
//!
//! Table I: *"Two parallel convolutions; limited up to 8-bit operands"* —
//! maximum parallelism per DSP at the cost of operand width.
//!
//! Microarchitecture: two windows are processed per pass through ONE
//! DSP48E2 by packing the two current pixels into the wide 27-bit path
//! using the slice's own pre-adder: `D = pix0 << S`, `A = sext(pix1)`,
//! `AD = D + A`, so the packing costs *zero* fabric logic (the known
//! INT8-packing technique, derived in [`crate::fixed::pack`]). After the
//! pass, fabric "correction logic" splits the 48-bit accumulator into the
//! two lane sums: the low lane is the sign-extended low `S` bits and the
//! high lane is incremented by the low lane's sign bit (the borrow).
//!
//! The lane-split feasibility constraint `S + data_bits ≤ 27` is exactly
//! what limits this IP to 8-bit operands for 3×3 kernels — the paper's
//! "reduced precision" caveat, reproduced mechanically here.

use super::common::{build_frame, delay_flag, output_stage, ConvIp};
use super::params::{ConvKind, ConvParams};
use crate::fabric::dsp48::Config;
use crate::fixed::pack;
use crate::netlist::builder::{Builder, Bus};
use crate::netlist::Netlist;

/// DSP pipeline depth (pre-adder path adds ADREG).
pub const DSP_LATENCY: u32 = 4;

/// Generate the `Conv_3` netlist for `p`. Errors when the dual-pixel
/// packing is infeasible for the operand widths / kernel size.
pub fn generate(p: &ConvParams) -> Result<ConvIp, String> {
    p.validate()?;
    let packing = pack::feasible(p.data_bits, p.coef_bits, p.taps()).ok_or_else(|| {
        format!(
            "Conv_3: dual-pixel packing infeasible for {}x{}-bit operands over a {}x{} kernel \
             (max symmetric width for k={} is {} bits)",
            p.data_bits,
            p.coef_bits,
            p.k,
            p.k,
            p.k,
            pack::max_symmetric_bits(p.k)
        )
    })?;
    let s = packing.shift as usize;
    // Rounding bias must leave the low lane's guard margin intact.
    let lane_cap = (1i64 << (s - 1)) - 1;
    let worst = p.taps() as i64 * (1i64 << (p.data_bits + p.coef_bits - 2));
    if p.round_bias() > lane_cap - worst {
        return Err(format!(
            "Conv_3: rounding bias {} would overflow the packed low lane",
            p.round_bias()
        ));
    }

    let mut nl = Netlist::new();
    let mut b = Builder::new(&mut nl);
    let f = build_frame(&mut b, p, 2);

    // High-lane precision clamp (min → min+1) when the packing sits on
    // the 27-bit port boundary: only the LSB changes (0b10..0 → 0b10..1),
    // so the clamp is an eq-detector plus one OR on bit 0.
    let sel0 = if packing.needs_high_clamp() {
        let raw = f.sel[0].clone();
        let is_min = b.eq_const(&raw, 1u64 << (p.data_bits - 1));
        let or2 = crate::fabric::lut::Lut::from_fn(2, |x| x != 0);
        let bit0 = b.lut(or2, vec![raw.bit(0), is_min]);
        let mut nets = vec![bit0];
        nets.extend(&raw.0[1..]);
        Bus(nets)
    } else {
        f.sel[0].clone()
    };

    // D = pix0 << S (high lane), A = sext(pix1) (low lane): AD = D + A.
    let zeros = b.const_bus(0, s);
    let dport = b.concat(&zeros, &sel0); // width s + data_bits ≤ 27
    let aport = f.sel[1].clone();

    let bit0 = b.not(f.first);
    let bias = p.round_bias();
    let bit1 = if bias != 0 { f.first } else { b.zero() };
    let zmux = Bus(vec![bit0, bit1]);
    let cbus = b.const_bus((bias << s) + bias, 48); // bias into both lanes
    let pbus = b.dsp(Config::full_macc(true), &aport, &f.coef, &cbus, &dport, &zmux, f.en);

    let dwrap = delay_flag(&mut b, f.wrap, DSP_LATENCY, f.en, f.rst);

    // Lane split + borrow correction.
    let low = pbus.slice(0, s);
    let high_raw = pbus.slice(s, (s + s).min(48));
    let borrow = pbus.bit(s - 1); // low lane's sign bit
    let high = b.add_carry_in(&high_raw, borrow);

    output_stage(&mut b, p, &high, dwrap, f.en, f.rst, 0, true);
    output_stage(&mut b, p, &low, dwrap, f.en, f.rst, 1, false);

    Ok(ConvIp {
        kind: ConvKind::Conv3,
        params: *p,
        netlist: nl,
        ii: p.taps(),
        out_latency: DSP_LATENCY + 1,
        high_lane_clamp: packing.needs_high_clamp(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::Prim;

    #[test]
    fn generates_and_checks() {
        let ip = generate(&ConvParams::paper_8bit()).unwrap();
        ip.netlist.check().expect("netlist valid");
        let census = ip.netlist.census();
        assert_eq!(census[&Prim::Dsp48e2], 1, "Conv_3 packs two convs into ONE DSP");
    }

    #[test]
    fn paper_operand_limit_enforced() {
        // 9-bit operands over 3x3 must be rejected — Table I's "limited
        // up to 8-bit operands".
        let mut p = ConvParams::paper_8bit();
        p.data_bits = 9;
        p.coef_bits = 9;
        let err = generate(&p).unwrap_err();
        assert!(err.contains("packing infeasible"), "{err}");
        assert!(err.contains("8 bits"), "{err}");
    }

    #[test]
    fn dual_lane_metadata() {
        let ip = generate(&ConvParams::paper_8bit()).unwrap();
        assert_eq!(ip.kind.lanes(), 2);
        assert!((ip.throughput_per_cycle() - 2.0 / 9.0).abs() < 1e-12);
        // Twice Conv_2's throughput with the same DSP count.
        let c2 = super::super::conv2::generate(&ip.params).unwrap();
        assert!(ip.throughput_per_cycle() > 1.9 * c2.throughput_per_cycle());
    }
}
