//! ReLU activation IP — first of the paper's promised future-work layers
//! ("expand the library to include pooling and activation functions").
//!
//! `out = max(0, in)` on signed data: every output bit is `in_i AND NOT
//! sign`, one LUT2 per bit plus the output register. One value per cycle,
//! latency 1.

use crate::fabric::lut::Lut;
use crate::netlist::builder::{Builder, Bus};
use crate::netlist::Netlist;

/// A generated ReLU IP.
#[derive(Debug, Clone)]
pub struct ReluIp {
    pub bits: u32,
    pub netlist: Netlist,
    pub latency: u32,
}

/// Behavioral reference.
pub fn relu_ref(v: i64) -> i64 {
    v.max(0)
}

/// Generate a `bits`-wide ReLU IP.
pub fn generate(bits: u32) -> ReluIp {
    assert!((2..=32).contains(&bits));
    let mut nl = Netlist::new();
    let mut b = Builder::new(&mut nl);
    let en = b.input("en", 1).bit(0);
    let rst = b.input("rst", 1).bit(0);
    let d = b.input("d", bits as usize);
    let sign = d.msb();
    let gated = Bus((0..bits as usize)
        .map(|i| b.lut(Lut::and_not(), vec![d.bit(i), sign]))
        .collect());
    let q = b.register(&gated, en, rst);
    b.output("out", &q);
    ReluIp { bits, netlist: nl, latency: 1 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::sim::Sim;

    #[test]
    fn matches_reference_exhaustive_8bit() {
        let ip = generate(8);
        ip.netlist.check().unwrap();
        let mut sim = Sim::new(&ip.netlist).unwrap();
        sim.set_input("en", 1);
        sim.set_input("rst", 0);
        for v in -128i64..=127 {
            sim.set_input("d", (v as u64) & 0xFF);
            sim.settle();
            sim.tick();
            assert_eq!(sim.output_signed("out"), relu_ref(v), "v={v}");
        }
    }

    #[test]
    fn resource_footprint_tiny() {
        let ip = generate(8);
        let u = crate::synth::synthesize(&ip.netlist);
        assert!(u.luts <= 8, "ReLU must be ~1 LUT/bit, got {}", u.luts);
        assert_eq!(u.dsps, 0);
    }

    #[test]
    fn meets_timing_easily() {
        let ip = generate(8);
        let t = crate::sta::analyze(&ip.netlist, 200.0, 1.0).unwrap();
        assert!(t.wns_ns > 3.0, "ReLU WNS {}", t.wns_ns);
    }
}
