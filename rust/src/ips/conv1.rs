//! `Conv_1` — logic-only serial-MAC convolution IP.
//!
//! Table I: *"Only logic, no DSP; one convolution per cycle"* — high LUT
//! use, zero DSPs, the variant for DSP-starved devices.
//!
//! Microarchitecture: the phase counter selects one window element per
//! cycle; a fused-LUT array multiplier (pipelined once mid-array to close
//! 200 MHz) multiplies it with the streamed coefficient; a fabric adder
//! accumulates; the requantized result is captured at the end of the pass.

use super::common::{build_frame, delay_flag, output_stage, ConvIp};
use super::params::{ConvKind, ConvParams};
use crate::netlist::builder::{Builder, Bus};
use crate::netlist::{CellKind, NetId, Netlist};

/// Generate the `Conv_1` netlist for `p`.
pub fn generate(p: &ConvParams) -> Result<ConvIp, String> {
    p.validate()?;
    let mut nl = Netlist::new();
    let mut b = Builder::new(&mut nl);
    let f = build_frame(&mut b, p, 1);

    // Pipelined logic multiplier: cut every ~3 rows for 200 MHz closure.
    let cuts: Vec<usize> = (1..p.coef_bits as usize).filter(|r| r % 2 == 0).collect();
    let (raw_prod, mult_stages) = b.mul_signed(&f.sel[0], &f.coef, &cuts, f.en, f.rst);
    // Register the product before the accumulator: keeps the final
    // multiplier rows and the accumulate/requant adder in separate cycles.
    let prod = b.register(&raw_prod, f.en, f.rst);
    let stages = mult_stages as u32 + 1;

    // Flag pipeline tracking the multiplier latency.
    let dfirst = delay_flag(&mut b, f.first, stages, f.en, f.rst);
    let dwrap = delay_flag(&mut b, f.wrap, stages, f.en, f.rst);

    // Accumulator loop: acc' = (dfirst ? bias : acc) + product.
    let acc_bits = p.acc_bits() as usize;
    let acc_q_nets: Vec<NetId> = (0..acc_bits).map(|_| b.nl.net()).collect();
    let acc_q = Bus(acc_q_nets.clone());
    let bias = b.const_bus(p.round_bias(), acc_bits);
    let base = b.mux2(dfirst, &acc_q, &bias);
    let sum = b.add(&base, &prod);
    let acc_d = b.trunc(&sum, acc_bits); // partial sums provably fit acc_bits
    for (i, &q) in acc_q_nets.iter().enumerate() {
        b.nl.add_cell(CellKind::Fdre, vec![acc_d.bit(i), f.en, f.rst], vec![q]);
    }

    // Requantize from the *registered* accumulator one cycle later — keeps
    // the adder and the saturation tree in separate cycles (200 MHz
    // closure; acc_q still holds the full sum during that cycle).
    let dwrap2 = delay_flag(&mut b, dwrap, 1, f.en, f.rst);
    output_stage(&mut b, p, &acc_q, dwrap2, f.en, f.rst, 0, true);

    Ok(ConvIp {
        kind: ConvKind::Conv1,
        params: *p,
        netlist: nl,
        ii: p.taps(),
        out_latency: stages + 2,
        high_lane_clamp: false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::Prim;

    #[test]
    fn generates_and_checks() {
        let ip = generate(&ConvParams::paper_8bit()).unwrap();
        ip.netlist.check().expect("netlist valid");
        let census = ip.netlist.census();
        assert_eq!(census.get(&Prim::Dsp48e2), None, "Conv_1 must use no DSPs");
        let luts = census[&Prim::Lut];
        assert!(luts > 60, "Conv_1 is the high-logic variant, got {luts} LUTs");
    }

    #[test]
    fn schedule_metadata() {
        let ip = generate(&ConvParams::paper_8bit()).unwrap();
        assert_eq!(ip.ii, 9);
        assert_eq!(ip.kind.lanes(), 1);
        assert!((ip.throughput_per_cycle() - 1.0 / 9.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_invalid_params() {
        let mut p = ConvParams::paper_8bit();
        p.data_bits = 20;
        assert!(generate(&p).is_err());
    }
}
