//! The paper's adaptive IP library, unified behind the engine registry.
//!
//! Four convolution IPs span the DSP/logic trade-off space (Table I), and
//! the future-work layers the paper's conclusion promises (pooling,
//! activation, fully-connected) sit beside them as first-class engines:
//!
//! | IP | DSPs | Logic | Lanes | Notes |
//! |----|------|-------|-------|-------|
//! | [`conv1`] | 0 | high | 1 | logic multiplier, for DSP-starved parts |
//! | [`conv2`] | 1 | minimal | 1 | plain DSP MACC |
//! | [`conv3`] | 1 | moderate | 2 | dual-pixel packing, ≤8-bit operands |
//! | [`conv4`] | 2 | moderate | 2 | two MACC lanes, wide operands |
//! | [`fc`] | 1 | minimal | 1 | serial dot-product MAC (1 MAC/cycle) |
//! | [`pool`] | 0 | low | 1 | max comparator tree (1 output/cycle) |
//! | [`relu`] | 0 | tiny | 1 | sign-gated AND (1 element/cycle) |
//!
//! [`engine`] is the single surface the planner consumes: an
//! [`engine::EngineKind`] names any of the above, and every kind answers
//! `generate` / `work_per_image` / `structural_cap` uniformly, so whole
//! networks — not just conv stacks — are planned, costed, and
//! bottleneck-checked through one abstraction.
//!
//! All netlists are generated from [`params::ConvParams`]-style parameter
//! blocks (the VHDL generics) into checked netlists, verified bit-exactly
//! against the behavioral models by [`verify`] and the per-module tests.

pub mod common;
pub mod conv1;
pub mod conv2;
pub mod conv3;
pub mod conv4;
pub mod engine;
pub mod fc;
pub mod params;
pub mod pool;
pub mod relu;
pub mod verify;
pub mod window_feed;

pub use common::ConvIp;
pub use params::{ConvKind, ConvParams};

/// Generate any of the four convolution IPs, optimized at the
/// process-wide [`crate::netlist::opt::level`]. The per-module
/// generators (`conv1::generate`, ...) stay raw for differential tests
/// and pre/post-opt reporting.
pub fn generate(kind: ConvKind, p: &ConvParams) -> Result<ConvIp, String> {
    let mut ip = match kind {
        ConvKind::Conv1 => conv1::generate(p),
        ConvKind::Conv2 => conv2::generate(p),
        ConvKind::Conv3 => conv3::generate(p),
        ConvKind::Conv4 => conv4::generate(p),
    }?;
    crate::netlist::opt::optimize(&mut ip.netlist);
    Ok(ip)
}

/// Table I row: qualitative characteristics (design intent, as published).
#[derive(Debug, Clone)]
pub struct Characteristics {
    pub kind: ConvKind,
    pub dsp_usage: &'static str,
    pub logic_usage: &'static str,
    pub key_features: &'static str,
}

/// The paper's Table I, regenerated from the library's metadata.
pub fn characteristics(kind: ConvKind) -> Characteristics {
    match kind {
        ConvKind::Conv1 => Characteristics {
            kind,
            dsp_usage: "None",
            logic_usage: "High",
            key_features: "Only logic, no DSP; one convolution per cycle.",
        },
        ConvKind::Conv2 => Characteristics {
            kind,
            dsp_usage: "1 DSP",
            logic_usage: "Moderate",
            key_features: "Reduces the use of logic; one convolution per cycle.",
        },
        ConvKind::Conv3 => Characteristics {
            kind,
            dsp_usage: "1 DSP",
            logic_usage: "High",
            key_features: "Two parallel convolutions; limited up to 8-bit operands.",
        },
        ConvKind::Conv4 => Characteristics {
            kind,
            dsp_usage: "2 DSPs",
            logic_usage: "Moderate",
            key_features: "Two parallel convolutions; optimized for parallelism.",
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn all_four_equivalent_to_behavioral_paper_config() {
        let p = ConvParams::paper_8bit();
        for kind in ConvKind::ALL {
            let ip = generate(kind, &p).unwrap();
            let n = verify::check_equivalence(&ip, 0xBEEF ^ kind as u64, 12);
            assert!(n >= 12);
        }
    }

    #[test]
    fn equivalence_with_rounding_bias() {
        use crate::fixed::Round;
        let p = ConvParams { round: Round::NearestEven, ..ConvParams::paper_8bit() };
        for kind in ConvKind::ALL {
            let ip = generate(kind, &p).unwrap();
            verify::check_equivalence(&ip, 0xD00D ^ kind as u64, 8);
        }
    }

    #[test]
    fn equivalence_across_widths() {
        // Sweep operand widths; Conv_3 drops out above its packing limit.
        for bits in [4u32, 6, 8, 10, 12] {
            let p = ConvParams {
                k: 3,
                data_bits: bits,
                coef_bits: bits,
                out_bits: bits,
                shift: bits - 1,
                round: crate::fixed::Round::Truncate,
            };
            for kind in ConvKind::ALL {
                match generate(kind, &p) {
                    Ok(ip) => {
                        verify::check_equivalence(&ip, bits as u64 ^ kind as u64, 6);
                    }
                    Err(_) => {
                        assert_eq!(kind, ConvKind::Conv3, "only Conv_3 may reject {bits}-bit");
                        assert!(bits > 8, "Conv_3 must accept ≤8-bit");
                    }
                }
            }
        }
    }

    #[test]
    fn equivalence_across_kernel_sizes() {
        for k in [1u32, 2, 3, 5] {
            let p = ConvParams {
                k,
                data_bits: 6,
                coef_bits: 6,
                out_bits: 8,
                shift: 4,
                round: crate::fixed::Round::Truncate,
            };
            for kind in ConvKind::ALL {
                if let Ok(ip) = generate(kind, &p) {
                    verify::check_equivalence(&ip, ((k as u64) << 8) | kind as u64, 5);
                }
            }
        }
    }

    #[test]
    fn extreme_operands_exact() {
        // All-min / all-max windows — the packing worst case.
        let p = ConvParams::paper_8bit();
        for kind in ConvKind::ALL {
            let ip = generate(kind, &p).unwrap();
            let lanes = kind.lanes() as usize;
            let lo = vec![-128i64; 9];
            let hi = vec![127i64; 9];
            let windows = vec![
                vec![lo.clone(); lanes],
                vec![hi.clone(); lanes],
                if lanes == 2 { vec![lo.clone(), hi.clone()] } else { vec![hi.clone()] },
            ];
            let coefs = vec![-128i64; 9];
            let got = verify::run_ip(&ip, &windows, &coefs);
            let want = verify::expected(&ip, &windows, &coefs);
            assert_eq!(got, want, "{}", kind.name());
        }
    }

    #[test]
    fn table1_characteristics_complete() {
        for kind in ConvKind::ALL {
            let c = characteristics(kind);
            assert!(!c.key_features.is_empty());
        }
        assert_eq!(characteristics(ConvKind::Conv1).dsp_usage, "None");
        assert_eq!(characteristics(ConvKind::Conv4).dsp_usage, "2 DSPs");
    }

    #[test]
    fn dsp_census_matches_kind_metadata() {
        let p = ConvParams::paper_8bit();
        for kind in ConvKind::ALL {
            let ip = generate(kind, &p).unwrap();
            let dsps = *ip.netlist.census().get(&crate::fabric::Prim::Dsp48e2).unwrap_or(&0);
            assert_eq!(dsps, kind.dsps() as u64, "{}", kind.name());
        }
    }

    #[test]
    fn stalls_do_not_corrupt_results() {
        // Insert random en=0 bubbles; outputs must be unchanged.
        let p = ConvParams::paper_8bit();
        for kind in ConvKind::ALL {
            let ip = generate(kind, &p).unwrap();
            let mut rng = Rng::new(77);
            let (windows, coefs) = verify::random_stimulus(&ip, &mut rng, 4);
            let want = verify::expected(&ip, &windows, &coefs);
            let got = run_with_stalls(&ip, &windows, &coefs, &mut rng);
            assert_eq!(got, want, "{} with stalls", kind.name());
        }
    }

    /// Like verify::run_ip but with random clock-enable bubbles.
    fn run_with_stalls(
        ip: &ConvIp,
        windows: &[verify::PassStimulus],
        coefs: &[i64],
        rng: &mut Rng,
    ) -> Vec<Vec<i64>> {
        use crate::netlist::sim::Sim;
        let p = &ip.params;
        let lanes = ip.kind.lanes() as usize;
        let taps = p.taps() as usize;
        let mut sim = Sim::new(&ip.netlist).unwrap();
        // Same shared driver as verify::run_ip; only the en-gating differs.
        let ports = verify::IpPorts::resolve(&sim, lanes);
        ports.reset(&mut sim, p);
        let mut results = Vec::new();
        let mut active = 0usize; // enabled cycles elapsed
        let total = windows.len() * taps + ip.out_latency as usize + 4;
        let mut guard = 0;
        while active < total {
            guard += 1;
            assert!(guard < total * 20, "stall test runaway");
            let en = !rng.chance(0.3);
            sim.set_input_at(ports.en, en as u64);
            let phase = active % taps;
            let pass = (active / taps).min(windows.len() - 1);
            ports.drive(&mut sim, p, windows, pass, coefs, phase);
            sim.settle();
            if let Some(row) = ports.capture(&sim) {
                results.push(row);
                if results.len() == windows.len() {
                    break;
                }
            }
            sim.tick();
            if en {
                active += 1;
            }
        }
        results
    }
}
