//! Sliding-window front-end — the streaming feeder every conv IP needs on
//! real hardware ("data inputs are loaded in parallel" presumes someone
//! assembled the K×K window).
//!
//! Pixels arrive one per cycle in raster order; K−1 RAMB18 line buffers
//! delay whole rows, and a K×K register file shifts horizontally, so a
//! complete window is available every cycle once primed. This is the
//! classic FPGA structure the paper's enclosing layer engine implies, and
//! it is what the BRAM column of a full deployment report accounts for.

use super::params::ConvParams;
use crate::fabric::bram::ramb18_count;
use crate::netlist::builder::{Builder, Bus};
use crate::netlist::{CellKind, NetId, Netlist};

/// A generated window feeder.
#[derive(Debug, Clone)]
pub struct WindowFeed {
    pub k: u32,
    pub data_bits: u32,
    /// Image row length the line buffers are sized for.
    pub row_len: u32,
    pub netlist: Netlist,
    /// Cycles from a pixel entering to the window containing it as its
    /// bottom-right element being presented: (K−1) rows + K columns + 1
    /// BRAM read register.
    pub prime_latency: u32,
}

/// Behavioral reference: feed `pixels` (raster order, `row_len` wide) and
/// return the window presented after each input cycle (LSB-first element
/// order matching the IP `win` port: element e = row e/K, col e%K, with
/// row 0 = OLDEST row and col 0 = oldest pixel).
pub fn feed_ref(pixels: &[i64], row_len: usize, k: usize) -> Vec<Vec<i64>> {
    let mut out = Vec::with_capacity(pixels.len());
    for t in 0..pixels.len() {
        let mut win = vec![0i64; k * k];
        for ry in 0..k {
            for rx in 0..k {
                // Element (ry, rx): the pixel (k-1-ry) rows and (k-1-rx)
                // columns before the current one.
                let back = (k - 1 - ry) * row_len + (k - 1 - rx);
                win[ry * k + rx] = if t >= back { pixels[t - back] } else { 0 };
            }
        }
        out.push(win);
    }
    out
}

/// Generate the feeder netlist. Ports: `px` (pixel in), `en`, `rst` →
/// `win` (K²·W bits, same layout as the conv IPs' `win0`).
pub fn generate(p: &ConvParams, row_len: u32) -> Result<WindowFeed, String> {
    p.validate()?;
    if row_len < p.k || row_len > 4096 {
        return Err(format!("row_len {row_len} unsupported"));
    }
    let k = p.k as usize;
    let w = p.data_bits as usize;
    let mut nl = Netlist::new();
    let mut b = Builder::new(&mut nl);
    let en = b.input("en", 1).bit(0);
    let rst = b.input("rst", 1).bit(0);
    let px = b.input("px", w);

    // Write address counter: modulo row_len, shared by all lines. Reads
    // run one slot AHEAD (the slot written row_len−1 cycles ago), so each
    // line's registered read output is its input delayed by EXACTLY
    // row_len cycles — chaining K−1 lines spaces the rows correctly.
    let (addr, wrap) = b.counter_mod(row_len as u64, en, rst);
    let inc = b.increment(&addr);
    let zero_addr = b.const_bus(0, addr.width());
    let raddr = b.mux2(wrap, &inc, &zero_addr);

    // Line buffers: line i delays by exactly row_len cycles. We write the
    // incoming stream of "row i" and read the slot written row_len ago —
    // same address, read-old semantics + output register = row_len delay
    // when the read register is CE-gated like the rest of the pipe.
    // BRAM read has 1 cycle latency; align the direct (newest) row with a
    // register so all rows see the same column phase.
    let mut rows: Vec<Bus> = Vec::with_capacity(k); // rows[0] = oldest
    // Newest row: the live input (combinational — the conv IPs register
    // their operands internally).
    let newest = px.clone();
    let mut upstream = newest.clone(); // what feeds the next line buffer
    let mut chain: Vec<Bus> = vec![newest.clone()];
    for _ in 1..k {
        // One RAMB18 line: write `upstream` at addr, read one slot ahead.
        let rdata: Vec<NetId> = (0..w).map(|_| b.nl.net()).collect();
        let mut ins: Vec<NetId> = upstream.nets().to_vec();
        ins.extend(addr.nets());
        ins.push(en); // WE gated by en
        ins.extend(raddr.nets());
        b.nl.add_cell(
            CellKind::Ramb18 { width: w as u32, depth: row_len.next_power_of_two() },
            ins,
            rdata.clone(),
        );
        let line_out = Bus(rdata);
        chain.push(line_out.clone());
        upstream = line_out;
    }
    // chain[0] = newest row ... chain[k-1] = oldest row.
    for i in (0..k).rev() {
        rows.push(chain[i].clone());
    }

    // Horizontal shift registers: per row, K column taps (tap 0 = oldest).
    let mut win_nets: Vec<NetId> = Vec::with_capacity(k * k * w);
    let mut all_taps: Vec<Vec<Bus>> = Vec::new();
    for row in &rows {
        let mut taps = vec![row.clone()];
        for _ in 1..k {
            let prev = taps.last().unwrap().clone();
            taps.push(b.register(&prev, en, rst));
        }
        taps.reverse(); // taps[0] = oldest column
        all_taps.push(taps);
    }
    for taps in &all_taps {
        for tap in taps {
            win_nets.extend(tap.nets());
        }
    }
    let win = Bus(win_nets);
    b.output("win", &win);

    Ok(WindowFeed {
        k: p.k,
        data_bits: p.data_bits,
        row_len,
        netlist: nl,
        prime_latency: (p.k - 1) * row_len + p.k - 1,
    })
}

/// BRAM cost of the feeder (for deployment resource reports).
pub fn bram_cost(p: &ConvParams, row_len: u32) -> u64 {
    ((p.k - 1) as u64) * ramb18_count(p.data_bits, row_len.next_power_of_two()) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::sim::Sim;
    use crate::util::rng::Rng;

    /// Drive the netlist with a pixel stream and capture the window after
    /// each cycle (aligned to the feeder's 1-cycle input register).
    fn run(feed: &WindowFeed, pixels: &[i64]) -> Vec<Vec<i64>> {
        let k = feed.k as usize;
        let w = feed.data_bits as usize;
        let mut sim = Sim::new(&feed.netlist).unwrap();
        sim.set_input("rst", 1);
        sim.set_input("en", 1);
        sim.set_input("px", 0);
        sim.settle();
        sim.tick();
        sim.set_input("rst", 0);
        let mask = (1u64 << w) - 1;
        let mut out = Vec::new();
        for &p in pixels {
            sim.set_input("px", (p as u64) & mask);
            sim.settle();
            // Mid-cycle view: the live pixel is the newest window element.
            let raw = (0..k * k)
                .map(|e| {
                    let bus: Vec<_> =
                        (0..w).map(|bit| feed.netlist.outputs[0].1[e * w + bit]).collect();
                    sim.get_signed(&bus)
                })
                .collect::<Vec<_>>();
            out.push(raw);
            sim.tick();
        }
        out
    }

    #[test]
    fn matches_reference_after_priming() {
        let p = ConvParams::paper_8bit();
        let row_len = 8u32;
        let feed = generate(&p, row_len).unwrap();
        feed.netlist.check().unwrap();
        let mut rng = Rng::new(4);
        let pixels: Vec<i64> = (0..(row_len as usize) * 6).map(|_| rng.signed_bits(8)).collect();
        let got = run(&feed, &pixels);
        let want = feed_ref(&pixels, row_len as usize, 3);
        // Compare once fully primed (all line buffers loaded with real data).
        let prime = feed.prime_latency as usize + row_len as usize;
        assert_eq!(&got[prime..], &want[prime..], "post-prime windows must match");
    }

    #[test]
    fn window_layout_matches_ip_port() {
        // A raster ramp: element (ry, rx) must equal the reference layout
        // used by ConvParams::window_ref / cnn::infer::window.
        let p = ConvParams::paper_8bit();
        let row_len = 8usize;
        let feed = generate(&p, row_len as u32).unwrap();
        let pixels: Vec<i64> = (0..row_len * 5).map(|i| (i as i64 % 120)).collect();
        let got = run(&feed, &pixels);
        let t = pixels.len() - 1;
        let want_last = feed_ref(&pixels, row_len, 3)[t].clone();
        assert_eq!(got[t], want_last);
        // And the reference itself must slice like infer::window on the
        // equivalent image.
        let win = &want_last;
        assert_eq!(win[8], pixels[t], "bottom-right = newest pixel");
        assert_eq!(win[0], pixels[t - 2 * row_len - 2], "top-left = oldest");
    }

    #[test]
    fn lane_batched_feed_matches_reference_per_lane() {
        // One lane-parallel Sim carries several independent pixel
        // streams through the SAME line buffers and shift registers —
        // the RAMB18 per-lane state path. Every lane must match the
        // behavioral reference for its own stream.
        let p = ConvParams::paper_8bit();
        let row_len = 8u32;
        let feed = generate(&p, row_len).unwrap();
        let k = feed.k as usize;
        let w = feed.data_bits as usize;
        let lanes = 5usize;
        let mut rng = Rng::new(9);
        let streams: Vec<Vec<i64>> = (0..lanes)
            .map(|_| (0..(row_len as usize) * 6).map(|_| rng.signed_bits(8)).collect())
            .collect();
        let mut sim = Sim::with_lanes(&feed.netlist, lanes).unwrap();
        sim.set_input("rst", 1);
        sim.set_input("en", 1);
        sim.set_input("px", 0);
        sim.settle();
        sim.tick();
        sim.set_input("rst", 0);
        let px_ix = sim.input_index("px");
        let mask = (1u64 << w) - 1;
        let mut got: Vec<Vec<Vec<i64>>> = vec![Vec::new(); lanes];
        for t in 0..streams[0].len() {
            for (lane, s) in streams.iter().enumerate() {
                sim.set_input_lane_at(px_ix, lane, (s[t] as u64) & mask);
            }
            sim.settle();
            for (lane, rows) in got.iter_mut().enumerate() {
                let win: Vec<i64> = (0..k * k)
                    .map(|e| {
                        let bus: Vec<_> =
                            (0..w).map(|bit| feed.netlist.outputs[0].1[e * w + bit]).collect();
                        sim.get_signed_lane(&bus, lane)
                    })
                    .collect();
                rows.push(win);
            }
            sim.tick();
        }
        let prime = feed.prime_latency as usize + row_len as usize;
        for (lane, stream) in streams.iter().enumerate() {
            let want = feed_ref(stream, row_len as usize, k);
            assert_eq!(&got[lane][prime..], &want[prime..], "lane {lane}");
        }
    }

    #[test]
    fn resource_cost_scales_with_k() {
        let p3 = ConvParams::paper_8bit();
        let p5 = ConvParams { k: 5, ..p3 };
        let f3 = generate(&p3, 64).unwrap();
        let f5 = generate(&p5, 64).unwrap();
        let u3 = crate::synth::synthesize(&f3.netlist);
        let u5 = crate::synth::synthesize(&f5.netlist);
        assert_eq!(u3.bram18, 2, "K-1 line buffers");
        assert_eq!(u5.bram18, 4);
        assert!(u5.regs > u3.regs);
        assert_eq!(bram_cost(&p3, 64), 2);
    }

    #[test]
    fn meets_timing() {
        let p = ConvParams::paper_8bit();
        let feed = generate(&p, 256).unwrap();
        let t = crate::sta::analyze(&feed.netlist, 200.0, 1.0).unwrap();
        assert!(t.met(), "window feeder WNS {}", t.wns_ns);
    }

    #[test]
    fn rejects_bad_geometry() {
        let p = ConvParams::paper_8bit();
        assert!(generate(&p, 2).is_err());
        assert!(generate(&p, 100_000).is_err());
    }
}
