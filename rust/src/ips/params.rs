//! IP parameterization — the Rust mirror of the paper's VHDL generics.
//!
//! All four convolution IPs share one parameter block: kernel size, data /
//! coefficient widths, the requantization contract, and the rounding mode.
//! The same struct parameterizes the behavioral models, the netlist
//! generators, and (through `aot.py`'s build flags) the Pallas kernels, so
//! every layer agrees on arithmetic by construction.

use crate::fixed::{self, requantize, Round};
use crate::util::json::{Json, JsonError};

/// Convolution IP parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ConvParams {
    /// Kernel size K (window is K×K).
    pub k: u32,
    /// Signed pixel width.
    pub data_bits: u32,
    /// Signed coefficient width.
    pub coef_bits: u32,
    /// Requantized output width.
    pub out_bits: u32,
    /// Requantization right-shift.
    pub shift: u32,
    /// Rounding mode (netlists implement both via +half injection).
    pub round: Round,
}

impl ConvParams {
    /// The paper's experimental configuration: 3×3 kernel, 8-bit operands.
    pub fn paper_8bit() -> ConvParams {
        ConvParams { k: 3, data_bits: 8, coef_bits: 8, out_bits: 8, shift: 7, round: Round::Truncate }
    }

    /// Window tap count K².
    pub fn taps(&self) -> u32 {
        self.k * self.k
    }

    /// Exact accumulator width for a full window.
    pub fn acc_bits(&self) -> u32 {
        fixed::acc_bits(self.data_bits, self.coef_bits, self.taps())
    }

    /// Phase-counter width.
    pub fn phase_bits(&self) -> u32 {
        fixed::ceil_log2(self.taps()).max(1)
    }

    /// The +half rounding constant injected into the accumulator
    /// (0 for truncation).
    pub fn round_bias(&self) -> i64 {
        match self.round {
            Round::Truncate => 0,
            Round::NearestEven => {
                if self.shift == 0 {
                    0
                } else {
                    1i64 << (self.shift - 1)
                }
            }
        }
    }

    /// Behavioral reference for ONE window: full-precision dot product,
    /// bias injection, shift, saturate. This is the function every netlist
    /// and the Pallas kernels must reproduce bit-exactly.
    ///
    /// Note: bias injection + truncating shift implements round-half-up
    /// for `NearestEven` configs only when ties are absent; the netlists
    /// use the same bias trick, so netlist-vs-behavioral equivalence holds
    /// exactly. (True convergent rounding needs the DSP pattern-detect
    /// path, out of scope — documented in DESIGN.md.)
    pub fn window_ref(&self, data: &[i64], coef: &[i64]) -> i64 {
        assert_eq!(data.len(), self.taps() as usize);
        assert_eq!(coef.len(), self.taps() as usize);
        debug_assert!(data.iter().all(|&d| fixed::Format::new(self.data_bits, 0).contains(d)));
        debug_assert!(coef.iter().all(|&c| fixed::Format::new(self.coef_bits, 0).contains(c)));
        let acc = fixed::window_dot(data, coef) + self.round_bias();
        requantize(acc, self.shift, Round::Truncate, self.out_bits)
    }

    pub fn to_json(&self) -> Json {
        crate::util::json::obj([
            ("k", self.k.into()),
            ("data_bits", self.data_bits.into()),
            ("coef_bits", self.coef_bits.into()),
            ("out_bits", self.out_bits.into()),
            ("shift", self.shift.into()),
            (
                "round",
                match self.round {
                    Round::Truncate => "truncate".into(),
                    Round::NearestEven => "nearest".into(),
                },
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ConvParams, JsonError> {
        let round = match v.get_opt("round")?.map(|r| r.as_str()).transpose()? {
            None | Some("truncate") => Round::Truncate,
            Some("nearest") => Round::NearestEven,
            Some(other) => {
                return Err(JsonError::Access(format!("unknown rounding mode '{other}'")))
            }
        };
        Ok(ConvParams {
            k: v.get("k")?.as_u64()? as u32,
            data_bits: v.get("data_bits")?.as_u64()? as u32,
            coef_bits: v.get("coef_bits")?.as_u64()? as u32,
            out_bits: v.get("out_bits")?.as_u64()? as u32,
            shift: v.get("shift")?.as_u64()? as u32,
            round,
        })
    }

    /// Validate parameter sanity (widths the primitives can honor).
    pub fn validate(&self) -> Result<(), String> {
        if !(1..=7).contains(&self.k) {
            return Err(format!("kernel size {} out of supported range 1..=7", self.k));
        }
        if !(2..=16).contains(&self.data_bits) || !(2..=16).contains(&self.coef_bits) {
            return Err("operand widths must be in 2..=16".into());
        }
        if !(2..=32).contains(&self.out_bits) {
            return Err("out_bits must be in 2..=32".into());
        }
        if self.shift + self.out_bits > self.acc_bits() + 8 {
            return Err(format!(
                "shift {} + out_bits {} far exceeds accumulator width {}",
                self.shift,
                self.out_bits,
                self.acc_bits()
            ));
        }
        Ok(())
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ConvKind {
    /// Logic-only serial MAC — no DSPs, high LUT use.
    Conv1,
    /// One DSP48E2 MACC — minimal logic.
    Conv2,
    /// One DSP48E2, dual-pixel packed — two windows per pass, ≤8-bit ops.
    Conv3,
    /// Two DSP48E2s — two windows per pass, wide operands.
    Conv4,
}

impl ConvKind {
    pub const ALL: [ConvKind; 4] = [ConvKind::Conv1, ConvKind::Conv2, ConvKind::Conv3, ConvKind::Conv4];

    pub fn name(&self) -> &'static str {
        match self {
            ConvKind::Conv1 => "Conv_1",
            ConvKind::Conv2 => "Conv_2",
            ConvKind::Conv3 => "Conv_3",
            ConvKind::Conv4 => "Conv_4",
        }
    }

    pub fn parse(s: &str) -> Option<ConvKind> {
        match s.to_ascii_lowercase().as_str() {
            "conv1" | "conv_1" => Some(ConvKind::Conv1),
            "conv2" | "conv_2" => Some(ConvKind::Conv2),
            "conv3" | "conv_3" => Some(ConvKind::Conv3),
            "conv4" | "conv_4" => Some(ConvKind::Conv4),
            _ => None,
        }
    }

    /// Output lanes (parallel windows per pass) — Table I "parallelism".
    pub fn lanes(&self) -> u32 {
        match self {
            ConvKind::Conv1 | ConvKind::Conv2 => 1,
            ConvKind::Conv3 | ConvKind::Conv4 => 2,
        }
    }

    /// DSP slices consumed — Table I "DSP usage".
    pub fn dsps(&self) -> u32 {
        match self {
            ConvKind::Conv1 => 0,
            ConvKind::Conv2 | ConvKind::Conv3 => 1,
            ConvKind::Conv4 => 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_params() {
        let p = ConvParams::paper_8bit();
        assert_eq!(p.taps(), 9);
        assert_eq!(p.acc_bits(), 20);
        assert_eq!(p.phase_bits(), 4);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn window_ref_basic() {
        let p = ConvParams { shift: 0, out_bits: 32, ..ConvParams::paper_8bit() };
        let d = [1, 2, 3, 4, 5, 6, 7, 8, 9];
        let c = [1, 1, 1, 1, 1, 1, 1, 1, 1];
        assert_eq!(p.window_ref(&d, &c), 45);
    }

    #[test]
    fn window_ref_shifts_and_saturates() {
        let p = ConvParams::paper_8bit(); // shift 7, out 8
        let d = [127i64; 9];
        let c = [127i64; 9];
        // 9*127*127 = 145161; >>7 = 1134 -> saturates to 127
        assert_eq!(p.window_ref(&d, &c), 127);
        let c2 = [-128i64; 9];
        assert_eq!(p.window_ref(&d, &c2), -128);
        let small = [1i64, 0, 0, 0, 0, 0, 0, 0, 0];
        // 127*1 >> 7 = 0
        assert_eq!(p.window_ref(&d, &small), 0);
    }

    #[test]
    fn round_bias() {
        let mut p = ConvParams::paper_8bit();
        assert_eq!(p.round_bias(), 0);
        p.round = Round::NearestEven;
        assert_eq!(p.round_bias(), 64);
    }

    #[test]
    fn json_roundtrip() {
        let p = ConvParams { k: 5, data_bits: 6, coef_bits: 7, out_bits: 8, shift: 5, round: Round::NearestEven };
        let back = ConvParams::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn validate_rejects_silly() {
        let mut p = ConvParams::paper_8bit();
        p.k = 9;
        assert!(p.validate().is_err());
        let mut p2 = ConvParams::paper_8bit();
        p2.data_bits = 1;
        assert!(p2.validate().is_err());
    }

    #[test]
    fn kind_metadata_matches_table1() {
        use ConvKind::*;
        assert_eq!(Conv1.dsps(), 0);
        assert_eq!(Conv2.dsps(), 1);
        assert_eq!(Conv3.dsps(), 1);
        assert_eq!(Conv4.dsps(), 2);
        assert_eq!(Conv1.lanes(), 1);
        assert_eq!(Conv3.lanes(), 2);
        assert_eq!(Conv4.lanes(), 2);
        assert_eq!(ConvKind::parse("conv_3"), Some(Conv3));
        assert_eq!(ConvKind::parse("zzz"), None);
    }
}
