//! `Conv_2` — single-DSP MACC convolution IP.
//!
//! Table I: *"1 DSP, reduces the use of logic; one convolution per
//! cycle"* — the minimal-logic variant for DSP-rich, LUT-poor devices.
//!
//! Microarchitecture: one DSP48E2 in multiply-accumulate mode. The window
//! mux feeds the A port, the streamed coefficient the B port; the Z
//! multiplexer starts each pass (`Zero`, or `C` when a rounding bias is
//! injected) and accumulates otherwise. Fabric logic is only the window
//! mux, phase counter, requantizer and output capture.

use super::common::{build_frame, delay_flag, output_stage, ConvIp};
use super::params::{ConvKind, ConvParams};
use crate::fabric::dsp48::Config;
use crate::netlist::builder::{Builder, Bus};
use crate::netlist::Netlist;

/// DSP pipeline depth used by this IP (full MACC pipelining, no D port).
pub const DSP_LATENCY: u32 = 3;

/// Generate the `Conv_2` netlist for `p`.
pub fn generate(p: &ConvParams) -> Result<ConvIp, String> {
    p.validate()?;
    if p.coef_bits > 18 {
        return Err(format!("Conv_2: coef_bits {} exceeds the DSP B port (18)", p.coef_bits));
    }
    if p.data_bits > 27 {
        return Err(format!("Conv_2: data_bits {} exceeds the DSP A port (27)", p.data_bits));
    }
    let mut nl = Netlist::new();
    let mut b = Builder::new(&mut nl);
    let f = build_frame(&mut b, p, 1);

    // Z-mux encoding (see netlist::CellKind::Dsp48e2): 00=Zero 01=P 10=C.
    let bit0 = b.not(f.first); // accumulate whenever not at phase 0
    let bit1 = if p.round_bias() != 0 { f.first } else { b.zero() };
    let zmux = Bus(vec![bit0, bit1]);
    let cbus = b.const_bus(p.round_bias(), 48);
    let dbus = b.const_bus(0, 1);
    let pbus = b.dsp(Config::full_macc(false), &f.sel[0], &f.coef, &cbus, &dbus, &zmux, f.en);

    let dwrap = delay_flag(&mut b, f.wrap, DSP_LATENCY, f.en, f.rst);
    // The exact sum occupies acc_bits (+1 headroom incl. bias); higher P
    // bits are sign copies.
    let acc_view = pbus.slice(0, (p.acc_bits() as usize + 1).min(48));
    output_stage(&mut b, p, &acc_view, dwrap, f.en, f.rst, 0, true);

    Ok(ConvIp {
        kind: ConvKind::Conv2,
        params: *p,
        netlist: nl,
        ii: p.taps(),
        out_latency: DSP_LATENCY + 1,
        high_lane_clamp: false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::Prim;

    #[test]
    fn generates_and_checks() {
        let ip = generate(&ConvParams::paper_8bit()).unwrap();
        ip.netlist.check().expect("netlist valid");
        let census = ip.netlist.census();
        assert_eq!(census[&Prim::Dsp48e2], 1);
    }

    #[test]
    fn minimal_logic_among_variants() {
        let p = ConvParams::paper_8bit();
        let c1 = super::super::conv1::generate(&p).unwrap();
        let c2 = generate(&p).unwrap();
        let l1 = c1.netlist.census()[&Prim::Lut];
        let l2 = c2.netlist.census()[&Prim::Lut];
        assert!(l2 * 2 < l1, "Conv_2 ({l2} LUTs) must be far below Conv_1 ({l1} LUTs)");
    }

    #[test]
    fn wide_coef_rejected() {
        let mut p = ConvParams::paper_8bit();
        p.coef_bits = 16;
        assert!(generate(&p).is_ok());
        // validate() caps at 16 anyway; the B-port guard is for safety.
    }
}
