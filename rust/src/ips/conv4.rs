//! `Conv_4` — dual-DSP parallel convolution IP.
//!
//! Table I: *"Two parallel convolutions; optimized for parallelism"* —
//! two independent DSP48E2 MACC lanes sharing the coefficient stream and
//! control, for DSP-rich devices. Unlike `Conv_3` there is no packing, so
//! operands may be wide ("provides greater precision by allowing larger
//! operands") and no lane-split correction logic is needed.

use super::common::{build_frame, delay_flag, output_stage, ConvIp};
use super::params::{ConvKind, ConvParams};
use crate::fabric::dsp48::Config;
use crate::netlist::builder::{Builder, Bus};
use crate::netlist::Netlist;

/// DSP pipeline depth (same MACC config as `Conv_2`).
pub const DSP_LATENCY: u32 = 3;

/// Generate the `Conv_4` netlist for `p`.
pub fn generate(p: &ConvParams) -> Result<ConvIp, String> {
    p.validate()?;
    if p.coef_bits > 18 {
        return Err(format!("Conv_4: coef_bits {} exceeds the DSP B port (18)", p.coef_bits));
    }
    let mut nl = Netlist::new();
    let mut b = Builder::new(&mut nl);
    let f = build_frame(&mut b, p, 2);

    let bit0 = b.not(f.first);
    let bit1 = if p.round_bias() != 0 { f.first } else { b.zero() };
    let zmux = Bus(vec![bit0, bit1]);
    let cbus = b.const_bus(p.round_bias(), 48);
    let dbus = b.const_bus(0, 1);

    let acc_view_w = (p.acc_bits() as usize + 1).min(48);
    // One shared capture-flag pipeline serves both lanes.
    let dwrap = delay_flag(&mut b, f.wrap, DSP_LATENCY, f.en, f.rst);
    for lane in 0..2u32 {
        let pbus = b.dsp(
            Config::full_macc(false),
            &f.sel[lane as usize],
            &f.coef,
            &cbus,
            &dbus,
            &zmux,
            f.en,
        );
        let acc_view = pbus.slice(0, acc_view_w);
        output_stage(&mut b, p, &acc_view, dwrap, f.en, f.rst, lane, lane == 0);
    }

    Ok(ConvIp {
        kind: ConvKind::Conv4,
        params: *p,
        netlist: nl,
        ii: p.taps(),
        out_latency: DSP_LATENCY + 1,
        high_lane_clamp: false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::Prim;

    #[test]
    fn generates_and_checks() {
        let ip = generate(&ConvParams::paper_8bit()).unwrap();
        ip.netlist.check().expect("netlist valid");
        assert_eq!(ip.netlist.census()[&Prim::Dsp48e2], 2);
    }

    #[test]
    fn supports_wide_operands_unlike_conv3() {
        let mut p = ConvParams::paper_8bit();
        p.data_bits = 16;
        p.coef_bits = 16;
        p.shift = 15;
        assert!(generate(&p).is_ok(), "Conv_4 must accept 16-bit operands");
        assert!(super::super::conv3::generate(&p).is_err(), "Conv_3 must not");
    }

    #[test]
    fn moderate_logic() {
        let p = ConvParams::paper_8bit();
        let c1 = super::super::conv1::generate(&p).unwrap().netlist.census()[&Prim::Lut];
        let c4 = generate(&p).unwrap().netlist.census()[&Prim::Lut];
        assert!(c4 < c1, "Conv_4 ({c4}) must use less logic than Conv_1 ({c1})");
    }
}
