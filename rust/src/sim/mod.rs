//! Deployment performance model: cycle-accurate schedule accounting for a
//! planned CNN, plus netlist-level spot verification of deployed IPs.
//!
//! The coordinator's workers compute *values* with the behavioral models
//! (bit-exact, fast); this module computes *time* from the engine
//! schedules (rate, instances) — the same split a hardware team uses
//! between RTL sim and analytical performance models. Every engine kind
//! in the plan (conv, FC, max-pool, fused ReLU) contributes its own
//! cycles: nothing rides along for free. For small layers,
//! [`netlist_layer_check`] additionally pushes real windows through the
//! generated netlist in the bit-exact simulator to witness that the
//! deployed IP kind computes exactly what the behavioral path computed.

use crate::cnn::model::{Layer, Model};
use crate::ips::engine::EngineKind;
use crate::planner::Plan;

/// Modeled timing of one deployed image stream.
#[derive(Debug, Clone)]
pub struct PerfReport {
    pub clock_mhz: f64,
    /// Per-engine cycles per image (layer index, engine, cycles), in plan
    /// order — a layer with a fused ReLU appears twice.
    pub engine_cycles: Vec<(usize, EngineKind, f64)>,
    /// Steady-state images/second (pipelined across layers).
    pub throughput_img_s: f64,
    /// Single-image latency (sum of engine fills), microseconds.
    pub latency_us: f64,
    pub bottleneck: usize,
}

/// Compute the performance model for a plan.
pub fn estimate(_model: &Model, plan: &Plan) -> PerfReport {
    let mut engine_cycles = Vec::with_capacity(plan.engines.len());
    let mut worst = 0.0f64;
    let mut bottleneck = 0;
    let mut total_cycles = 0.0f64;
    for ep in &plan.engines {
        engine_cycles.push((ep.layer, ep.kind, ep.cycles_per_image));
        total_cycles += ep.cycles_per_image;
        if ep.cycles_per_image > worst {
            worst = ep.cycles_per_image;
            bottleneck = ep.layer;
        }
    }
    let hz = plan.clock_mhz * 1e6;
    PerfReport {
        clock_mhz: plan.clock_mhz,
        engine_cycles,
        throughput_img_s: hz / worst.max(1e-9),
        latency_us: total_cycles / hz * 1e6,
        bottleneck,
    }
}

/// Result of one netlist spot check: how many windows were verified and
/// how much of the fabric the event-driven settle actually evaluated
/// doing it (a quiet layer shows a small `evaluated_fraction`).
#[derive(Debug, Clone)]
pub struct LayerCheck {
    /// Windows driven through the netlist and matched bit-exactly.
    pub windows: usize,
    /// Settle-scheduler activity of the verifying simulator.
    pub activity: crate::netlist::sim::SettleStats,
}

/// Drive `n_windows` real windows of layer `layer_idx`'s workload through
/// the *generated netlist* of the planned conv IP kind and compare against
/// the behavioral expectation. The windows are spread across simulator
/// lanes ([`crate::netlist::sim::LANES`]-wide lane words), so the check
/// runs one lane-batched pass schedule instead of a serial pass per
/// window group. Returns the window count and the run's activity stats.
pub fn netlist_layer_check(
    model: &Model,
    plan: &Plan,
    layer_idx: usize,
    seed: u64,
    n_windows: usize,
) -> Result<LayerCheck, String> {
    netlist_layer_check_traced(model, plan, layer_idx, seed, n_windows, None)
}

/// [`netlist_layer_check`] with settle attribution: when `trace` carries a
/// live tracer, the check's lane-batched run emits per-pass `"sim"` spans
/// (with interval [`crate::netlist::sim::SettleStats`] args) on the trace
/// track named by the context — how `acf serve --trace` puts per-engine
/// settle activity on each device group's control track.
pub fn netlist_layer_check_traced(
    model: &Model,
    plan: &Plan,
    layer_idx: usize,
    seed: u64,
    n_windows: usize,
    trace: Option<&crate::trace::SettleTrace<'_>>,
) -> Result<LayerCheck, String> {
    let kind = plan
        .engines
        .iter()
        .find_map(|ep| (ep.layer == layer_idx).then(|| ep.kind.conv_kind()).flatten())
        .ok_or_else(|| format!("layer {layer_idx} is not a planned conv layer"))?;
    let Layer::Conv { params, .. } = &model.layers[layer_idx] else {
        return Err("not a conv layer".into());
    };
    let ip = crate::ips::generate(kind, params).map_err(|e| e.to_string())?;
    let mut rng = crate::util::rng::Rng::new(seed);
    let ip_lanes = kind.lanes() as usize;
    let total_passes = n_windows.div_ceil(ip_lanes).max(1);
    let sim_lanes = total_passes.min(crate::netlist::sim::LANES);
    let passes_per_lane = total_passes.div_ceil(sim_lanes);
    let (per_lane, coefs) =
        crate::ips::verify::random_stimulus_lanes(&ip, &mut rng, sim_lanes, passes_per_lane);
    let report =
        crate::ips::verify::run_ip_lanes_report_traced(&ip, &per_lane, &coefs, false, trace);
    for (lane, stim) in per_lane.iter().enumerate() {
        let want = crate::ips::verify::expected(&ip, stim, &coefs);
        if report.outputs[lane] != want {
            return Err(format!(
                "netlist mismatch on layer {layer_idx} ({}, sim lane {lane})",
                kind.name()
            ));
        }
    }
    Ok(LayerCheck {
        windows: sim_lanes * passes_per_lane * ip_lanes,
        activity: report.activity,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::model::Model;
    use crate::fabric::device::by_name;
    use crate::planner::{plan, Policy};

    fn lenet_plan() -> (Model, Plan) {
        let m = Model::lenet_tiny();
        let dev = by_name("zcu104").unwrap();
        let p = plan(&m, &dev, 200.0, &Policy::adaptive()).unwrap();
        (m, p)
    }

    #[test]
    fn perf_model_consistent_with_plan() {
        let (m, p) = lenet_plan();
        let perf = estimate(&m, &p);
        assert!((perf.throughput_img_s - p.images_per_sec).abs() / p.images_per_sec < 1e-9);
        assert!(perf.latency_us > 0.0);
        // Every engine site is accounted, pool/ReLU included.
        assert_eq!(perf.engine_cycles.len(), p.engines.len());
        assert!(perf
            .engine_cycles
            .iter()
            .any(|(_, k, c)| *k == EngineKind::MaxPool && *c > 0.0));
        // Latency must be at least one bottleneck interval.
        let interval_us = 1e6 / perf.throughput_img_s;
        assert!(perf.latency_us >= interval_us * 0.99);
    }

    #[test]
    fn netlist_spot_check_passes() {
        let (m, p) = lenet_plan();
        for ep in p.convs() {
            let chk = netlist_layer_check(&m, &p, ep.layer, 11, 8).unwrap();
            assert!(chk.windows >= 8);
            // Activity accounting is well-formed on real layers, too.
            assert!(chk.activity.settles > 0);
            assert!(chk.activity.ops_evaluated <= chk.activity.ops_total);
            assert!(chk.activity.evaluated_fraction() <= 1.0);
        }
    }

    #[test]
    fn netlist_check_rejects_non_conv() {
        let (m, p) = lenet_plan();
        assert!(netlist_layer_check(&m, &p, 1, 0, 4).is_err()); // pool layer
    }
}
