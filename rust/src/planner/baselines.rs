//! Fixed-policy baseline planners — the comparators behind the paper's
//! Table III.
//!
//! Each baseline models the *resource posture* of a related work as a
//! restriction of the planner's choice set, so the comparison runs on
//! identical infrastructure:
//!
//! * [`dsp_first`] — "maximize throughput" pipelines in the Luo et al. [4]
//!   mold: always the most parallel DSP engine (`Conv_4`), regardless of
//!   what the device actually has.
//! * [`quantize_first`] — Shao et al. [5]-style: commit to packed 8-bit
//!   arithmetic everywhere (`Conv_3`), trading precision for density.
//! * [`static_single`] — Shi et al. [1]-style fixed accelerator: one
//!   engine kind (`Conv_2`) for every layer.
//!
//! The adaptive policy ([`super::Policy::adaptive`]) is the paper's
//! contribution; Table III's qualitative rows are derived by sweeping all
//! four policies across devices and model variants (see
//! [`crate::report::table3`]). A policy restricts only the *conv*
//! candidate set — FC, max-pool, and ReLU engines come from the unified
//! registry identically under every policy, so the comparison isolates
//! the conv-IP selection strategy.

use super::Policy;
use crate::ips::ConvKind;

/// Throughput-max posture: `Conv_4` only.
pub fn dsp_first() -> Policy {
    Policy { name: "dsp-first".into(), allowed: vec![ConvKind::Conv4] }
}

/// Quantize-everything posture: `Conv_3` only (8-bit ceiling).
pub fn quantize_first() -> Policy {
    Policy { name: "quantize-first".into(), allowed: vec![ConvKind::Conv3] }
}

/// Fixed single-engine posture: `Conv_2` only.
pub fn static_single() -> Policy {
    Policy { name: "static-single".into(), allowed: vec![ConvKind::Conv2] }
}

/// All policies for sweep reports (adaptive first).
pub fn all() -> Vec<Policy> {
    vec![Policy::adaptive(), dsp_first(), quantize_first(), static_single()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::model::{Layer, Model};
    use crate::fabric::device::by_name;
    use crate::planner::plan;

    #[test]
    fn dsp_first_fails_on_dsp_starved_device() {
        // The crux of Table III's "FPGA architecture dependency: High".
        let m = Model::lenet_tiny();
        let dev = by_name("edge-nodsp").unwrap();
        assert!(plan(&m, &dev, 200.0, &dsp_first()).is_err());
        assert!(plan(&m, &dev, 200.0, &Policy::adaptive()).is_ok());
    }

    #[test]
    fn quantize_first_fails_on_wide_precision() {
        // Table III "Multiple precisions": Conv_3-only cannot do 12-bit.
        let mut m = Model::lenet_tiny();
        for layer in &mut m.layers {
            if let Layer::Conv { params, .. } = layer {
                params.data_bits = 12;
                params.coef_bits = 12;
                params.shift = 11;
            }
        }
        let dev = by_name("zcu104").unwrap();
        assert!(plan(&m, &dev, 200.0, &quantize_first()).is_err());
        assert!(plan(&m, &dev, 200.0, &Policy::adaptive()).is_ok());
    }

    #[test]
    fn adaptive_at_least_matches_every_baseline() {
        let m = Model::lenet_tiny();
        for dev in ["zu2cg", "zcu104", "edge-nodsp"] {
            let dev = by_name(dev).unwrap();
            let ours = plan(&m, &dev, 200.0, &Policy::adaptive());
            for pol in [dsp_first(), quantize_first(), static_single()] {
                if let Ok(b) = plan(&m, &dev, 200.0, &pol) {
                    let o = ours.as_ref().expect("adaptive must be feasible wherever a baseline is");
                    assert!(
                        o.images_per_sec >= b.images_per_sec * 0.999,
                        "{} on {}: adaptive {} < {} {}",
                        pol.name,
                        dev.name,
                        o.images_per_sec,
                        pol.name,
                        b.images_per_sec
                    );
                }
            }
        }
    }
}
