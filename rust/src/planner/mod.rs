//! The resource-driven planner — the paper's headline capability
//! ("automatic adaptation to the available resources") plus the
//! future-work item ("automating IP selection based on resource
//! availability").
//!
//! Given a CNN and a device budget, choose a convolution IP *kind* and an
//! *instance count* per conv layer (and FC engine counts) that maximize
//! streaming throughput. Strategy: binary-search the achievable
//! images-per-cycle target; at each target, pick per-layer assignments
//! scored by scarcity-weighted resource pressure; accept if the summed
//! utilization fits the device.
//!
//! [`baselines`] holds the fixed-policy planners used for the Table III
//! comparison.

pub mod baselines;

use crate::cnn::model::{Layer, Model};
use crate::fabric::device::Device;
use crate::ips::{self, ConvKind, ConvParams};
use crate::synth::{synthesize, Utilization};

/// Profiled IP variant: resources + schedule for one parameterization.
#[derive(Debug, Clone)]
pub struct IpProfile {
    pub kind: ConvKind,
    pub params: ConvParams,
    pub util: Utilization,
    /// Steady-state windows per cycle.
    pub rate: f64,
    /// WNS at the target clock (must be ≥ 0 to deploy).
    pub wns_ns: f64,
}

/// Profile one IP kind under `params` at `clock_mhz` on `dev`.
/// Errors when the kind cannot implement the parameters (e.g. `Conv_3`
/// above 8-bit) or fails timing. Results are memoized process-wide —
/// generation + synthesis + STA is pure in (kind, params, clock, derate)
/// and the planner's binary search re-asks constantly
/// (EXPERIMENTS.md §Perf item 4).
pub fn profile(
    kind: ConvKind,
    params: &ConvParams,
    clock_mhz: f64,
    dev: &Device,
) -> Result<IpProfile, String> {
    use std::collections::HashMap;
    use std::sync::Mutex;
    type Key = (ConvKind, ConvParams, u64, u64);
    static CACHE: once_cell::sync::Lazy<Mutex<HashMap<Key, Result<IpProfile, String>>>> =
        once_cell::sync::Lazy::new(|| Mutex::new(HashMap::new()));
    let key = (kind, *params, clock_mhz.to_bits(), dev.speed_derate.to_bits());
    if let Some(hit) = CACHE.lock().unwrap().get(&key) {
        return hit.clone();
    }
    let result = profile_uncached(kind, params, clock_mhz, dev);
    CACHE.lock().unwrap().insert(key, result.clone());
    result
}

fn profile_uncached(
    kind: ConvKind,
    params: &ConvParams,
    clock_mhz: f64,
    dev: &Device,
) -> Result<IpProfile, String> {
    let ip = ips::generate(kind, params)?;
    let util = synthesize(&ip.netlist);
    let timing = crate::sta::analyze(&ip.netlist, clock_mhz, dev.speed_derate)
        .map_err(|e| e.to_string())?;
    if !timing.met() {
        return Err(format!(
            "{} fails timing at {clock_mhz} MHz on {} (WNS {:.3})",
            kind.name(),
            dev.name,
            timing.wns_ns
        ));
    }
    Ok(IpProfile { kind, params: *params, util, rate: ip.throughput_per_cycle(), wns_ns: timing.wns_ns })
}

/// Per-conv-layer assignment.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    /// Index into `model.layers`.
    pub layer: usize,
    pub kind: ConvKind,
    pub instances: u64,
    pub util: Utilization,
    /// Window passes per image for this layer.
    pub windows: u64,
    /// Cycles per image at this assignment.
    pub cycles_per_image: f64,
}

/// A full deployment plan.
#[derive(Debug, Clone)]
pub struct Plan {
    pub device: Device,
    pub clock_mhz: f64,
    pub conv: Vec<LayerPlan>,
    /// FC engines: (layer index, instances, util, cycles/img).
    pub fc: Vec<(usize, u64, Utilization, f64)>,
    pub total: Utilization,
    /// Modeled steady-state throughput.
    pub images_per_sec: f64,
    /// Layer index that bounds throughput.
    pub bottleneck: usize,
    /// Which policy produced this plan (for reports).
    pub policy: String,
}

impl Plan {
    /// Utilization fractions (DSP, LUT) for reports.
    pub fn pressure(&self) -> (f64, f64) {
        (
            self.total.dsps as f64 / self.device.dsps.max(1) as f64,
            self.total.luts as f64 / self.device.luts.max(1) as f64,
        )
    }
}

#[derive(Debug, thiserror::Error)]
pub enum PlanError {
    #[error("model invalid: {0}")]
    Model(String),
    #[error("no feasible plan on {device}: {reason}")]
    Infeasible { device: String, reason: String },
}

/// Kinds a policy is allowed to use.
#[derive(Debug, Clone)]
pub struct Policy {
    pub name: String,
    pub allowed: Vec<ConvKind>,
}

impl Policy {
    /// The paper's approach: all four IPs, chosen per layer.
    pub fn adaptive() -> Policy {
        Policy { name: "adaptive".into(), allowed: ConvKind::ALL.to_vec() }
    }
}

/// Plan `model` onto `dev` at `clock_mhz` under `policy`.
pub fn plan(model: &Model, dev: &Device, clock_mhz: f64, policy: &Policy) -> Result<Plan, PlanError> {
    let shapes_all = model.shapes().map_err(PlanError::Model)?;
    let workloads = model.conv_workloads();
    // Structural parallelism ceiling per conv layer: one engine per
    // (in_ch, out_ch, output_row) tuple. Finer-grained splits would need
    // window broadcast bandwidth the streaming front-end doesn't have —
    // this keeps modeled throughput within what the dataflow can feed.
    let caps: Vec<u64> = workloads
        .iter()
        .map(|&(li, _)| {
            let Layer::Conv { in_ch, out_ch, .. } = &model.layers[li] else { unreachable!() };
            (*in_ch as u64) * (*out_ch as u64) * shapes_all[li].h as u64
        })
        .collect();

    // Profile every allowed kind once per distinct conv-layer params.
    let mut profiles: Vec<Vec<IpProfile>> = Vec::new();
    for &(li, _) in &workloads {
        let Layer::Conv { params, .. } = &model.layers[li] else { unreachable!() };
        let mut avail = Vec::new();
        for kind in &policy.allowed {
            if let Ok(p) = profile(*kind, params, clock_mhz, dev) {
                avail.push(p);
            }
        }
        if avail.is_empty() {
            return Err(PlanError::Infeasible {
                device: dev.name.clone(),
                reason: format!(
                    "no allowed IP can implement layer {li} ({}-bit operands) under policy '{}'",
                    match &model.layers[li] {
                        Layer::Conv { params, .. } => params.data_bits,
                        _ => 0,
                    },
                    policy.name
                ),
            });
        }
        profiles.push(avail);
    }

    // FC engines: fan-in derives from shapes; 1 MAC/cycle per instance.
    let shapes = &shapes_all;
    let mut fc_specs: Vec<(usize, Utilization, u64, u64)> = Vec::new(); // (layer, util/inst, macs, max engines)
    for (li, layer) in model.layers.iter().enumerate() {
        if let Layer::Fc { out_dim, params, .. } = layer {
            let in_dim = if li == 0 {
                model.in_h * model.in_w * model.in_ch
            } else {
                shapes[li - 1].numel()
            };
            let fcip = crate::ips::fc::generate(params, in_dim as u32)
                .map_err(|e| PlanError::Infeasible { device: dev.name.clone(), reason: e })?;
            fc_specs.push((li, synthesize(&fcip.netlist), (in_dim * out_dim) as u64, *out_dim as u64));
        }
    }

    // Feasibility of a target (images/cycle); returns the assignment.
    type FcPlan = Vec<(usize, u64, Utilization, f64)>;
    let eval = |target: f64| -> Option<(Vec<LayerPlan>, FcPlan, Utilization)> {
        let mut total = Utilization::default();
        let mut convs = Vec::new();
        for (wi, &(li, windows)) in workloads.iter().enumerate() {
            let mut best: Option<(f64, LayerPlan)> = None;
            for prof in &profiles[wi] {
                let need_rate = target * windows as f64; // windows/cycle
                let inst = (need_rate / prof.rate).ceil().max(1.0) as u64;
                if inst > caps[wi] {
                    continue; // dataflow cannot feed this many engines
                }
                let u = prof.util.times(inst);
                let score = u.dsps as f64 / dev.dsps.max(1) as f64
                    + u.luts as f64 / dev.luts.max(1) as f64
                    + u.clbs as f64 / dev.clbs.max(1) as f64;
                let lp = LayerPlan {
                    layer: li,
                    kind: prof.kind,
                    instances: inst,
                    util: u,
                    windows,
                    cycles_per_image: windows as f64 / (prof.rate * inst as f64),
                };
                if best.as_ref().map(|(s, _)| score < *s).unwrap_or(true) {
                    best = Some((score, lp));
                }
            }
            let (_, lp) = best?;
            total = total.plus(&lp.util);
            convs.push(lp);
        }
        let mut fcs = Vec::new();
        for &(li, ref u, macs, out_dim) in &fc_specs {
            let inst = (target * macs as f64).ceil().max(1.0) as u64;
            if inst > out_dim {
                return None; // one engine per neuron is the ceiling
            }
            let uu = u.times(inst);
            total = total.plus(&uu);
            fcs.push((li, inst, uu, macs as f64 / inst as f64));
        }
        if total.fits(dev) {
            Some((convs, fcs, total))
        } else {
            None
        }
    };

    if eval(1e-9).is_none() {
        return Err(PlanError::Infeasible {
            device: dev.name.clone(),
            reason: "even one instance per layer exceeds the device".into(),
        });
    }
    let mut lo = 1e-9f64;
    let mut hi = 1.0f64; // 1 image/cycle is far beyond reach
    for _ in 0..48 {
        let mid = 0.5 * (lo + hi);
        if eval(mid).is_some() {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let (convs, fcs, total) = eval(lo).expect("lo feasible by construction");

    // Throughput from the realized assignment (≥ target).
    let mut worst_cycles = 0.0f64;
    let mut bottleneck = 0usize;
    for lp in &convs {
        if lp.cycles_per_image > worst_cycles {
            worst_cycles = lp.cycles_per_image;
            bottleneck = lp.layer;
        }
    }
    for &(li, _, _, cyc) in &fcs {
        if cyc > worst_cycles {
            worst_cycles = cyc;
            bottleneck = li;
        }
    }
    let images_per_sec = clock_mhz * 1.0e6 / worst_cycles.max(1e-9);

    Ok(Plan {
        device: dev.clone(),
        clock_mhz,
        conv: convs,
        fc: fcs,
        total,
        images_per_sec,
        bottleneck,
        policy: policy.name.clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::model::Model;
    use crate::fabric::device::by_name;

    #[test]
    fn adaptive_plan_on_zcu104() {
        let m = Model::lenet_tiny();
        let dev = by_name("zcu104").unwrap();
        let p = plan(&m, &dev, 200.0, &Policy::adaptive()).unwrap();
        assert_eq!(p.conv.len(), 2);
        assert!(p.total.fits(&dev));
        assert!(p.images_per_sec > 1000.0, "throughput {}", p.images_per_sec);
        assert!(p.total.dsps > 0, "big device should exploit DSPs");
    }

    #[test]
    fn adapts_to_dsp_starved_device() {
        // The paper's motivating case: "suitable for FPGAs with limited
        // DSPs" — the planner must fall back to Conv_1.
        let m = Model::lenet_tiny();
        let dev = by_name("edge-nodsp").unwrap();
        let p = plan(&m, &dev, 200.0, &Policy::adaptive()).unwrap();
        assert!(p.total.dsps <= dev.dsps);
        let conv1_instances: u64 = p
            .conv
            .iter()
            .filter(|lp| lp.kind == ConvKind::Conv1)
            .map(|lp| lp.instances)
            .sum();
        assert!(conv1_instances > 0, "expected Conv_1 fallback, got {:?}", p.conv);
    }

    #[test]
    fn bigger_device_more_throughput() {
        // lenet-tiny saturates its structural-parallelism caps on mid-size
        // parts; the wide variant differentiates devices.
        let m = Model::lenet_wide(4);
        let small = by_name("zu2cg").unwrap();
        let big = by_name("zcu104").unwrap();
        let ps = plan(&m, &small, 200.0, &Policy::adaptive()).unwrap();
        let pb = plan(&m, &big, 200.0, &Policy::adaptive()).unwrap();
        assert!(
            pb.images_per_sec > 2.0 * ps.images_per_sec,
            "big {} vs small {}",
            pb.images_per_sec,
            ps.images_per_sec
        );
    }

    #[test]
    fn utilization_never_exceeds_device() {
        let m = Model::lenet_wide(4);
        for dev in crate::fabric::device::catalog() {
            if let Ok(p) = plan(&m, &dev, 200.0, &Policy::adaptive()) {
                assert!(p.total.fits(&dev), "{}", dev.name);
                let (d, l) = p.pressure();
                assert!(d <= 1.0 && l <= 1.0);
            }
        }
    }

    #[test]
    fn profile_rejects_infeasible() {
        let dev = by_name("zcu104").unwrap();
        let mut p = ConvParams::paper_8bit();
        p.data_bits = 12;
        p.coef_bits = 12;
        p.shift = 11;
        assert!(profile(ConvKind::Conv3, &p, 200.0, &dev).is_err());
        assert!(profile(ConvKind::Conv4, &p, 200.0, &dev).is_ok());
    }
}
