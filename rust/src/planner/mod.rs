//! The resource-driven planner — the paper's headline capability
//! ("automatic adaptation to the available resources") plus the
//! future-work item ("automating IP selection based on resource
//! availability"), generalized to the whole network.
//!
//! Given a CNN and a device budget, the planner assigns an *engine* (an
//! [`EngineKind`] from the unified registry) and an *instance count* to
//! every layer — convolution, fully-connected, max-pool, and fused ReLU
//! alike — maximizing streaming throughput. There are no layer-type
//! special cases: `plan()` runs one uniform loop that, per engine site,
//! profiles the candidate engines, picks the scarcity-cheapest assignment
//! meeting a throughput target, sums utilization, and checks the device
//! budget; a binary search over the target finds the best feasible rate,
//! and the realized bottleneck is the engine (any kind) with the worst
//! cycles-per-image.
//!
//! [`baselines`] holds the fixed-policy planners used for the Table III
//! comparison; they restrict only the *conv* candidate set — the rest of
//! the registry is policy-independent.

pub mod baselines;

use crate::cnn::model::{Layer, Model};
use crate::fabric::device::Device;
use crate::ips::engine::{self, EngineKind, EngineParams};
use crate::ips::ConvKind;
use crate::synth::{synthesize, Utilization};

/// Profiled engine variant: resources + schedule for one parameterization.
#[derive(Debug, Clone)]
pub struct EngineProfile {
    pub kind: EngineKind,
    pub params: EngineParams,
    pub util: Utilization,
    /// Steady-state work units per cycle (windows, MACs, elements).
    pub rate: f64,
    /// WNS at the target clock (must be ≥ 0 to deploy).
    pub wns_ns: f64,
}

/// Profile one engine kind under `params` at `clock_mhz` on `dev`.
/// Errors when the kind cannot implement the parameters (e.g. `Conv_3`
/// above 8-bit) or fails timing. Results are memoized process-wide —
/// generation + synthesis + STA is pure in (kind, params, clock, derate)
/// and the planner's binary search re-asks constantly.
///
/// Cache-safety note: the memo key carries only `dev.speed_derate`, not
/// the device name, so the cached value (including a cached `Err`) must
/// be a pure function of the key. Error strings therefore name the
/// derate, never `dev.name` — callers add device context themselves.
pub fn profile(
    kind: EngineKind,
    params: &EngineParams,
    clock_mhz: f64,
    dev: &Device,
) -> Result<EngineProfile, String> {
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};
    type Key = (EngineKind, EngineParams, u64, u64);
    type Cache = Mutex<HashMap<Key, Result<EngineProfile, String>>>;
    static CACHE: OnceLock<Cache> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let key = (kind, *params, clock_mhz.to_bits(), dev.speed_derate.to_bits());
    if let Some(hit) = cache.lock().unwrap().get(&key) {
        return hit.clone();
    }
    let result = profile_uncached(kind, params, clock_mhz, dev.speed_derate);
    cache.lock().unwrap().insert(key, result.clone());
    result
}

fn profile_uncached(
    kind: EngineKind,
    params: &EngineParams,
    clock_mhz: f64,
    derate: f64,
) -> Result<EngineProfile, String> {
    let ip = engine::generate(kind, params)?;
    let util = synthesize(&ip.netlist);
    let timing =
        crate::sta::analyze(&ip.netlist, clock_mhz, derate).map_err(|e| e.to_string())?;
    if !timing.met() {
        // Deliberately device-name-free: this string is memoized under a
        // (kind, params, clock, derate) key shared by every device with
        // the same derate.
        return Err(format!(
            "{} fails timing at {clock_mhz} MHz (derate {derate}, WNS {:.3})",
            kind.name(),
            timing.wns_ns
        ));
    }
    Ok(EngineProfile { kind, params: *params, util, rate: ip.rate, wns_ns: timing.wns_ns })
}

/// One planned engine: which engine serves which layer, how many
/// instances, and what it costs. Uniform across layer types.
#[derive(Debug, Clone)]
pub struct EnginePlan {
    /// Index into `model.layers` (a conv/fc layer with fused ReLU yields
    /// two engine plans at the same index).
    pub layer: usize,
    pub kind: EngineKind,
    pub instances: u64,
    pub util: Utilization,
    /// Work units per image (windows, MACs, or elements).
    pub work: u64,
    /// Cycles per image at this assignment.
    pub cycles_per_image: f64,
}

/// A full deployment plan: every layer's engine assignment, uniformly.
#[derive(Debug, Clone)]
pub struct Plan {
    pub device: Device,
    pub clock_mhz: f64,
    /// One entry per engine site, in layer order (ReLU sites follow their
    /// host conv/fc site).
    pub engines: Vec<EnginePlan>,
    pub total: Utilization,
    /// Modeled steady-state throughput.
    pub images_per_sec: f64,
    /// Layer index that bounds throughput (any engine kind).
    pub bottleneck: usize,
    /// Which policy produced this plan (for reports).
    pub policy: String,
}

impl Plan {
    /// Utilization fractions (DSP, LUT) for reports.
    pub fn pressure(&self) -> (f64, f64) {
        (
            self.total.dsps as f64 / self.device.dsps.max(1) as f64,
            self.total.luts as f64 / self.device.luts.max(1) as f64,
        )
    }

    /// The convolution engine plans, in layer order.
    pub fn convs(&self) -> impl Iterator<Item = &EnginePlan> {
        self.engines.iter().filter(|e| matches!(e.kind, EngineKind::Conv(_)))
    }
}

#[derive(Debug)]
pub enum PlanError {
    Model(String),
    Infeasible { device: String, reason: String },
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::Model(m) => write!(f, "model invalid: {m}"),
            PlanError::Infeasible { device, reason } => {
                write!(f, "no feasible plan on {device}: {reason}")
            }
        }
    }
}

impl std::error::Error for PlanError {}

/// Conv kinds a policy is allowed to use (non-conv engines are
/// policy-independent — every policy deploys the same FC/pool/ReLU IPs).
#[derive(Debug, Clone)]
pub struct Policy {
    pub name: String,
    pub allowed: Vec<ConvKind>,
}

impl Policy {
    /// The paper's approach: all four IPs, chosen per layer.
    pub fn adaptive() -> Policy {
        Policy { name: "adaptive".into(), allowed: ConvKind::ALL.to_vec() }
    }
}

/// One engine site awaiting assignment: a layer slot, its workload, its
/// structural parallelism ceiling, and the candidate engine profiles.
struct Site {
    layer: usize,
    work: u64,
    cap: u64,
    candidates: Vec<EngineProfile>,
}

/// Enumerate the engine sites of `model`: one per conv/pool/fc layer plus
/// one ReLU site per fused activation. Errors if any site ends up with no
/// feasible candidate.
fn engine_sites(
    model: &Model,
    dev: &Device,
    clock_mhz: f64,
    policy: &Policy,
) -> Result<Vec<Site>, PlanError> {
    let shapes = model.shapes().map_err(PlanError::Model)?;
    let infeasible = |li: usize, what: &str, detail: String| PlanError::Infeasible {
        device: dev.name.clone(),
        reason: format!("layer {li}: no {what} engine is feasible ({detail})"),
    };
    let mut sites = Vec::new();
    // Width of the element stream entering each layer (ingress pixels are
    // 8-bit range; each conv/fc requantizes to its out_bits).
    let mut stream_bits = 8u32;
    for (li, layer) in model.layers.iter().enumerate() {
        match layer {
            Layer::Conv { params, relu, .. } => {
                let kind_of = EngineKind::Conv;
                let mut cands = Vec::new();
                let mut last_err = String::new();
                for &ck in &policy.allowed {
                    match profile(kind_of(ck), &EngineParams::conv(*params), clock_mhz, dev) {
                        Ok(p) => cands.push(p),
                        Err(e) => last_err = e,
                    }
                }
                if cands.is_empty() {
                    return Err(infeasible(
                        li,
                        "conv",
                        format!(
                            "{}-bit operands under policy '{}': {last_err}",
                            params.data_bits, policy.name
                        ),
                    ));
                }
                let ek = kind_of(policy.allowed[0]);
                sites.push(Site {
                    layer: li,
                    work: ek.work_per_image(model, li, &shapes),
                    cap: ek.structural_cap(model, li, &shapes),
                    candidates: cands,
                });
                if *relu {
                    sites.push(relu_site(model, li, params.out_bits, &shapes, dev, clock_mhz)?);
                }
                stream_bits = params.out_bits;
            }
            Layer::MaxPool => {
                let ep = EngineParams::pool(stream_bits, crate::cnn::model::POOL_WINDOW);
                let prof = profile(EngineKind::MaxPool, &ep, clock_mhz, dev)
                    .map_err(|e| infeasible(li, "max-pool", e))?;
                sites.push(Site {
                    layer: li,
                    work: EngineKind::MaxPool.work_per_image(model, li, &shapes),
                    cap: EngineKind::MaxPool.structural_cap(model, li, &shapes),
                    candidates: vec![prof],
                });
            }
            Layer::Fc { params, relu, .. } => {
                let fanin = engine::fc_in_dim(model, li, &shapes) as u32;
                let ep = EngineParams::fc(*params, fanin);
                let prof = profile(EngineKind::Fc, &ep, clock_mhz, dev)
                    .map_err(|e| infeasible(li, "fully-connected", e))?;
                sites.push(Site {
                    layer: li,
                    work: EngineKind::Fc.work_per_image(model, li, &shapes),
                    cap: EngineKind::Fc.structural_cap(model, li, &shapes),
                    candidates: vec![prof],
                });
                if *relu {
                    sites.push(relu_site(model, li, params.out_bits, &shapes, dev, clock_mhz)?);
                }
                stream_bits = params.out_bits;
            }
        }
    }
    Ok(sites)
}

fn relu_site(
    model: &Model,
    li: usize,
    bits: u32,
    shapes: &[crate::cnn::model::Shape],
    dev: &Device,
    clock_mhz: f64,
) -> Result<Site, PlanError> {
    let prof =
        profile(EngineKind::Relu, &EngineParams::relu(bits), clock_mhz, dev).map_err(|e| {
            PlanError::Infeasible {
                device: dev.name.clone(),
                reason: format!("layer {li}: no ReLU engine is feasible ({e})"),
            }
        })?;
    Ok(Site {
        layer: li,
        work: EngineKind::Relu.work_per_image(model, li, shapes),
        cap: EngineKind::Relu.structural_cap(model, li, shapes),
        candidates: vec![prof],
    })
}

/// RAMB18s one replica needs to hold the model's coefficients (conv
/// filters + FC matrices), independent of how the device is sharded.
///
/// Each layer's coefficient store is modeled as its own memory (engines
/// stream different layers concurrently, so the stores cannot share a
/// port) sized `#coefficients × coef_bits` through the same aspect-ratio
/// fit the line buffers use ([`crate::fabric::bram::ramb18_count`]).
pub fn coefficient_bram18(model: &Model) -> u64 {
    // Invalid geometry is the planner's error to report, not this
    // helper's — without shapes an FC fan-in is unknown, so charge the
    // conv stores only (plan() rejects the model right after anyway).
    let shapes = model.shapes().unwrap_or_default();
    let mut total = 0u64;
    for (li, layer) in model.layers.iter().enumerate() {
        let (coefs, bits) = match layer {
            Layer::Conv { in_ch, out_ch, params, .. } => {
                ((*in_ch as u64) * (*out_ch as u64) * u64::from(params.taps()), params.coef_bits)
            }
            Layer::Fc { out_dim, params, .. } if li == 0 || li <= shapes.len() => {
                let fanin = engine::fc_in_dim(model, li, &shapes) as u64;
                (fanin * (*out_dim as u64), params.coef_bits)
            }
            _ => continue,
        };
        let depth = coefs.clamp(1, u32::MAX as u64) as u32;
        total += u64::from(crate::fabric::bram::ramb18_count(bits, depth));
    }
    total
}

/// Plan `model` under a `1/share` slice of `dev` — the primitive the
/// serving tier's fleet planner ([`crate::serve::fleet`]) iterates to
/// find the best replica count: each replica of a `share`-replica fleet
/// gets an equal shard of the device and is planned exactly like a whole
/// device (same profile → select → budget loop, same scarcity scoring).
///
/// BRAM is NOT divided evenly: every replica stores its own full copy of
/// the model's coefficients ([`coefficient_bram18`]) no matter how small
/// its shard is, so `share × coef` RAMB18s are charged off the top of the
/// whole device and only the remainder is floor-divided among replicas
/// (each shard budget then carries its own copy's worth back, since
/// [`plan`] charges the coefficient store on whatever budget it is
/// given — `share == 1` is exactly [`plan`] on the whole device). A
/// device whose BRAM cannot hold `share` coefficient copies is
/// infeasible at that share even if logic and DSPs would fit.
pub fn plan_under_fraction(
    model: &Model,
    dev: &Device,
    clock_mhz: f64,
    policy: &Policy,
    share: u64,
) -> Result<Plan, PlanError> {
    let share = share.max(1);
    let coef = coefficient_bram18(model);
    let reserved = coef.saturating_mul(share);
    if dev.bram18 < reserved {
        return Err(PlanError::Infeasible {
            device: dev.name.clone(),
            reason: format!(
                "{share} replica(s) need {reserved} RAMB18 of coefficient storage \
                 ({coef} per replica, not divisible by sharding) but the part has {}",
                dev.bram18
            ),
        });
    }
    let mut budget = dev.shard(share);
    // Engines may spend (B - share×coef)/share; plan() re-charges this
    // replica's own coefficient copy, so hand it back on top.
    budget.bram18 = (dev.bram18 - reserved) / share + coef;
    plan(model, &budget, clock_mhz, policy)
}

/// Plan `model` onto `dev` at `clock_mhz` under `policy`.
///
/// Feasibility charges the model's coefficient store
/// ([`coefficient_bram18`]) against the device's BRAM on top of the
/// engine resources, so a part that cannot hold the weights is rejected
/// on every path — whole-device deployments and fleet shards alike.
/// `Plan::total` stays engine-only (the coefficient store is a property
/// of the model, reported separately by the serving tier's group bills).
pub fn plan(model: &Model, dev: &Device, clock_mhz: f64, policy: &Policy) -> Result<Plan, PlanError> {
    let sites = engine_sites(model, dev, clock_mhz, policy)?;
    let coef_bram = coefficient_bram18(model);

    // Feasibility of a target (images/cycle); returns the assignment.
    let eval = |target: f64| -> Option<(Vec<EnginePlan>, Utilization)> {
        let mut total = Utilization::default();
        let mut engines = Vec::with_capacity(sites.len());
        for site in &sites {
            let mut best: Option<(f64, EnginePlan)> = None;
            for prof in &site.candidates {
                let need_rate = target * site.work as f64; // work units/cycle
                let inst = (need_rate / prof.rate).ceil().max(1.0) as u64;
                if inst > site.cap {
                    continue; // dataflow cannot feed this many engines
                }
                let u = prof.util.times(inst);
                let score = u.dsps as f64 / dev.dsps.max(1) as f64
                    + u.luts as f64 / dev.luts.max(1) as f64
                    + u.clbs as f64 / dev.clbs.max(1) as f64;
                let ep = EnginePlan {
                    layer: site.layer,
                    kind: prof.kind,
                    instances: inst,
                    util: u,
                    work: site.work,
                    cycles_per_image: site.work as f64 / (prof.rate * inst as f64),
                };
                if best.as_ref().map(|(s, _)| score < *s).unwrap_or(true) {
                    best = Some((score, ep));
                }
            }
            let (_, ep) = best?;
            total = total.plus(&ep.util);
            engines.push(ep);
        }
        let mut charged = total;
        charged.bram18 += coef_bram;
        if charged.fits(dev) {
            Some((engines, total))
        } else {
            None
        }
    };

    if eval(1e-9).is_none() {
        return Err(PlanError::Infeasible {
            device: dev.name.clone(),
            reason: format!(
                "even one instance per engine site (plus {coef_bram} RAMB18 of \
                 coefficient storage) exceeds the device"
            ),
        });
    }
    let mut lo = 1e-9f64;
    let mut hi = 1.0f64; // 1 image/cycle is far beyond reach
    for _ in 0..48 {
        let mid = 0.5 * (lo + hi);
        if eval(mid).is_some() {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let (engines, total) = eval(lo).expect("lo feasible by construction");

    // Throughput from the realized assignment (≥ target): the bottleneck
    // search spans every engine kind, pool/ReLU included.
    let mut worst_cycles = 0.0f64;
    let mut bottleneck = 0usize;
    for ep in &engines {
        if ep.cycles_per_image > worst_cycles {
            worst_cycles = ep.cycles_per_image;
            bottleneck = ep.layer;
        }
    }
    let images_per_sec = clock_mhz * 1.0e6 / worst_cycles.max(1e-9);

    Ok(Plan {
        device: dev.clone(),
        clock_mhz,
        engines,
        total,
        images_per_sec,
        bottleneck,
        policy: policy.name.clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::model::Model;
    use crate::fabric::device::by_name;
    use crate::ips::ConvParams;

    #[test]
    fn adaptive_plan_on_zcu104() {
        let m = Model::lenet_tiny();
        let dev = by_name("zcu104").unwrap();
        let p = plan(&m, &dev, 200.0, &Policy::adaptive()).unwrap();
        assert_eq!(p.convs().count(), 2);
        // conv+relu, pool, conv+relu, pool, fc => 7 engine sites.
        assert_eq!(p.engines.len(), 7);
        assert!(p.total.fits(&dev));
        assert!(p.images_per_sec > 1000.0, "throughput {}", p.images_per_sec);
        assert!(p.total.dsps > 0, "big device should exploit DSPs");
    }

    #[test]
    fn pool_and_relu_engines_cost_resources_and_bound_throughput() {
        // The registry's point: the formerly-free layers now have real
        // instances, real utilization, and participate in the bottleneck
        // search.
        let m = Model::lenet_tiny();
        let dev = by_name("zcu104").unwrap();
        let p = plan(&m, &dev, 200.0, &Policy::adaptive()).unwrap();
        let of_kind =
            |k: EngineKind| p.engines.iter().filter(|e| e.kind == k).collect::<Vec<_>>();
        let pools = of_kind(EngineKind::MaxPool);
        let relus = of_kind(EngineKind::Relu);
        let fcs = of_kind(EngineKind::Fc);
        assert_eq!(pools.len(), 2);
        assert_eq!(relus.len(), 2);
        assert_eq!(fcs.len(), 1);
        for ep in pools.iter().chain(&relus).chain(&fcs) {
            assert!(ep.instances >= 1, "{} x{}", ep.kind.name(), ep.instances);
            assert!(ep.util.luts > 0, "{} must cost LUTs", ep.kind.name());
            assert!(ep.work > 0 && ep.cycles_per_image > 0.0);
        }
        // The bottleneck search spans ALL engines: the layer it names must
        // carry the global worst cycles-per-image.
        let worst = p
            .engines
            .iter()
            .map(|e| e.cycles_per_image)
            .fold(0.0f64, f64::max);
        let bneck = p
            .engines
            .iter()
            .filter(|e| e.layer == p.bottleneck)
            .map(|e| e.cycles_per_image)
            .fold(0.0f64, f64::max);
        assert_eq!(bneck, worst);
        assert!((p.images_per_sec - 200.0e6 / worst).abs() < 1e-6);
    }

    #[test]
    fn adapts_to_dsp_starved_device() {
        // The paper's motivating case: "suitable for FPGAs with limited
        // DSPs" — the planner must fall back to Conv_1.
        let m = Model::lenet_tiny();
        let dev = by_name("edge-nodsp").unwrap();
        let p = plan(&m, &dev, 200.0, &Policy::adaptive()).unwrap();
        assert!(p.total.dsps <= dev.dsps);
        let conv1_instances: u64 = p
            .convs()
            .filter(|ep| ep.kind == EngineKind::Conv(ConvKind::Conv1))
            .map(|ep| ep.instances)
            .sum();
        assert!(conv1_instances > 0, "expected Conv_1 fallback, got {:?}", p.engines);
    }

    #[test]
    fn bigger_device_more_throughput() {
        // lenet-tiny saturates its structural-parallelism caps on mid-size
        // parts; the wide variant differentiates devices.
        let m = Model::lenet_wide(4);
        let small = by_name("zu2cg").unwrap();
        let big = by_name("zcu104").unwrap();
        let ps = plan(&m, &small, 200.0, &Policy::adaptive()).unwrap();
        let pb = plan(&m, &big, 200.0, &Policy::adaptive()).unwrap();
        assert!(
            pb.images_per_sec > 2.0 * ps.images_per_sec,
            "big {} vs small {}",
            pb.images_per_sec,
            ps.images_per_sec
        );
    }

    #[test]
    fn utilization_never_exceeds_device() {
        let m = Model::lenet_wide(4);
        for dev in crate::fabric::device::catalog() {
            if let Ok(p) = plan(&m, &dev, 200.0, &Policy::adaptive()) {
                assert!(p.total.fits(&dev), "{}", dev.name);
                let (d, l) = p.pressure();
                assert!(d <= 1.0 && l <= 1.0);
            }
        }
    }

    #[test]
    fn fractional_budgets_shrink_but_still_plan() {
        let m = Model::lenet_tiny();
        let dev = by_name("zcu104").unwrap();
        let whole = plan(&m, &dev, 200.0, &Policy::adaptive()).unwrap();
        let half = plan_under_fraction(&m, &dev, 200.0, &Policy::adaptive(), 2).unwrap();
        // A half-budget replica fits a half device, so two of them fit the
        // whole one; throughput can only shrink per replica.
        assert!(half.total.fits(&dev.shard(2)));
        assert!(half.total.times(2).fits(&dev));
        assert!(half.images_per_sec <= whole.images_per_sec * (1.0 + 1e-9));
        // share=1 is plain plan().
        let one = plan_under_fraction(&m, &dev, 200.0, &Policy::adaptive(), 1).unwrap();
        assert_eq!(one.device.name, whole.device.name);
        assert!((one.images_per_sec - whole.images_per_sec).abs() < 1e-9);
    }

    #[test]
    fn coefficient_bram_counts_every_weighted_layer() {
        let m = Model::lenet_tiny();
        // conv0: 1×4×9 = 36 coefs, conv1: 4×8×9 = 288, fc: 32×10 = 320 —
        // each 8-bit store fits one RAMB18 (9×2048 aspect), one per layer.
        assert_eq!(coefficient_bram18(&m), 3);
        // Wider layers need more coefficient storage, never less.
        let wide = Model::lenet_wide(4);
        assert!(coefficient_bram18(&wide) >= coefficient_bram18(&m));
    }

    #[test]
    fn sharding_reserves_coefficient_bram_off_the_top() {
        let m = Model::lenet_tiny();
        let coef = coefficient_bram18(&m);
        assert!(coef > 0);
        // A part with plenty of logic but BRAM for only one coefficient
        // copy: share=1 plans, share=2 is rejected — the shard math used
        // to floor-divide BRAM as if coefficients shrank with the shard.
        let mut dev = by_name("zcu104").unwrap();
        dev.bram18 = coef + 1;
        assert!(plan_under_fraction(&m, &dev, 200.0, &Policy::adaptive(), 1).is_ok());
        let err = plan_under_fraction(&m, &dev, 200.0, &Policy::adaptive(), 2).unwrap_err();
        assert!(err.to_string().contains("coefficient"), "{err}");
        // share=1 hands the whole budget through — identical to plan().
        let p = plan_under_fraction(&m, &dev, 200.0, &Policy::adaptive(), 1).unwrap();
        assert_eq!(p.device.name, "zcu104");
        assert_eq!(p.device.bram18, coef + 1);
        // plan() itself charges the coefficient store, so the non-serve
        // path gives the same verdict: BRAM below one copy rejects.
        dev.bram18 = coef - 1;
        let err = plan(&m, &dev, 200.0, &Policy::adaptive()).unwrap_err();
        assert!(err.to_string().contains("coefficient"), "{err}");
        assert!(plan_under_fraction(&m, &dev, 200.0, &Policy::adaptive(), 1).is_err());
    }

    #[test]
    fn profile_rejects_infeasible() {
        let dev = by_name("zcu104").unwrap();
        let mut p = ConvParams::paper_8bit();
        p.data_bits = 12;
        p.coef_bits = 12;
        p.shift = 11;
        let ep = EngineParams::conv(p);
        assert!(profile(EngineKind::Conv(ConvKind::Conv3), &ep, 200.0, &dev).is_err());
        assert!(profile(EngineKind::Conv(ConvKind::Conv4), &ep, 200.0, &dev).is_ok());
    }

    #[test]
    fn cached_profile_errors_are_device_name_free() {
        // Regression for the stale-device-name bug: the memo key is
        // (kind, params, clock, derate), so two devices sharing a derate
        // share cached errors — the message must not bake in a name.
        let mut a = by_name("zcu104").unwrap();
        a.name = "first-asker".into();
        let mut b = by_name("zcu104").unwrap();
        b.name = "second-asker".into();
        let ep = EngineParams::conv(ConvParams::paper_8bit());
        // An absurd clock fails timing for every conv kind.
        let kind = EngineKind::Conv(ConvKind::Conv1);
        let e1 = profile(kind, &ep, 40_000.0, &a).unwrap_err();
        let e2 = profile(kind, &ep, 40_000.0, &b).unwrap_err();
        assert_eq!(e1, e2);
        assert!(
            !e1.contains("first-asker") && !e1.contains("second-asker"),
            "cached error leaked a device name: {e1}"
        );
    }
}
