//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Each `benches/*.rs` target is a `harness = false` binary that calls
//! [`Bench::run`] for its cases and prints both timing statistics and the
//! regenerated paper table. Methodology: warmup iterations, then batched
//! timed iterations until a wall-clock budget is spent; reports min /
//! median / mean so outliers are visible.

use std::time::{Duration, Instant};

/// One benchmark case's statistics (nanoseconds per iteration).
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: u64,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub max_ns: f64,
}

impl Stats {
    /// Iterations per second at the median.
    pub fn throughput(&self) -> f64 {
        1.0e9 / self.median_ns
    }

    /// A flat-valued case for figures of merit that are not timed
    /// iterations (modeled ns/img, latency quantiles, ...): every field
    /// carries the same value so each JSON entry is self-describing
    /// regardless of which field a tracker reads.
    pub fn flat(name: impl Into<String>, iters: u64, ns: f64) -> Stats {
        Stats { name: name.into(), iters, min_ns: ns, median_ns: ns, mean_ns: ns, max_ns: ns }
    }
}

/// True when `ACF_BENCH_QUICK=1` (or any value other than `0`): benches
/// shrink their workloads — shorter measurement budgets, fewer open-loop
/// requests — so the CI bench job finishes in minutes. Full mode stays
/// the default for local runs.
pub fn quick_env() -> bool {
    std::env::var("ACF_BENCH_QUICK").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        // Modest defaults: `cargo bench` runs a dozen cases across several
        // targets and must finish in CI time.
        Bench { warmup: Duration::from_millis(100), budget: Duration::from_millis(600), min_samples: 10 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { warmup: Duration::from_millis(20), budget: Duration::from_millis(120), min_samples: 5 }
    }

    /// [`Bench::quick`] when [`quick_env`] is set (CI), the full default
    /// otherwise.
    pub fn from_env() -> Self {
        if quick_env() {
            Bench::quick()
        } else {
            Bench::default()
        }
    }

    /// Time `f`, which performs ONE logical iteration, returning stats.
    /// The closure's return value is black-boxed to keep the optimizer
    /// honest.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Stats {
        // Warmup + batch-size calibration.
        let wstart = Instant::now();
        let mut wcount = 0u64;
        while wstart.elapsed() < self.warmup || wcount == 0 {
            black_box(f());
            wcount += 1;
        }
        let est_ns = (wstart.elapsed().as_nanos() as f64 / wcount as f64).max(1.0);
        // Aim for ~50 samples within budget; batch iterations so each
        // sample is ≥ ~20µs (clock-resolution floor).
        let batch = ((20_000.0 / est_ns).ceil() as u64).max(1);
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.budget || samples.len() < self.min_samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
            if samples.len() >= 10_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        Stats {
            name: name.to_string(),
            iters: batch * n as u64,
            min_ns: samples[0],
            median_ns: samples[n / 2],
            mean_ns: mean,
            max_ns: samples[n - 1],
        }
    }
}

/// Optimizer barrier (stable-rust version of `std::hint::black_box`,
/// which we use directly since Rust 1.66+).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Human-friendly duration formatting for bench reports.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// JSON form of a stats series (for `BENCH_*.json` artifacts: criterion
/// is unavailable offline, so the harness emits its own machine-readable
/// series for regression tracking).
pub fn stats_json(stats: &[Stats]) -> crate::util::json::Json {
    use crate::util::json::{obj, Json};
    Json::Arr(
        stats
            .iter()
            .map(|s| {
                obj([
                    ("name", s.name.as_str().into()),
                    ("iters", s.iters.into()),
                    ("min_ns", s.min_ns.into()),
                    ("median_ns", s.median_ns.into()),
                    ("mean_ns", s.mean_ns.into()),
                    ("max_ns", s.max_ns.into()),
                ])
            })
            .collect(),
    )
}

/// Write a `BENCH_*.json` artifact (`{"bench": title, "cases": [...]}`).
pub fn write_json(path: &str, title: &str, stats: &[Stats]) -> std::io::Result<()> {
    use crate::util::json::obj;
    let doc = obj([("bench", title.into()), ("cases", stats_json(stats))]);
    std::fs::write(path, doc.dump())
}

// ---------------------------------------------------------------------
// Bench regression gate (`acf bench-check`)
//
// CI runs the three bench targets and uploads `BENCH_*.json`; the gate
// then compares the fresh series against the committed
// `BENCH_baseline/` in two ways:
//
//  * **Modeled series** (case name contains "modeled") are
//    deterministic model evaluations — planner outcomes, not host
//    timings — so they are compared against a *pinned* baseline with a
//    small tolerance and FAIL the job on regression. This is what
//    protects the PR 1–4 wins (engine selection, fleet composition)
//    from quietly degrading.
//  * **Measured series** are host timings and vary across runners; they
//    are reported (drift vs baseline) but never gate.
//
// A second, machine-independent gate is the *relations* file: ordering
// invariants between same-run series (e.g. "64-lane sim must be ≥ 8×
// cheaper per image than scalar", "the heterogeneous fleet must model
// at least as fast as the best single device"). These hold on any
// hardware and gate from the very first CI run, before any absolute
// baseline has been pinned on a reference machine with
// `acf bench-check --update`.
// ---------------------------------------------------------------------

/// One `(name, median_ns)` series point loaded back from a
/// `BENCH_*.json` document.
#[derive(Debug, Clone)]
pub struct BenchCase {
    pub name: String,
    pub median_ns: f64,
}

/// A parsed `BENCH_*.json` (or baseline) document.
#[derive(Debug, Clone)]
pub struct BenchDoc {
    pub bench: String,
    /// Baselines start unpinned (`"pinned": false`, no cases): the
    /// modeled gate stays quiet until a maintainer runs
    /// `acf bench-check --update` on a reference machine and commits
    /// the result. Fresh bench output parses as pinned.
    pub pinned: bool,
    pub cases: Vec<BenchCase>,
}

/// Parse a bench/baseline JSON document (tolerates extra keys such as
/// `derived`).
pub fn parse_bench_doc(json: &crate::util::json::Json) -> Result<BenchDoc, String> {
    let bench = json
        .get("bench")
        .and_then(|b| b.as_str().map(str::to_string))
        .map_err(|e| format!("bad 'bench' field: {e}"))?;
    let pinned = match json.get_opt("pinned").map_err(|e| e.to_string())? {
        Some(p) => p.as_bool().map_err(|e| format!("bad 'pinned' field: {e}"))?,
        None => true,
    };
    let mut cases = Vec::new();
    let raw = json
        .get("cases")
        .and_then(|c| c.as_arr().map(<[_]>::to_vec))
        .map_err(|e| e.to_string())?;
    for c in raw {
        cases.push(BenchCase {
            name: c
                .get("name")
                .and_then(|n| n.as_str().map(str::to_string))
                .map_err(|e| format!("case missing 'name': {e}"))?,
            median_ns: c
                .get("median_ns")
                .and_then(|m| m.as_f64())
                .map_err(|e| format!("case missing 'median_ns': {e}"))?,
        });
    }
    Ok(BenchDoc { bench, pinned, cases })
}

/// Whether a series is a deterministic model evaluation (gated) rather
/// than a host timing (report-only). Convention: modeled case names
/// carry the word "modeled".
pub fn is_modeled(name: &str) -> bool {
    name.contains("modeled")
}

/// An ordering invariant between two same-run series:
/// `median(a) <= max_ratio × median(b)`.
#[derive(Debug, Clone)]
pub struct Relation {
    pub a: String,
    pub b: String,
    pub max_ratio: f64,
    pub why: String,
}

/// Parse `BENCH_baseline/relations.json`: an array of
/// `{"a": ..., "b": ..., "max_ratio": ..., "why": ...}` objects.
pub fn parse_relations(json: &crate::util::json::Json) -> Result<Vec<Relation>, String> {
    let mut out = Vec::new();
    for r in json.as_arr().map_err(|e| e.to_string())? {
        out.push(Relation {
            a: r.get("a").and_then(|v| v.as_str().map(str::to_string)).map_err(|e| e.to_string())?,
            b: r.get("b").and_then(|v| v.as_str().map(str::to_string)).map_err(|e| e.to_string())?,
            max_ratio: r.get("max_ratio").and_then(|v| v.as_f64()).map_err(|e| e.to_string())?,
            why: r
                .get_opt("why")
                .map_err(|e| e.to_string())?
                .map(|v| v.as_str().map(str::to_string))
                .transpose()
                .map_err(|e| e.to_string())?
                .unwrap_or_default(),
        });
    }
    Ok(out)
}

/// Outcome of a check pass: hard failures (exit non-zero) and
/// informational notes.
#[derive(Debug, Clone, Default)]
pub struct CheckReport {
    pub failures: Vec<String>,
    pub notes: Vec<String>,
}

impl CheckReport {
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }

    pub fn merge(&mut self, other: CheckReport) {
        self.failures.extend(other.failures);
        self.notes.extend(other.notes);
    }
}

/// Compare a fresh bench document against its committed baseline:
/// modeled series gate within `tolerance` (fractional — 0.05 allows a
/// 5% slowdown), measured series report drift only.
pub fn check_against_baseline(
    current: &BenchDoc,
    baseline: &BenchDoc,
    tolerance: f64,
) -> CheckReport {
    let mut rep = CheckReport::default();
    if !baseline.pinned {
        rep.notes.push(format!(
            "{}: baseline unpinned — modeled gate idle (pin with `acf bench-check --update` on a reference machine and commit BENCH_baseline/)",
            current.bench
        ));
        return rep;
    }
    for base in &baseline.cases {
        let Some(cur) = current.cases.iter().find(|c| c.name == base.name) else {
            if is_modeled(&base.name) {
                rep.failures.push(format!(
                    "{}: modeled series '{}' vanished from the fresh run",
                    current.bench, base.name
                ));
            } else {
                rep.notes.push(format!(
                    "{}: measured series '{}' no longer emitted",
                    current.bench, base.name
                ));
            }
            continue;
        };
        let ratio = cur.median_ns / base.median_ns.max(1e-12);
        if is_modeled(&base.name) {
            if ratio > 1.0 + tolerance {
                rep.failures.push(format!(
                    "{}: modeled regression in '{}': {:.1} -> {:.1} ns ({:+.1}% > {:.0}% tolerance)",
                    current.bench,
                    base.name,
                    base.median_ns,
                    cur.median_ns,
                    (ratio - 1.0) * 100.0,
                    tolerance * 100.0
                ));
            } else if ratio < 1.0 - tolerance {
                rep.notes.push(format!(
                    "{}: modeled improvement in '{}' ({:+.1}%) — refresh the baseline to lock it in",
                    current.bench,
                    base.name,
                    (ratio - 1.0) * 100.0
                ));
            }
        } else {
            rep.notes.push(format!(
                "{}: measured '{}' drift {:+.1}% (report-only)",
                current.bench,
                base.name,
                (ratio - 1.0) * 100.0
            ));
        }
    }
    for cur in &current.cases {
        if is_modeled(&cur.name) && !baseline.cases.iter().any(|b| b.name == cur.name) {
            rep.notes.push(format!(
                "{}: new modeled series '{}' is unpinned — refresh the baseline to gate it",
                current.bench, cur.name
            ));
        }
    }
    rep
}

/// Evaluate ordering relations over the union of all fresh cases. A
/// relation whose endpoints are missing is a hard failure — a silently
/// unevaluable gate is no gate.
pub fn check_relations(cases: &[BenchCase], relations: &[Relation]) -> CheckReport {
    let mut rep = CheckReport::default();
    let find = |name: &str| cases.iter().find(|c| c.name == name);
    for r in relations {
        let (Some(a), Some(b)) = (find(&r.a), find(&r.b)) else {
            rep.failures.push(format!(
                "relation '{}' <= {:.3} x '{}': series missing from the fresh run",
                r.a, r.max_ratio, r.b
            ));
            continue;
        };
        if a.median_ns > r.max_ratio * b.median_ns {
            rep.failures.push(format!(
                "relation violated: '{}' ({:.1} ns) > {:.3} x '{}' ({:.1} ns){}",
                r.a,
                a.median_ns,
                r.max_ratio,
                r.b,
                b.median_ns,
                if r.why.is_empty() { String::new() } else { format!(" — {}", r.why) }
            ));
        } else {
            rep.notes.push(format!(
                "relation holds: '{}' <= {:.3} x '{}' (ratio {:.3})",
                r.a,
                r.max_ratio,
                r.b,
                a.median_ns / b.median_ns.max(1e-12)
            ));
        }
    }
    rep
}

/// Print a standard bench-report block for a list of stats.
pub fn report(title: &str, stats: &[Stats]) {
    use super::table::{Align, Table};
    println!("\n== {title} ==");
    let mut t = Table::new(vec!["case", "median", "mean", "min", "max", "iters"]).align(vec![
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);
    for s in stats {
        t.row(vec![
            s.name.clone(),
            fmt_ns(s.median_ns),
            fmt_ns(s.mean_ns),
            fmt_ns(s.min_ns),
            fmt_ns(s.max_ns),
            s.iters.to_string(),
        ]);
    }
    print!("{}", t.plain());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench::quick();
        let s = b.run("noop-ish", || 1u64 + black_box(1));
        assert!(s.min_ns >= 0.0);
        assert!(s.median_ns >= s.min_ns);
        assert!(s.max_ns >= s.median_ns);
        assert!(s.iters > 0);
    }

    #[test]
    fn ordering_of_costs() {
        let b = Bench::quick();
        let cheap = b.run("cheap", || black_box(3u64).wrapping_mul(7));
        let costly = b.run("costly", || {
            let mut acc = 0u64;
            for i in 0..2000u64 {
                acc = acc.wrapping_add(black_box(i).wrapping_mul(2654435761));
            }
            acc
        });
        assert!(
            costly.median_ns > cheap.median_ns * 5.0,
            "cheap={} costly={}",
            cheap.median_ns,
            costly.median_ns
        );
    }

    #[test]
    fn json_emission_parses_back() {
        let b = Bench::quick();
        let s = b.run("case", || black_box(1u64));
        let text = stats_json(&[s]).dump();
        let back = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(back.as_arr().unwrap().len(), 1);
        assert_eq!(back.as_arr().unwrap()[0].get("name").unwrap().as_str().unwrap(), "case");
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(12.0), "12 ns");
        assert_eq!(fmt_ns(1_500.0), "1.50 µs");
        assert_eq!(fmt_ns(2_500_000.0), "2.50 ms");
        assert_eq!(fmt_ns(3_200_000_000.0), "3.200 s");
    }

    fn doc(bench: &str, pinned: bool, cases: &[(&str, f64)]) -> BenchDoc {
        BenchDoc {
            bench: bench.into(),
            pinned,
            cases: cases
                .iter()
                .map(|&(n, v)| BenchCase { name: n.into(), median_ns: v })
                .collect(),
        }
    }

    #[test]
    fn bench_doc_round_trips_through_json() {
        let b = Bench::quick();
        let s = b.run("case", || black_box(1u64));
        let modeled = Stats::flat("x: modeled ns/img", 1, 42.5);
        let text = crate::util::json::obj([
            ("bench", "t".into()),
            ("cases", stats_json(&[s, modeled])),
        ])
        .dump();
        let parsed = parse_bench_doc(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed.bench, "t");
        assert!(parsed.pinned, "fresh bench output parses as pinned");
        assert_eq!(parsed.cases.len(), 2);
        assert_eq!(parsed.cases[1].name, "x: modeled ns/img");
        assert!((parsed.cases[1].median_ns - 42.5).abs() < 1e-9);
        assert!(is_modeled(&parsed.cases[1].name));
        assert!(!is_modeled(&parsed.cases[0].name));
    }

    #[test]
    fn modeled_regression_fails_and_baseline_passes() {
        let base = doc("serve", true, &[("a: modeled ns/img", 100.0), ("b timing", 50.0)]);
        // Identical run: clean.
        let rep = check_against_baseline(&base, &base, 0.05);
        assert!(rep.ok(), "{:?}", rep.failures);
        // Within tolerance: clean.
        let near = doc("serve", true, &[("a: modeled ns/img", 104.0), ("b timing", 400.0)]);
        let rep = check_against_baseline(&near, &base, 0.05);
        assert!(rep.ok(), "{:?}", rep.failures);
        // Measured drift is report-only even at 8x.
        assert!(rep.notes.iter().any(|n| n.contains("report-only")));
        // An injected modeled regression fails.
        let bad = doc("serve", true, &[("a: modeled ns/img", 120.0), ("b timing", 50.0)]);
        let rep = check_against_baseline(&bad, &base, 0.05);
        assert!(!rep.ok());
        assert!(rep.failures[0].contains("modeled regression"), "{:?}", rep.failures);
        // A vanished modeled series fails too.
        let gone = doc("serve", true, &[("b timing", 50.0)]);
        assert!(!check_against_baseline(&gone, &base, 0.05).ok());
        // Improvements do not fail, they nudge a refresh.
        let better = doc("serve", true, &[("a: modeled ns/img", 80.0), ("b timing", 50.0)]);
        let rep = check_against_baseline(&better, &base, 0.05);
        assert!(rep.ok());
        assert!(rep.notes.iter().any(|n| n.contains("improvement")));
    }

    #[test]
    fn unpinned_baseline_is_idle_not_green_lit() {
        let base = doc("serve", false, &[]);
        let cur = doc("serve", true, &[("a: modeled ns/img", 1e12)]);
        let rep = check_against_baseline(&cur, &base, 0.05);
        assert!(rep.ok());
        assert!(rep.notes.iter().any(|n| n.contains("unpinned")));
    }

    #[test]
    fn relations_gate_orderings_machine_independently() {
        let cases = vec![
            BenchCase { name: "scalar".into(), median_ns: 800.0 },
            BenchCase { name: "wide".into(), median_ns: 90.0 },
        ];
        let holds = Relation {
            a: "wide".into(),
            b: "scalar".into(),
            max_ratio: 0.125,
            why: "lane packing".into(),
        };
        assert!(check_relations(&cases, &[holds.clone()]).ok());
        // Injected regression: the wide path got slower than the bound.
        let slow = vec![
            BenchCase { name: "scalar".into(), median_ns: 800.0 },
            BenchCase { name: "wide".into(), median_ns: 300.0 },
        ];
        let rep = check_relations(&slow, &[holds.clone()]);
        assert!(!rep.ok());
        assert!(rep.failures[0].contains("lane packing"));
        // A relation over a missing series is a loud failure, not a skip.
        let rep = check_relations(&[], &[holds]);
        assert!(!rep.ok());
        // Relations parse from the committed JSON shape.
        let text = r#"[{"a":"wide","b":"scalar","max_ratio":0.125,"why":"lanes"}]"#;
        let rels = parse_relations(&crate::util::json::Json::parse(text).unwrap()).unwrap();
        assert_eq!(rels.len(), 1);
        assert!((rels[0].max_ratio - 0.125).abs() < 1e-12);
    }

    #[test]
    fn quick_mode_reads_the_environment() {
        // Don't mutate the process env (tests run in parallel); just pin
        // the parsing contract on the current state.
        let expect = std::env::var("ACF_BENCH_QUICK")
            .map(|v| v != "0" && !v.is_empty())
            .unwrap_or(false);
        assert_eq!(quick_env(), expect);
        let b = Bench::from_env();
        assert!(b.budget >= Bench::quick().budget);
    }
}
