//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Each `benches/*.rs` target is a `harness = false` binary that calls
//! [`Bench::run`] for its cases and prints both timing statistics and the
//! regenerated paper table. Methodology: warmup iterations, then batched
//! timed iterations until a wall-clock budget is spent; reports min /
//! median / mean so outliers are visible.

use std::time::{Duration, Instant};

/// One benchmark case's statistics (nanoseconds per iteration).
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: u64,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub max_ns: f64,
}

impl Stats {
    /// Iterations per second at the median.
    pub fn throughput(&self) -> f64 {
        1.0e9 / self.median_ns
    }
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        // Modest defaults: `cargo bench` runs a dozen cases across several
        // targets and must finish in CI time.
        Bench { warmup: Duration::from_millis(100), budget: Duration::from_millis(600), min_samples: 10 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { warmup: Duration::from_millis(20), budget: Duration::from_millis(120), min_samples: 5 }
    }

    /// Time `f`, which performs ONE logical iteration, returning stats.
    /// The closure's return value is black-boxed to keep the optimizer
    /// honest.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Stats {
        // Warmup + batch-size calibration.
        let wstart = Instant::now();
        let mut wcount = 0u64;
        while wstart.elapsed() < self.warmup || wcount == 0 {
            black_box(f());
            wcount += 1;
        }
        let est_ns = (wstart.elapsed().as_nanos() as f64 / wcount as f64).max(1.0);
        // Aim for ~50 samples within budget; batch iterations so each
        // sample is ≥ ~20µs (clock-resolution floor).
        let batch = ((20_000.0 / est_ns).ceil() as u64).max(1);
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.budget || samples.len() < self.min_samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
            if samples.len() >= 10_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        Stats {
            name: name.to_string(),
            iters: batch * n as u64,
            min_ns: samples[0],
            median_ns: samples[n / 2],
            mean_ns: mean,
            max_ns: samples[n - 1],
        }
    }
}

/// Optimizer barrier (stable-rust version of `std::hint::black_box`,
/// which we use directly since Rust 1.66+).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Human-friendly duration formatting for bench reports.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// JSON form of a stats series (for `BENCH_*.json` artifacts: criterion
/// is unavailable offline, so the harness emits its own machine-readable
/// series for regression tracking).
pub fn stats_json(stats: &[Stats]) -> crate::util::json::Json {
    use crate::util::json::{obj, Json};
    Json::Arr(
        stats
            .iter()
            .map(|s| {
                obj([
                    ("name", s.name.as_str().into()),
                    ("iters", s.iters.into()),
                    ("min_ns", s.min_ns.into()),
                    ("median_ns", s.median_ns.into()),
                    ("mean_ns", s.mean_ns.into()),
                    ("max_ns", s.max_ns.into()),
                ])
            })
            .collect(),
    )
}

/// Write a `BENCH_*.json` artifact (`{"bench": title, "cases": [...]}`).
pub fn write_json(path: &str, title: &str, stats: &[Stats]) -> std::io::Result<()> {
    use crate::util::json::obj;
    let doc = obj([("bench", title.into()), ("cases", stats_json(stats))]);
    std::fs::write(path, doc.dump())
}

/// Print a standard bench-report block for a list of stats.
pub fn report(title: &str, stats: &[Stats]) {
    use super::table::{Align, Table};
    println!("\n== {title} ==");
    let mut t = Table::new(vec!["case", "median", "mean", "min", "max", "iters"]).align(vec![
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);
    for s in stats {
        t.row(vec![
            s.name.clone(),
            fmt_ns(s.median_ns),
            fmt_ns(s.mean_ns),
            fmt_ns(s.min_ns),
            fmt_ns(s.max_ns),
            s.iters.to_string(),
        ]);
    }
    print!("{}", t.plain());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench::quick();
        let s = b.run("noop-ish", || 1u64 + black_box(1));
        assert!(s.min_ns >= 0.0);
        assert!(s.median_ns >= s.min_ns);
        assert!(s.max_ns >= s.median_ns);
        assert!(s.iters > 0);
    }

    #[test]
    fn ordering_of_costs() {
        let b = Bench::quick();
        let cheap = b.run("cheap", || black_box(3u64).wrapping_mul(7));
        let costly = b.run("costly", || {
            let mut acc = 0u64;
            for i in 0..2000u64 {
                acc = acc.wrapping_add(black_box(i).wrapping_mul(2654435761));
            }
            acc
        });
        assert!(
            costly.median_ns > cheap.median_ns * 5.0,
            "cheap={} costly={}",
            cheap.median_ns,
            costly.median_ns
        );
    }

    #[test]
    fn json_emission_parses_back() {
        let b = Bench::quick();
        let s = b.run("case", || black_box(1u64));
        let text = stats_json(&[s]).dump();
        let back = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(back.as_arr().unwrap().len(), 1);
        assert_eq!(back.as_arr().unwrap()[0].get("name").unwrap().as_str().unwrap(), "case");
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(12.0), "12 ns");
        assert_eq!(fmt_ns(1_500.0), "1.50 µs");
        assert_eq!(fmt_ns(2_500_000.0), "2.50 ms");
        assert_eq!(fmt_ns(3_200_000_000.0), "3.200 s");
    }
}
