//! Self-contained utility substrate.
//!
//! This environment has no network access, so every convenience that a
//! production crate would pull from crates.io (serde, clap, criterion,
//! proptest, rand) is implemented here from scratch:
//!
//! * [`json`] — a strict JSON parser/serializer backing the config system.
//! * [`rng`] — a deterministic xorshift64* PRNG.
//! * [`prop`] — a miniature property-based testing harness with shrinking.
//! * [`table`] — aligned-column table formatting for reports/benches.
//! * [`cli`] — a subcommand + flag argument parser for the `acf` binary.
//! * [`bench`] — a micro-benchmark harness (warmup, iterations, robust
//!   statistics) used by the `benches/` targets in place of criterion.
//! * [`sync`] — poison-tolerant lock helpers for the serve request path.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod sync;
pub mod table;
