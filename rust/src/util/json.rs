//! Strict JSON parser and serializer.
//!
//! Backs the config system (device catalogs, CNN model descriptions,
//! deployment plans) and the metrics dump format. Implements RFC 8259
//! minus unicode escapes beyond the BMP surrogate-pair handling that the
//! config files never need (surrogate pairs *are* handled).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so serialization
/// is deterministic — important for golden-file tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse or access error with a location hint.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JsonError {
    Parse(usize, String),
    Access(String),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Parse(at, what) => write!(f, "json parse error at byte {at}: {what}"),
            JsonError::Access(what) => write!(f, "json access error: {what}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document; trailing non-whitespace is an error.
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(JsonError::Parse(p.i, "trailing characters".into()));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(JsonError::Access(format!("expected number, got {}", other.kind()))),
        }
    }

    pub fn as_u64(&self) -> Result<u64, JsonError> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 || f > u64::MAX as f64 {
            return Err(JsonError::Access(format!("expected unsigned integer, got {f}")));
        }
        Ok(f as u64)
    }

    pub fn as_i64(&self) -> Result<i64, JsonError> {
        let f = self.as_f64()?;
        if f.fract() != 0.0 || f < i64::MIN as f64 || f > i64::MAX as f64 {
            return Err(JsonError::Access(format!("expected integer, got {f}")));
        }
        Ok(f as i64)
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(JsonError::Access(format!("expected bool, got {}", other.kind()))),
        }
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(JsonError::Access(format!("expected string, got {}", other.kind()))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(JsonError::Access(format!("expected array, got {}", other.kind()))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(JsonError::Access(format!("expected object, got {}", other.kind()))),
        }
    }

    /// Object field access with a helpful error naming the missing key.
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::Access(format!("missing key '{key}'")))
    }

    /// Optional field: `Ok(None)` if the key is absent or explicitly null.
    pub fn get_opt(&self, key: &str) -> Result<Option<&Json>, JsonError> {
        Ok(self.as_obj()?.get(key).filter(|v| !matches!(v, Json::Null)))
    }

    /// Optional numeric field with a default (config-parsing shorthand).
    pub fn get_f64_or(&self, key: &str, default: f64) -> Result<f64, JsonError> {
        self.get_opt(key)?.map(Json::as_f64).transpose().map(|v| v.unwrap_or(default))
    }

    /// Optional integer field with a default.
    pub fn get_usize_or(&self, key: &str, default: usize) -> Result<usize, JsonError> {
        self.get_opt(key)?.map(Json::as_usize).transpose().map(|v| v.unwrap_or(default))
    }

    /// Optional boolean field with a default.
    pub fn get_bool_or(&self, key: &str, default: bool) -> Result<bool, JsonError> {
        self.get_opt(key)?.map(Json::as_bool).transpose().map(|v| v.unwrap_or(default))
    }

    /// Optional string field with a default.
    pub fn get_str_or(&self, key: &str, default: &str) -> Result<String, JsonError> {
        Ok(self.get_opt(key)?.map(Json::as_str).transpose()?.unwrap_or(default).to_string())
    }

    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    /// Compact serialization (deterministic: object keys sorted).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with `indent` spaces per level.
    pub fn pretty(&self, indent: usize) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(indent), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.dump())
    }
}

/// Convenience constructors used throughout the crate.
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u32> for Json {
    fn from(v: u32) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i32> for Json {
    fn from(v: i32) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build a `Json::Obj` from `(key, value)` pairs.
pub fn obj<const N: usize>(pairs: [(&str, Json); N]) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError::Parse(self.i, msg.into()))
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected character '{}'", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            self.err(format!("expected '{word}'"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            if map.insert(key.clone(), val).is_some() {
                return self.err(format!("duplicate key '{key}'"));
            }
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair.
                                if self.peek() != Some(b'\\') {
                                    return self.err("expected low surrogate");
                                }
                                self.i += 1;
                                if self.peek() != Some(b'u') {
                                    return self.err("expected low surrogate");
                                }
                                self.i += 1;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return self.err("invalid low surrogate");
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            match char::from_u32(cp) {
                                Some(c) => s.push(c),
                                None => return self.err("invalid codepoint"),
                            }
                            continue; // hex4 advanced past the digits
                        }
                        _ => return self.err("invalid escape"),
                    }
                    self.i += 1;
                }
                Some(c) if c < 0x20 => return self.err("control character in string"),
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    if start + len > self.b.len() {
                        return self.err("truncated utf-8");
                    }
                    match std::str::from_utf8(&self.b[start..start + len]) {
                        Ok(chunk) => s.push_str(chunk),
                        Err(_) => return self.err("invalid utf-8"),
                    }
                    self.i += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return self.err("truncated \\u escape");
        }
        let txt = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| JsonError::Parse(self.i, "invalid \\u escape".into()))?;
        let v = u32::from_str_radix(txt, 16)
            .map_err(|_| JsonError::Parse(self.i, "invalid \\u escape".into()))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        // Integer part: 0 | [1-9][0-9]*
        match self.peek() {
            Some(b'0') => self.i += 1,
            Some(c) if c.is_ascii_digit() => {
                while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    self.i += 1;
                }
            }
            _ => return self.err("invalid number"),
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return self.err("digit expected after '.'");
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return self.err("digit expected in exponent");
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).expect("ascii");
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| JsonError::Parse(start, format!("bad number: {e}")))
    }
}

fn utf8_len(lead: u8) -> usize {
    match lead {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn defaulted_getters() {
        let v = Json::parse(r#"{"a": 2.5, "n": 7, "b": false, "s": "x", "z": null}"#).unwrap();
        assert_eq!(v.get_f64_or("a", 1.0).unwrap(), 2.5);
        assert_eq!(v.get_f64_or("missing", 1.0).unwrap(), 1.0);
        assert_eq!(v.get_usize_or("n", 3).unwrap(), 7);
        assert_eq!(v.get_usize_or("missing", 3).unwrap(), 3);
        assert!(!v.get_bool_or("b", true).unwrap());
        assert!(v.get_bool_or("missing", true).unwrap());
        assert_eq!(v.get_str_or("s", "d").unwrap(), "x");
        assert_eq!(v.get_str_or("missing", "d").unwrap(), "d");
        // Explicit null falls back to the default, same as get_opt.
        assert_eq!(v.get_f64_or("z", 9.0).unwrap(), 9.0);
        // Type mismatches still error instead of defaulting.
        assert!(v.get_f64_or("s", 1.0).is_err());
        assert!(v.get_bool_or("a", true).is_err());
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].as_u64().unwrap(), 1);
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A 😀");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a":1,"a":2}"#).is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("\"\\x\"").is_err());
    }

    #[test]
    fn roundtrip_dump() {
        let src = r#"{"device":"zcu104","dsp":1728,"luts":230400,"ok":true,"tags":["a","b"]}"#;
        let v = Json::parse(src).unwrap();
        let dumped = v.dump();
        assert_eq!(Json::parse(&dumped).unwrap(), v);
        assert_eq!(dumped, src); // keys already sorted
    }

    #[test]
    fn pretty_reparses() {
        let v = obj([
            ("name", "conv1".into()),
            ("dsps", 0u64.into()),
            ("wns_ns", Json::Num(2.596)),
            ("features", vec!["logic-only", "serial-mac"].into()),
        ]);
        let p = v.pretty(2);
        assert_eq!(Json::parse(&p).unwrap(), v);
        assert!(p.contains("\n  \"dsps\": 0"));
    }

    #[test]
    fn integer_access_guards() {
        assert!(Json::parse("1.5").unwrap().as_u64().is_err());
        assert!(Json::parse("-1").unwrap().as_u64().is_err());
        assert_eq!(Json::parse("-1").unwrap().as_i64().unwrap(), -1);
        assert!(Json::parse("\"x\"").unwrap().as_f64().is_err());
    }

    #[test]
    fn missing_key_error_names_key() {
        let v = Json::parse(r#"{"a":1}"#).unwrap();
        let err = v.get("zzz").unwrap_err().to_string();
        assert!(err.contains("zzz"), "{err}");
    }

    #[test]
    fn get_opt_null_is_none() {
        let v = Json::parse(r#"{"a":null,"b":2}"#).unwrap();
        assert!(v.get_opt("a").unwrap().is_none());
        assert!(v.get_opt("missing").unwrap().is_none());
        assert_eq!(v.get_opt("b").unwrap().unwrap().as_u64().unwrap(), 2);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ✓");
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }
}
