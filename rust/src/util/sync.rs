//! Poison-tolerant lock helpers for the serving tier.
//!
//! A `Mutex` is poisoned when a thread panics while holding it. On the
//! serve request path that must never cascade: a replica runner that
//! panicked mid-batch has already been accounted as a failure, and the
//! shared structures it guarded (slot lists, latency reservoirs, event
//! logs) are plain data that remain structurally valid. Every lock site
//! on a request- or fault-reachable path therefore goes through these
//! helpers, which recover the inner guard instead of propagating the
//! poison — turning "one panicked runner aborts the process on the next
//! metrics read" into a logged degradation.
//!
//! The first recovery per process prints a single warning to stderr so
//! a poisoned run is visible in CI logs without flooding them.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

static POISON_SEEN: AtomicBool = AtomicBool::new(false);

fn note_poison(what: &str) {
    if !POISON_SEEN.swap(true, Ordering::Relaxed) {
        eprintln!("warn: recovered a poisoned {what} (a holder panicked); continuing degraded");
    }
}

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn lock_ok<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| {
        note_poison("mutex");
        e.into_inner()
    })
}

/// Read-lock `l`, recovering the guard if a previous writer panicked.
pub fn read_ok<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|e| {
        note_poison("rwlock");
        e.into_inner()
    })
}

/// Write-lock `l`, recovering the guard if a previous writer panicked.
pub fn write_ok<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|e| {
        note_poison("rwlock");
        e.into_inner()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_ok_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        assert_eq!(*lock_ok(&m), 7);
        *lock_ok(&m) = 8;
        assert_eq!(*lock_ok(&m), 8);
    }

    #[test]
    fn rwlock_helpers_recover_from_poison() {
        let l = Arc::new(RwLock::new(vec![1, 2, 3]));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison it");
        })
        .join();
        assert_eq!(read_ok(&l).len(), 3);
        write_ok(&l).push(4);
        assert_eq!(read_ok(&l).len(), 4);
    }
}
