//! Miniature property-based testing harness (proptest is unavailable
//! offline).
//!
//! Supports: seeded case generation, failure reporting with the seed that
//! reproduces it, and greedy shrinking for integer vectors / scalars. The
//! coordinator/planner invariants and the netlist-vs-behavioral
//! equivalence checks run through this.
//!
//! ```no_run
//! use acf::util::prop::{forall, Gen};
//! forall("add commutes", 200, |g| {
//!     let a = g.i64_in(-1000, 1000);
//!     let b = g.i64_in(-1000, 1000);
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```

use super::rng::Rng;

/// Case-local generator handed to the property body.
pub struct Gen {
    rng: Rng,
    /// Log of drawn scalars, used by shrinking to replay with smaller
    /// values.
    log: Vec<i64>,
    /// When replaying a shrunk candidate, draws are served from here.
    replay: Option<Vec<i64>>,
    cursor: usize,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), log: Vec::new(), replay: None, cursor: 0 }
    }

    fn draw(&mut self, fresh: impl FnOnce(&mut Rng) -> i64, clamp: impl Fn(i64) -> i64) -> i64 {
        let v = if let Some(r) = &self.replay {
            let raw = r.get(self.cursor).copied().unwrap_or(0);
            clamp(raw)
        } else {
            fresh(&mut self.rng)
        };
        self.cursor += 1;
        self.log.push(v);
        v
    }

    /// Uniform i64 in `[lo, hi]`.
    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        self.draw(|r| r.range_i64(lo, hi), |v| v.clamp(lo, hi))
    }

    /// Uniform usize in `[lo, hi]`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.i64_in(lo as i64, hi as i64) as usize
    }

    /// Signed value fitting `bits` bits — matches the IP operand domain.
    pub fn signed_bits(&mut self, bits: u32) -> i64 {
        let lo = -(1i64 << (bits - 1));
        let hi = (1i64 << (bits - 1)) - 1;
        self.i64_in(lo, hi)
    }

    /// Vector of signed `bits`-bit values with the given length.
    pub fn signed_vec(&mut self, bits: u32, len: usize) -> Vec<i64> {
        (0..len).map(|_| self.signed_bits(bits)).collect()
    }

    /// Bernoulli draw.
    pub fn bool(&mut self) -> bool {
        self.i64_in(0, 1) == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }
}

/// Outcome of a property body: `Ok(())` on pass, `Err(msg)` describing the
/// counterexample on failure.
pub type PropResult = Result<(), String>;

/// Run `cases` random cases of `body`. Panics with a reproduction seed and
/// the (shrunk) counterexample on failure. The base seed derives from the
/// property name so independent properties explore independent streams but
/// every run is reproducible.
pub fn forall(name: &str, cases: u64, mut body: impl FnMut(&mut Gen) -> PropResult) {
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E3779B97F4A7C15) | 1);
        let mut g = Gen::new(seed);
        if let Err(msg) = body(&mut g) {
            let draws = g.log.clone();
            let (shrunk_draws, shrunk_msg) = shrink(&draws, &mut body).unwrap_or((draws, msg));
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x})\n  counterexample: {shrunk_msg}\n  draws: {shrunk_draws:?}"
            );
        }
    }
}

/// Greedy shrink: repeatedly try halving each drawn scalar toward zero and
/// truncating the draw log; keep any candidate that still fails.
fn shrink(
    draws: &[i64],
    body: &mut impl FnMut(&mut Gen) -> PropResult,
) -> Option<(Vec<i64>, String)> {
    let mut best: Option<(Vec<i64>, String)> = None;
    let mut current = draws.to_vec();
    let mut improved = true;
    let mut budget = 500usize;
    while improved && budget > 0 {
        improved = false;
        for i in 0..current.len() {
            if budget == 0 {
                break;
            }
            let orig = current[i];
            for cand in shrink_candidates(orig) {
                budget -= 1;
                current[i] = cand;
                if let Err(msg) = run_replay(&current, body) {
                    best = Some((current.clone(), msg));
                    improved = true;
                    break; // keep this smaller value, move on
                }
                current[i] = orig;
                if budget == 0 {
                    break;
                }
            }
        }
    }
    best
}

fn shrink_candidates(v: i64) -> Vec<i64> {
    if v == 0 {
        return vec![];
    }
    let mut out = vec![0];
    if v.abs() > 1 {
        out.push(v / 2);
    }
    if v < 0 {
        out.push(-v);
    }
    out.push(v - v.signum());
    out.dedup();
    out.retain(|&c| c != v);
    out
}

fn run_replay(draws: &[i64], body: &mut impl FnMut(&mut Gen) -> PropResult) -> PropResult {
    let mut g = Gen::new(1);
    g.replay = Some(draws.to_vec());
    body(&mut g)
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        forall("always true", 50, |g| {
            let _ = g.i64_in(0, 10);
            n += 1;
            Ok(())
        });
        // body re-invoked only during the 50 cases (no shrinking)
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always false' failed")]
    fn failing_property_panics_with_name() {
        forall("always false", 10, |g| {
            let _ = g.i64_in(0, 10);
            Err("nope".into())
        });
    }

    #[test]
    fn shrinking_reduces_counterexample() {
        // Property: v < 50. Counterexamples are 50..=1000; minimal is 50.
        let caught = std::panic::catch_unwind(|| {
            forall("shrinks", 100, |g| {
                let v = g.i64_in(0, 1000);
                if v < 50 {
                    Ok(())
                } else {
                    Err(format!("v={v}"))
                }
            });
        });
        let msg = *caught.unwrap_err().downcast::<String>().unwrap();
        // shrinker should get at or near the boundary — well below 900.
        let v: i64 = msg
            .split("v=")
            .nth(1)
            .unwrap()
            .split(|c: char| !c.is_ascii_digit())
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(v <= 100, "shrunk to {v}, msg: {msg}");
    }

    #[test]
    fn signed_bits_domain() {
        forall("signed bits domain", 300, |g| {
            let v = g.signed_bits(8);
            if (-128..=127).contains(&v) {
                Ok(())
            } else {
                Err(format!("{v}"))
            }
        });
    }

    #[test]
    fn choose_and_vec() {
        forall("choose/vec", 50, |g| {
            let xs = g.signed_vec(4, 9);
            if xs.len() != 9 {
                return Err("len".into());
            }
            let pick = *g.choose(&[1i64, 2, 3]);
            if (1..=3).contains(&pick) {
                Ok(())
            } else {
                Err(format!("{pick}"))
            }
        });
    }
}
