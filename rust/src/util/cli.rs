//! Minimal subcommand + flag argument parser (clap is unavailable
//! offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! `-h/--help` synthesis, and typed accessors with defaults. Unknown
//! options are errors — silent typos in a deployment CLI are worse than
//! crashes.

use std::collections::BTreeMap;

/// Declared option for help text + validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub value: bool, // takes a value?
    pub help: &'static str,
    pub default: Option<&'static str>,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    BadValue(String, String, String),
    MissingPositional(&'static str),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(n) => write!(f, "unknown option --{n}"),
            CliError::MissingValue(n) => write!(f, "option --{n} requires a value"),
            CliError::BadValue(n, v, why) => write!(f, "invalid value for --{n}: '{v}' ({why})"),
            CliError::MissingPositional(n) => {
                write!(f, "missing required positional argument <{n}>")
            }
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse `argv` against the declared option specs.
    pub fn parse(argv: &[String], specs: &[OptSpec]) -> Result<Args, CliError> {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(rest) = tok.strip_prefix("--") {
                let (name, inline) = match rest.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| CliError::Unknown(name.clone()))?;
                if spec.value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i).cloned().ok_or_else(|| CliError::MissingValue(name.clone()))?
                        }
                    };
                    a.opts.insert(name, v);
                } else {
                    if inline.is_some() {
                        return Err(CliError::BadValue(
                            name.clone(),
                            inline.unwrap(),
                            "flag takes no value".into(),
                        ));
                    }
                    a.flags.push(name);
                }
            } else {
                a.positional.push(tok.clone());
            }
            i += 1;
        }
        // Apply defaults.
        for s in specs {
            if let Some(d) = s.default {
                a.opts.entry(s.name.to_string()).or_insert_with(|| d.to_string());
            }
        }
        Ok(a)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, CliError> {
        self.typed(name, |s| s.parse::<usize>().map_err(|e| e.to_string()))
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>, CliError> {
        self.typed(name, |s| s.parse::<u64>().map_err(|e| e.to_string()))
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, CliError> {
        self.typed(name, |s| s.parse::<f64>().map_err(|e| e.to_string()))
    }

    /// Typed accessor where the sentinel strings `auto` / `none` mean
    /// "unset" — for options whose default is a search, not a number
    /// (e.g. `acf serve --replicas auto --target-img-s none`).
    pub fn get_u64_auto(&self, name: &str) -> Result<Option<u64>, CliError> {
        match self.get(name) {
            None | Some("auto") | Some("none") => Ok(None),
            Some(_) => self.get_u64(name),
        }
    }

    /// Float twin of [`Args::get_u64_auto`].
    pub fn get_f64_auto(&self, name: &str) -> Result<Option<f64>, CliError> {
        match self.get(name) {
            None | Some("auto") | Some("none") => Ok(None),
            Some(_) => self.get_f64(name),
        }
    }

    /// Duration accessor for `--*-ms` options: a bare number is
    /// milliseconds, and the suffixes `ms` / `s` are accepted so
    /// `--window-ms 250`, `--window-ms 250ms`, and `--window-ms 2s` all
    /// parse (serving-control knobs read more naturally with units).
    pub fn get_ms(&self, name: &str) -> Result<Option<std::time::Duration>, CliError> {
        self.typed(name, |s| {
            let (num, scale_ms) = if let Some(n) = s.strip_suffix("ms") {
                (n, 1.0)
            } else if let Some(n) = s.strip_suffix('s') {
                (n, 1_000.0)
            } else {
                (s, 1.0)
            };
            let v: f64 = num
                .trim()
                .parse()
                .map_err(|_| "want a duration like 250, 250ms, or 2s".to_string())?;
            if !v.is_finite() || v < 0.0 {
                return Err("duration must be a finite non-negative number".to_string());
            }
            Ok(std::time::Duration::from_secs_f64(v * scale_ms / 1_000.0))
        })
    }

    /// `auto`/`none`-aware twin of [`Args::get_ms`].
    pub fn get_ms_auto(&self, name: &str) -> Result<Option<std::time::Duration>, CliError> {
        match self.get(name) {
            None | Some("auto") | Some("none") => Ok(None),
            Some(_) => self.get_ms(name),
        }
    }

    fn typed<T>(
        &self,
        name: &str,
        f: impl Fn(&str) -> Result<T, String>,
    ) -> Result<Option<T>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => f(s)
                .map(Some)
                .map_err(|e| CliError::BadValue(name.to_string(), s.to_string(), e)),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn pos(&self, idx: usize, name: &'static str) -> Result<&str, CliError> {
        self.positional
            .get(idx)
            .map(|s| s.as_str())
            .ok_or(CliError::MissingPositional(name))
    }
}

/// Render help text for a subcommand.
pub fn help(cmd: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut s = format!("{cmd} — {about}\n\noptions:\n");
    for o in specs {
        let head = if o.value { format!("--{} <v>", o.name) } else { format!("--{}", o.name) };
        let dflt = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
        s.push_str(&format!("  {head:<24} {}{}\n", o.help, dflt));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "device", value: true, help: "part name", default: Some("zcu104") },
            OptSpec { name: "clock-mhz", value: true, help: "target clock", default: Some("200") },
            OptSpec { name: "verbose", value: false, help: "chatty", default: None },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_mixed() {
        let a = Args::parse(&sv(&["--device", "zu3eg", "--verbose", "plan.json"]), &specs()).unwrap();
        assert_eq!(a.get("device"), Some("zu3eg"));
        assert!(a.flag("verbose"));
        assert_eq!(a.pos(0, "plan").unwrap(), "plan.json");
        assert_eq!(a.get_f64("clock-mhz").unwrap(), Some(200.0)); // default applied
    }

    #[test]
    fn equals_syntax() {
        let a = Args::parse(&sv(&["--clock-mhz=300"]), &specs()).unwrap();
        assert_eq!(a.get_f64("clock-mhz").unwrap(), Some(300.0));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(matches!(
            Args::parse(&sv(&["--nope"]), &specs()),
            Err(CliError::Unknown(n)) if n == "nope"
        ));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(
            Args::parse(&sv(&["--device"]), &specs()),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn auto_sentinels_mean_unset() {
        let specs = vec![
            OptSpec { name: "replicas", value: true, help: "auto|N", default: Some("auto") },
            OptSpec { name: "rate", value: true, help: "none|R", default: Some("none") },
        ];
        let a = Args::parse(&sv(&[]), &specs).unwrap();
        assert_eq!(a.get_u64_auto("replicas").unwrap(), None);
        assert_eq!(a.get_f64_auto("rate").unwrap(), None);
        let a = Args::parse(&sv(&["--replicas", "3", "--rate", "250.5"]), &specs).unwrap();
        assert_eq!(a.get_u64_auto("replicas").unwrap(), Some(3));
        assert_eq!(a.get_f64_auto("rate").unwrap(), Some(250.5));
        let a = Args::parse(&sv(&["--replicas", "lots"]), &specs).unwrap();
        assert!(a.get_u64_auto("replicas").is_err());
    }

    #[test]
    fn ms_durations_with_and_without_suffix() {
        use std::time::Duration;
        let specs = vec![
            OptSpec { name: "window-ms", value: true, help: "period", default: Some("250") },
            OptSpec { name: "cooldown-ms", value: true, help: "auto|ms", default: Some("auto") },
        ];
        let a = Args::parse(&sv(&[]), &specs).unwrap();
        assert_eq!(a.get_ms("window-ms").unwrap(), Some(Duration::from_millis(250)));
        assert_eq!(a.get_ms_auto("cooldown-ms").unwrap(), None);
        let a = Args::parse(&sv(&["--window-ms", "100ms", "--cooldown-ms", "2s"]), &specs).unwrap();
        assert_eq!(a.get_ms("window-ms").unwrap(), Some(Duration::from_millis(100)));
        assert_eq!(a.get_ms_auto("cooldown-ms").unwrap(), Some(Duration::from_secs(2)));
        let a = Args::parse(&sv(&["--window-ms", "1.5s"]), &specs).unwrap();
        assert_eq!(a.get_ms("window-ms").unwrap(), Some(Duration::from_millis(1500)));
        for bad in ["fast", "-5", "nan", "infs"] {
            let a = Args::parse(&sv(&["--window-ms", bad]), &specs).unwrap();
            assert!(a.get_ms("window-ms").is_err(), "'{bad}' must not parse");
        }
    }

    #[test]
    fn bad_typed_value() {
        let a = Args::parse(&sv(&["--clock-mhz", "fast"]), &specs()).unwrap();
        assert!(a.get_f64("clock-mhz").is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(Args::parse(&sv(&["--verbose=yes"]), &specs()).is_err());
    }

    #[test]
    fn missing_positional_named() {
        let a = Args::parse(&sv(&[]), &specs()).unwrap();
        let e = a.pos(0, "model").unwrap_err().to_string();
        assert!(e.contains("model"));
    }

    #[test]
    fn help_lists_options() {
        let h = help("synth", "synthesize an IP", &specs());
        assert!(h.contains("--device"));
        assert!(h.contains("default: zcu104"));
    }
}
