//! Aligned-column table formatting.
//!
//! The report module regenerates the paper's Tables I–III through this;
//! benches print their series with it too. Output styles: GitHub-flavored
//! markdown and plain aligned text.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple table builder.
#[derive(Debug, Clone)]
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create with headers; all columns left-aligned by default.
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        let headers: Vec<String> = headers.into_iter().map(Into::into).collect();
        let aligns = vec![Align::Left; headers.len()];
        Table { headers, aligns, rows: Vec::new() }
    }

    /// Set per-column alignment (panics if length mismatches headers).
    pub fn align(mut self, aligns: Vec<Align>) -> Self {
        assert_eq!(aligns.len(), self.headers.len(), "alignment arity");
        self.aligns = aligns;
        self
    }

    /// Convenience: right-align every column except the first.
    pub fn numeric(mut self) -> Self {
        for (i, a) in self.aligns.iter_mut().enumerate() {
            *a = if i == 0 { Align::Left } else { Align::Right };
        }
        self
    }

    /// Append a row (panics on arity mismatch — a malformed report is a bug).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Cell accessor used by tests that assert on regenerated tables.
    pub fn cell(&self, row: usize, col: usize) -> &str {
        &self.rows[row][col]
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// GitHub-flavored markdown rendering.
    pub fn markdown(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        out.push_str(&self.md_row(&self.headers, &w));
        out.push('\n');
        out.push('|');
        for (i, wi) in w.iter().enumerate() {
            match self.aligns[i] {
                Align::Left => out.push_str(&format!(" {:-<1$} |", "", *wi)),
                Align::Right => out.push_str(&format!(" {:-<1$}: |", "", wi.saturating_sub(1))),
            }
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&self.md_row(row, &w));
            out.push('\n');
        }
        out
    }

    fn md_row(&self, cells: &[String], w: &[usize]) -> String {
        let mut s = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            let pad = w[i].saturating_sub(c.chars().count());
            match self.aligns[i] {
                Align::Left => s.push_str(&format!(" {}{} |", c, " ".repeat(pad))),
                Align::Right => s.push_str(&format!(" {}{} |", " ".repeat(pad), c)),
            }
        }
        s
    }

    /// Plain aligned-text rendering (two-space gutters).
    pub fn plain(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    s.push_str("  ");
                }
                let pad = w[i].saturating_sub(c.chars().count());
                match self.aligns[i] {
                    Align::Left => {
                        s.push_str(c);
                        if i + 1 < cells.len() {
                            s.push_str(&" ".repeat(pad));
                        }
                    }
                    Align::Right => {
                        s.push_str(&" ".repeat(pad));
                        s.push_str(c);
                    }
                }
            }
            s
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len().saturating_sub(1))));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format a float with `prec` decimals, trimming to a stable width for
/// table cells (e.g. WNS values: `2.596`).
pub fn fnum(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new(vec!["IP", "LUTs", "DSPs"]).numeric();
        t.row(vec!["Conv_1", "105", "0"]);
        t.row(vec!["Conv_2", "30", "1"]);
        t
    }

    #[test]
    fn markdown_shape() {
        let md = sample().markdown();
        let lines: Vec<&str> = md.trim_end().lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("| IP"));
        assert!(lines[1].contains("---"));
        assert!(lines[2].contains("Conv_1"));
        // numeric columns right-aligned: "105" appears right-padded-left
        assert!(lines[2].contains(" 105 |"));
    }

    #[test]
    fn plain_alignment() {
        let p = sample().plain();
        let lines: Vec<&str> = p.lines().collect();
        // All data lines same width for right-aligned last col.
        assert!(lines[2].ends_with('0'));
        assert!(lines[3].ends_with('1'));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only one"]);
    }

    #[test]
    fn fnum_precision() {
        assert_eq!(fnum(2.5964, 3), "2.596");
        assert_eq!(fnum(0.5935, 3), "0.594"); // rounds
    }

    #[test]
    fn cell_access() {
        let t = sample();
        assert_eq!(t.cell(1, 1), "30");
        assert_eq!(t.n_rows(), 2);
    }
}
