//! Deterministic xorshift64* PRNG.
//!
//! Used for test-vector generation, the property harness, the synthetic
//! digit corpus, and toggle-activity stimulus in the power model. No
//! `rand` crate exists in this offline environment; xorshift64* passes the
//! statistical smoke tests we need (equidistribution over small moduli,
//! no short cycles) and is trivially reproducible from a seed.

/// xorshift64* generator. `Copy` is deliberately not derived: accidental
/// copies silently fork the stream, which makes test failures
/// irreproducible.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create from a seed. A zero seed is remapped (xorshift has a fixed
    /// point at 0).
    pub fn new(seed: u64) -> Self {
        Rng { state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed } }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform u32.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` (n > 0) via Lemire-style rejection to avoid
    /// modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % n;
            }
        }
    }

    /// Uniform usize in `[0, n)`.
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform i64 in the inclusive range `[lo, hi]`.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u128;
        let v = if span > u64::MAX as u128 { self.next_u64() as u128 } else { self.below(span as u64) as u128 };
        (lo as i128 + v as i128) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.unit_f64() < p
    }

    /// A signed value that fits in `bits` bits (two's complement),
    /// i.e. `[-2^(bits-1), 2^(bits-1)-1]`. This is the operand generator
    /// for the fixed-point IP tests.
    pub fn signed_bits(&mut self, bits: u32) -> i64 {
        assert!((1..=63).contains(&bits));
        let lo = -(1i64 << (bits - 1));
        let hi = (1i64 << (bits - 1)) - 1;
        self.range_i64(lo, hi)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork a child stream (for parallel workers needing independent
    /// deterministic streams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_ok() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(1);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit: {seen:?}");
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = Rng::new(2);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..5000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            saw_lo |= v == -3;
            saw_hi |= v == 3;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn signed_bits_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..2000 {
            let v = r.signed_bits(8);
            assert!((-128..=127).contains(&v));
        }
        // 1-bit: only -1 and 0.
        for _ in 0..50 {
            let v = r.signed_bits(1);
            assert!(v == -1 || v == 0);
        }
    }

    #[test]
    fn unit_f64_range_and_mean() {
        let mut r = Rng::new(4);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.unit_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..20).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn forked_streams_diverge() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
