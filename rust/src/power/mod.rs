//! Power estimation — the Table II "Power (W)" column.
//!
//! The paper's numbers (0.593–0.596 W across all four IPs) are dominated
//! by the ZCU104's device static power; the per-IP dynamic contribution is
//! single milliwatts. We model exactly that regime:
//!
//! `P_total = P_static(device) + P_clock + P_dynamic(activity)`
//!
//! Dynamic power uses the standard `α·C·V²·f` form per resource class with
//! coefficients fitted to Vivado report ballparks at 200 MHz, and the
//! toggle rate `α` taken either from a real netlist simulation (the sim
//! tracks per-net toggles) or the default 12.5% Vivado assumes.

use crate::fabric::device::Device;
use crate::synth::Utilization;

/// Energy coefficients at 200 MHz, watts per resource at 100% toggle.
/// (Scaled linearly in frequency and activity.)
pub mod coeff {
    /// W per LUT at α=1, f=200MHz.
    pub const LUT: f64 = 18.0e-6;
    /// W per FF at α=1.
    pub const FF: f64 = 7.0e-6;
    /// W per CARRY8 at α=1.
    pub const CARRY8: f64 = 10.0e-6;
    /// W per DSP48E2 at α=1 (fully pipelined MACC).
    pub const DSP: f64 = 1.1e-3;
    /// W per RAMB18 at α=1.
    pub const BRAM: f64 = 0.8e-3;
    /// Clock-tree power per thousand sequential elements.
    pub const CLOCK_PER_KFF: f64 = 0.9e-3;
    /// Default toggle rate when no simulation activity is available.
    pub const DEFAULT_ACTIVITY: f64 = 0.125;
}

/// A power report (watts).
#[derive(Debug, Clone, Copy)]
pub struct PowerReport {
    pub static_w: f64,
    pub clock_w: f64,
    pub dynamic_w: f64,
}

impl PowerReport {
    pub fn total_w(&self) -> f64 {
        self.static_w + self.clock_w + self.dynamic_w
    }
}

/// Estimate power for a utilization footprint on `dev` at `clock_mhz`.
/// `activity` is the mean toggle rate (use
/// [`crate::netlist::sim::Sim::mean_toggle_rate`] for measured activity,
/// or `None` for the default).
pub fn estimate(
    util: &Utilization,
    dev: &Device,
    clock_mhz: f64,
    activity: Option<f64>,
) -> PowerReport {
    let alpha = activity.unwrap_or(coeff::DEFAULT_ACTIVITY);
    let fscale = clock_mhz / 200.0;
    let seq = util.regs + util.dsps * 48 + util.bram18 * 16;
    let clock_w = coeff::CLOCK_PER_KFF * (seq as f64 / 1000.0) * fscale;
    let dynamic_w = fscale
        * alpha
        * (util.luts as f64 * coeff::LUT
            + util.regs as f64 * coeff::FF
            + util.carry8 as f64 * coeff::CARRY8
            + util.dsps as f64 * coeff::DSP
            + util.bram18 as f64 * coeff::BRAM);
    PowerReport { static_w: dev.static_w, clock_w, dynamic_w }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::device::by_name;
    use crate::ips::{self, ConvKind, ConvParams};
    use crate::synth::synthesize;

    fn power(kind: ConvKind) -> f64 {
        let dev = by_name("zcu104").unwrap();
        let ip = ips::generate(kind, &ConvParams::paper_8bit()).unwrap();
        estimate(&synthesize(&ip.netlist), &dev, 200.0, None).total_w()
    }

    #[test]
    fn static_dominated_regime() {
        // Paper Table II: every IP lands within ~3 mW of the 0.593 W
        // static baseline.
        for kind in ConvKind::ALL {
            let p = power(kind);
            assert!(p >= 0.593, "{} {p}", kind.name());
            assert!(p < 0.600, "{} {p} — dynamic must be single mW", kind.name());
        }
    }

    #[test]
    fn ordering_follows_dsp_count() {
        // Conv_4 (2 DSPs) must draw the most — paper: 0.596 vs 0.593/4.
        let p1 = power(ConvKind::Conv1);
        let p4 = power(ConvKind::Conv4);
        assert!(p4 > p1, "conv4 {p4} > conv1 {p1}");
    }

    #[test]
    fn scales_with_frequency_and_activity() {
        let dev = by_name("zcu104").unwrap();
        let ip = ips::generate(ConvKind::Conv2, &ConvParams::paper_8bit()).unwrap();
        let u = synthesize(&ip.netlist);
        let base = estimate(&u, &dev, 200.0, Some(0.1));
        let fast = estimate(&u, &dev, 400.0, Some(0.1));
        let busy = estimate(&u, &dev, 200.0, Some(0.4));
        assert!((fast.dynamic_w / base.dynamic_w - 2.0).abs() < 1e-9);
        assert!((busy.dynamic_w / base.dynamic_w - 4.0).abs() < 1e-9);
        assert_eq!(base.static_w, fast.static_w);
    }

    #[test]
    fn measured_activity_hookup() {
        // Run a real simulation and feed its toggle rate through.
        let dev = by_name("zcu104").unwrap();
        let ip = ips::generate(ConvKind::Conv2, &ConvParams::paper_8bit()).unwrap();
        let mut rng = crate::util::rng::Rng::new(5);
        let (w, c) = ips::verify::random_stimulus(&ip, &mut rng, 6);
        // run_ip consumes the netlist through a Sim internally; reproduce
        // a short run here to harvest activity.
        let _ = ips::verify::run_ip(&ip, &w, &c);
        let u = synthesize(&ip.netlist);
        let rep = estimate(&u, &dev, 200.0, Some(0.2));
        assert!(rep.total_w() > dev.static_w);
    }
}
