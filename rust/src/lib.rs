//! # adaptive-conv-fpga (`acf`)
//!
//! Reproduction of *"A Resource-Driven Approach for Implementing CNNs on
//! FPGAs Using Adaptive IPs"* (Magalhães, Fresse, Suffran, Alata — CS.AR
//! 2025) grown into a whole-network resource-driven compiler.
//!
//! The paper contributes a library of four parameterizable fixed-point
//! convolution IPs (`Conv_1..Conv_4`) whose selection *adapts to the FPGA
//! resources available*, and promises (conclusion) expanding the library
//! to pooling and activation functions. This crate delivers both through
//! a **unified engine registry**: every layer engine — the four conv IPs,
//! the serial FC MAC, the max-pool tree, and the ReLU gate — is an
//! [`ips::engine::EngineKind`] exposing the same `generate` /
//! `work_per_image` / `structural_cap` surface, and the planner runs one
//! uniform profile → select → budget loop over all of them. No layer
//! executes for free: pool and activation engines occupy real LUTs, meet
//! real timing, and can be the modeled bottleneck.
//!
//! Since no Vivado/ZCU104 testbed exists in this environment, the crate
//! builds the whole substrate:
//!
//! * [`fabric`] — UltraScale+ primitive models (LUT6, CARRY8, FDRE,
//!   DSP48E2, RAMB18) and a device catalog.
//! * [`netlist`] — structural netlists plus a bit-exact simulator (with
//!   O(1) pre-resolved port access for the verification hot loops).
//! * [`ips`] — netlist generators for all engines and the registry
//!   ([`ips::engine`]) the planner consumes.
//! * [`synth`], [`sta`], [`power`] — a Vivado-like reporting flow (CLB
//!   packing, static timing, power) that regenerates Table II.
//! * [`cnn`], [`planner`], [`coordinator`] — the headline feature: a
//!   resource-driven planner that assigns an engine + instance count to
//!   *every* layer under a device budget (memoized profiles, scarcity
//!   scoring, whole-network bottleneck search), then deploys the network
//!   on a *persistent* threaded pipeline — long-lived layer workers fed
//!   by bounded channels, shared by every caller — with per-layer
//!   metrics keyed off the plan.
//! * [`serve`] — the traffic-scale serving tier (`acf serve`): a fleet
//!   planner that replicates *several* networks across a *heterogeneous
//!   device catalog* (a model×device frontier assigns each part the
//!   model it serves fastest, with coverage repair; one replica group
//!   per part, each under divided budgets with per-replica coefficient
//!   BRAM charged off the top, memoized as a count → plan frontier), a
//!   request scheduler with quota-sharded bounded admission and
//!   weighted-fair `(tenant, model)` dispatch (`acf serve --models
//!   lenet-tiny:acme,lenet-wide-2x:bitworks`), per-replica micro-batch
//!   clamps, throughput-weighted replica selection, and a *dynamic*
//!   replica set, a live rebalance controller that grows/shrinks device
//!   groups under load from the memoized frontier (`acf serve
//!   --rebalance`), fleet metrics (per-tenant and fleet-wide
//!   p50/p95/p99 latency, shed rates vs quota, sustained throughput,
//!   per-replica and per-device-group utilization, drain summaries, the
//!   rebalance event log), and a deterministic open-loop / step-load
//!   synthetic traffic generator.
//! * [`trace`] — end-to-end request tracing: per-request span chains
//!   (admit → queue wait → batch form → dispatch → sim → reply), fleet
//!   events and per-pass settle attribution on one injectable [`trace::Clock`],
//!   a bounded ring [`trace::TraceSink`], and a Chrome trace-event
//!   exporter (`acf serve --trace out.json`, viewable in Perfetto).
//! * [`runtime`] — PJRT/XLA execution of the AOT-compiled JAX/Pallas
//!   model used as the golden numeric reference (behind the `xla` cargo
//!   feature; a same-surface stub otherwise).
//!
//! See `DESIGN.md` for the experiment index and substitution rationale.

pub mod cnn;
pub mod coordinator;
pub mod fabric;
pub mod fixed;
pub mod ips;
pub mod netlist;
pub mod planner;
pub mod power;
pub mod report;
#[cfg(feature = "xla")]
pub mod runtime;
#[cfg(not(feature = "xla"))]
#[path = "runtime/stub.rs"]
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod sta;
pub mod synth;
pub mod trace;
pub mod util;

/// Crate version string reported by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
