//! # adaptive-conv-fpga (`acf`)
//!
//! Reproduction of *"A Resource-Driven Approach for Implementing CNNs on
//! FPGAs Using Adaptive IPs"* (Magalhães, Fresse, Suffran, Alata — CS.AR
//! 2025) as a three-layer Rust + JAX + Pallas system.
//!
//! The paper contributes a library of four parameterizable fixed-point
//! convolution IPs (`Conv_1..Conv_4`) whose selection *adapts to the FPGA
//! resources available*. Since no Vivado/ZCU104 testbed exists in this
//! environment, this crate builds the whole substrate:
//!
//! * [`fabric`] — UltraScale+ primitive models (LUT6, CARRY8, FDRE,
//!   DSP48E2, RAMB18) and a device catalog.
//! * [`netlist`] — structural netlists plus a bit-exact simulator.
//! * [`ips`] — netlist generators for the paper's four convolution IPs and
//!   the future-work pooling/activation/FC IPs.
//! * [`synth`], [`sta`], [`power`] — a Vivado-like reporting flow (CLB
//!   packing, static timing, power) that regenerates Table II.
//! * [`cnn`], [`planner`], [`coordinator`] — the headline feature: a
//!   resource-driven planner that picks IP variants per CNN layer under a
//!   device budget, then deploys and simulates the network.
//! * [`runtime`] — PJRT/XLA execution of the AOT-compiled JAX/Pallas model
//!   (`artifacts/*.hlo.txt`) used as the golden numeric reference.
//!
//! See `DESIGN.md` for the experiment index and substitution rationale.

pub mod cnn;
pub mod coordinator;
pub mod fabric;
pub mod fixed;
pub mod ips;
pub mod netlist;
pub mod planner;
pub mod power;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod sta;
pub mod synth;
pub mod util;

/// Crate version string reported by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// CLI entry (placeholder; fleshed out in `report`/`main`).
pub fn cli_main() {
    println!("acf {VERSION}");
}
