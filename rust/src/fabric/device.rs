//! FPGA part catalog — the resource inventories the planner budgets
//! against.
//!
//! The paper's board is the ZCU104 (XCZU7EV-2FFVC1156). The catalog also
//! carries smaller and larger Zynq UltraScale+ parts so the adaptation
//! sweeps (Table III / Sweep-A in DESIGN.md) can show how the IP mix
//! shifts across resource envelopes. Inventories follow the public Xilinx
//! product tables. Custom parts can be loaded from JSON for what-if
//! studies.

use crate::util::json::{Json, JsonError};

/// Resource inventory of one part.
#[derive(Debug, Clone, PartialEq)]
pub struct Device {
    pub name: String,
    pub part: String,
    pub luts: u64,
    pub ffs: u64,
    pub clbs: u64,
    pub dsps: u64,
    pub bram18: u64,
    /// Device static power at nominal conditions (W) — dominates the
    /// paper's Table II power column.
    pub static_w: f64,
    /// Speed-grade derating multiplier applied to the delay model
    /// (1.0 = the -2 grade the ZCU104 ships).
    pub speed_derate: f64,
}

impl Device {
    /// Fraction of DSPs a `need` would consume (for utilization reports).
    pub fn dsp_util(&self, need: u64) -> f64 {
        need as f64 / self.dsps.max(1) as f64
    }

    pub fn lut_util(&self, need: u64) -> f64 {
        need as f64 / self.luts.max(1) as f64
    }

    /// An equal `1/n` slice of this part's resources — the budget one
    /// replica of an `n`-replica serving fleet may spend. The planner runs
    /// unchanged against the slice (resource-driven replication: the
    /// paper's scarcity logic lifted one level up); `n` shards always fit
    /// the whole device because each capacity is floor-divided. Static
    /// power is split too so per-replica power reports stay meaningful;
    /// the speed grade is a property of the silicon and is not divided.
    /// The degenerate `shard(1)` is the whole part and keeps its name, so
    /// single-replica fleet reports and plan memo keys don't churn.
    pub fn shard(&self, n: u64) -> Device {
        let n = n.max(1);
        Device {
            name: if n == 1 { self.name.clone() } else { format!("{}/{n}", self.name) },
            part: self.part.clone(),
            luts: self.luts / n,
            ffs: self.ffs / n,
            clbs: self.clbs / n,
            dsps: self.dsps / n,
            bram18: self.bram18 / n,
            static_w: self.static_w / n as f64,
            speed_derate: self.speed_derate,
        }
    }

    /// Serialize for config round-trips.
    pub fn to_json(&self) -> Json {
        crate::util::json::obj([
            ("name", self.name.as_str().into()),
            ("part", self.part.as_str().into()),
            ("luts", self.luts.into()),
            ("ffs", self.ffs.into()),
            ("clbs", self.clbs.into()),
            ("dsps", self.dsps.into()),
            ("bram18", self.bram18.into()),
            ("static_w", Json::Num(self.static_w)),
            ("speed_derate", Json::Num(self.speed_derate)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Device, JsonError> {
        Ok(Device {
            name: v.get("name")?.as_str()?.to_string(),
            part: v.get("part")?.as_str()?.to_string(),
            luts: v.get("luts")?.as_u64()?,
            ffs: v.get("ffs")?.as_u64()?,
            clbs: v.get("clbs")?.as_u64()?,
            dsps: v.get("dsps")?.as_u64()?,
            bram18: v.get("bram18")?.as_u64()?,
            static_w: v.get("static_w")?.as_f64()?,
            speed_derate: v.get_opt("speed_derate")?.map(|j| j.as_f64()).transpose()?.unwrap_or(1.0),
        })
    }
}

fn dev(
    name: &str,
    part: &str,
    luts: u64,
    dsps: u64,
    bram18: u64,
    static_w: f64,
    speed_derate: f64,
) -> Device {
    Device {
        name: name.into(),
        part: part.into(),
        luts,
        ffs: luts * 2,
        clbs: luts / 8, // UltraScale+ CLB = 8 LUT6 + 16 FF
        dsps,
        bram18,
        static_w,
        speed_derate,
    }
}

/// Built-in catalog. First entry is the paper's board.
pub fn catalog() -> Vec<Device> {
    vec![
        // The paper's testbed: ZCU104 carries an XCZU7EV-2FFVC1156.
        dev("zcu104", "xczu7ev-2ffvc1156", 230_400, 1_728, 624, 0.593, 1.0),
        // Smaller siblings for the adaptation sweep.
        dev("zu2cg", "xczu2cg-1sbva484", 47_232, 240, 300, 0.28, 1.12),
        dev("zu3eg", "xczu3eg-1sbva484", 70_560, 360, 432, 0.32, 1.12),
        dev("zu5ev", "xczu5ev-1sfvc784", 117_120, 1_248, 288, 0.45, 1.12),
        // Larger sibling.
        dev("zu9eg", "xczu9eg-2ffvb1156", 274_080, 2_520, 1_824, 0.72, 1.0),
        // A deliberately DSP-starved profile (e.g. DSPs consumed by other
        // tenants of the shell) to exercise Conv_1 selection; the paper's
        // motivation — "suitable for FPGAs with limited DSPs".
        dev("edge-nodsp", "hypothetical-dsp-starved", 20_000, 4, 60, 0.15, 1.25),
    ]
}

/// Look up a part by name (case-insensitive).
pub fn by_name(name: &str) -> Option<Device> {
    let lower = name.to_ascii_lowercase();
    catalog().into_iter().find(|d| d.name == lower || d.part == lower)
}

/// Load extra devices from a JSON array file (config-system entry point).
pub fn load_catalog(json_text: &str) -> Result<Vec<Device>, JsonError> {
    Json::parse(json_text)?.as_arr()?.iter().map(Device::from_json).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zcu104_inventory() {
        let d = by_name("zcu104").unwrap();
        assert_eq!(d.luts, 230_400);
        assert_eq!(d.dsps, 1_728);
        assert_eq!(d.clbs, 28_800);
        assert!((d.static_w - 0.593).abs() < 1e-9);
    }

    #[test]
    fn lookup_by_part_number() {
        assert!(by_name("XCZU7EV-2FFVC1156").is_some());
        assert!(by_name("nonexistent").is_none());
    }

    #[test]
    fn catalog_spans_resource_range() {
        let c = catalog();
        let min_dsp = c.iter().map(|d| d.dsps).min().unwrap();
        let max_dsp = c.iter().map(|d| d.dsps).max().unwrap();
        assert!(min_dsp < 10, "need a DSP-starved part for Conv_1 scenarios");
        assert!(max_dsp > 2000, "need a DSP-rich part for Conv_4 scenarios");
    }

    #[test]
    fn json_roundtrip() {
        for d in catalog() {
            let j = d.to_json();
            let back = Device::from_json(&j).unwrap();
            assert_eq!(back, d);
        }
    }

    #[test]
    fn json_roundtrip_survives_serialized_text() {
        // Property over the built-in catalog: to_json → render → parse →
        // from_json is the identity, including shards (what `--catalog`
        // files and fleet reports actually round-trip through).
        for d in catalog() {
            for n in [1u64, 2, 3, 7] {
                let s = d.shard(n);
                let text = s.to_json().to_string();
                let back = Device::from_json(&Json::parse(&text).unwrap()).unwrap();
                assert_eq!(back, s, "{} shard({n})", d.name);
            }
        }
    }

    #[test]
    fn load_catalog_from_text() {
        let text = r#"[{"name":"custom","part":"x1","luts":1000,"ffs":2000,"clbs":125,
                        "dsps":8,"bram18":4,"static_w":0.1,"speed_derate":1.3}]"#;
        let devs = load_catalog(text).unwrap();
        assert_eq!(devs.len(), 1);
        assert_eq!(devs[0].dsps, 8);
        assert!((devs[0].speed_derate - 1.3).abs() < 1e-12);
    }

    #[test]
    fn load_catalog_error_paths() {
        // Non-array root.
        let e = load_catalog(r#"{"name":"x"}"#).unwrap_err();
        assert!(e.to_string().contains("array"), "{e}");
        // Missing field: the error names the absent key.
        let e = load_catalog(r#"[{"name":"x","part":"p","luts":10,"ffs":20,"clbs":2,"dsps":1,"bram18":1}]"#)
            .unwrap_err();
        assert!(e.to_string().contains("static_w"), "{e}");
        // Wrong type for a numeric field.
        let e = load_catalog(
            r#"[{"name":"x","part":"p","luts":"many","ffs":20,"clbs":2,"dsps":1,"bram18":1,"static_w":0.1}]"#,
        )
        .unwrap_err();
        assert!(e.to_string().contains("number"), "{e}");
        // Not JSON at all.
        assert!(load_catalog("not json").is_err());
        // Empty array is a valid (empty) catalog.
        assert_eq!(load_catalog("[]").unwrap().len(), 0);
    }

    #[test]
    fn by_name_mixed_case_hit_and_miss() {
        // Lookups are case-insensitive over both the short name and the
        // full part string.
        for q in ["zcu104", "ZCU104", "ZcU104", "xCZU7Ev-2ffVC1156"] {
            assert_eq!(by_name(q).unwrap().name, "zcu104", "query '{q}'");
        }
        for q in ["zcu104x", "xczu7ev", "", " zcu104"] {
            assert!(by_name(q).is_none(), "query '{q}' must miss");
        }
    }

    #[test]
    fn shard_one_keeps_name_and_budget() {
        let d = by_name("zcu104").unwrap();
        let s1 = d.shard(1);
        assert_eq!(s1, d, "shard(1) is the whole part, name included");
        let s3 = d.shard(3);
        assert_eq!(s3.name, "zcu104/3");
        assert_eq!(s3.luts, d.luts / 3);
        assert!((s3.static_w - d.static_w / 3.0).abs() < 1e-12);
        assert_eq!(s3.speed_derate, d.speed_derate);
    }

    #[test]
    fn utilization_math() {
        let d = by_name("zcu104").unwrap();
        assert!((d.dsp_util(1728) - 1.0).abs() < 1e-12);
        assert!((d.lut_util(2304) - 0.01).abs() < 1e-12);
    }
}
