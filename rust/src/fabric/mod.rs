//! UltraScale+ fabric primitive models and the device catalog.
//!
//! These are the "atoms" the IP generators instantiate and the synthesis
//! flow counts — the same post-mapping primitives a structural VHDL design
//! pins down in Vivado:
//!
//! * [`lut::Lut`] — LUT6 truth-table function generator.
//! * [`ff`] — FDRE D flip-flop semantics.
//! * [`carry::Carry8`] — the CLB carry chain (adders/subtractors).
//! * [`dsp48::Dsp48e2`] — the DSP48E2 slice: pre-adder, 27×18 multiplier,
//!   48-bit ALU/accumulator, pipeline registers.
//! * [`bram`] — RAMB18 simple-dual-port memory (line buffers).
//! * [`device`] — the part catalog (ZCU104's XCZU7EV and siblings) with
//!   resource inventories the planner budgets against.
//!
//! Behavioral evaluation lives here; *timing* numbers live in
//! [`crate::sta::delay_model`] and *power* numbers in [`crate::power`] so
//! that calibration is centralized.

pub mod bram;
pub mod carry;
pub mod device;
pub mod dsp48;
pub mod ff;
pub mod lut;

/// Kinds of fabric primitives — the census axis for resource reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Prim {
    Lut,
    Ff,
    Carry8,
    Dsp48e2,
    Ramb18,
}

impl Prim {
    pub fn name(&self) -> &'static str {
        match self {
            Prim::Lut => "LUT",
            Prim::Ff => "FF",
            Prim::Carry8 => "CARRY8",
            Prim::Dsp48e2 => "DSP48E2",
            Prim::Ramb18 => "RAMB18",
        }
    }
}
