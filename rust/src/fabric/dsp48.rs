//! DSP48E2 slice model (UltraScale+).
//!
//! Models the subset the convolution IPs configure: the 27-bit pre-adder
//! (`AD = D + A`), the 27×18 signed multiplier, the 48-bit ALU with the
//! `Z` multiplexer (0 / P / C) for accumulate-or-load, and the pipeline
//! registers (`AREG/BREG/DREG`, `ADREG`, `MREG`, `PREG`). All datapaths
//! wrap in two's complement at their port widths — saturation, when the
//! IPs need it, is fabric logic *around* the slice, as on real hardware.
//!
//! `Conv_2` uses one slice in MACC mode (`Z=P`); `Conv_3` feeds packed
//! dual-pixel operands through the same mode (see [`crate::fixed::pack`]);
//! `Conv_4` instantiates two slices side by side.

use crate::fixed::pack::sign_extend;

/// Z-multiplexer selection — what the ALU adds the product to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ZMux {
    /// `P' = M` — start a fresh accumulation.
    Zero,
    /// `P' = P + M` — multiply-accumulate.
    P,
    /// `P' = C + M` — load C (bias/rounding constant injection).
    C,
}

/// Static configuration (pipeline depth).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Config {
    /// Input registers on A/B/D.
    pub input_reg: bool,
    /// Pre-adder output register.
    pub adreg: bool,
    /// Multiplier output register.
    pub mreg: bool,
    /// Accumulator/output register (always true in our IPs).
    pub preg: bool,
    /// Use the D-port pre-adder (`AD = D + A`); otherwise `AD = A`.
    pub use_dport: bool,
}

impl Config {
    /// Fully pipelined MACC configuration — what the IP generators use for
    /// 200 MHz closure (matches Vivado guidance: all pipeline stages on).
    pub fn full_macc(use_dport: bool) -> Config {
        Config { input_reg: true, adreg: use_dport, mreg: true, preg: true, use_dport }
    }

    /// Cycles from operand presentation to P reflecting them.
    pub fn latency(&self) -> u32 {
        self.input_reg as u32 + self.adreg as u32 + self.mreg as u32 + self.preg as u32
    }
}

/// Per-cycle inputs.
#[derive(Debug, Clone, Copy)]
pub struct Inputs {
    pub a: i64,
    pub b: i64,
    pub c: i64,
    pub d: i64,
    pub zmux: ZMux,
    /// Clock-enable for the whole slice (stalls hold state).
    pub ce: bool,
}

impl Inputs {
    pub fn mac(a: i64, b: i64, first: bool) -> Inputs {
        Inputs { a, b, c: 0, d: 0, zmux: if first { ZMux::Zero } else { ZMux::P }, ce: true }
    }
}

/// Dynamic state: pipeline registers.
#[derive(Debug, Clone)]
pub struct Dsp48e2 {
    pub cfg: Config,
    a_r: i64,
    b_r: i64,
    d_r: i64,
    z_r: ZMux,
    ad_r: i64,
    b2_r: i64,
    z2_r: ZMux,
    c_r: i64,
    m_r: i64,
    zm_r: ZMux,
    cm_r: i64,
    p: i64,
}

/// Port widths.
pub const A_BITS: u32 = 27;
pub const B_BITS: u32 = 18;
pub const C_BITS: u32 = 48;
pub const D_BITS: u32 = 27;
pub const P_BITS: u32 = 48;

fn wrap_to(v: i64, bits: u32) -> i64 {
    sign_extend(v & mask(bits), bits)
}

fn mask(bits: u32) -> i64 {
    if bits >= 64 {
        -1
    } else {
        (1i64 << bits) - 1
    }
}

impl Dsp48e2 {
    pub fn new(cfg: Config) -> Self {
        Dsp48e2 {
            cfg,
            a_r: 0,
            b_r: 0,
            d_r: 0,
            z_r: ZMux::Zero,
            ad_r: 0,
            b2_r: 0,
            z2_r: ZMux::Zero,
            c_r: 0,
            m_r: 0,
            zm_r: ZMux::Zero,
            cm_r: 0,
            p: 0,
        }
    }

    /// Current registered output.
    pub fn p(&self) -> i64 {
        self.p
    }

    /// Advance one clock. Returns the post-edge P value.
    pub fn clock(&mut self, inp: Inputs) -> i64 {
        if !inp.ce {
            return self.p;
        }
        // Wrap inputs at port widths (hardware truncation).
        let a_in = wrap_to(inp.a, A_BITS);
        let b_in = wrap_to(inp.b, B_BITS);
        let c_in = wrap_to(inp.c, C_BITS);
        let d_in = wrap_to(inp.d, D_BITS);

        // Stage values *feeding* each register this cycle (pre-edge).
        let (a_s, b_s, d_s, z_s) = if self.cfg.input_reg {
            (self.a_r, self.b_r, self.d_r, self.z_r)
        } else {
            (a_in, b_in, d_in, inp.zmux)
        };
        let ad_comb = if self.cfg.use_dport { wrap_to(d_s + a_s, D_BITS) } else { a_s };
        let (ad_s, b2_s, z2_s) =
            if self.cfg.adreg { (self.ad_r, self.b2_r, self.z2_r) } else { (ad_comb, b_s, z_s) };
        let m_comb = ad_s * b2_s; // 27x18 -> 45 bits, fits i64
        let (m_s, zm_s, cm_s) =
            if self.cfg.mreg { (self.m_r, self.zm_r, self.cm_r) } else { (m_comb, z2_s, self.c_pipe(c_in)) };
        let z_val = match zm_s {
            ZMux::Zero => 0,
            ZMux::P => self.p,
            ZMux::C => cm_s,
        };
        let p_next = wrap_to(z_val + m_s, P_BITS);

        // Commit the edge (reverse order irrelevant now that stage inputs
        // are snapshotted above).
        if self.cfg.preg {
            self.p = p_next;
        } else {
            self.p = p_next; // modelled identically; PREG=0 unused by IPs
        }
        if self.cfg.mreg {
            self.m_r = m_comb;
            self.zm_r = z2_s;
            self.cm_r = self.c_pipe(c_in);
        }
        if self.cfg.adreg {
            self.ad_r = ad_comb;
            self.b2_r = b_s;
            self.z2_r = z_s;
        }
        if self.cfg.input_reg {
            self.a_r = a_in;
            self.b_r = b_in;
            self.d_r = d_in;
            self.z_r = inp.zmux;
            self.c_r = c_in;
        }
        self.p
    }

    fn c_pipe(&self, c_in: i64) -> i64 {
        if self.cfg.input_reg {
            self.c_r
        } else {
            c_in
        }
    }

    /// Reset all registers (RSTP/RSTM/... asserted together).
    pub fn reset(&mut self) {
        let cfg = self.cfg;
        *self = Dsp48e2::new(cfg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Drive a MACC over `pairs`, flushing the pipeline, and return the
    /// final accumulator.
    fn run_macc(dsp: &mut Dsp48e2, pairs: &[(i64, i64)]) -> i64 {
        for (i, &(a, b)) in pairs.iter().enumerate() {
            dsp.clock(Inputs::mac(a, b, i == 0));
        }
        // Flush: hold ZMux::P with zero operands for `latency` cycles.
        for _ in 0..dsp.cfg.latency() {
            dsp.clock(Inputs { a: 0, b: 0, c: 0, d: 0, zmux: ZMux::P, ce: true });
        }
        dsp.p()
    }

    #[test]
    fn single_multiply_zero_mode() {
        let mut d = Dsp48e2::new(Config::full_macc(false));
        let p = run_macc(&mut d, &[(123, -45)]);
        assert_eq!(p, 123 * -45);
    }

    #[test]
    fn macc_accumulates_window() {
        let mut d = Dsp48e2::new(Config::full_macc(false));
        let pairs: Vec<(i64, i64)> = (1..=9).map(|i| (i, 10 - i)).collect();
        let want: i64 = pairs.iter().map(|&(a, b)| a * b).sum();
        assert_eq!(run_macc(&mut d, &pairs), want);
    }

    #[test]
    fn latency_matches_config() {
        assert_eq!(Config::full_macc(false).latency(), 3);
        assert_eq!(Config::full_macc(true).latency(), 4);
        let comb = Config { input_reg: false, adreg: false, mreg: false, preg: true, use_dport: false };
        assert_eq!(comb.latency(), 1);
    }

    #[test]
    fn preadder_sums_d_and_a() {
        let cfg = Config::full_macc(true);
        let mut d = Dsp48e2::new(cfg);
        // (D + A) * B = (100 + 23) * 7
        for i in 0..1 + cfg.latency() {
            d.clock(Inputs {
                a: 23,
                b: 7,
                c: 0,
                d: 100,
                zmux: if i == 0 { ZMux::Zero } else { ZMux::P },
                ce: true,
            });
        }
        // After first op retires, further flushes add 123*7 again unless
        // operands are zeroed — so check directly at retirement:
        let mut d2 = Dsp48e2::new(cfg);
        d2.clock(Inputs { a: 23, b: 7, c: 0, d: 100, zmux: ZMux::Zero, ce: true });
        for _ in 0..cfg.latency() {
            d2.clock(Inputs { a: 0, b: 0, c: 0, d: 0, zmux: ZMux::P, ce: true });
        }
        assert_eq!(d2.p(), 123 * 7);
    }

    #[test]
    fn c_load_mode() {
        // P = C + M with C used as a rounding/bias constant.
        let cfg = Config::full_macc(false);
        let mut d = Dsp48e2::new(cfg);
        d.clock(Inputs { a: 5, b: 6, c: 1000, d: 0, zmux: ZMux::C, ce: true });
        // Flush in accumulate mode with zero operands so the retired C+M
        // result is preserved (flushing in C mode would reload C).
        for _ in 0..cfg.latency() {
            d.clock(Inputs { a: 0, b: 0, c: 0, d: 0, zmux: ZMux::P, ce: true });
        }
        assert_eq!(d.p(), 1030);
    }

    #[test]
    fn ce_stalls_hold_state() {
        let cfg = Config::full_macc(false);
        let mut d = Dsp48e2::new(cfg);
        d.clock(Inputs::mac(7, 8, true));
        let snap = d.clone();
        for _ in 0..5 {
            d.clock(Inputs { a: 99, b: 99, c: 0, d: 0, zmux: ZMux::P, ce: false });
        }
        assert_eq!(d.p(), snap.p());
        // Resume: pipeline continues as if no stall occurred.
        for _ in 0..cfg.latency() {
            d.clock(Inputs { a: 0, b: 0, c: 0, d: 0, zmux: ZMux::P, ce: true });
        }
        assert_eq!(d.p(), 56);
    }

    #[test]
    fn port_wrap_at_18_bits() {
        // B port wraps two's-complement at 18 bits: 2^17 -> -2^17.
        let mut d = Dsp48e2::new(Config::full_macc(false));
        let p = run_macc(&mut d, &[(1, 1 << 17)]);
        assert_eq!(p, -(1 << 17));
    }

    #[test]
    fn accumulator_wraps_at_48_bits() {
        let cfg = Config { input_reg: false, adreg: false, mreg: false, preg: true, use_dport: false };
        let mut d = Dsp48e2::new(cfg);
        // Repeatedly add the max product until wrap.
        let big = (1i64 << 26) - 1;
        let bigb = (1i64 << 17) - 1;
        let step = big * bigb;
        let mut model = 0i64;
        let mut first = true;
        for _ in 0..3000 {
            d.clock(Inputs { a: big, b: bigb, c: 0, d: 0, zmux: if first { ZMux::Zero } else { ZMux::P }, ce: true });
            model = if first { step } else { super::wrap_to(model + step, 48) };
            first = false;
        }
        assert_eq!(d.p(), model);
        assert!(model.abs() < (1i64 << 47));
    }

    #[test]
    fn random_macc_vs_integer_model() {
        let mut rng = Rng::new(42);
        for trial in 0..200 {
            let n = 1 + rng.index(12);
            let pairs: Vec<(i64, i64)> =
                (0..n).map(|_| (rng.signed_bits(27.min(20)), rng.signed_bits(18))).collect();
            let want: i64 = pairs.iter().map(|&(a, b)| a * b).sum();
            let mut d = Dsp48e2::new(Config::full_macc(false));
            assert_eq!(run_macc(&mut d, &pairs), want, "trial {trial}");
        }
    }

    #[test]
    fn conv3_packed_macc_through_dsp() {
        // End-to-end: the fixed::pack math flowing through the slice model.
        use crate::fixed::pack;
        let packing = pack::feasible(8, 8, 9).unwrap();
        let mut rng = Rng::new(7);
        for _ in 0..100 {
            let a1: Vec<i64> = (0..9).map(|_| packing.clamp_high(rng.signed_bits(8))).collect();
            let a2: Vec<i64> = (0..9).map(|_| rng.signed_bits(8)).collect();
            let b: Vec<i64> = (0..9).map(|_| rng.signed_bits(8)).collect();
            let mut d = Dsp48e2::new(Config::full_macc(false));
            let pairs: Vec<(i64, i64)> =
                (0..9).map(|i| (packing.pack(a1[i], a2[i]), b[i])).collect();
            let acc = run_macc(&mut d, &pairs);
            let (h, l) = packing.split(acc);
            assert_eq!(h, (0..9).map(|i| a1[i] * b[i]).sum::<i64>());
            assert_eq!(l, (0..9).map(|i| a2[i] * b[i]).sum::<i64>());
        }
    }
}
