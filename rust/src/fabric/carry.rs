//! CARRY8 — the UltraScale+ CLB carry chain.
//!
//! Eight cascaded carry-mux stages. Stage *i* takes a *propagate* bit
//! `S[i]` (from a LUT, usually `a XOR b`) and a *generate/DI* bit `DI[i]`
//! (usually `a`), producing
//!
//! ```text
//!   O[i]  = S[i] XOR C[i]                 (sum output)
//!   C[i+1] = S[i] ? C[i] : DI[i]          (carry mux)
//! ```
//!
//! which is exactly a ripple-carry adder with single-LUT-per-bit cost —
//! the reason FPGA adders are cheap and the paper's `Conv_1` logic
//! multiplier is viable at all. One CARRY8 covers 8 bits; wider adders
//! cascade via `CO[7] → CI`.

/// Number of stages in one CARRY8 primitive.
pub const CARRY8_WIDTH: usize = 8;

/// Evaluate one CARRY8: returns (O[0..8], CO[0..8]).
/// `s` and `di` are packed bit vectors (bit i = stage i), `ci` the carry-in.
pub fn carry8_eval(s: u8, di: u8, ci: bool) -> (u8, u8) {
    let mut o = 0u8;
    let mut co = 0u8;
    let mut c = ci;
    for i in 0..CARRY8_WIDTH {
        let si = (s >> i) & 1 == 1;
        let dii = (di >> i) & 1 == 1;
        if si ^ c {
            o |= 1 << i;
        }
        c = if si { c } else { dii };
        if c {
            co |= 1 << i;
        }
    }
    (o, co)
}

/// Lane-parallel CARRY8: evaluate all 64 simulator lanes at once. Each
/// element of `s`/`di` is a *lane word* (bit *l* = that stage's input in
/// lane *l*), `ci` likewise; the eight stages ripple with pure bitwise
/// ops, so one call does the work of 64 scalar [`carry8_eval`]s.
pub fn carry8_eval_lanes(s: &[u64; 8], di: &[u64; 8], ci: u64) -> ([u64; 8], [u64; 8]) {
    let mut o = [0u64; 8];
    let mut co = [0u64; 8];
    let mut c = ci;
    for i in 0..CARRY8_WIDTH {
        o[i] = s[i] ^ c;
        c = (s[i] & c) | (!s[i] & di[i]);
        co[i] = c;
    }
    (o, co)
}

/// Number of CARRY8 primitives needed for a `bits`-wide adder.
pub fn carry8_count(bits: u32) -> u32 {
    bits.div_ceil(CARRY8_WIDTH as u32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    /// Reference: build an 8-bit adder from the carry chain and check
    /// against integer addition. S = a^b, DI = a.
    fn add8(a: u8, b: u8, cin: bool) -> (u8, bool) {
        let s = a ^ b;
        let (o, co) = carry8_eval(s, a, cin);
        (o, (co >> 7) & 1 == 1)
    }

    #[test]
    fn adder_exhaustive_corners() {
        for (a, b, c) in [(0u8, 0u8, false), (255, 1, false), (255, 255, true), (170, 85, false), (1, 2, true)] {
            let (sum, cout) = add8(a, b, c);
            let want = a as u16 + b as u16 + c as u16;
            assert_eq!(sum as u16, want & 0xFF, "a={a} b={b} c={c}");
            assert_eq!(cout, want > 0xFF, "a={a} b={b} c={c}");
        }
    }

    #[test]
    fn prop_adder_matches_integer_add() {
        forall("carry8 adder == +", 500, |g| {
            let a = g.i64_in(0, 255) as u8;
            let b = g.i64_in(0, 255) as u8;
            let c = g.bool();
            let (sum, cout) = add8(a, b, c);
            let want = a as u16 + b as u16 + c as u16;
            if sum as u16 == (want & 0xFF) && cout == (want > 0xFF) {
                Ok(())
            } else {
                Err(format!("a={a} b={b} cin={c}"))
            }
        });
    }

    #[test]
    fn carry_mux_semantics() {
        // S=0 everywhere: carries come from DI, outputs = carry-in chain.
        let (o, co) = carry8_eval(0x00, 0xFF, false);
        assert_eq!(co, 0xFF); // every stage generates
        assert_eq!(o, 0xFE); // stage 0 sees ci=0, others see 1
    }

    #[test]
    fn prop_lane_eval_matches_scalar_per_lane() {
        forall("carry8 lanes == scalar/lane", 300, |g| {
            let lanes = g.usize_in(1, 64);
            // Per-lane scalar stimuli, packed into lane words.
            let mut s = [0u64; 8];
            let mut di = [0u64; 8];
            let mut ci = 0u64;
            let mut scalars = Vec::with_capacity(lanes);
            for lane in 0..lanes {
                let sv = g.i64_in(0, 255) as u8;
                let dv = g.i64_in(0, 255) as u8;
                let cv = g.bool();
                for stage in 0..8 {
                    s[stage] |= (((sv >> stage) & 1) as u64) << lane;
                    di[stage] |= (((dv >> stage) & 1) as u64) << lane;
                }
                ci |= (cv as u64) << lane;
                scalars.push((sv, dv, cv));
            }
            let (o, co) = carry8_eval_lanes(&s, &di, ci);
            for (lane, &(sv, dv, cv)) in scalars.iter().enumerate() {
                let (ow, cow) = carry8_eval(sv, dv, cv);
                let ol = (0..8).fold(0u8, |a, i| a | ((((o[i] >> lane) & 1) as u8) << i));
                let col = (0..8).fold(0u8, |a, i| a | ((((co[i] >> lane) & 1) as u8) << i));
                if ol != ow || col != cow {
                    return Err(format!("lane {lane}: s={sv:#x} di={dv:#x} ci={cv}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn count() {
        assert_eq!(carry8_count(8), 1);
        assert_eq!(carry8_count(9), 2);
        assert_eq!(carry8_count(20), 3);
        assert_eq!(carry8_count(1), 1);
    }
}
