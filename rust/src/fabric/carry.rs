//! CARRY8 — the UltraScale+ CLB carry chain.
//!
//! Eight cascaded carry-mux stages. Stage *i* takes a *propagate* bit
//! `S[i]` (from a LUT, usually `a XOR b`) and a *generate/DI* bit `DI[i]`
//! (usually `a`), producing
//!
//! ```text
//!   O[i]  = S[i] XOR C[i]                 (sum output)
//!   C[i+1] = S[i] ? C[i] : DI[i]          (carry mux)
//! ```
//!
//! which is exactly a ripple-carry adder with single-LUT-per-bit cost —
//! the reason FPGA adders are cheap and the paper's `Conv_1` logic
//! multiplier is viable at all. One CARRY8 covers 8 bits; wider adders
//! cascade via `CO[7] → CI`.

/// Number of stages in one CARRY8 primitive.
pub const CARRY8_WIDTH: usize = 8;

/// Evaluate one CARRY8: returns (O[0..8], CO[0..8]).
/// `s` and `di` are packed bit vectors (bit i = stage i), `ci` the carry-in.
pub fn carry8_eval(s: u8, di: u8, ci: bool) -> (u8, u8) {
    let mut o = 0u8;
    let mut co = 0u8;
    let mut c = ci;
    for i in 0..CARRY8_WIDTH {
        let si = (s >> i) & 1 == 1;
        let dii = (di >> i) & 1 == 1;
        if si ^ c {
            o |= 1 << i;
        }
        c = if si { c } else { dii };
        if c {
            co |= 1 << i;
        }
    }
    (o, co)
}

/// Number of CARRY8 primitives needed for a `bits`-wide adder.
pub fn carry8_count(bits: u32) -> u32 {
    bits.div_ceil(CARRY8_WIDTH as u32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    /// Reference: build an 8-bit adder from the carry chain and check
    /// against integer addition. S = a^b, DI = a.
    fn add8(a: u8, b: u8, cin: bool) -> (u8, bool) {
        let s = a ^ b;
        let (o, co) = carry8_eval(s, a, cin);
        (o, (co >> 7) & 1 == 1)
    }

    #[test]
    fn adder_exhaustive_corners() {
        for (a, b, c) in [(0u8, 0u8, false), (255, 1, false), (255, 255, true), (170, 85, false), (1, 2, true)] {
            let (sum, cout) = add8(a, b, c);
            let want = a as u16 + b as u16 + c as u16;
            assert_eq!(sum as u16, want & 0xFF, "a={a} b={b} c={c}");
            assert_eq!(cout, want > 0xFF, "a={a} b={b} c={c}");
        }
    }

    #[test]
    fn prop_adder_matches_integer_add() {
        forall("carry8 adder == +", 500, |g| {
            let a = g.i64_in(0, 255) as u8;
            let b = g.i64_in(0, 255) as u8;
            let c = g.bool();
            let (sum, cout) = add8(a, b, c);
            let want = a as u16 + b as u16 + c as u16;
            if sum as u16 == (want & 0xFF) && cout == (want > 0xFF) {
                Ok(())
            } else {
                Err(format!("a={a} b={b} cin={c}"))
            }
        });
    }

    #[test]
    fn carry_mux_semantics() {
        // S=0 everywhere: carries come from DI, outputs = carry-in chain.
        let (o, co) = carry8_eval(0x00, 0xFF, false);
        assert_eq!(co, 0xFF); // every stage generates
        assert_eq!(o, 0xFE); // stage 0 sees ci=0, others see 1
    }

    #[test]
    fn count() {
        assert_eq!(carry8_count(8), 1);
        assert_eq!(carry8_count(9), 2);
        assert_eq!(carry8_count(20), 3);
        assert_eq!(carry8_count(1), 1);
    }
}
