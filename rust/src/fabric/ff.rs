//! FDRE — D flip-flop with clock-enable and synchronous reset.
//!
//! The only sequential bit-element the IP generators use. Semantics on the
//! rising clock edge: `R` (sync reset) wins, then `CE` gates the load.

/// One FDRE evaluation step. Returns the next Q given current inputs.
#[inline]
pub fn fdre_next(q: bool, d: bool, ce: bool, r: bool) -> bool {
    if r {
        false
    } else if ce {
        d
    } else {
        q
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_wins() {
        assert!(!fdre_next(true, true, true, true));
        assert!(!fdre_next(false, true, false, true));
    }

    #[test]
    fn ce_gates() {
        assert!(fdre_next(false, true, true, false));
        assert!(!fdre_next(false, true, false, false)); // holds
        assert!(fdre_next(true, false, false, false)); // holds
    }

    #[test]
    fn load() {
        assert!(!fdre_next(true, false, true, false));
    }
}
