//! FDRE — D flip-flop with clock-enable and synchronous reset.
//!
//! The only sequential bit-element the IP generators use. Semantics on the
//! rising clock edge: `R` (sync reset) wins, then `CE` gates the load.

/// One FDRE evaluation step. Returns the next Q given current inputs.
#[inline]
pub fn fdre_next(q: bool, d: bool, ce: bool, r: bool) -> bool {
    if r {
        false
    } else if ce {
        d
    } else {
        q
    }
}

/// Lane-parallel FDRE: every argument is a 64-lane word (bit *l* = that
/// pin's value in simulator lane *l*); one expression of bitwise ops
/// evaluates all lanes at once with the same R-beats-CE priority.
#[inline]
pub fn fdre_next_lanes(q: u64, d: u64, ce: u64, r: u64) -> u64 {
    !r & ((ce & d) | (!ce & q))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_wins() {
        assert!(!fdre_next(true, true, true, true));
        assert!(!fdre_next(false, true, false, true));
    }

    #[test]
    fn ce_gates() {
        assert!(fdre_next(false, true, true, false));
        assert!(!fdre_next(false, true, false, false)); // holds
        assert!(fdre_next(true, false, false, false)); // holds
    }

    #[test]
    fn load() {
        assert!(!fdre_next(true, false, true, false));
    }

    #[test]
    fn lane_eval_matches_scalar_exhaustively() {
        // 4 input bits -> 16 combinations; pack all of them into 16 lanes
        // and check the lane word agrees with the scalar model per lane.
        let (mut q, mut d, mut ce, mut r) = (0u64, 0u64, 0u64, 0u64);
        for lane in 0..16u64 {
            q |= (lane & 1) << lane;
            d |= ((lane >> 1) & 1) << lane;
            ce |= ((lane >> 2) & 1) << lane;
            r |= ((lane >> 3) & 1) << lane;
        }
        let next = fdre_next_lanes(q, d, ce, r);
        for lane in 0..16u64 {
            let want = fdre_next(lane & 1 == 1, (lane >> 1) & 1 == 1, (lane >> 2) & 1 == 1, (lane >> 3) & 1 == 1);
            assert_eq!((next >> lane) & 1 == 1, want, "lane {lane}");
        }
    }
}
