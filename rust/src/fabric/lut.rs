//! LUT6 function generator.
//!
//! An UltraScale+ LUT6 evaluates any boolean function of up to six inputs
//! from a 64-bit truth table (`INIT`). Index = `{i5,i4,i3,i2,i1,i0}` as an
//! integer; output = bit `INIT[index]`. Narrower LUTs (LUT2..LUT5) are the
//! same primitive with unused high inputs tied off — the synthesis census
//! still counts one LUT each, matching Vivado's report.

/// A LUT with `k ≤ 6` used inputs and a truth-table `init`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Lut {
    pub k: u8,
    pub init: u64,
}

impl Lut {
    pub fn new(k: u8, init: u64) -> Self {
        assert!((1..=6).contains(&k), "LUT arity {k}");
        if k < 6 {
            let used = 1u128 << (1 << k);
            assert!(
                (init as u128) < used,
                "INIT {init:#x} wider than 2^{} bits",
                1 << k
            );
        }
        Lut { k, init }
    }

    /// Evaluate against packed input bits (bit i of `inputs` = input i).
    pub fn eval(&self, inputs: u64) -> bool {
        debug_assert!(inputs < (1 << self.k), "input bits exceed arity");
        (self.init >> inputs) & 1 == 1
    }

    // ---- Common generator functions used by the IP netlist builders ----

    /// 2-input XOR (half-adder sum).
    pub fn xor2() -> Lut {
        Lut::new(2, 0b0110)
    }

    /// 3-input XOR (full-adder sum, carry-chain S input).
    pub fn xor3() -> Lut {
        Lut::new(3, 0b1001_0110)
    }

    /// 2-input AND (partial-product bit).
    pub fn and2() -> Lut {
        Lut::new(2, 0b1000)
    }

    /// 2-input MUX select between i0 (sel=0) and i1 (sel=1); sel is i2.
    pub fn mux2() -> Lut {
        // index = {sel, i1, i0}
        // sel=0 -> out=i0: indices 000->0, 001->1, 010->0, 011->1
        // sel=1 -> out=i1: 100->0, 101->0, 110->1, 111->1
        Lut::new(3, 0b1100_1010)
    }

    /// Majority of 3 (full-adder carry).
    pub fn maj3() -> Lut {
        Lut::new(3, 0b1110_1000)
    }

    /// Inverter.
    pub fn not1() -> Lut {
        Lut::new(1, 0b01)
    }

    /// Buffer/identity (used for port isolation registers' D pins).
    pub fn buf1() -> Lut {
        Lut::new(1, 0b10)
    }

    /// AND of (i0, !i1) — gating with an inverted enable.
    pub fn and_not() -> Lut {
        Lut::new(2, 0b0010)
    }

    /// Arbitrary function from an evaluator closure over `k` inputs.
    pub fn from_fn(k: u8, f: impl Fn(u64) -> bool) -> Lut {
        assert!((1..=6).contains(&k));
        let mut init = 0u64;
        for idx in 0..(1u64 << k) {
            if f(idx) {
                init |= 1 << idx;
            }
        }
        Lut::new(k, init)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xor2_truth() {
        let l = Lut::xor2();
        assert!(!l.eval(0b00));
        assert!(l.eval(0b01));
        assert!(l.eval(0b10));
        assert!(!l.eval(0b11));
    }

    #[test]
    fn xor3_maj3_full_adder() {
        let s = Lut::xor3();
        let c = Lut::maj3();
        for bits in 0..8u64 {
            let a = bits & 1;
            let b = (bits >> 1) & 1;
            let ci = (bits >> 2) & 1;
            let sum = a + b + ci;
            assert_eq!(s.eval(bits) as u64, sum & 1, "sum bits={bits:03b}");
            assert_eq!(c.eval(bits) as u64, sum >> 1, "carry bits={bits:03b}");
        }
    }

    #[test]
    fn mux2_selects() {
        let m = Lut::mux2();
        // {sel,i1,i0}
        assert!(!m.eval(0b000)); // sel=0 -> i0=0
        assert!(m.eval(0b001)); // sel=0 -> i0=1
        assert!(!m.eval(0b101)); // sel=1 -> i1=0
        assert!(m.eval(0b110)); // sel=1 -> i1=1
    }

    #[test]
    fn from_fn_matches_closure() {
        let f = |idx: u64| (idx.count_ones() % 2) == 1; // parity of 5 bits
        let l = Lut::from_fn(5, f);
        for idx in 0..32u64 {
            assert_eq!(l.eval(idx), f(idx));
        }
    }

    #[test]
    #[should_panic(expected = "LUT arity")]
    fn arity_checked() {
        Lut::new(7, 0);
    }

    #[test]
    #[should_panic(expected = "wider")]
    fn init_width_checked() {
        Lut::new(2, 0x1F); // 2-input LUT has a 4-bit INIT
    }

    #[test]
    fn not_buf() {
        assert!(Lut::not1().eval(0));
        assert!(!Lut::not1().eval(1));
        assert!(!Lut::buf1().eval(0));
        assert!(Lut::buf1().eval(1));
    }
}
