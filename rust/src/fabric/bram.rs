//! RAMB18 — simple-dual-port block RAM.
//!
//! Used by the streaming front-end as line buffers (a K-1-row delay for
//! the sliding 3×3 window). Modelled as a synchronous write / registered
//! read memory of 18 Kb organized `depth × width` with the standard
//! aspect ratios.

/// Legal RAMB18 aspect ratios (width, depth) in SDP mode.
pub const ASPECTS: &[(u32, u32)] = &[(1, 16384), (2, 8192), (4, 4096), (9, 2048), (18, 1024), (36, 512)];

/// Pick the shallowest aspect whose width covers `width` and depth covers
/// `depth`; returns the number of RAMB18s needed (widths can gang).
pub fn ramb18_count(width: u32, depth: u32) -> u32 {
    assert!(width > 0 && depth > 0);
    // Use widest aspect (36) unless depth forces deeper/narrower config.
    let mut best = u32::MAX;
    for &(w, d) in ASPECTS {
        let per_row = width.div_ceil(w);
        let rows = depth.div_ceil(d);
        best = best.min(per_row * rows);
    }
    best
}

/// Behavioral simple-dual-port RAM with registered read (1-cycle latency).
#[derive(Debug, Clone)]
pub struct Ramb18 {
    pub width: u32,
    data: Vec<u64>,
    rd_reg: u64,
}

impl Ramb18 {
    pub fn new(width: u32, depth: usize) -> Self {
        assert!(width <= 36, "RAMB18 max SDP width is 36");
        Ramb18 { width, data: vec![0; depth], rd_reg: 0 }
    }

    /// One clock: optional write, then registered read of `raddr`
    /// (read-old semantics on collision, matching SDP defaults).
    pub fn clock(&mut self, waddr: Option<(usize, u64)>, raddr: usize) -> u64 {
        let out = self.rd_reg;
        self.rd_reg = self.data[raddr] & mask(self.width);
        if let Some((addr, val)) = waddr {
            self.data[addr] = val & mask(self.width);
        }
        out
    }

    /// Current read register (valid one cycle after the address).
    pub fn rd(&self) -> u64 {
        self.rd_reg
    }
}

fn mask(bits: u32) -> u64 {
    if bits >= 64 {
        u64::MAX
    } else {
        (1u64 << bits) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts() {
        assert_eq!(ramb18_count(8, 512), 1); // fits 9x2048 or 18x1024
        assert_eq!(ramb18_count(36, 512), 1);
        assert_eq!(ramb18_count(36, 1024), 2);
        assert_eq!(ramb18_count(72, 512), 2);
        assert_eq!(ramb18_count(8, 2048), 1);
        assert_eq!(ramb18_count(8, 4096), 2);
    }

    #[test]
    fn registered_read_latency() {
        let mut m = Ramb18::new(8, 16);
        m.clock(Some((3, 0xAB)), 0);
        m.clock(None, 3); // read issued
        let v = m.clock(None, 0); // value appears on the NEXT edge's output
        assert_eq!(v, 0xAB);
    }

    #[test]
    fn read_old_on_collision() {
        let mut m = Ramb18::new(8, 8);
        m.clock(Some((1, 0x11)), 1);
        // Same-cycle read addr 1 + write addr 1: read sees OLD data.
        m.clock(Some((1, 0x22)), 1);
        let v = m.clock(None, 1);
        assert_eq!(v, 0x11);
        let v2 = m.clock(None, 1);
        assert_eq!(v2, 0x22);
    }

    #[test]
    fn width_mask() {
        let mut m = Ramb18::new(4, 4);
        m.clock(Some((0, 0xFF)), 0);
        m.clock(None, 0);
        assert_eq!(m.clock(None, 0), 0x0F);
    }
}
