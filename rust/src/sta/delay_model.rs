//! UltraScale+ delay model — THE calibration point of the timing flow.
//!
//! All constants are in nanoseconds at speed grade -2 (the ZCU104's
//! XCZU7EV-2). They are fitted so the generated IPs' worst paths land in
//! the envelope the paper's Table II reports at 200 MHz (WNS ≈ 2.0–2.9 ns,
//! all positive, `Conv_3` worst); the *structure* of each path comes from
//! the real netlist, only these coefficients are calibrated. Other parts
//! scale every delay by `Device::speed_derate`.

/// LUT6 logic delay (pin-to-pin).
pub const LUT_DELAY: f64 = 0.08;

/// FDRE clock-to-Q.
pub const FF_CLK2Q: f64 = 0.09;

/// FDRE setup at D/CE/R.
pub const FF_SETUP: f64 = 0.06;

/// CARRY8: entry from an S/DI pin into the chain.
pub const CARRY_ENTRY: f64 = 0.10;

/// CARRY8: per-stage carry-mux propagation.
pub const CARRY_STAGE: f64 = 0.02;

/// CARRY8: carry to same-stage sum output (the final XOR).
pub const CARRY_SUM: f64 = 0.07;

/// CO7 → next-CARRY8 CI (dedicated vertical route).
pub const CARRY_CASCADE: f64 = 0.03;

/// DSP48E2 input setup (A/B/C/D/OPMODE with input registers enabled).
/// Large: includes the dedicated-column routing penalty.
pub const DSP_SETUP: f64 = 1.20;

/// DSP48E2 P output clock-to-Q (PREG enabled).
pub const DSP_CLK2Q: f64 = 0.45;

/// RAMB18 input setup / output clock-to-access.
pub const BRAM_SETUP: f64 = 0.35;
pub const BRAM_CLK2Q: f64 = 0.60;

/// Primary inputs are launched by the enclosing engine's registers.
pub const INPUT_LAUNCH: f64 = FF_CLK2Q;

/// Top-level outputs are captured by the enclosing engine's registers.
pub const OUTPUT_CAPTURE: f64 = FF_SETUP;

/// Clock uncertainty subtracted from every period.
pub const CLOCK_UNCERTAINTY: f64 = 0.10;

/// Routing delay of a net as a function of its fanout. Base hop plus a
/// congestion-ish term that grows sub-linearly (high-fanout control nets
/// get longer but the router balances them).
pub fn net_delay(fanout: u32) -> f64 {
    let f = fanout.max(1) as f64;
    0.15 + 0.08 * (f.ln())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn net_delay_monotone() {
        assert!(net_delay(1) < net_delay(4));
        assert!(net_delay(4) < net_delay(32));
        assert!(net_delay(1) > 0.1);
        assert!(net_delay(100) < 1.0, "even huge fanout stays sane");
    }

    #[test]
    fn constants_ordering() {
        // DSP paths must be heavier than LUT paths; carry stages light.
        assert!(DSP_SETUP > LUT_DELAY);
        assert!(CARRY_STAGE < LUT_DELAY);
        assert!(BRAM_CLK2Q > FF_CLK2Q);
    }
}
