//! Static timing analysis over mapped netlists.
//!
//! Computes per-net arrival times in topological order from all launch
//! points (register outputs, primary inputs), checks every capture point
//! (register inputs, DSP/BRAM ports, top-level outputs), and reports the
//! worst path and WNS at a target clock — the Table II "WNS (ns)" column.
//!
//! The delay *structure* is the netlist's; the coefficients live in
//! [`delay_model`] and are scaled by the device's speed derate.

pub mod delay_model;

use crate::netlist::{CellKind, Netlist};
use delay_model as dm;

/// One timing report.
#[derive(Debug, Clone)]
pub struct TimingReport {
    /// Target clock period (ns).
    pub period_ns: f64,
    /// Worst data-path delay (launch→capture, ns).
    pub critical_path_ns: f64,
    /// Worst negative slack (positive = timing met).
    pub wns_ns: f64,
    /// Human-readable capture point of the critical path.
    pub endpoint: String,
    /// The net feeding the worst endpoint (for path tracing).
    pub worst_net: Option<u32>,
}

impl TimingReport {
    pub fn met(&self) -> bool {
        self.wns_ns >= 0.0
    }

    /// Maximum clock frequency implied by the critical path (MHz).
    pub fn fmax_mhz(&self) -> f64 {
        1000.0 / (self.critical_path_ns + dm::CLOCK_UNCERTAINTY)
    }
}

/// `report_timing`-style critical-path trace: sequence of
/// `(description, arrival_ns)` hops from launch to capture.
pub fn trace_critical(nl: &Netlist, clock_mhz: f64, derate: f64) -> Vec<(String, f64)> {
    let Ok((report, arr, pred)) = analyze_full(nl, clock_mhz, derate) else {
        return Vec::new();
    };
    let mut path = vec![(format!("capture {}", report.endpoint), report.critical_path_ns)];
    // Walk predecessor nets from the endpoint's worst input.
    let mut cur = report.worst_net;
    let mut guard = 0;
    while let Some(net) = cur {
        guard += 1;
        if guard > 10_000 {
            break;
        }
        let who = match nl.driver(crate::netlist::NetId(net)) {
            Some((cid, pin)) => format!("{:?} pin {pin} (cell {})", kind_name(&nl.cell(cid).kind), cid.0),
            None => "(undriven)".into(),
        };
        path.push((who, arr[net as usize]));
        cur = pred[net as usize];
    }
    path.reverse();
    path
}

fn kind_name(k: &CellKind) -> &'static str {
    match k {
        CellKind::Lut { .. } => "LUT",
        CellKind::Fdre => "FDRE",
        CellKind::Carry8 => "CARRY8",
        CellKind::Dsp48e2 { .. } => "DSP48E2",
        CellKind::Ramb18 { .. } => "RAMB18",
        CellKind::Const { .. } => "CONST",
        CellKind::Input { .. } => "INPUT",
    }
}

/// Run STA at `clock_mhz` with a speed derate multiplier.
pub fn analyze(nl: &Netlist, clock_mhz: f64, derate: f64) -> Result<TimingReport, crate::netlist::NetlistError> {
    analyze_full(nl, clock_mhz, derate).map(|(r, _, _)| r)
}

#[allow(clippy::type_complexity)]
fn analyze_full(
    nl: &Netlist,
    clock_mhz: f64,
    derate: f64,
) -> Result<(TimingReport, Vec<f64>, Vec<Option<u32>>), crate::netlist::NetlistError> {
    let order = nl.check()?;
    let fanouts = nl.fanouts();
    let n = nl.n_nets();
    // Arrival time at each net's driver pin, plus the predecessor net on
    // the worst path into it (None for launch points).
    let mut arr = vec![0.0f64; n];
    let mut pred: Vec<Option<u32>> = vec![None; n];

    let hop = |net: u32, arr: &[f64], fanouts: &[u32]| -> f64 {
        arr[net as usize] + dm::net_delay(fanouts[net as usize]) * derate
    };

    // Launch points.
    for cell in &nl.cells {
        match &cell.kind {
            CellKind::Input { .. } => arr[cell.outs[0].0 as usize] = dm::INPUT_LAUNCH * derate,
            CellKind::Const { .. } => arr[cell.outs[0].0 as usize] = 0.0,
            CellKind::Fdre => arr[cell.outs[0].0 as usize] = dm::FF_CLK2Q * derate,
            CellKind::Dsp48e2 { .. } => {
                for &o in &cell.outs {
                    arr[o.0 as usize] = dm::DSP_CLK2Q * derate;
                }
            }
            CellKind::Ramb18 { .. } => {
                for &o in &cell.outs {
                    arr[o.0 as usize] = dm::BRAM_CLK2Q * derate;
                }
            }
            _ => {}
        }
    }

    // Propagate through combinational cells.
    for cid in order {
        let cell = nl.cell(cid);
        match &cell.kind {
            CellKind::Lut { .. } => {
                let (mut worst, mut wn) = (0.0f64, None);
                for &i in &cell.ins {
                    let t = hop(i.0, &arr, &fanouts);
                    if t > worst {
                        worst = t;
                        wn = Some(i.0);
                    }
                }
                let out_t = worst + dm::LUT_DELAY * derate;
                for &o in &cell.outs {
                    arr[o.0 as usize] = out_t;
                    pred[o.0 as usize] = wn;
                }
            }
            CellKind::Carry8 => {
                // ins: S0..7, DI0..7, CI; outs: O0..7, CO0..7.
                let ci_t = hop(cell.ins[16].0, &arr, &fanouts) + dm::CARRY_CASCADE * derate;
                let mut chain = ci_t;
                let mut chain_pred = Some(cell.ins[16].0);
                for i in 0..8 {
                    let s_t = hop(cell.ins[i].0, &arr, &fanouts) + dm::CARRY_ENTRY * derate;
                    let di_t = hop(cell.ins[8 + i].0, &arr, &fanouts) + dm::CARRY_ENTRY * derate;
                    // Sum output: carry-in vs same-stage S through the XOR.
                    let (o_t, o_p) = if s_t > chain {
                        (s_t, Some(cell.ins[i].0))
                    } else {
                        (chain, chain_pred)
                    };
                    arr[cell.outs[i].0 as usize] = o_t + dm::CARRY_SUM * derate;
                    pred[cell.outs[i].0 as usize] = o_p;
                    // Carry out of this stage.
                    let (c_t, c_p) = if s_t >= chain && s_t >= di_t {
                        (s_t, Some(cell.ins[i].0))
                    } else if di_t >= chain {
                        (di_t, Some(cell.ins[8 + i].0))
                    } else {
                        (chain, chain_pred)
                    };
                    chain = c_t + dm::CARRY_STAGE * derate;
                    chain_pred = c_p;
                    arr[cell.outs[8 + i].0 as usize] = chain;
                    pred[cell.outs[8 + i].0 as usize] = c_p;
                }
            }
            CellKind::Input { .. } | CellKind::Const { .. } => {}
            _ => unreachable!("sequential in comb order"),
        }
    }

    // Capture points.
    let mut worst = 0.0f64;
    let mut endpoint = String::from("(none)");
    let mut worst_net: Option<u32> = None;
    let consider =
        |t: f64, net: u32, name: String, worst: &mut f64, endpoint: &mut String, wn: &mut Option<u32>| {
            if t > *worst {
                *worst = t;
                *endpoint = name;
                *wn = Some(net);
            }
        };
    for (ci, cell) in nl.cells.iter().enumerate() {
        match &cell.kind {
            CellKind::Fdre => {
                for (pin, &i) in cell.ins.iter().enumerate() {
                    let t = hop(i.0, &arr, &fanouts) + dm::FF_SETUP * derate;
                    consider(t, i.0, format!("FDRE#{ci}.{}", ["D", "CE", "R"][pin]), &mut worst, &mut endpoint, &mut worst_net);
                }
            }
            CellKind::Dsp48e2 { .. } => {
                for &i in &cell.ins {
                    let t = hop(i.0, &arr, &fanouts) + dm::DSP_SETUP * derate;
                    consider(t, i.0, format!("DSP48E2#{ci}"), &mut worst, &mut endpoint, &mut worst_net);
                }
            }
            CellKind::Ramb18 { .. } => {
                for &i in &cell.ins {
                    let t = hop(i.0, &arr, &fanouts) + dm::BRAM_SETUP * derate;
                    consider(t, i.0, format!("RAMB18#{ci}"), &mut worst, &mut endpoint, &mut worst_net);
                }
            }
            _ => {}
        }
    }
    for (name, bus) in &nl.outputs {
        for &o in bus {
            let t = hop(o.0, &arr, &fanouts) + dm::OUTPUT_CAPTURE * derate;
            consider(t, o.0, format!("out:{name}"), &mut worst, &mut endpoint, &mut worst_net);
        }
    }

    let period = 1000.0 / clock_mhz;
    let report = TimingReport {
        period_ns: period,
        critical_path_ns: worst,
        wns_ns: period - dm::CLOCK_UNCERTAINTY - worst,
        endpoint,
        worst_net,
    };
    Ok((report, arr, pred))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ips::{self, ConvKind, ConvParams};

    fn wns(kind: ConvKind) -> f64 {
        let ip = ips::generate(kind, &ConvParams::paper_8bit()).unwrap();
        analyze(&ip.netlist, 200.0, 1.0).unwrap().wns_ns
    }

    #[test]
    fn all_ips_meet_200mhz() {
        // Paper §III.B: "All IPs meet timing constraints with positive WNS".
        for kind in ConvKind::ALL {
            let w = wns(kind);
            assert!(w > 0.0, "{} WNS={w:.3}", kind.name());
            assert!(w < 5.0, "{} WNS={w:.3} suspiciously large", kind.name());
        }
    }

    #[test]
    fn conv3_is_the_tightest() {
        // Paper §III.B: "Conv_3 demonstrates the lowest [timing margin]
        // due to its increased complexity" (lane-split correction after
        // the DSP).
        let w3 = wns(ConvKind::Conv3);
        for kind in [ConvKind::Conv1, ConvKind::Conv2, ConvKind::Conv4] {
            assert!(w3 < wns(kind), "Conv_3 ({w3:.3}) must be tightest vs {}", kind.name());
        }
    }

    #[test]
    fn derate_reduces_slack() {
        let ip = ips::generate(ConvKind::Conv1, &ConvParams::paper_8bit()).unwrap();
        let fast = analyze(&ip.netlist, 200.0, 1.0).unwrap();
        let slow = analyze(&ip.netlist, 200.0, 1.25).unwrap();
        assert!(slow.wns_ns < fast.wns_ns);
        assert!(slow.critical_path_ns > fast.critical_path_ns);
    }

    #[test]
    fn wider_operands_slow_conv1() {
        let p8 = ConvParams::paper_8bit();
        let p12 = ConvParams { data_bits: 12, coef_bits: 12, shift: 11, ..p8 };
        let w8 = analyze(&ips::generate(ConvKind::Conv1, &p8).unwrap().netlist, 200.0, 1.0).unwrap();
        let w12 = analyze(&ips::generate(ConvKind::Conv1, &p12).unwrap().netlist, 200.0, 1.0).unwrap();
        assert!(w12.critical_path_ns > w8.critical_path_ns);
    }

    #[test]
    fn fmax_consistent() {
        let ip = ips::generate(ConvKind::Conv2, &ConvParams::paper_8bit()).unwrap();
        let r = analyze(&ip.netlist, 200.0, 1.0).unwrap();
        assert!(r.met());
        assert!(r.fmax_mhz() > 200.0);
        // At fmax the slack should be ~0.
        let at_fmax = analyze(&ip.netlist, r.fmax_mhz(), 1.0).unwrap();
        assert!(at_fmax.wns_ns.abs() < 0.02, "slack at fmax = {}", at_fmax.wns_ns);
    }

    #[test]
    fn endpoint_reported() {
        let ip = ips::generate(ConvKind::Conv3, &ConvParams::paper_8bit()).unwrap();
        let r = analyze(&ip.netlist, 200.0, 1.0).unwrap();
        assert_ne!(r.endpoint, "(none)");
    }
}
