//! Synthesis-style resource reporting: primitive census + CLB packing.
//!
//! Produces the LUT / Reg / CLB / DSP columns of the paper's Table II.
//! The census is exact (the IP generators emit mapped primitives); CLB
//! count comes from a packer model of the UltraScale+ CLB (8 LUT6 + 16
//! FF + 1 CARRY8 per CLB) with Vivado-like packing efficiency:
//!
//! * every CARRY8 claims a CLB and co-locates its 8 S/DI source LUTs;
//! * remaining LUTs pack at [`LUT_PACK_EFF`] density (the packer rarely
//!   fills all 8 sites — control sets and routing pressure);
//! * flip-flops ride in LUT CLBs up to 16 per CLB; excess FFs open CLBs.

use crate::fabric::Prim;
use crate::netlist::{CellKind, Netlist};

/// Fraction of the 8 LUT sites the packer fills on average.
pub const LUT_PACK_EFF: f64 = 0.72;

/// Resource utilization of one synthesized netlist — a Table II row.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Utilization {
    pub luts: u64,
    pub regs: u64,
    pub carry8: u64,
    pub clbs: u64,
    pub dsps: u64,
    pub bram18: u64,
}

impl Utilization {
    /// Component-wise sum (for composing layer engines out of IPs).
    pub fn plus(&self, other: &Utilization) -> Utilization {
        Utilization {
            luts: self.luts + other.luts,
            regs: self.regs + other.regs,
            carry8: self.carry8 + other.carry8,
            clbs: self.clbs + other.clbs,
            dsps: self.dsps + other.dsps,
            bram18: self.bram18 + other.bram18,
        }
    }

    /// Scale by an instance count.
    pub fn times(&self, n: u64) -> Utilization {
        Utilization {
            luts: self.luts * n,
            regs: self.regs * n,
            carry8: self.carry8 * n,
            clbs: self.clbs * n,
            dsps: self.dsps * n,
            bram18: self.bram18 * n,
        }
    }

    /// Does this fit within a device budget?
    pub fn fits(&self, dev: &crate::fabric::device::Device) -> bool {
        self.luts <= dev.luts
            && self.regs <= dev.ffs
            && self.dsps <= dev.dsps
            && self.clbs <= dev.clbs
            && self.bram18 <= dev.bram18
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::obj([
            ("luts", self.luts.into()),
            ("regs", self.regs.into()),
            ("carry8", self.carry8.into()),
            ("clbs", self.clbs.into()),
            ("dsps", self.dsps.into()),
            ("bram18", self.bram18.into()),
        ])
    }
}

/// Count primitives and run the CLB packer.
pub fn synthesize(nl: &Netlist) -> Utilization {
    let census = nl.census();
    let regs = *census.get(&Prim::Ff).unwrap_or(&0);
    let carry8 = *census.get(&Prim::Carry8).unwrap_or(&0);
    let dsps = *census.get(&Prim::Dsp48e2).unwrap_or(&0);
    let bram18 = *census.get(&Prim::Ramb18).unwrap_or(&0);

    // On UltraScale+ a CARRY8 S pin is fed only by the O6 output of the
    // LUT in the same slice position: when the netlist drives S with a
    // bare signal (the optimizer folds identity LUTs away), the router
    // still burns that LUT site as a route-thru. Count those back in so
    // utilization reflects the fabric, not the simulated netlist.
    let route_thrus = count_carry_route_thrus(nl);
    let luts = *census.get(&Prim::Lut).unwrap_or(&0) + route_thrus;

    // LUTs feeding carry chains co-locate with their CARRY8 (up to 8
    // each); route-thrus are S-feeders by definition, so they pack there.
    let carry_hosted_luts = (count_carry_source_luts(nl) + route_thrus).min(luts);
    let loose_luts = luts - carry_hosted_luts;
    let carry_clbs = carry8;
    let lut_clbs = (loose_luts as f64 / (8.0 * LUT_PACK_EFF)).ceil() as u64;
    // FF capacity: 16 per CLB across all opened CLBs.
    let ff_clbs = regs.div_ceil(16);
    let clbs = (carry_clbs + lut_clbs).max(ff_clbs).max(u64::from(luts + regs > 0));

    Utilization { luts, regs, carry8, clbs, dsps, bram18 }
}

/// Count CARRY8 S pins driven by neither a LUT nor a constant: each such
/// pin occupies its slice's LUT site as a route-thru LUT (UltraScale+
/// CARRY8 S inputs come only from the co-located LUT's O6; constants tie
/// off inside the carry). Pre-optimization netlists always interpose a
/// real LUT (`addsub_w` / `add_carry_in`), so this is zero for raw IPs;
/// it recovers the sites the netlist optimizer's identity-fold frees.
fn count_carry_route_thrus(nl: &Netlist) -> u64 {
    let mut n = 0u64;
    for c in &nl.cells {
        if !matches!(c.kind, CellKind::Carry8) {
            continue;
        }
        for &s in &c.ins[..8] {
            let lut_or_const = nl.driver(s).is_some_and(|(d, _)| {
                matches!(nl.cell(d).kind, CellKind::Lut { .. } | CellKind::Const { .. })
            });
            if !lut_or_const {
                n += 1;
            }
        }
    }
    n
}

/// Count LUT cells whose outputs drive only CARRY8 S/DI pins (these pack
/// into the carry CLB rather than loose LUT sites).
fn count_carry_source_luts(nl: &Netlist) -> u64 {
    use std::collections::HashSet;
    let mut carry_ins: HashSet<u32> = HashSet::new();
    let mut other_ins: HashSet<u32> = HashSet::new();
    for c in &nl.cells {
        match &c.kind {
            CellKind::Carry8 => {
                for &n in &c.ins[..16] {
                    carry_ins.insert(n.0);
                }
                other_ins.insert(c.ins[16].0); // CI comes from cascade/logic
            }
            _ => {
                for &n in &c.ins {
                    other_ins.insert(n.0);
                }
            }
        }
    }
    for (_, bus) in &nl.outputs {
        for &n in bus {
            other_ins.insert(n.0);
        }
    }
    nl.cells
        .iter()
        .filter(|c| {
            matches!(c.kind, CellKind::Lut { .. })
                && !c.outs.is_empty()
                && c.outs.iter().all(|o| carry_ins.contains(&o.0) && !other_ins.contains(&o.0))
        })
        .count() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ips::{self, ConvKind, ConvParams};

    fn util(kind: ConvKind) -> Utilization {
        synthesize(&ips::generate(kind, &ConvParams::paper_8bit()).unwrap().netlist)
    }

    #[test]
    fn table2_shape_luts() {
        // Paper Table II LUT ordering: Conv_2 < Conv_4 <= Conv_3 < Conv_1.
        let (c1, c2, c3, c4) =
            (util(ConvKind::Conv1), util(ConvKind::Conv2), util(ConvKind::Conv3), util(ConvKind::Conv4));
        assert!(c2.luts < c4.luts, "conv2 {} < conv4 {}", c2.luts, c4.luts);
        assert!(c4.luts <= c3.luts, "conv4 {} <= conv3 {}", c4.luts, c3.luts);
        assert!(c3.luts < c1.luts, "conv3 {} < conv1 {}", c3.luts, c1.luts);
    }

    #[test]
    fn table2_shape_dsps() {
        assert_eq!(util(ConvKind::Conv1).dsps, 0);
        assert_eq!(util(ConvKind::Conv2).dsps, 1);
        assert_eq!(util(ConvKind::Conv3).dsps, 1);
        assert_eq!(util(ConvKind::Conv4).dsps, 2);
    }

    #[test]
    fn table2_shape_regs() {
        // Paper: Conv_2 (22) < Conv_4 (23) < Conv_3 (32) < Conv_1 (54).
        let (c1, c2, c3, c4) =
            (util(ConvKind::Conv1), util(ConvKind::Conv2), util(ConvKind::Conv3), util(ConvKind::Conv4));
        assert!(c2.regs <= c4.regs);
        assert!(c4.regs <= c3.regs);
        assert!(c3.regs < c1.regs);
    }

    #[test]
    fn clb_packing_sane() {
        for kind in ConvKind::ALL {
            let u = util(kind);
            assert!(u.clbs >= u.carry8, "{kind:?}");
            assert!(u.clbs * 16 >= u.regs, "{kind:?} FF capacity");
            let density = u.luts as f64 / u.clbs as f64;
            assert!((2.0..=8.0).contains(&density), "{kind:?} density {density}");
        }
    }

    #[test]
    fn carry_route_thrus_keep_utilization_honest() {
        // The optimizer folds `add_carry_in`'s identity LUTs out of the
        // netlist; the fabric still burns those slice LUT sites to reach
        // the CARRY8 S pins, so synthesize() must count them back in.
        let mut nl = crate::netlist::Netlist::new();
        let mut b = crate::netlist::builder::Builder::new(&mut nl);
        let a = b.input("a", 4);
        let one = b.one();
        let sum = b.add_carry_in(&a, one);
        b.output("y", &sum);
        let pre = synthesize(&nl);
        assert_eq!(pre.luts, 4, "raw add_carry_in interposes one LUT per bit");
        crate::netlist::opt::optimize_at(&mut nl, crate::netlist::opt::OptLevel::O2);
        assert!(
            nl.census().get(&Prim::Lut).is_none(),
            "the netlist itself sheds the identity buf1s"
        );
        let post = synthesize(&nl);
        assert_eq!(post.luts, 4, "folded S-feeders return as route-thrus");
        assert_eq!(post.clbs, pre.clbs);
    }

    #[test]
    fn arithmetic_helpers() {
        let a = Utilization { luts: 10, regs: 4, carry8: 1, clbs: 2, dsps: 1, bram18: 0 };
        let b = a.times(3);
        assert_eq!(b.luts, 30);
        assert_eq!(a.plus(&b).dsps, 4);
        let dev = crate::fabric::device::by_name("zcu104").unwrap();
        assert!(b.fits(&dev));
        let huge = a.times(1_000_000);
        assert!(!huge.fits(&dev));
    }
}
