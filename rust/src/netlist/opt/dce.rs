//! Dead-net / unobservable-logic elimination.
//!
//! Backward reachability from the declared outputs: a cell is live iff
//! some declared output transitively reads one of its outputs (paths
//! through sequential cells — FDRE/DSP/RAM data and control pins —
//! count, so state feeding an observable output is kept). Everything
//! else is dropped. `Input` cells are always kept: they are the
//! simulator's port contract, whether or not the surviving logic reads
//! them.

use super::super::{CellKind, NetId, Netlist};
use super::{Edit, Pass, PassStats};

pub struct Dce;

impl Pass for Dce {
    fn name(&self) -> &'static str {
        "dce"
    }

    fn run(&self, nl: &mut Netlist) -> PassStats {
        let mut st = PassStats { pass: self.name(), ..PassStats::default() };
        let n = nl.n_cells();
        let mut live = vec![false; n];
        let mut stack: Vec<u32> = Vec::new();
        for (ci, c) in nl.cells.iter().enumerate() {
            if matches!(c.kind, CellKind::Input { .. }) {
                live[ci] = true;
            }
        }
        let mut mark = |net: NetId, live: &mut Vec<bool>, stack: &mut Vec<u32>| {
            if let Some((c, _)) = nl.driver(net) {
                if !live[c.0 as usize] {
                    live[c.0 as usize] = true;
                    stack.push(c.0);
                }
            }
        };
        for (_, bus) in &nl.outputs {
            for &net in bus {
                mark(net, &mut live, &mut stack);
            }
        }
        while let Some(ci) = stack.pop() {
            for i in 0..nl.cells[ci as usize].ins.len() {
                mark(nl.cells[ci as usize].ins[i], &mut live, &mut stack);
            }
        }
        if live.iter().all(|&l| l) {
            return st;
        }
        let mut edit = Edit::new(nl);
        for (ci, &l) in live.iter().enumerate() {
            if !l {
                edit.drop_cell(ci);
            }
        }
        let (c, nn) = edit.apply(nl);
        st.cells_removed = c;
        st.nets_removed = nn;
        st
    }
}
