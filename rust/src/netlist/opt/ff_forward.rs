//! FF forwarding: bypass FDREs whose output is knowable without them.
//!
//! Two rewrites, both exactly cycle-preserving (FDREs power up at 0 and
//! `next = R ? 0 : (CE ? D : state)`):
//!
//! * **Stuck-at-zero collapse** — a register that can never leave its
//!   power-up state drives constant 0 from cycle 0, so reads forward to
//!   a const net. That holds when `D ≡ 0` (captures only zero), `CE ≡ 0`
//!   (never captures), `R ≡ 1` (always reset), or `D = Q` (captures its
//!   own state). Note `D ≡ 1` is *not* collapsible: Q is 0 until the
//!   first enabled edge.
//! * **Duplicate-register forwarding** — FDREs with identical
//!   `(D, CE, R)` pins and identical initial state follow identical
//!   state trajectories forever, so later duplicates forward to the
//!   first. The builder mints these freely when registering
//!   sign-extended buses (the replicated MSB net is registered once per
//!   bit position).
//!
//! Constness comes from literal `Const` drivers only — [`const_prop`]
//! (which runs earlier in the pipeline) is responsible for rewriting
//! constant logic cones into `Const` cells, and the pipeline's fixpoint
//! loop feeds each pass's discoveries to the other.
//!
//! [`const_prop`]: super::const_prop

use super::super::{CellKind, NetId, Netlist};
use super::{const_net, const_seeds, Edit, Pass, PassStats};
use std::collections::hash_map::Entry;
use std::collections::HashMap;

pub struct FfForward;

impl Pass for FfForward {
    fn name(&self) -> &'static str {
        "ff_forward"
    }

    fn run(&self, nl: &mut Netlist) -> PassStats {
        let mut st = PassStats { pass: self.name(), ..PassStats::default() };
        let konst = const_seeds(nl);
        let k = |n: NetId| konst[n.0 as usize];
        enum To {
            Net(NetId),
            Zero,
        }
        let mut drops: Vec<usize> = Vec::new();
        let mut aliases: Vec<(NetId, To)> = Vec::new();
        let mut zero_needed = false;
        let mut dups: HashMap<(u32, u32, u32), NetId> = HashMap::new();
        for (ci, c) in nl.cells.iter().enumerate() {
            if !matches!(c.kind, CellKind::Fdre) {
                continue;
            }
            let (d, ce, r, q) = (c.ins[0], c.ins[1], c.ins[2], c.outs[0]);
            let stuck_zero =
                k(d) == Some(false) || k(ce) == Some(false) || k(r) == Some(true) || d == q;
            if stuck_zero {
                drops.push(ci);
                aliases.push((q, To::Zero));
                zero_needed = true;
                continue;
            }
            match dups.entry((d.0, ce.0, r.0)) {
                Entry::Vacant(e) => {
                    e.insert(q);
                }
                Entry::Occupied(e) => {
                    drops.push(ci);
                    aliases.push((q, To::Net(*e.get())));
                }
            }
        }
        if drops.is_empty() {
            return st;
        }
        let zero = if zero_needed { Some(const_net(nl, false)) } else { None };
        let mut edit = Edit::new(nl);
        for ci in drops {
            edit.drop_cell(ci);
        }
        for (net, to) in aliases {
            let target = match to {
                To::Net(n) => n,
                To::Zero => zero.expect("zero net materialized"),
            };
            edit.alias_net(net, target);
        }
        let (c, n) = edit.apply(nl);
        st.cells_removed = c;
        st.nets_removed = n;
        st
    }
}
