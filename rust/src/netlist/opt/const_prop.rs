//! Constant propagation through LUT truth tables.
//!
//! Computes per-net constness (seeded from `Const` cells, propagated
//! forward through LUTs in topological order), then folds every LUT:
//! constant pins and duplicate pins collapse out of the truth table,
//! inputs the folded function no longer depends on are pruned, and the
//! result is classified — a constant (cell dropped, output aliased to a
//! canonical const net), an identity buffer (cell dropped, output
//! aliased to its surviving input), or a smaller retabled LUT. Dual
//! LUT6_2 cells fold per-function and demote to single-function cells
//! when one half dies. Duplicate `Const` cells are deduplicated to the
//! first driver of each value.

use super::super::{Cell, CellKind, NetId, Netlist};
use super::{const_net, const_seeds, Edit, Pass, PassStats};
use crate::fabric::lut::Lut;

pub struct ConstProp;

/// Result of folding one LUT function against known-constant and
/// duplicate inputs.
pub(crate) enum Folded {
    /// Function is constant regardless of surviving inputs.
    Const(bool),
    /// Function is the identity on this single surviving input.
    Ident(NetId),
    /// Reduced function over the listed surviving inputs (`k ≥ 1`).
    Fun(Vec<NetId>, Lut),
}

/// Fold `f` (over input nets `ins`, one per pin) against `konst`:
/// constant pins become literals, repeated nets share one variable, and
/// variables the folded table does not depend on are pruned.
pub(crate) fn fold_func(f: &Lut, ins: &[NetId], konst: &[Option<bool>]) -> Folded {
    debug_assert_eq!(ins.len(), f.k as usize);
    enum Src {
        K(bool),
        V(usize),
    }
    let mut survivors: Vec<NetId> = Vec::new();
    let srcs: Vec<Src> = ins
        .iter()
        .map(|&n| {
            if let Some(v) = konst[n.0 as usize] {
                Src::K(v)
            } else {
                match survivors.iter().position(|&s| s == n) {
                    Some(p) => Src::V(p),
                    None => {
                        survivors.push(n);
                        Src::V(survivors.len() - 1)
                    }
                }
            }
        })
        .collect();
    let m = survivors.len();
    // Truth table over the surviving variables.
    let table: Vec<bool> = (0..(1u64 << m))
        .map(|a| {
            let mut idx = 0u64;
            for (pin, s) in srcs.iter().enumerate() {
                let bit = match s {
                    Src::K(v) => *v,
                    Src::V(p) => (a >> p) & 1 == 1,
                };
                if bit {
                    idx |= 1 << pin;
                }
            }
            f.eval(idx)
        })
        .collect();
    // Support pruning: drop variables the table never depends on.
    let dep: Vec<usize> = (0..m)
        .filter(|&s| (0..(1u64 << m)).any(|a| table[a as usize] != table[(a ^ (1 << s)) as usize]))
        .collect();
    if dep.is_empty() {
        return Folded::Const(table[0]);
    }
    let final_ins: Vec<NetId> = dep.iter().map(|&s| survivors[s]).collect();
    let lut = Lut::from_fn(dep.len() as u8, |a| {
        let mut full = 0u64;
        for (j, &s) in dep.iter().enumerate() {
            if (a >> j) & 1 == 1 {
                full |= 1 << s;
            }
        }
        table[full as usize]
    });
    if lut.k == 1 && lut.init == 0b10 {
        return Folded::Ident(final_ins[0]);
    }
    Folded::Fun(final_ins, lut)
}

/// Where an aliased net should point after the rewrite.
enum To {
    Net(NetId),
    Const(bool),
}

/// Planned rewrite of one cell.
enum Act {
    Keep,
    Drop,
    /// Replace with a single-function LUT driving `out`.
    Single { ins: Vec<NetId>, f: Lut, out: NetId },
    /// Replace with a dual-function LUT over shared inputs (outs kept).
    Dual { ins: Vec<NetId>, funcs: [Lut; 2] },
}

impl Pass for ConstProp {
    fn name(&self) -> &'static str {
        "const_prop"
    }

    fn run(&self, nl: &mut Netlist) -> PassStats {
        let mut st = PassStats { pass: self.name(), ..PassStats::default() };
        let order = match nl.topo_comb() {
            Ok(o) => o,
            Err(_) => return st,
        };
        let mut konst = const_seeds(nl);
        let mut acts: Vec<Act> = (0..nl.n_cells()).map(|_| Act::Keep).collect();
        let mut aliases: Vec<(NetId, To)> = Vec::new();
        let mut seen_const: [Option<NetId>; 2] = [None, None];
        let mut need_const = [false; 2];
        // One topological sweep: constness of a LUT's inputs is final by
        // the time the LUT is classified, so constant chains fold in a
        // single application.
        for &cid in &order {
            let ci = cid.0 as usize;
            let c = &nl.cells[ci];
            match &c.kind {
                CellKind::Const { value } => {
                    let v = *value as usize;
                    match seen_const[v] {
                        None => seen_const[v] = Some(c.outs[0]),
                        Some(canon) => {
                            aliases.push((c.outs[0], To::Net(canon)));
                            acts[ci] = Act::Drop;
                        }
                    }
                }
                CellKind::Lut { funcs } => {
                    let folded: Vec<Folded> =
                        funcs.iter().map(|f| fold_func(f, &c.ins, &konst)).collect();
                    for (fi, fd) in folded.iter().enumerate() {
                        if let Folded::Const(v) = fd {
                            konst[c.outs[fi].0 as usize] = Some(*v);
                        }
                    }
                    acts[ci] = classify(c, funcs, folded, &mut aliases, &mut need_const, &mut st);
                }
                _ => {}
            }
        }
        let edit_needed = !aliases.is_empty() || acts.iter().any(|a| !matches!(a, Act::Keep));
        if !edit_needed {
            return st;
        }
        // Materialize const nets the aliases need (may append cells; the
        // appended cells are untouched by `acts`, which is indexed by the
        // original cell ids).
        let canon: [Option<NetId>; 2] = [
            if need_const[0] { Some(seen_const[0].unwrap_or_else(|| const_net(nl, false))) } else { None },
            if need_const[1] { Some(seen_const[1].unwrap_or_else(|| const_net(nl, true))) } else { None },
        ];
        let mut edit = Edit::new(nl);
        for (ci, act) in acts.iter().enumerate() {
            match act {
                Act::Keep => {}
                Act::Drop => edit.drop_cell(ci),
                Act::Single { ins, f, out } => edit.replace_cell(
                    ci,
                    Cell { kind: CellKind::Lut { funcs: vec![*f] }, ins: ins.clone(), outs: vec![*out] },
                ),
                Act::Dual { ins, funcs } => edit.replace_cell(
                    ci,
                    Cell {
                        kind: CellKind::Lut { funcs: funcs.to_vec() },
                        ins: ins.clone(),
                        outs: nl.cells[ci].outs.clone(),
                    },
                ),
            }
        }
        for (net, to) in aliases {
            let target = match to {
                To::Net(n) => n,
                To::Const(v) => canon[v as usize].expect("const target materialized"),
            };
            edit.alias_net(net, target);
        }
        let (c, n) = edit.apply(nl);
        st.cells_removed = c;
        st.nets_removed = n;
        st
    }
}

/// Turn the folded function(s) of one LUT cell into a planned action,
/// recording any output aliases and which const values they need.
fn classify(
    c: &Cell,
    orig: &[Lut],
    folded: Vec<Folded>,
    aliases: &mut Vec<(NetId, To)>,
    need_const: &mut [bool; 2],
    st: &mut PassStats,
) -> Act {
    let mut alias_out = |out: NetId, fd: &Folded, need_const: &mut [bool; 2]| match fd {
        Folded::Const(v) => {
            need_const[*v as usize] = true;
            aliases.push((out, To::Const(*v)));
        }
        Folded::Ident(n) => aliases.push((out, To::Net(*n))),
        Folded::Fun(..) => unreachable!("only dead halves are aliased"),
    };
    let live: Vec<usize> =
        (0..folded.len()).filter(|&i| matches!(folded[i], Folded::Fun(..))).collect();
    match live.len() {
        0 => {
            for (fi, fd) in folded.iter().enumerate() {
                alias_out(c.outs[fi], fd, need_const);
            }
            Act::Drop
        }
        1 if folded.len() == 2 => {
            // One half of a dual LUT died: alias it, demote to single.
            let dead = 1 - live[0];
            alias_out(c.outs[dead], &folded[dead], need_const);
            let Folded::Fun(ins, f) = &folded[live[0]] else { unreachable!() };
            st.luts_retabled += 1;
            Act::Single { ins: ins.clone(), f: *f, out: c.outs[live[0]] }
        }
        1 => {
            let Folded::Fun(ins, f) = &folded[0] else { unreachable!() };
            if ins.as_slice() == c.ins.as_slice() && *f == orig[0] {
                Act::Keep
            } else {
                st.luts_retabled += 1;
                Act::Single { ins: ins.clone(), f: *f, out: c.outs[0] }
            }
        }
        _ => {
            // Both halves alive. Identical halves collapse to one output.
            let (Folded::Fun(i0, f0), Folded::Fun(i1, f1)) = (&folded[0], &folded[1]) else {
                unreachable!()
            };
            if i0 == i1 && f0 == f1 {
                aliases.push((c.outs[1], To::Net(c.outs[0])));
                st.luts_retabled += 1;
                return Act::Single { ins: i0.clone(), f: *f0, out: c.outs[0] };
            }
            // Re-share: a dual LUT needs both functions over one pin
            // list, so expand each half over the union of survivors
            // (ordered as in the original pin list).
            let shared: Vec<NetId> = {
                let mut s = Vec::new();
                for &n in &c.ins {
                    if !s.contains(&n) && (i0.contains(&n) || i1.contains(&n)) {
                        s.push(n);
                    }
                }
                s
            };
            let expand = |ins: &Vec<NetId>, f: &Lut| {
                Lut::from_fn(shared.len() as u8, |a| {
                    let mut idx = 0u64;
                    for (j, n) in ins.iter().enumerate() {
                        let pos = shared.iter().position(|x| x == n).unwrap();
                        if (a >> pos) & 1 == 1 {
                            idx |= 1 << j;
                        }
                    }
                    f.eval(idx)
                })
            };
            let (e0, e1) = (expand(i0, f0), expand(i1, f1));
            if shared.as_slice() == c.ins.as_slice() && e0 == orig[0] && e1 == orig[1] {
                Act::Keep
            } else {
                st.luts_retabled += 1;
                Act::Dual { ins: shared, funcs: [e0, e1] }
            }
        }
    }
}
