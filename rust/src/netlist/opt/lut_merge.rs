//! LUT merging: absorb single-fanout LUT chains into one ≤6-input LUT.
//!
//! When a single-function LUT's only reader is another single-function
//! LUT, and the union of their input nets fits the 6-input budget, the
//! producer's function is composed into the consumer's truth table and
//! the producer is dropped. Consumers are visited in topological order
//! and absorb repeatedly, so a whole chain (mux trees, compare ladders,
//! gating logic) collapses bottom-up in one application. Producers that
//! drive a declared output, have fanout > 1, or are dual LUT6_2 cells
//! are left alone.

use super::super::{CellKind, Netlist};
use super::{Edit, Pass, PassStats};
use crate::fabric::lut::Lut;

pub struct LutMerge;

impl Pass for LutMerge {
    fn name(&self) -> &'static str {
        "lut_merge"
    }

    fn run(&self, nl: &mut Netlist) -> PassStats {
        let mut st = PassStats { pass: self.name(), ..PassStats::default() };
        let order = match nl.topo_comb() {
            Ok(o) => o,
            Err(_) => return st,
        };
        let n = nl.n_cells();
        let mut is_out = vec![false; nl.n_nets()];
        for (_, bus) in &nl.outputs {
            for &net in bus {
                is_out[net.0 as usize] = true;
            }
        }
        // Working copies: merges rewrite consumer pins/tables in place
        // and mark producers dropped; drivers never change.
        let mut cells = nl.cells.clone();
        let mut dropped = vec![false; n];
        let mut changed = vec![false; n];
        let mut fan = vec![0u32; nl.n_nets()];
        for c in &cells {
            for &i in &c.ins {
                fan[i.0 as usize] += 1;
            }
        }
        for (_, bus) in &nl.outputs {
            for &net in bus {
                fan[net.0 as usize] += 1;
            }
        }
        for &cid in &order {
            let bi = cid.0 as usize;
            'absorb: loop {
                let (bf, bins) = match &cells[bi].kind {
                    CellKind::Lut { funcs } if funcs.len() == 1 => (funcs[0], cells[bi].ins.clone()),
                    _ => break,
                };
                for (p, &an) in bins.iter().enumerate() {
                    if is_out[an.0 as usize] || fan[an.0 as usize] != 1 {
                        continue;
                    }
                    let Some((ac, _)) = nl.driver(an) else { continue };
                    let ai = ac.0 as usize;
                    if dropped[ai] {
                        continue;
                    }
                    let af = match &cells[ai].kind {
                        CellKind::Lut { funcs } if funcs.len() == 1 => funcs[0],
                        _ => continue,
                    };
                    let ains = cells[ai].ins.clone();
                    // Merged pin list: consumer pins with the absorbed
                    // pin spliced out for the producer's pins, deduped.
                    let mut merged: Vec<super::super::NetId> = Vec::new();
                    for (q, &bn) in bins.iter().enumerate() {
                        if q == p {
                            for &x in &ains {
                                if !merged.contains(&x) {
                                    merged.push(x);
                                }
                            }
                        } else if !merged.contains(&bn) {
                            merged.push(bn);
                        }
                    }
                    if merged.len() > 6 {
                        continue;
                    }
                    let f = Lut::from_fn(merged.len() as u8, |a| {
                        let bit = |net| {
                            let pos = merged.iter().position(|&x| x == net).unwrap();
                            (a >> pos) & 1 == 1
                        };
                        let mut aidx = 0u64;
                        for (j, &x) in ains.iter().enumerate() {
                            if bit(x) {
                                aidx |= 1 << j;
                            }
                        }
                        let av = af.eval(aidx);
                        let mut bidx = 0u64;
                        for (j, &x) in bins.iter().enumerate() {
                            let v = if j == p { av } else { bit(x) };
                            if v {
                                bidx |= 1 << j;
                            }
                        }
                        bf.eval(bidx)
                    });
                    // Fanout deltas: the producer's output loses its one
                    // read; every net the pair used to read is now read
                    // exactly once by the merged consumer.
                    fan[an.0 as usize] -= 1;
                    for &x in &ains {
                        fan[x.0 as usize] -= 1;
                    }
                    for (q, &bn) in bins.iter().enumerate() {
                        if q != p {
                            fan[bn.0 as usize] -= 1;
                        }
                    }
                    for &x in &merged {
                        fan[x.0 as usize] += 1;
                    }
                    cells[bi].ins = merged;
                    cells[bi].kind = CellKind::Lut { funcs: vec![f] };
                    dropped[ai] = true;
                    changed[bi] = true;
                    st.luts_retabled += 1;
                    continue 'absorb;
                }
                break;
            }
        }
        if !dropped.iter().any(|&d| d) && !changed.iter().any(|&c| c) {
            return st;
        }
        #[cfg(debug_assertions)]
        {
            // The incremental fanout deltas must agree with a recount
            // over the working copies (dropped producers read nothing).
            let mut want = vec![0u32; nl.n_nets()];
            for (ci, c) in cells.iter().enumerate() {
                if dropped[ci] {
                    continue;
                }
                for &i in &c.ins {
                    want[i.0 as usize] += 1;
                }
            }
            for (_, bus) in &nl.outputs {
                for &net in bus {
                    want[net.0 as usize] += 1;
                }
            }
            assert_eq!(fan, want, "lut_merge fanout bookkeeping drifted");
        }
        let mut edit = Edit::new(nl);
        for ci in 0..n {
            if dropped[ci] {
                edit.drop_cell(ci);
            } else if changed[ci] {
                edit.replace_cell(ci, cells[ci].clone());
            }
        }
        let (c, nn) = edit.apply(nl);
        st.cells_removed = c;
        st.nets_removed = nn;
        st
    }
}
