//! Differential harness shared by all passes, plus per-pass unit tests.
//!
//! [`check_equiv`] is the correctness bar: for a raw netlist, every opt
//! level must produce bit-exact output values on every cycle of a
//! shared random stimulus at 1/8/64 lanes, and the optimized netlist's
//! event-driven settle must match its own dense reference exactly
//! (outputs *and* toggle totals on the surviving nets). Under the
//! `dense-check` CI feature the long runs additionally cross-check
//! every 16th settle inside the simulator itself.

use super::super::builder::{Builder, Bus};
use super::super::sim::Sim;
use super::super::{CellKind, NetId, Netlist};
use super::*;
use crate::fabric::lut::Lut;
use crate::fabric::Prim;
use crate::util::rng::Rng;

/// Assert `raw` and its optimized forms are observably identical.
pub(crate) fn check_equiv(raw: &Netlist, seed: u64, cycles: usize) {
    for level in [OptLevel::O1, OptLevel::O2] {
        let mut opt = raw.clone();
        optimize_at(&mut opt, level);
        opt.check().unwrap_or_else(|e| panic!("{level:?} broke check(): {e}"));
        for lanes in [1usize, 8, 64] {
            assert_outputs_match(raw, &opt, lanes, seed ^ ((level as u64) << 8), cycles, level);
        }
    }
    let mut opt = raw.clone();
    optimize_at(&mut opt, OptLevel::O2);
    event_matches_dense(&opt, seed ^ 0x5151, cycles);
}

/// Drive both netlists with one random stimulus; outputs must agree on
/// every cycle, every lane.
fn assert_outputs_match(
    a_nl: &Netlist,
    b_nl: &Netlist,
    lanes: usize,
    seed: u64,
    cycles: usize,
    level: OptLevel,
) {
    let mut sa = Sim::with_lanes(a_nl, lanes).unwrap();
    let mut sb = Sim::with_lanes(b_nl, lanes).unwrap();
    let in_meta: Vec<(String, usize)> =
        a_nl.inputs.iter().map(|(n, bus)| (n.clone(), bus.len())).collect();
    assert_eq!(
        a_nl.outputs.iter().map(|(n, b)| (n.clone(), b.len())).collect::<Vec<_>>(),
        b_nl.outputs.iter().map(|(n, b)| (n.clone(), b.len())).collect::<Vec<_>>(),
        "opt must preserve the output port contract"
    );
    assert_eq!(
        a_nl.inputs.iter().map(|(n, b)| (n.clone(), b.len())).collect::<Vec<_>>(),
        b_nl.inputs.iter().map(|(n, b)| (n.clone(), b.len())).collect::<Vec<_>>(),
        "opt must preserve the input port contract"
    );
    let mut rng = Rng::new(seed);
    for cyc in 0..cycles {
        for (name, w) in &in_meta {
            let m = if *w >= 64 { u64::MAX } else { (1u64 << *w) - 1 };
            for lane in 0..lanes {
                let v = rng.next_u64() & m;
                sa.set_input_lane(name, lane, v);
                sb.set_input_lane(name, lane, v);
            }
        }
        sa.settle();
        sb.settle();
        for (oi, (name, _)) in a_nl.outputs.iter().enumerate() {
            for lane in 0..lanes {
                assert_eq!(
                    sa.output_unsigned_lane_at(oi, lane),
                    sb.output_unsigned_lane_at(oi, lane),
                    "output {name} lane {lane} cycle {cyc} at {level:?}/{lanes} lanes"
                );
            }
        }
        sa.tick();
        sb.tick();
    }
}

/// Event-driven settle of an (optimized) netlist against its own dense
/// reference: identical outputs every cycle and identical toggle totals
/// over the run — the event scheduler's wake signal is the toggle diff,
/// so this pins the fanout-CSR/`comb_levels` invariants post-rewrite.
fn event_matches_dense(nl: &Netlist, seed: u64, cycles: usize) {
    let lanes = 8;
    let mut ev = Sim::with_lanes(nl, lanes).unwrap();
    let mut dn = Sim::with_lanes(nl, lanes).unwrap();
    dn.set_force_dense(true);
    let in_meta: Vec<(String, usize)> =
        nl.inputs.iter().map(|(n, bus)| (n.clone(), bus.len())).collect();
    let mut rng = Rng::new(seed);
    for cyc in 0..cycles {
        for (name, w) in &in_meta {
            let m = if *w >= 64 { u64::MAX } else { (1u64 << *w) - 1 };
            for lane in 0..lanes {
                let v = rng.next_u64() & m;
                ev.set_input_lane(name, lane, v);
                dn.set_input_lane(name, lane, v);
            }
        }
        ev.settle();
        dn.settle();
        for (oi, (name, _)) in nl.outputs.iter().enumerate() {
            for lane in 0..lanes {
                assert_eq!(
                    ev.output_unsigned_lane_at(oi, lane),
                    dn.output_unsigned_lane_at(oi, lane),
                    "event vs dense: output {name} lane {lane} cycle {cyc}"
                );
            }
        }
        ev.tick();
        dn.tick();
    }
    assert_eq!(ev.toggle_total(), dn.toggle_total(), "event vs dense toggle totals");
}

/// Random registered-arithmetic netlist with deliberately optimizable
/// material: constant operands, sign-extension duplicate nets, dead
/// logic, and pass-through/stuck registers.
fn random_netlist(seed: u64) -> Netlist {
    let mut rng = Rng::new(seed);
    let mut nl = Netlist::new();
    let mut b = Builder::new(&mut nl);
    let en = b.input("en", 1).bit(0);
    let rst = b.input("rst", 1).bit(0);
    let a = b.input("a", 6);
    let c = b.input("c", 5);
    let mut pool: Vec<Bus> = vec![a.clone(), c.clone()];
    pool.push(b.const_bus(rng.range_i64(-8, 7), 5));
    pool.push(b.sext(&a, 9));
    for _ in 0..14 {
        let x = pool[rng.index(pool.len())].clone();
        let y = pool[rng.index(pool.len())].clone();
        let next = match rng.below(6) {
            0 => b.add(&x, &y),
            1 => b.sub(&x, &y),
            2 => {
                let w = x.width().min(y.width());
                let (xt, yt) = (b.trunc(&x, w), b.trunc(&y, w));
                b.mux2(y.msb(), &xt, &yt)
            }
            3 => b.register(&x, en, rst),
            4 => b.increment(&x),
            _ => {
                let g = b.and2(x.bit(0), y.msb());
                let h = b.xor2(g, x.msb());
                let mut bits = x.0.clone();
                bits[0] = h;
                Bus(bits)
            }
        };
        // Cap widths so carry chains stay small.
        let next = if next.width() > 12 { b.trunc(&next, 12) } else { next };
        pool.push(next);
    }
    // Dead logic: built, never observed.
    let dead = b.add(&a, &c);
    let _ = b.register(&dead, en, rst);
    // Stuck register: clock-enable tied low.
    let z = b.zero();
    let stuck = b.register(&a, z, rst);
    let last = pool.len() - 1;
    let obs = b.add(&pool[last], &Bus(stuck.0.clone()));
    let y0 = pool[rng.index(pool.len())].clone();
    b.output("y0", &y0);
    b.output("y1", &obs);
    nl
}

#[test]
fn random_netlists_equivalent_at_all_levels_and_lanes() {
    for seed in [1u64, 7, 42, 1234, 0xDEAD] {
        let nl = random_netlist(seed);
        nl.check().unwrap();
        check_equiv(&nl, seed.wrapping_mul(0x9E37), 24);
    }
}

#[test]
fn every_shipped_ip_equivalent_post_opt() {
    use crate::ips::{ConvKind, ConvParams};
    let p = ConvParams::paper_8bit();
    for kind in ConvKind::ALL {
        let raw = match kind {
            ConvKind::Conv1 => crate::ips::conv1::generate(&p),
            ConvKind::Conv2 => crate::ips::conv2::generate(&p),
            ConvKind::Conv3 => crate::ips::conv3::generate(&p),
            ConvKind::Conv4 => crate::ips::conv4::generate(&p),
        }
        .unwrap();
        check_equiv(&raw.netlist, 0xC0FFEE ^ kind as u64, 20);
    }
    let fc = crate::ips::fc::generate(&p, 32).unwrap();
    check_equiv(&fc.netlist, 0xFC, 20);
    let pool = crate::ips::pool::generate(8, 4);
    check_equiv(&pool.netlist, 0xB001, 20);
    let relu = crate::ips::relu::generate(8);
    check_equiv(&relu.netlist, 0x3E1, 20);
}

#[test]
fn conv1_shrinks_measurably() {
    let p = crate::ips::ConvParams::paper_8bit();
    let mut nl = crate::ips::conv1::generate(&p).unwrap().netlist;
    let pre_luts = *nl.census().get(&Prim::Lut).unwrap_or(&0);
    let pre_ffs = *nl.census().get(&Prim::Ff).unwrap_or(&0);
    let report = optimize_at(&mut nl, OptLevel::O2);
    assert!(report.cells_removed() > 0, "O2 must remove cells from Conv_1");
    assert!(report.iterations < MAX_ROUNDS, "pipeline must converge, not hit the round cap");
    let post_luts = report.post_count(Prim::Lut);
    let post_ffs = report.post_count(Prim::Ff);
    assert!(
        post_luts + post_ffs < pre_luts + pre_ffs,
        "LUT+FF count must shrink: {pre_luts}+{pre_ffs} -> {post_luts}+{post_ffs}"
    );
    let by_pass: usize = report.passes.iter().map(|p| p.cells_removed).sum();
    assert_eq!(by_pass, report.cells_removed(), "per-pass stats must account for every removal");
}

#[test]
fn o0_is_identity() {
    let nl = random_netlist(3);
    let mut opt = nl.clone();
    let report = optimize_at(&mut opt, OptLevel::O0);
    assert_eq!(report.cells_removed(), 0);
    assert_eq!(report.iterations, 0);
    assert_eq!(opt.n_cells(), nl.n_cells());
    assert_eq!(opt.n_nets(), nl.n_nets());
}

#[test]
fn shipped_ips_have_zero_unread_nets_post_opt() {
    use crate::ips::{ConvKind, ConvParams};
    let p = ConvParams::paper_8bit();
    for kind in ConvKind::ALL {
        let mut nl = crate::ips::generate(kind, &p).unwrap().netlist;
        optimize_at(&mut nl, OptLevel::O2);
        let (_, unread) = nl.check_warn().unwrap();
        assert!(unread.is_empty(), "{}: {} unread nets post-opt", kind.name(), unread.len());
    }
    let mut nl = crate::ips::fc::generate(&p, 32).unwrap().netlist;
    optimize_at(&mut nl, OptLevel::O2);
    assert!(nl.check_warn().unwrap().1.is_empty(), "FC unread nets post-opt");
}

#[test]
fn unread_nets_flags_unobservable_cells() {
    let mut nl = Netlist::new();
    let mut b = Builder::new(&mut nl);
    let a = b.input("a", 4);
    let c = b.input("c", 4);
    let dead = b.add(&a, &c); // driven, never read, not an output
    let live = b.sub(&a, &c);
    let _ = dead;
    b.output("y", &live);
    let (_, unread) = nl.check_warn().unwrap();
    assert!(!unread.is_empty(), "dead adder outputs must be flagged");
    let mut opt = nl;
    optimize_at(&mut opt, OptLevel::O1);
    assert!(opt.check_warn().unwrap().1.is_empty(), "DCE must clear the warnings");
}

// ---------------- per-pass unit tests ----------------

#[test]
fn const_prop_folds_constant_pins_and_outputs() {
    let mut nl = Netlist::new();
    let mut b = Builder::new(&mut nl);
    let a = b.input("a", 1).bit(0);
    let one = b.one();
    let z = b.zero();
    let y_and = b.and2(a, one); // identity on a
    let y_or0 = b.xor2(a, z); // identity on a
    let y_const = b.and2(a, z); // constant 0
    let y_dup = b.xor2(a, a); // constant 0 via duplicate pins
    b.output("y", &Bus(vec![y_and, y_or0, y_const, y_dup]));
    let report = optimize_at(&mut nl, OptLevel::O1);
    let luts = report.post_count(Prim::Lut);
    assert_eq!(luts, 0, "every LUT folds to identity or constant, got {luts}");
    // Semantics: y = {a, a, 0, 0}.
    let mut sim = Sim::new(&nl).unwrap();
    for v in [0u64, 1] {
        sim.set_input("a", v);
        sim.settle();
        assert_eq!(sim.output_unsigned("y"), v | (v << 1));
        sim.tick();
    }
}

#[test]
fn const_prop_propagates_through_chains_in_one_pass() {
    let mut nl = Netlist::new();
    let mut b = Builder::new(&mut nl);
    let a = b.input("a", 1).bit(0);
    let z = b.zero();
    // not(not(and(a, 0))) — the whole cone is constant 0.
    let g = b.and2(a, z);
    let h = b.not(g);
    let y = b.not(h);
    b.output("y", &Bus(vec![y]));
    let pass = const_prop::ConstProp;
    let st = Pass::run(&pass, &mut nl);
    assert!(st.cells_removed >= 3, "one application folds the chain, got {st:?}");
    let mut sim = Sim::new(&nl).unwrap();
    sim.set_input("a", 1);
    sim.settle();
    assert_eq!(sim.output_unsigned("y"), 0);
}

#[test]
fn const_prop_dedupes_const_cells() {
    let mut nl = Netlist::new();
    let q0 = nl.net();
    let q1 = nl.net();
    let y = nl.net();
    nl.add_cell(CellKind::Const { value: true }, vec![], vec![q0]);
    nl.add_cell(CellKind::Const { value: true }, vec![], vec![q1]);
    nl.add_cell(CellKind::Fdre, vec![q0, q1, q0], vec![y]);
    nl.outputs.push(("y".into(), vec![y]));
    let pass = const_prop::ConstProp;
    let st = Pass::run(&pass, &mut nl);
    assert_eq!(st.cells_removed, 1, "duplicate const driver removed");
}

#[test]
fn dce_removes_unobservable_cone_keeps_inputs() {
    let mut nl = Netlist::new();
    let mut b = Builder::new(&mut nl);
    let a = b.input("a", 4);
    let unused = b.input("unused", 3);
    let dead = b.add(&a, &unused);
    let deader = b.increment(&dead);
    let _ = deader;
    let live = b.increment(&a);
    b.output("y", &live);
    let pre = nl.n_cells();
    let pass = dce::Dce;
    let st = Pass::run(&pass, &mut nl);
    assert!(st.cells_removed > 0);
    assert!(nl.n_cells() < pre);
    assert_eq!(nl.inputs.len(), 2, "input ports survive even when unread");
    nl.check().unwrap();
    let mut sim = Sim::new(&nl).unwrap();
    sim.set_input("a", 5);
    sim.set_input("unused", 0);
    sim.settle();
    assert_eq!(sim.output_unsigned("y"), 6);
}

#[test]
fn lut_merge_collapses_single_fanout_chain() {
    let mut nl = Netlist::new();
    let mut b = Builder::new(&mut nl);
    let a = b.input("a", 1).bit(0);
    let c = b.input("c", 1).bit(0);
    let d = b.input("d", 1).bit(0);
    // and(and(a, c), d): two LUTs with a single-fanout link -> one LUT3.
    let g = b.and2(a, c);
    let y = b.and2(g, d);
    b.output("y", &Bus(vec![y]));
    let pass = lut_merge::LutMerge;
    let st = Pass::run(&pass, &mut nl);
    assert_eq!(st.cells_removed, 1, "producer absorbed");
    assert_eq!(*nl.census().get(&Prim::Lut).unwrap(), 1);
    let mut sim = Sim::new(&nl).unwrap();
    for bits in 0..8u64 {
        sim.set_input("a", bits & 1);
        sim.set_input("c", (bits >> 1) & 1);
        sim.set_input("d", (bits >> 2) & 1);
        sim.settle();
        assert_eq!(sim.output_unsigned("y"), u64::from(bits == 7), "bits {bits:03b}");
        sim.tick();
    }
}

#[test]
fn lut_merge_respects_fanout_and_budget() {
    let mut nl = Netlist::new();
    let mut b = Builder::new(&mut nl);
    let a = b.input("a", 1).bit(0);
    let c = b.input("c", 1).bit(0);
    let shared = b.xor2(a, c); // fanout 2: must not be absorbed
    let y0 = b.and2(shared, a);
    let y1 = b.xor2(shared, c);
    b.output("y", &Bus(vec![y0, y1]));
    let pre = nl.n_cells();
    let pass = lut_merge::LutMerge;
    let st = Pass::run(&pass, &mut nl);
    assert_eq!(st.cells_removed, 0, "{st:?}");
    assert_eq!(nl.n_cells(), pre);
}

#[test]
fn ff_forward_merges_duplicate_registers() {
    let mut raw = Netlist::new();
    let mut b = Builder::new(&mut raw);
    let en = b.input("en", 1).bit(0);
    let rst = b.input("rst", 1).bit(0);
    let a = b.input("a", 3);
    // Sign-extension registers the MSB net once per padded bit — the
    // exact duplicate-FDRE shape the builder mints.
    let wide = b.sext(&a, 8);
    let q = b.register(&wide, en, rst);
    b.output("q", &q);
    let mut nl = raw.clone();
    let pass = ff_forward::FfForward;
    let st = Pass::run(&pass, &mut nl);
    assert_eq!(st.cells_removed, 5, "8 FDREs, 3 distinct D pins -> 5 merged; {st:?}");
    check_equiv(&raw, 99, 16);
}

#[test]
fn ff_forward_collapses_stuck_registers() {
    let mut nl = Netlist::new();
    let mut b = Builder::new(&mut nl);
    let a = b.input("a", 2);
    let z = b.zero();
    let one = b.one();
    let never_enabled = b.register(&a, z, z);
    let always_reset = b.register(&a, one, one);
    let cat = b.concat(&never_enabled, &always_reset);
    b.output("q", &cat);
    let pass = ff_forward::FfForward;
    let st = Pass::run(&pass, &mut nl);
    assert_eq!(st.cells_removed, 4, "all four FDREs are stuck at zero; {st:?}");
    let mut sim = Sim::new(&nl).unwrap();
    sim.set_input("a", 3);
    sim.settle();
    sim.tick();
    sim.settle();
    assert_eq!(sim.output_unsigned("q"), 0);
}

#[test]
fn ff_forward_keeps_d_const_one_register() {
    // D≡1 is NOT collapsible: Q is 0 until the first enabled edge.
    let mut nl = Netlist::new();
    let mut b = Builder::new(&mut nl);
    let en = b.input("en", 1).bit(0);
    let one = b.one();
    let z = b.zero();
    let q = b.register(&Bus(vec![one]), en, z);
    b.output("q", &q);
    let raw = nl.clone();
    let pass = ff_forward::FfForward;
    let st = Pass::run(&pass, &mut nl);
    assert_eq!(st.cells_removed, 0, "{st:?}");
    check_equiv(&raw, 7, 12);
}

#[test]
fn opt_level_parsing() {
    assert_eq!(OptLevel::parse("0"), Some(OptLevel::O0));
    assert_eq!(OptLevel::parse(" 2 "), Some(OptLevel::O2));
    assert_eq!(OptLevel::parse("3"), None);
    assert_eq!(OptLevel::parse(""), None);
    assert_eq!(OptLevel::O2.to_string(), "2");
}

#[test]
fn fold_func_classifies() {
    use const_prop::{fold_func, Folded};
    let n0 = NetId(0);
    let n1 = NetId(1);
    let konst = vec![None, Some(true)];
    // and2(a, 1) -> identity on a.
    match fold_func(&Lut::and2(), &[n0, n1], &konst) {
        Folded::Ident(n) => assert_eq!(n, n0),
        _ => panic!("expected identity"),
    }
    // xor2(a, a) -> constant 0.
    match fold_func(&Lut::xor2(), &[n0, n0], &konst) {
        Folded::Const(v) => assert!(!v),
        _ => panic!("expected const"),
    }
    // xor2(a, 1) -> not(a).
    match fold_func(&Lut::xor2(), &[n0, n1], &konst) {
        Folded::Fun(ins, f) => {
            assert_eq!(ins, vec![n0]);
            assert_eq!(f, Lut::not1());
        }
        _ => panic!("expected function"),
    }
}
