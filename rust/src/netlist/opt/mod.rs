//! Netlist optimization pass pipeline: shrink the netlist before
//! simulating it.
//!
//! The builder DSL emits naive structural netlists — constant-fed LUTs
//! (zero-padding, rounding biases), buffer LUTs in front of carry
//! chains, duplicate registers on sign-extended buses, and logic no
//! output can observe. PR 6's event-driven settle skips *quiet* ops;
//! this module deletes ops that never needed to exist, so every
//! lane-parallel `settle()` touches a smaller op list and the reported
//! LUT/FF census moves closer to what vendor synthesis would keep.
//!
//! Shape: each transform is a [`Pass`] producing [`PassStats`];
//! [`PassPipeline`] runs the passes for an [`OptLevel`] to a fixpoint
//! (a round in which no pass changes the netlist). Rewrites are
//! expressed as an [`Edit`] — net aliases + cell drops/replacements —
//! applied by one rebuild that renumbers nets compactly and preserves
//! the input/output port contract (names, widths, order), so `Sim`,
//! `verify::IpPorts`, and the synthesis census all keep working on the
//! rewritten netlist unchanged.
//!
//! The correctness bar is bit-exactness: optimized and unoptimized
//! netlists must produce identical output values on every cycle of any
//! stimulus, at any lane count (see [`tests::check_equiv`]).

pub mod const_prop;
pub mod dce;
pub mod ff_forward;
pub mod lut_merge;

use super::{Cell, CellKind, NetId, Netlist};
use crate::fabric::Prim;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU8, Ordering};

/// How aggressively to optimize generated netlists.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum OptLevel {
    /// No optimization — simulate exactly what the builder emitted.
    O0 = 0,
    /// Constant propagation + dead-logic elimination only.
    O1 = 1,
    /// Full pipeline: const prop, FF forwarding, LUT merging, DCE.
    O2 = 2,
}

impl OptLevel {
    pub fn parse(s: &str) -> Option<OptLevel> {
        match s.trim() {
            "0" => Some(OptLevel::O0),
            "1" => Some(OptLevel::O1),
            "2" => Some(OptLevel::O2),
            _ => None,
        }
    }

    /// Level requested by the `ACF_OPT_LEVEL` env var; full opt when
    /// unset or unparsable.
    pub fn from_env() -> OptLevel {
        std::env::var("ACF_OPT_LEVEL").ok().and_then(|s| OptLevel::parse(&s)).unwrap_or(OptLevel::O2)
    }
}

impl std::fmt::Display for OptLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", *self as u8)
    }
}

const LEVEL_UNSET: u8 = u8::MAX;
static LEVEL: AtomicU8 = AtomicU8::new(LEVEL_UNSET);

/// Process-wide default level used by [`optimize`]. First read resolves
/// `ACF_OPT_LEVEL` (default: full opt); [`set_level`] (the CLI's
/// `--opt-level`) overrides it.
pub fn level() -> OptLevel {
    match LEVEL.load(Ordering::Relaxed) {
        0 => OptLevel::O0,
        1 => OptLevel::O1,
        2 => OptLevel::O2,
        _ => {
            let l = OptLevel::from_env();
            LEVEL.store(l as u8, Ordering::Relaxed);
            l
        }
    }
}

/// Override the process-wide opt level (e.g. from `--opt-level`).
pub fn set_level(l: OptLevel) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// What one pass application did to the netlist.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PassStats {
    pub pass: &'static str,
    pub cells_removed: usize,
    pub nets_removed: usize,
    /// LUTs whose truth table was rewritten in place (shrunk arity,
    /// folded pins, or absorbed a producer) without removing the cell.
    pub luts_retabled: usize,
    /// Fixpoint rounds in which this pass changed the netlist
    /// (aggregated view only; a single application reports 0 or 1).
    pub rounds: usize,
}

impl PassStats {
    fn named(pass: &'static str) -> PassStats {
        PassStats { pass, ..PassStats::default() }
    }

    pub fn changed(&self) -> bool {
        self.cells_removed > 0 || self.nets_removed > 0 || self.luts_retabled > 0
    }
}

/// One netlist transform. Passes must preserve bit-exact cycle
/// semantics on every declared output and never touch the input/output
/// port contract.
pub trait Pass {
    fn name(&self) -> &'static str;
    fn run(&self, nl: &mut Netlist) -> PassStats;
}

/// Summary of a full [`PassPipeline::run`].
#[derive(Debug, Clone)]
pub struct PipelineReport {
    pub level: OptLevel,
    /// Fixpoint rounds executed (last round is the one that found
    /// nothing left to do).
    pub iterations: usize,
    /// Per-pass stats aggregated over all rounds, in pipeline order.
    pub passes: Vec<PassStats>,
    pub pre_cells: usize,
    pub pre_nets: usize,
    pub post_cells: usize,
    pub post_nets: usize,
    pub pre_census: BTreeMap<Prim, u64>,
    pub post_census: BTreeMap<Prim, u64>,
}

impl PipelineReport {
    pub fn cells_removed(&self) -> usize {
        self.pre_cells - self.post_cells
    }

    pub fn nets_removed(&self) -> usize {
        self.pre_nets - self.post_nets
    }

    pub fn pre_count(&self, p: Prim) -> u64 {
        *self.pre_census.get(&p).unwrap_or(&0)
    }

    pub fn post_count(&self, p: Prim) -> u64 {
        *self.post_census.get(&p).unwrap_or(&0)
    }
}

/// Ordered pass list for an [`OptLevel`], run to a fixpoint.
pub struct PassPipeline {
    level: OptLevel,
    passes: Vec<Box<dyn Pass>>,
}

/// Hard cap on fixpoint rounds — generously above what any real netlist
/// needs (shipped IPs converge in ≤4) but bounds a pathological
/// ping-pong between passes.
pub const MAX_ROUNDS: usize = 16;

impl PassPipeline {
    pub fn for_level(level: OptLevel) -> PassPipeline {
        let passes: Vec<Box<dyn Pass>> = match level {
            OptLevel::O0 => vec![],
            OptLevel::O1 => vec![Box::new(const_prop::ConstProp), Box::new(dce::Dce)],
            // Order: fold constants first (exposes stuck FFs), collapse
            // FFs (exposes more constants next round), merge LUT chains,
            // then sweep everything unobservable.
            OptLevel::O2 => vec![
                Box::new(const_prop::ConstProp),
                Box::new(ff_forward::FfForward),
                Box::new(lut_merge::LutMerge),
                Box::new(dce::Dce),
            ],
        };
        PassPipeline { level, passes }
    }

    pub fn run(&self, nl: &mut Netlist) -> PipelineReport {
        let pre_cells = nl.n_cells();
        let pre_nets = nl.n_nets();
        let pre_census = nl.census();
        let mut agg: Vec<PassStats> = self.passes.iter().map(|p| PassStats::named(p.name())).collect();
        let mut iterations = 0;
        if !self.passes.is_empty() {
            for _ in 0..MAX_ROUNDS {
                iterations += 1;
                let mut round_changed = false;
                for (pi, pass) in self.passes.iter().enumerate() {
                    let st = pass.run(nl);
                    if st.changed() {
                        round_changed = true;
                        agg[pi].cells_removed += st.cells_removed;
                        agg[pi].nets_removed += st.nets_removed;
                        agg[pi].luts_retabled += st.luts_retabled;
                        agg[pi].rounds += 1;
                    }
                }
                if !round_changed {
                    break;
                }
            }
            debug_assert!(nl.check().is_ok(), "optimization broke netlist invariants");
        }
        PipelineReport {
            level: self.level,
            iterations,
            passes: agg,
            pre_cells,
            pre_nets,
            post_cells: nl.n_cells(),
            post_nets: nl.n_nets(),
            pre_census,
            post_census: nl.census(),
        }
    }
}

/// Optimize in place at the process-wide [`level`].
pub fn optimize(nl: &mut Netlist) -> PipelineReport {
    optimize_at(nl, level())
}

/// Optimize in place at an explicit level.
pub fn optimize_at(nl: &mut Netlist, level: OptLevel) -> PipelineReport {
    PassPipeline::for_level(level).run(nl)
}

/// A batch of rewrites: net aliases (reads of `from` become reads of
/// `to`), cell drops, and cell replacements. [`Edit::apply`] rebuilds
/// the netlist in one sweep — kept cells in original order, nets
/// renumbered compactly, port names/widths/order preserved — so passes
/// never have to reason about renumbering.
pub(crate) struct Edit {
    alias: Vec<u32>,
    drop: Vec<bool>,
    replace: Vec<Option<Cell>>,
    changed: bool,
}

impl Edit {
    pub fn new(nl: &Netlist) -> Edit {
        Edit {
            alias: (0..nl.n_nets() as u32).collect(),
            drop: vec![false; nl.n_cells()],
            replace: vec![None; nl.n_cells()],
            changed: false,
        }
    }

    /// Canonical replacement for `n`, following alias chains.
    pub fn resolve(&self, n: NetId) -> NetId {
        let mut cur = n.0;
        while self.alias[cur as usize] != cur {
            cur = self.alias[cur as usize];
        }
        NetId(cur)
    }

    /// Redirect all reads of `from` to `to`. No-op if they already
    /// resolve to the same net (which also keeps the chain acyclic).
    pub fn alias_net(&mut self, from: NetId, to: NetId) {
        let t = self.resolve(to);
        if self.resolve(from) != t {
            self.alias[from.0 as usize] = t.0;
            self.changed = true;
        }
    }

    pub fn drop_cell(&mut self, ci: usize) {
        if !self.drop[ci] {
            self.drop[ci] = true;
            self.changed = true;
        }
    }

    /// Swap in a replacement cell. Its outs must be a subset of the
    /// original outs; outs it no longer drives must have been aliased.
    pub fn replace_cell(&mut self, ci: usize, cell: Cell) {
        self.replace[ci] = Some(cell);
        self.changed = true;
    }

    pub fn changed(&self) -> bool {
        self.changed
    }

    /// Rebuild `nl` with the edits applied; returns
    /// `(cells_removed, nets_removed)`.
    pub fn apply(self, nl: &mut Netlist) -> (usize, usize) {
        if !self.changed {
            return (0, 0);
        }
        let old = std::mem::take(nl);
        let mut new = Netlist::new();
        let mut map: Vec<Option<NetId>> = vec![None; old.n_nets()];
        for (ci, c) in old.cells.iter().enumerate() {
            if self.drop[ci] {
                continue;
            }
            let cell = self.replace[ci].as_ref().unwrap_or(c);
            for &o in &cell.outs {
                debug_assert!(map[o.0 as usize].is_none(), "net {o:?} kept by two cells");
                map[o.0 as usize] = Some(new.net());
            }
        }
        for (ci, c) in old.cells.iter().enumerate() {
            if self.drop[ci] {
                continue;
            }
            let cell = self.replace[ci].as_ref().unwrap_or(c);
            let ins = cell
                .ins
                .iter()
                .map(|&i| {
                    let r = self.resolve(i);
                    map[r.0 as usize].expect("pass redirected a read to a dropped net")
                })
                .collect();
            let outs = cell.outs.iter().map(|&o| map[o.0 as usize].unwrap()).collect();
            new.add_cell(cell.kind.clone(), ins, outs);
        }
        for (name, bus) in &old.inputs {
            let bus = bus
                .iter()
                .map(|&n| map[n.0 as usize].expect("pass dropped a declared input net"))
                .collect();
            new.inputs.push((name.clone(), bus));
        }
        for (name, bus) in &old.outputs {
            let bus = bus
                .iter()
                .map(|&n| {
                    let r = self.resolve(n);
                    map[r.0 as usize].expect("pass dropped a declared output net")
                })
                .collect();
            new.outputs.push((name.clone(), bus));
        }
        let cells_removed = old.n_cells() - new.n_cells();
        let nets_removed = old.n_nets() - new.n_nets();
        *nl = new;
        (cells_removed, nets_removed)
    }
}

/// Net of a `Const { value }` cell, adding one if the netlist has none.
/// Returns the *first* such cell's net — the same canonical driver the
/// const-dedup rewrite in [`const_prop`] aliases duplicates to.
pub(crate) fn const_net(nl: &mut Netlist, value: bool) -> NetId {
    for c in &nl.cells {
        if let CellKind::Const { value: v } = c.kind {
            if v == value {
                return c.outs[0];
            }
        }
    }
    let q = nl.net();
    nl.add_cell(CellKind::Const { value }, vec![], vec![q]);
    q
}

/// Per-net constness seeded from `Const` cells only (passes that need
/// deeper constant knowledge run after [`const_prop`] has rewritten
/// constant logic into literal `Const` drivers).
pub(crate) fn const_seeds(nl: &Netlist) -> Vec<Option<bool>> {
    let mut k = vec![None; nl.n_nets()];
    for c in &nl.cells {
        if let CellKind::Const { value } = c.kind {
            k[c.outs[0].0 as usize] = Some(value);
        }
    }
    k
}

#[cfg(test)]
pub(crate) mod tests;
