//! Bit-exact netlist simulator.
//!
//! Cycle-based, two-phase:
//! 1. [`Sim::settle`] — evaluate combinational cells in topological order
//!    from primary inputs, constants, and sequential-cell outputs.
//! 2. [`Sim::tick`] — clock edge: every sequential cell latches its
//!    settled input values; then combinational logic re-settles.
//!
//! This is the oracle that proves an IP netlist implements its behavioral
//! model: `ips::verify` drives both with the same stimulus and compares
//! outputs cycle by cycle. Toggle counts are tracked per net for the
//! activity-based dynamic power estimate.

use super::{CellKind, NetId, Netlist, NetlistError};
use crate::fabric::carry::carry8_eval;
use crate::fabric::dsp48::{self, Dsp48e2, ZMux};
use crate::fabric::ff::fdre_next;

/// Pre-decoded sequential element with inline state (perf: tick() runs
/// allocation-free and in place — DESIGN.md §Perf item 3).
enum FastSeq {
    Ff { d: u32, ce: u32, r: u32, q: u32, state: bool, next: bool },
    Dsp { ins: Vec<u32>, outs: Vec<u32>, dsp: Dsp48e2 },
    Ram {
        width: u32,
        wdata: Vec<u32>,
        waddr: Vec<u32>,
        we: u32,
        raddr: Vec<u32>,
        outs: Vec<u32>,
        data: Vec<u64>,
        rd: u64,
    },
}

/// Simulator instance bound to a checked netlist.
pub struct Sim<'nl> {
    nl: &'nl Netlist,
    /// Pre-decoded combinational ops in topological order (perf: avoids
    /// per-cycle CellKind matching and NetId indirection — see
    /// DESIGN.md §Perf items 2–3).
    fast: Vec<FastOp>,
    /// Pre-decoded sequential elements with inline state.
    fastseq: Vec<FastSeq>,
    /// Bus-name resolution built once at construction, so the per-cycle
    /// setters/getters never clone a bus or scan the port lists.
    input_ix: std::collections::HashMap<String, usize>,
    output_ix: std::collections::HashMap<String, usize>,
    values: Vec<bool>,
    toggles: Vec<u64>,
    cycles: u64,
}

/// Pre-decoded combinational operation.
enum FastOp {
    /// Plain or fractured LUT: gather input bits by flat net index, index
    /// the truth table(s).
    Lut { ins: Vec<u32>, funcs: Vec<(u64, u32)> }, // (init, out_net)
    /// Carry chain: (s[8], di[8], ci, o[8], co[8]) as flat net indices.
    Carry { s: [u32; 8], di: [u32; 8], ci: u32, o: [u32; 8], co: [u32; 8] },
}

impl<'nl> Sim<'nl> {
    /// Build from a netlist; runs [`Netlist::check`].
    pub fn new(nl: &'nl Netlist) -> Result<Self, NetlistError> {
        let order = nl.check()?;
        let mut fastseq = Vec::new();
        for c in &nl.cells {
            match &c.kind {
                CellKind::Fdre => fastseq.push(FastSeq::Ff {
                    d: c.ins[0].0,
                    ce: c.ins[1].0,
                    r: c.ins[2].0,
                    q: c.outs[0].0,
                    state: false,
                    next: false,
                }),
                CellKind::Dsp48e2 { cfg } => fastseq.push(FastSeq::Dsp {
                    ins: c.ins.iter().map(|n| n.0).collect(),
                    outs: c.outs.iter().map(|n| n.0).collect(),
                    dsp: Dsp48e2::new(*cfg),
                }),
                CellKind::Ramb18 { width, depth } => {
                    let w = *width as usize;
                    let ab = (*depth as f64).log2().ceil() as usize;
                    fastseq.push(FastSeq::Ram {
                        width: *width,
                        wdata: c.ins[0..w].iter().map(|n| n.0).collect(),
                        waddr: c.ins[w..w + ab].iter().map(|n| n.0).collect(),
                        we: c.ins[w + ab].0,
                        raddr: c.ins[w + ab + 1..w + ab + 1 + ab].iter().map(|n| n.0).collect(),
                        outs: c.outs.iter().map(|n| n.0).collect(),
                        data: vec![0; *depth as usize],
                        rd: 0,
                    });
                }
                _ => {}
            }
        }
        // Pre-decode the comb order into flat ops. Constants are written
        // once here and never re-evaluated.
        let mut values = vec![false; nl.n_nets()];
        let mut fast = Vec::new();
        for &cid in &order {
            let cell = nl.cell(cid);
            match &cell.kind {
                CellKind::Lut { funcs } => fast.push(FastOp::Lut {
                    ins: cell.ins.iter().map(|n| n.0).collect(),
                    funcs: funcs
                        .iter()
                        .zip(&cell.outs)
                        .map(|(f, o)| (f.init, o.0))
                        .collect(),
                }),
                CellKind::Carry8 => {
                    let g = |i: usize| cell.ins[i].0;
                    let h = |i: usize| cell.outs[i].0;
                    fast.push(FastOp::Carry {
                        s: std::array::from_fn(|i| g(i)),
                        di: std::array::from_fn(|i| g(8 + i)),
                        ci: g(16),
                        o: std::array::from_fn(|i| h(i)),
                        co: std::array::from_fn(|i| h(8 + i)),
                    });
                }
                CellKind::Const { value } => values[cell.outs[0].0 as usize] = *value,
                CellKind::Input { .. } => {}
                _ => unreachable!("sequential in comb order"),
            }
        }
        let input_ix =
            nl.inputs.iter().enumerate().map(|(i, (n, _))| (n.clone(), i)).collect();
        let output_ix =
            nl.outputs.iter().enumerate().map(|(i, (n, _))| (n.clone(), i)).collect();
        let mut sim = Sim {
            nl,
            fast,
            fastseq,
            input_ix,
            output_ix,
            values,
            toggles: vec![0; nl.n_nets()],
            cycles: 0,
        };
        sim.publish_seq_outputs();
        sim.settle();
        Ok(sim)
    }

    /// Resolve a declared input bus name to its index (for the `_at`
    /// setters in hot loops). Panics if `name` is not a declared input.
    pub fn input_index(&self, name: &str) -> usize {
        *self.input_ix.get(name).unwrap_or_else(|| panic!("no input named '{name}'"))
    }

    /// Resolve a declared output bus name to its index. Panics if `name`
    /// is not a declared output.
    pub fn output_index(&self, name: &str) -> usize {
        *self.output_ix.get(name).unwrap_or_else(|| panic!("no output named '{name}'"))
    }

    /// Set a primary input bus (LSB-first nets) to an integer value.
    /// Panics if `name` is not a declared input.
    pub fn set_input(&mut self, name: &str, value: u64) {
        self.set_input_at(self.input_index(name), value);
    }

    /// [`Self::set_input`] by pre-resolved index — allocation- and
    /// lookup-free, for per-cycle driver loops.
    pub fn set_input_at(&mut self, input: usize, value: u64) {
        let nl = self.nl; // reborrow at 'nl, independent of &mut self
        for (i, net) in nl.inputs[input].1.iter().enumerate() {
            self.values[net.0 as usize] = (value >> i) & 1 == 1;
        }
    }

    /// Set a contiguous field `[lo, lo+width)` of a (possibly >64-bit)
    /// input bus. Used to pack K×K windows element by element.
    pub fn set_input_field(&mut self, name: &str, lo: usize, width: usize, value: u64) {
        self.set_input_field_at(self.input_index(name), lo, width, value);
    }

    /// [`Self::set_input_field`] by pre-resolved index.
    pub fn set_input_field_at(&mut self, input: usize, lo: usize, width: usize, value: u64) {
        let nl = self.nl;
        let (name, bus) = &nl.inputs[input];
        assert!(lo + width <= bus.len(), "field [{lo},{}) exceeds '{name}'", lo + width);
        for i in 0..width {
            self.values[bus[lo + i].0 as usize] = (value >> i) & 1 == 1;
        }
    }

    /// Read a bus as an unsigned integer.
    pub fn get_unsigned(&self, bus: &[NetId]) -> u64 {
        let mut v = 0u64;
        for (i, net) in bus.iter().enumerate() {
            if self.values[net.0 as usize] {
                v |= 1 << i;
            }
        }
        v
    }

    /// Read a bus as a signed (two's complement) integer.
    pub fn get_signed(&self, bus: &[NetId]) -> i64 {
        let raw = self.get_unsigned(bus);
        let w = bus.len() as u32;
        crate::fixed::pack::sign_extend(raw as i64, w)
    }

    /// Read a declared output by name (signed).
    pub fn output_signed(&self, name: &str) -> i64 {
        self.output_signed_at(self.output_index(name))
    }

    /// Read a declared output by name (unsigned).
    pub fn output_unsigned(&self, name: &str) -> u64 {
        self.output_unsigned_at(self.output_index(name))
    }

    /// [`Self::output_signed`] by pre-resolved index.
    pub fn output_signed_at(&self, output: usize) -> i64 {
        self.get_signed(&self.nl.outputs[output].1)
    }

    /// [`Self::output_unsigned`] by pre-resolved index.
    pub fn output_unsigned_at(&self, output: usize) -> u64 {
        self.get_unsigned(&self.nl.outputs[output].1)
    }

    /// Propagate combinational logic to a fixed point (single topological
    /// pass over the pre-decoded ops — the order is a DAG order).
    pub fn settle(&mut self) {
        let values = &mut self.values;
        let toggles = &mut self.toggles;
        #[inline(always)]
        fn write(values: &mut [bool], toggles: &mut [u64], net: u32, v: bool) {
            let slot = &mut values[net as usize];
            if *slot != v {
                toggles[net as usize] += 1;
                *slot = v;
            }
        }
        for op in &self.fast {
            match op {
                FastOp::Lut { ins, funcs } => {
                    let mut idx = 0u64;
                    for (i, &n) in ins.iter().enumerate() {
                        idx |= (values[n as usize] as u64) << i;
                    }
                    for &(init, out) in funcs {
                        write(values, toggles, out, (init >> idx) & 1 == 1);
                    }
                }
                FastOp::Carry { s, di, ci, o, co } => {
                    let mut sv = 0u8;
                    let mut dv = 0u8;
                    for i in 0..8 {
                        sv |= (values[s[i] as usize] as u8) << i;
                        dv |= (values[di[i] as usize] as u8) << i;
                    }
                    let (ov, cv) = carry8_eval(sv, dv, values[*ci as usize]);
                    for i in 0..8 {
                        write(values, toggles, o[i], (ov >> i) & 1 == 1);
                        write(values, toggles, co[i], (cv >> i) & 1 == 1);
                    }
                }
            }
        }
    }


    /// Clock edge: latch every sequential cell from settled values, then
    /// re-settle combinational logic. Runs allocation-free: phase 1 reads
    /// settled nets and updates inline state, phase 2 publishes outputs
    /// (a two-phase split so FF->FF shift chains latch atomically).
    pub fn tick(&mut self) {
        self.cycles += 1;
        fn bits(values: &[bool], nets: &[u32]) -> u64 {
            let mut v = 0u64;
            for (i, &n) in nets.iter().enumerate() {
                v |= (values[n as usize] as u64) << i;
            }
            v
        }
        fn signed(values: &[bool], nets: &[u32]) -> i64 {
            crate::fixed::pack::sign_extend(bits(values, nets) as i64, nets.len() as u32)
        }
        // Phase 1: compute next states from the settled snapshot.
        let values = &self.values;
        for op in &mut self.fastseq {
            match op {
                FastSeq::Ff { d, ce, r, q: _, state, next } => {
                    *next = fdre_next(
                        *state,
                        values[*d as usize],
                        values[*ce as usize],
                        values[*r as usize],
                    );
                }
                FastSeq::Dsp { ins, outs: _, dsp } => {
                    let a = signed(values, &ins[0..27]);
                    let b = signed(values, &ins[27..45]);
                    let c = signed(values, &ins[45..93]);
                    let d = signed(values, &ins[93..120]);
                    let zmux = match bits(values, &ins[120..122]) {
                        0 => ZMux::Zero,
                        1 => ZMux::P,
                        _ => ZMux::C,
                    };
                    let ce = values[ins[122] as usize];
                    dsp.clock(dsp48::Inputs { a, b, c, d, zmux, ce });
                }
                FastSeq::Ram { width, wdata, waddr, we, raddr, outs: _, data, rd } => {
                    let wd = bits(values, wdata);
                    let wa = bits(values, waddr) as usize;
                    let ra = bits(values, raddr) as usize;
                    let len = data.len();
                    *rd = data[ra % len];
                    if values[*we as usize] {
                        let w = *width as usize;
                        let m = if w >= 64 { u64::MAX } else { (1u64 << w) - 1 };
                        data[wa % len] = wd & m;
                    }
                }
            }
        }
        for op in &mut self.fastseq {
            if let FastSeq::Ff { state, next, .. } = op {
                *state = *next;
            }
        }
        // Phase 2: publish sequential outputs and re-settle.
        self.publish_seq_outputs();
        self.settle();
    }

    fn publish_seq_outputs(&mut self) {
        let values = &mut self.values;
        let toggles = &mut self.toggles;
        #[inline(always)]
        fn write(values: &mut [bool], toggles: &mut [u64], net: u32, v: bool) {
            let slot = &mut values[net as usize];
            if *slot != v {
                toggles[net as usize] += 1;
                *slot = v;
            }
        }
        for op in &self.fastseq {
            match op {
                FastSeq::Ff { q, state, .. } => write(values, toggles, *q, *state),
                FastSeq::Dsp { outs, dsp, .. } => {
                    let p = dsp.p();
                    for (i, &net) in outs.iter().enumerate() {
                        write(values, toggles, net, (p >> i) & 1 == 1);
                    }
                }
                FastSeq::Ram { outs, rd, .. } => {
                    for (i, &net) in outs.iter().enumerate() {
                        write(values, toggles, net, (rd >> i) & 1 == 1);
                    }
                }
            }
        }
    }



    /// Cycles simulated so far.
    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Mean toggle rate per net per cycle — feeds the dynamic power model.
    pub fn mean_toggle_rate(&self) -> f64 {
        if self.cycles == 0 || self.toggles.is_empty() {
            return 0.0;
        }
        let total: u64 = self.toggles.iter().sum();
        total as f64 / (self.toggles.len() as f64 * self.cycles as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::lut::Lut;
    use crate::netlist::Netlist;

    /// Build: y = a XOR b, z = register(y).
    fn xor_reg() -> Netlist {
        let mut nl = Netlist::new();
        let a = nl.net();
        let b = nl.net();
        let y = nl.net();
        let q = nl.net();
        let one = nl.net();
        let zero = nl.net();
        nl.add_cell(CellKind::Input { name: "a".into() }, vec![], vec![a]);
        nl.add_cell(CellKind::Input { name: "b".into() }, vec![], vec![b]);
        nl.add_cell(CellKind::Const { value: true }, vec![], vec![one]);
        nl.add_cell(CellKind::Const { value: false }, vec![], vec![zero]);
        nl.add_cell(CellKind::Lut { funcs: vec![Lut::xor2()] }, vec![a, b], vec![y]);
        nl.add_cell(CellKind::Fdre, vec![y, one, zero], vec![q]);
        nl.inputs.push(("a".into(), vec![a]));
        nl.inputs.push(("b".into(), vec![b]));
        nl.outputs.push(("y".into(), vec![y]));
        nl.outputs.push(("q".into(), vec![q]));
        nl
    }

    #[test]
    fn comb_and_register() {
        let nl = xor_reg();
        let mut sim = Sim::new(&nl).unwrap();
        sim.set_input("a", 1);
        sim.set_input("b", 0);
        sim.settle();
        assert_eq!(sim.output_unsigned("y"), 1);
        assert_eq!(sim.output_unsigned("q"), 0, "register not yet clocked");
        sim.tick();
        assert_eq!(sim.output_unsigned("q"), 1);
        sim.set_input("b", 1);
        sim.settle();
        assert_eq!(sim.output_unsigned("y"), 0);
        assert_eq!(sim.output_unsigned("q"), 1, "holds until edge");
        sim.tick();
        assert_eq!(sim.output_unsigned("q"), 0);
    }

    #[test]
    fn signed_bus_read() {
        let mut nl = Netlist::new();
        let nets: Vec<_> = (0..4).map(|_| nl.net()).collect();
        for (i, &n) in nets.iter().enumerate() {
            nl.add_cell(CellKind::Const { value: i == 3 }, vec![], vec![n]); // 0b1000 = -8
        }
        nl.outputs.push(("v".into(), nets.clone()));
        let sim = Sim::new(&nl).unwrap();
        assert_eq!(sim.output_signed("v"), -8);
        assert_eq!(sim.output_unsigned("v"), 8);
    }

    #[test]
    fn toggle_counting() {
        let nl = xor_reg();
        let mut sim = Sim::new(&nl).unwrap();
        for i in 0..10 {
            sim.set_input("a", i & 1);
            sim.set_input("b", 0);
            sim.settle();
            sim.tick();
        }
        assert!(sim.mean_toggle_rate() > 0.0);
        assert_eq!(sim.cycles(), 10);
    }

    #[test]
    fn dsp_cell_macc_via_netlist() {
        use crate::fabric::dsp48::Config;
        let mut nl = Netlist::new();
        let a: Vec<_> = (0..27).map(|_| nl.net()).collect();
        let b: Vec<_> = (0..18).map(|_| nl.net()).collect();
        let c: Vec<_> = (0..48).map(|_| nl.net()).collect();
        let d: Vec<_> = (0..27).map(|_| nl.net()).collect();
        let zm: Vec<_> = (0..2).map(|_| nl.net()).collect();
        let ce = nl.net();
        let p: Vec<_> = (0..48).map(|_| nl.net()).collect();
        for (name, bus) in [("a", &a), ("b", &b), ("c", &c), ("d", &d), ("zm", &zm)] {
            for &n in bus.iter() {
                nl.add_cell(CellKind::Input { name: name.into() }, vec![], vec![n]);
            }
            nl.inputs.push((name.into(), bus.to_vec()));
        }
        nl.add_cell(CellKind::Const { value: true }, vec![], vec![ce]);
        let mut ins = a.clone();
        ins.extend(&b);
        ins.extend(&c);
        ins.extend(&d);
        ins.extend(&zm);
        ins.push(ce);
        nl.add_cell(CellKind::Dsp48e2 { cfg: Config::full_macc(false) }, ins, vec![p.clone()].concat());
        nl.outputs.push(("p".into(), p));
        let mut sim = Sim::new(&nl).unwrap();
        // MAC 3*4 then 5*6, flush 3 cycles.
        let vals = [(3i64, 4i64, 0u64), (5, 6, 1), (0, 0, 1), (0, 0, 1), (0, 0, 1)];
        for (av, bv, zmv) in vals {
            sim.set_input("a", (av as u64) & ((1 << 27) - 1));
            sim.set_input("b", (bv as u64) & ((1 << 18) - 1));
            sim.set_input("c", 0);
            sim.set_input("d", 0);
            sim.set_input("zm", zmv);
            sim.settle();
            sim.tick();
        }
        assert_eq!(sim.output_signed("p"), 3 * 4 + 5 * 6);
    }

    #[test]
    fn bram_cell_roundtrip() {
        let mut nl = Netlist::new();
        let wdata: Vec<_> = (0..8).map(|_| nl.net()).collect();
        let waddr: Vec<_> = (0..4).map(|_| nl.net()).collect();
        let we = nl.net();
        let raddr: Vec<_> = (0..4).map(|_| nl.net()).collect();
        let rdata: Vec<_> = (0..8).map(|_| nl.net()).collect();
        for (name, bus) in [("wdata", &wdata), ("waddr", &waddr), ("raddr", &raddr)] {
            for &n in bus.iter() {
                nl.add_cell(CellKind::Input { name: name.into() }, vec![], vec![n]);
            }
            nl.inputs.push((name.into(), bus.to_vec()));
        }
        nl.add_cell(CellKind::Input { name: "we".into() }, vec![], vec![we]);
        nl.inputs.push(("we".into(), vec![we]));
        let mut ins = wdata.clone();
        ins.extend(&waddr);
        ins.push(we);
        ins.extend(&raddr);
        nl.add_cell(CellKind::Ramb18 { width: 8, depth: 16 }, ins, rdata.clone());
        nl.outputs.push(("rdata".into(), rdata));
        let mut sim = Sim::new(&nl).unwrap();
        sim.set_input("wdata", 0xCD);
        sim.set_input("waddr", 5);
        sim.set_input("we", 1);
        sim.set_input("raddr", 5);
        sim.settle();
        sim.tick(); // write lands; read of OLD value (0) captured
        sim.set_input("we", 0);
        sim.settle();
        sim.tick(); // read of 0xCD captured into rd reg
        assert_eq!(sim.output_unsigned("rdata"), 0xCD);
    }
}
