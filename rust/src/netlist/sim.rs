//! Bit-exact, lane-parallel netlist simulator.
//!
//! **Representation.** Simulation state is *lane-major*: every net holds
//! one `u64` word whose bit *i* is that net's boolean value in
//! independent lane *i*. A lane is a complete, isolated stimulus stream —
//! one image of a micro-batch — so a single [`Sim::settle`]/[`Sim::tick`]
//! pass evaluates up to [`LANES`] images at once (the same bit-parallel
//! trick the paper's `Conv_3` plays at the operand level with dual-pixel
//! packing, applied here across the whole netlist).
//!
//! **Cycle model** (unchanged from the scalar simulator), two-phase:
//! 1. [`Sim::settle`] — evaluate combinational cells in topological order
//!    from primary inputs, constants, and sequential-cell outputs.
//! 2. [`Sim::tick`] — clock edge: every sequential cell latches its
//!    settled input values; then combinational logic re-settles.
//!
//! **Per-cell evaluation.**
//! * LUTs evaluate bit-parallel by Shannon mux-tree reduction of the
//!   truth table: the 2^k INIT bits are broadcast to lane words, then
//!   folded by each input's lane word with `(t0 & !x) | (t1 & x)` — 2^k−1
//!   word ops evaluate all 64 lanes, so the per-lane cost *falls* as
//!   occupancy rises. (A 1-lane `Sim` takes the classic index-the-table
//!   scalar path instead, which is cheaper at occupancy 1.)
//! * `Carry8` ripples its 8 stages with pure bitwise ops on lane words
//!   ([`carry8_eval_lanes`]); FDRE is three bitwise ops
//!   ([`fdre_next_lanes`]).
//! * DSP48E2 and RAMB18 keep per-lane architectural state and iterate
//!   only over the live lanes.
//!
//! **Toggle exactness.** Every published word is diffed against the old
//! value and masked by the live-lane mask; `count_ones()` on `old ⊕ new`
//! charges exactly one toggle per lane per transition, so per-net counts
//! equal the sum of the counts that per-lane scalar runs would have
//! produced and the activity-based power model is unchanged at any
//! occupancy (see the differential property tests below, and
//! [`Sim::mean_toggle_rate`] which normalizes per lane).
//!
//! This is the oracle that proves an IP netlist implements its behavioral
//! model: `ips::verify` drives both with the same stimulus — lane-batched
//! via [`Sim::with_lanes`] — and compares outputs cycle by cycle.
//!
//! **Bus-width contract.** Whole-bus accessors ([`Sim::set_input`],
//! [`Sim::get_unsigned`], ...) carry at most 64 bits and assert it;
//! wider buses (e.g. a K²·W window port) must go through the field
//! accessors ([`Sim::set_input_field_at`] and per-element output
//! slices), which is what every driver in the tree already does.

use super::{CellKind, NetId, Netlist, NetlistError};
use crate::fabric::carry::carry8_eval_lanes;
use crate::fabric::dsp48::{self, Dsp48e2, ZMux};
use crate::fabric::ff::fdre_next_lanes;

/// Maximum (and word-width) lane count of one simulator instance: one
/// image per bit of a `u64` lane word.
pub const LANES: usize = 64;

/// Pre-decoded sequential element with inline per-lane state (perf:
/// tick() runs allocation-free and in place — DESIGN.md §Perf item 3).
enum FastSeq {
    Ff { d: u32, ce: u32, r: u32, q: u32, state: u64, next: u64 },
    Dsp { ins: Vec<u32>, outs: Vec<u32>, dsps: Vec<Dsp48e2> },
    Ram {
        width: u32,
        wdata: Vec<u32>,
        waddr: Vec<u32>,
        we: u32,
        raddr: Vec<u32>,
        outs: Vec<u32>,
        /// Lane-major contents: entry `lane * depth + addr`.
        depth: usize,
        data: Vec<u64>,
        /// Registered read value per lane.
        rd: Vec<u64>,
    },
}

/// Simulator instance bound to a checked netlist.
pub struct Sim<'nl> {
    nl: &'nl Netlist,
    /// Pre-decoded combinational ops in topological order (perf: avoids
    /// per-cycle CellKind matching and NetId indirection — see
    /// DESIGN.md §Perf items 2–3).
    fast: Vec<FastOp>,
    /// Pre-decoded sequential elements with inline state.
    fastseq: Vec<FastSeq>,
    /// Bus-name resolution built once at construction, so the per-cycle
    /// setters/getters never clone a bus or scan the port lists.
    input_ix: std::collections::HashMap<String, usize>,
    output_ix: std::collections::HashMap<String, usize>,
    /// Live lane count (1..=LANES) and its bit mask.
    lanes: usize,
    live: u64,
    /// Lane word per net: bit i = the net's value in lane i.
    values: Vec<u64>,
    toggles: Vec<u64>,
    cycles: u64,
}

/// Pre-decoded combinational operation.
enum FastOp {
    /// Plain or fractured LUT: gather input lane words by flat net index,
    /// reduce the truth table(s).
    Lut { ins: Vec<u32>, funcs: Vec<(u64, u32)> }, // (init, out_net)
    /// Carry chain: (s[8], di[8], ci, o[8], co[8]) as flat net indices.
    Carry { s: [u32; 8], di: [u32; 8], ci: u32, o: [u32; 8], co: [u32; 8] },
}

/// Publish `word` onto `net`, charging toggles for every live lane whose
/// bit changed — `count_ones()` on `old ⊕ new` under the live mask keeps
/// the power model's activity exact at any lane occupancy. The single
/// shared write path of `settle`/`publish_seq_outputs`.
#[inline(always)]
fn write_net(values: &mut [u64], toggles: &mut [u64], live: u64, net: u32, word: u64) {
    let slot = &mut values[net as usize];
    let diff = (*slot ^ word) & live;
    if diff != 0 {
        toggles[net as usize] += diff.count_ones() as u64;
    }
    *slot = word;
}

/// Evaluate one LUT truth table over all lanes at once: broadcast each
/// INIT bit to a full/empty lane word, then Shannon-fold by each input's
/// lane word. 2^k−1 word muxes evaluate up to 64 lanes.
#[inline]
fn lut_eval_lanes(init: u64, xs: &[u64]) -> u64 {
    debug_assert!((1..=6).contains(&xs.len()), "LUT arity {}", xs.len());
    let n = 1usize << xs.len();
    let mut tab = [0u64; 64];
    for (j, t) in tab.iter_mut().enumerate().take(n) {
        *t = 0u64.wrapping_sub((init >> j) & 1); // all-ones / all-zeros
    }
    let mut size = n;
    for &x in xs {
        size >>= 1;
        for j in 0..size {
            tab[j] = (tab[2 * j] & !x) | (tab[2 * j + 1] & x);
        }
    }
    tab[0]
}

/// Gather one lane's integer value from a list of net lane words.
#[inline]
fn bits_lane(values: &[u64], nets: &[u32], lane: usize) -> u64 {
    let mut v = 0u64;
    for (i, &n) in nets.iter().enumerate() {
        v |= ((values[n as usize] >> lane) & 1) << i;
    }
    v
}

/// [`bits_lane`] as a signed (two's complement) value.
#[inline]
fn signed_lane(values: &[u64], nets: &[u32], lane: usize) -> i64 {
    crate::fixed::pack::sign_extend(bits_lane(values, nets, lane) as i64, nets.len() as u32)
}

impl<'nl> Sim<'nl> {
    /// Build a single-lane (scalar) simulator; runs [`Netlist::check`].
    pub fn new(nl: &'nl Netlist) -> Result<Self, NetlistError> {
        Sim::with_lanes(nl, 1)
    }

    /// Build a `lanes`-lane simulator (1..=[`LANES`]); every lane is an
    /// independent stimulus stream evaluated by the same settle/tick
    /// passes. Runs [`Netlist::check`].
    pub fn with_lanes(nl: &'nl Netlist, lanes: usize) -> Result<Self, NetlistError> {
        assert!(
            (1..=LANES).contains(&lanes),
            "lane count {lanes} outside 1..={LANES}"
        );
        let live = if lanes == LANES { u64::MAX } else { (1u64 << lanes) - 1 };
        let order = nl.check()?;
        let mut fastseq = Vec::new();
        for c in &nl.cells {
            match &c.kind {
                CellKind::Fdre => fastseq.push(FastSeq::Ff {
                    d: c.ins[0].0,
                    ce: c.ins[1].0,
                    r: c.ins[2].0,
                    q: c.outs[0].0,
                    state: 0,
                    next: 0,
                }),
                CellKind::Dsp48e2 { cfg } => fastseq.push(FastSeq::Dsp {
                    ins: c.ins.iter().map(|n| n.0).collect(),
                    outs: c.outs.iter().map(|n| n.0).collect(),
                    dsps: vec![Dsp48e2::new(*cfg); lanes],
                }),
                CellKind::Ramb18 { width, depth } => {
                    let w = *width as usize;
                    assert!(w <= 64, "RAMB18 width {w} > 64 unsupported");
                    let ab = super::ram_addr_bits(*depth);
                    fastseq.push(FastSeq::Ram {
                        width: *width,
                        wdata: c.ins[0..w].iter().map(|n| n.0).collect(),
                        waddr: c.ins[w..w + ab].iter().map(|n| n.0).collect(),
                        we: c.ins[w + ab].0,
                        raddr: c.ins[w + ab + 1..w + ab + 1 + ab].iter().map(|n| n.0).collect(),
                        outs: c.outs.iter().map(|n| n.0).collect(),
                        depth: *depth as usize,
                        data: vec![0; *depth as usize * lanes],
                        rd: vec![0; lanes],
                    });
                }
                _ => {}
            }
        }
        // Pre-decode the comb order into flat ops. Constants are written
        // once here (broadcast across live lanes) and never re-evaluated.
        let mut values = vec![0u64; nl.n_nets()];
        let mut fast = Vec::new();
        for &cid in &order {
            let cell = nl.cell(cid);
            match &cell.kind {
                CellKind::Lut { funcs } => fast.push(FastOp::Lut {
                    ins: cell.ins.iter().map(|n| n.0).collect(),
                    funcs: funcs
                        .iter()
                        .zip(&cell.outs)
                        .map(|(f, o)| (f.init, o.0))
                        .collect(),
                }),
                CellKind::Carry8 => {
                    let g = |i: usize| cell.ins[i].0;
                    let h = |i: usize| cell.outs[i].0;
                    fast.push(FastOp::Carry {
                        s: std::array::from_fn(|i| g(i)),
                        di: std::array::from_fn(|i| g(8 + i)),
                        ci: g(16),
                        o: std::array::from_fn(|i| h(i)),
                        co: std::array::from_fn(|i| h(8 + i)),
                    });
                }
                CellKind::Const { value } => {
                    values[cell.outs[0].0 as usize] = if *value { live } else { 0 }
                }
                CellKind::Input { .. } => {}
                _ => unreachable!("sequential in comb order"),
            }
        }
        let input_ix =
            nl.inputs.iter().enumerate().map(|(i, (n, _))| (n.clone(), i)).collect();
        let output_ix =
            nl.outputs.iter().enumerate().map(|(i, (n, _))| (n.clone(), i)).collect();
        let mut sim = Sim {
            nl,
            fast,
            fastseq,
            input_ix,
            output_ix,
            lanes,
            live,
            values,
            toggles: vec![0; nl.n_nets()],
            cycles: 0,
        };
        sim.publish_seq_outputs();
        sim.settle();
        Ok(sim)
    }

    /// Live lane count of this instance.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Resolve a declared input bus name to its index (for the `_at`
    /// setters in hot loops). Panics if `name` is not a declared input.
    pub fn input_index(&self, name: &str) -> usize {
        *self.input_ix.get(name).unwrap_or_else(|| panic!("no input named '{name}'"))
    }

    /// Resolve a declared output bus name to its index. Panics if `name`
    /// is not a declared output.
    pub fn output_index(&self, name: &str) -> usize {
        *self.output_ix.get(name).unwrap_or_else(|| panic!("no output named '{name}'"))
    }

    /// Set a primary input bus (LSB-first nets) to an integer value in
    /// EVERY live lane (broadcast — the natural shape for shared control
    /// and coefficient streams). Panics if `name` is not a declared
    /// input or the bus is wider than 64 bits.
    pub fn set_input(&mut self, name: &str, value: u64) {
        self.set_input_at(self.input_index(name), value);
    }

    /// [`Self::set_input`] by pre-resolved index — allocation- and
    /// lookup-free, for per-cycle driver loops.
    pub fn set_input_at(&mut self, input: usize, value: u64) {
        let nl = self.nl; // reborrow at 'nl, independent of &mut self
        let (name, bus) = &nl.inputs[input];
        assert!(
            bus.len() <= 64,
            "input '{name}' is {} bits wide (> 64): drive it with the field accessors",
            bus.len()
        );
        let live = self.live;
        for (i, net) in bus.iter().enumerate() {
            let slot = &mut self.values[net.0 as usize];
            *slot = if (value >> i) & 1 == 1 { *slot | live } else { *slot & !live };
        }
    }

    /// Set one lane of a primary input bus, leaving the other lanes
    /// untouched — the per-image setter of a lane-batched driver.
    pub fn set_input_lane(&mut self, name: &str, lane: usize, value: u64) {
        self.set_input_lane_at(self.input_index(name), lane, value);
    }

    /// [`Self::set_input_lane`] by pre-resolved index.
    pub fn set_input_lane_at(&mut self, input: usize, lane: usize, value: u64) {
        let nl = self.nl;
        let (name, bus) = &nl.inputs[input];
        assert!(
            bus.len() <= 64,
            "input '{name}' is {} bits wide (> 64): drive it with the field accessors",
            bus.len()
        );
        assert!(lane < self.lanes, "lane {lane} out of range ({} lanes)", self.lanes);
        let bit = 1u64 << lane;
        for (i, net) in bus.iter().enumerate() {
            let slot = &mut self.values[net.0 as usize];
            *slot = if (value >> i) & 1 == 1 { *slot | bit } else { *slot & !bit };
        }
    }

    /// Set a contiguous field `[lo, lo+width)` of a (possibly >64-bit)
    /// input bus in every live lane. Used to pack K×K windows element by
    /// element.
    pub fn set_input_field(&mut self, name: &str, lo: usize, width: usize, value: u64) {
        self.set_input_field_at(self.input_index(name), lo, width, value);
    }

    /// [`Self::set_input_field`] by pre-resolved index.
    pub fn set_input_field_at(&mut self, input: usize, lo: usize, width: usize, value: u64) {
        let nl = self.nl;
        let (name, bus) = &nl.inputs[input];
        assert!(width <= 64, "field width {width} > 64 on '{name}'");
        assert!(lo + width <= bus.len(), "field [{lo},{}) exceeds '{name}'", lo + width);
        let live = self.live;
        for i in 0..width {
            let slot = &mut self.values[bus[lo + i].0 as usize];
            *slot = if (value >> i) & 1 == 1 { *slot | live } else { *slot & !live };
        }
    }

    /// Set a contiguous field of an input bus in ONE lane — the
    /// per-image window packer of the lane-batched verify drivers.
    pub fn set_input_field_lane_at(
        &mut self,
        input: usize,
        lane: usize,
        lo: usize,
        width: usize,
        value: u64,
    ) {
        let nl = self.nl;
        let (name, bus) = &nl.inputs[input];
        assert!(width <= 64, "field width {width} > 64 on '{name}'");
        assert!(lo + width <= bus.len(), "field [{lo},{}) exceeds '{name}'", lo + width);
        assert!(lane < self.lanes, "lane {lane} out of range ({} lanes)", self.lanes);
        let bit = 1u64 << lane;
        for i in 0..width {
            let slot = &mut self.values[bus[lo + i].0 as usize];
            *slot = if (value >> i) & 1 == 1 { *slot | bit } else { *slot & !bit };
        }
    }

    /// Read a bus as an unsigned integer in lane 0 (the scalar view).
    /// Panics on buses wider than 64 bits — slice them field-wise.
    pub fn get_unsigned(&self, bus: &[NetId]) -> u64 {
        self.get_unsigned_lane(bus, 0)
    }

    /// Read a bus as an unsigned integer in one lane.
    pub fn get_unsigned_lane(&self, bus: &[NetId], lane: usize) -> u64 {
        assert!(
            bus.len() <= 64,
            "bus is {} bits wide (> 64): read it through field slices",
            bus.len()
        );
        assert!(lane < self.lanes, "lane {lane} out of range ({} lanes)", self.lanes);
        let mut v = 0u64;
        for (i, net) in bus.iter().enumerate() {
            v |= ((self.values[net.0 as usize] >> lane) & 1) << i;
        }
        v
    }

    /// Read a bus as a signed (two's complement) integer in lane 0.
    pub fn get_signed(&self, bus: &[NetId]) -> i64 {
        self.get_signed_lane(bus, 0)
    }

    /// Read a bus as a signed integer in one lane.
    pub fn get_signed_lane(&self, bus: &[NetId], lane: usize) -> i64 {
        let raw = self.get_unsigned_lane(bus, lane);
        let w = bus.len() as u32;
        crate::fixed::pack::sign_extend(raw as i64, w)
    }

    /// Read a declared output by name (signed, lane 0).
    pub fn output_signed(&self, name: &str) -> i64 {
        self.output_signed_at(self.output_index(name))
    }

    /// Read a declared output by name (unsigned, lane 0).
    pub fn output_unsigned(&self, name: &str) -> u64 {
        self.output_unsigned_at(self.output_index(name))
    }

    /// [`Self::output_signed`] by pre-resolved index.
    pub fn output_signed_at(&self, output: usize) -> i64 {
        self.output_signed_lane_at(output, 0)
    }

    /// [`Self::output_unsigned`] by pre-resolved index.
    pub fn output_unsigned_at(&self, output: usize) -> u64 {
        self.output_unsigned_lane_at(output, 0)
    }

    /// Read a declared output in one lane (signed).
    pub fn output_signed_lane_at(&self, output: usize, lane: usize) -> i64 {
        self.get_signed_lane(&self.nl.outputs[output].1, lane)
    }

    /// Read a declared output in one lane (unsigned).
    pub fn output_unsigned_lane_at(&self, output: usize, lane: usize) -> u64 {
        self.get_unsigned_lane(&self.nl.outputs[output].1, lane)
    }

    /// Propagate combinational logic to a fixed point (single topological
    /// pass over the pre-decoded ops — the order is a DAG order). All
    /// lanes settle in the same pass.
    pub fn settle(&mut self) {
        let values = &mut self.values;
        let toggles = &mut self.toggles;
        let live = self.live;
        let scalar = self.lanes == 1;
        for op in &self.fast {
            match op {
                FastOp::Lut { ins, funcs } => {
                    if scalar {
                        // Occupancy-1 fast path: classic index-the-table.
                        let mut idx = 0usize;
                        for (i, &n) in ins.iter().enumerate() {
                            idx |= ((values[n as usize] & 1) as usize) << i;
                        }
                        for &(init, out) in funcs {
                            write_net(values, toggles, live, out, (init >> idx) & 1);
                        }
                    } else {
                        let mut x = [0u64; 6];
                        for (i, &n) in ins.iter().enumerate() {
                            x[i] = values[n as usize];
                        }
                        for &(init, out) in funcs {
                            let word = lut_eval_lanes(init, &x[..ins.len()]);
                            write_net(values, toggles, live, out, word);
                        }
                    }
                }
                FastOp::Carry { s, di, ci, o, co } => {
                    let mut sv = [0u64; 8];
                    let mut dv = [0u64; 8];
                    for i in 0..8 {
                        sv[i] = values[s[i] as usize];
                        dv[i] = values[di[i] as usize];
                    }
                    let (ov, cv) = carry8_eval_lanes(&sv, &dv, values[*ci as usize]);
                    for i in 0..8 {
                        write_net(values, toggles, live, o[i], ov[i]);
                        write_net(values, toggles, live, co[i], cv[i]);
                    }
                }
            }
        }
    }

    /// Clock edge: latch every sequential cell from settled values, then
    /// re-settle combinational logic. Runs allocation-free: phase 1 reads
    /// settled nets and updates inline state, phase 2 publishes outputs
    /// (a two-phase split so FF->FF shift chains latch atomically).
    /// FDREs latch all lanes with three bitwise ops; DSP and RAM state
    /// advances per live lane.
    pub fn tick(&mut self) {
        self.cycles += 1;
        // Phase 1: compute next states from the settled snapshot.
        let values = &self.values;
        let lanes = self.lanes;
        for op in &mut self.fastseq {
            match op {
                FastSeq::Ff { d, ce, r, q: _, state, next } => {
                    *next = fdre_next_lanes(
                        *state,
                        values[*d as usize],
                        values[*ce as usize],
                        values[*r as usize],
                    );
                }
                FastSeq::Dsp { ins, outs: _, dsps } => {
                    for (lane, dsp) in dsps.iter_mut().enumerate() {
                        let a = signed_lane(values, &ins[0..27], lane);
                        let b = signed_lane(values, &ins[27..45], lane);
                        let c = signed_lane(values, &ins[45..93], lane);
                        let d = signed_lane(values, &ins[93..120], lane);
                        let zmux = match bits_lane(values, &ins[120..122], lane) {
                            0 => ZMux::Zero,
                            1 => ZMux::P,
                            _ => ZMux::C,
                        };
                        let ce = (values[ins[122] as usize] >> lane) & 1 == 1;
                        dsp.clock(dsp48::Inputs { a, b, c, d, zmux, ce });
                    }
                }
                FastSeq::Ram { width, wdata, waddr, we, raddr, outs: _, depth, data, rd } => {
                    let w = *width as usize;
                    let m = if w >= 64 { u64::MAX } else { (1u64 << w) - 1 };
                    for lane in 0..lanes {
                        let wd = bits_lane(values, wdata, lane);
                        let wa = bits_lane(values, waddr, lane) as usize;
                        let ra = bits_lane(values, raddr, lane) as usize;
                        let base = lane * *depth;
                        // Read-old semantics: capture before the write lands.
                        rd[lane] = data[base + ra % *depth];
                        if (values[*we as usize] >> lane) & 1 == 1 {
                            data[base + wa % *depth] = wd & m;
                        }
                    }
                }
            }
        }
        for op in &mut self.fastseq {
            if let FastSeq::Ff { state, next, .. } = op {
                *state = *next;
            }
        }
        // Phase 2: publish sequential outputs and re-settle.
        self.publish_seq_outputs();
        self.settle();
    }

    fn publish_seq_outputs(&mut self) {
        let values = &mut self.values;
        let toggles = &mut self.toggles;
        let live = self.live;
        let lanes = self.lanes;
        for op in &self.fastseq {
            match op {
                FastSeq::Ff { q, state, .. } => write_net(values, toggles, live, *q, *state),
                FastSeq::Dsp { outs, dsps, .. } => {
                    // Transpose per-lane P values into output lane words.
                    let mut outw = [0u64; 48];
                    for (lane, dsp) in dsps.iter().enumerate().take(lanes) {
                        let p = dsp.p() as u64;
                        for (i, w) in outw.iter_mut().enumerate() {
                            *w |= ((p >> i) & 1) << lane;
                        }
                    }
                    for (i, &net) in outs.iter().enumerate() {
                        write_net(values, toggles, live, net, outw[i]);
                    }
                }
                FastSeq::Ram { outs, rd, .. } => {
                    let mut outw = [0u64; 64];
                    for (lane, &v) in rd.iter().enumerate().take(lanes) {
                        for (i, w) in outw.iter_mut().enumerate().take(outs.len()) {
                            *w |= ((v >> i) & 1) << lane;
                        }
                    }
                    for (i, &net) in outs.iter().enumerate() {
                        write_net(values, toggles, live, net, outw[i]);
                    }
                }
            }
        }
    }

    /// Cycles simulated so far (one per [`Self::tick`], regardless of
    /// occupancy — a full 64-lane tick is still one hardware cycle).
    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Total toggles across all nets and live lanes — equals the sum a
    /// set of per-lane scalar runs would have produced (the differential
    /// property tests assert this exactly).
    pub fn toggle_total(&self) -> u64 {
        self.toggles.iter().sum()
    }

    /// Mean toggle rate per net per cycle *per lane* — feeds the dynamic
    /// power model. At 1 lane this is the classic scalar definition; at
    /// higher occupancy it is the average activity of the lanes.
    pub fn mean_toggle_rate(&self) -> f64 {
        if self.cycles == 0 || self.toggles.is_empty() {
            return 0.0;
        }
        let total = self.toggle_total();
        total as f64 / (self.toggles.len() as f64 * self.cycles as f64 * self.lanes as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::lut::Lut;
    use crate::netlist::builder::Builder;
    use crate::netlist::Netlist;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    /// Build: y = a XOR b, z = register(y).
    fn xor_reg() -> Netlist {
        let mut nl = Netlist::new();
        let a = nl.net();
        let b = nl.net();
        let y = nl.net();
        let q = nl.net();
        let one = nl.net();
        let zero = nl.net();
        nl.add_cell(CellKind::Input { name: "a".into() }, vec![], vec![a]);
        nl.add_cell(CellKind::Input { name: "b".into() }, vec![], vec![b]);
        nl.add_cell(CellKind::Const { value: true }, vec![], vec![one]);
        nl.add_cell(CellKind::Const { value: false }, vec![], vec![zero]);
        nl.add_cell(CellKind::Lut { funcs: vec![Lut::xor2()] }, vec![a, b], vec![y]);
        nl.add_cell(CellKind::Fdre, vec![y, one, zero], vec![q]);
        nl.inputs.push(("a".into(), vec![a]));
        nl.inputs.push(("b".into(), vec![b]));
        nl.outputs.push(("y".into(), vec![y]));
        nl.outputs.push(("q".into(), vec![q]));
        nl
    }

    #[test]
    fn comb_and_register() {
        let nl = xor_reg();
        let mut sim = Sim::new(&nl).unwrap();
        sim.set_input("a", 1);
        sim.set_input("b", 0);
        sim.settle();
        assert_eq!(sim.output_unsigned("y"), 1);
        assert_eq!(sim.output_unsigned("q"), 0, "register not yet clocked");
        sim.tick();
        assert_eq!(sim.output_unsigned("q"), 1);
        sim.set_input("b", 1);
        sim.settle();
        assert_eq!(sim.output_unsigned("y"), 0);
        assert_eq!(sim.output_unsigned("q"), 1, "holds until edge");
        sim.tick();
        assert_eq!(sim.output_unsigned("q"), 0);
    }

    #[test]
    fn signed_bus_read() {
        let mut nl = Netlist::new();
        let nets: Vec<_> = (0..4).map(|_| nl.net()).collect();
        for (i, &n) in nets.iter().enumerate() {
            nl.add_cell(CellKind::Const { value: i == 3 }, vec![], vec![n]); // 0b1000 = -8
        }
        nl.outputs.push(("v".into(), nets.clone()));
        let sim = Sim::new(&nl).unwrap();
        assert_eq!(sim.output_signed("v"), -8);
        assert_eq!(sim.output_unsigned("v"), 8);
    }

    #[test]
    fn toggle_counting() {
        let nl = xor_reg();
        let mut sim = Sim::new(&nl).unwrap();
        for i in 0..10 {
            sim.set_input("a", i & 1);
            sim.set_input("b", 0);
            sim.settle();
            sim.tick();
        }
        assert!(sim.mean_toggle_rate() > 0.0);
        assert_eq!(sim.cycles(), 10);
    }

    #[test]
    fn dsp_cell_macc_via_netlist() {
        use crate::fabric::dsp48::Config;
        let mut nl = Netlist::new();
        let a: Vec<_> = (0..27).map(|_| nl.net()).collect();
        let b: Vec<_> = (0..18).map(|_| nl.net()).collect();
        let c: Vec<_> = (0..48).map(|_| nl.net()).collect();
        let d: Vec<_> = (0..27).map(|_| nl.net()).collect();
        let zm: Vec<_> = (0..2).map(|_| nl.net()).collect();
        let ce = nl.net();
        let p: Vec<_> = (0..48).map(|_| nl.net()).collect();
        for (name, bus) in [("a", &a), ("b", &b), ("c", &c), ("d", &d), ("zm", &zm)] {
            for &n in bus.iter() {
                nl.add_cell(CellKind::Input { name: name.into() }, vec![], vec![n]);
            }
            nl.inputs.push((name.into(), bus.to_vec()));
        }
        nl.add_cell(CellKind::Const { value: true }, vec![], vec![ce]);
        let mut ins = a.clone();
        ins.extend(&b);
        ins.extend(&c);
        ins.extend(&d);
        ins.extend(&zm);
        ins.push(ce);
        nl.add_cell(CellKind::Dsp48e2 { cfg: Config::full_macc(false) }, ins, vec![p.clone()].concat());
        nl.outputs.push(("p".into(), p));
        let mut sim = Sim::new(&nl).unwrap();
        // MAC 3*4 then 5*6, flush 3 cycles.
        let vals = [(3i64, 4i64, 0u64), (5, 6, 1), (0, 0, 1), (0, 0, 1), (0, 0, 1)];
        for (av, bv, zmv) in vals {
            sim.set_input("a", (av as u64) & ((1 << 27) - 1));
            sim.set_input("b", (bv as u64) & ((1 << 18) - 1));
            sim.set_input("c", 0);
            sim.set_input("d", 0);
            sim.set_input("zm", zmv);
            sim.settle();
            sim.tick();
        }
        assert_eq!(sim.output_signed("p"), 3 * 4 + 5 * 6);
    }

    #[test]
    fn bram_cell_roundtrip() {
        let mut nl = Netlist::new();
        let wdata: Vec<_> = (0..8).map(|_| nl.net()).collect();
        let waddr: Vec<_> = (0..4).map(|_| nl.net()).collect();
        let we = nl.net();
        let raddr: Vec<_> = (0..4).map(|_| nl.net()).collect();
        let rdata: Vec<_> = (0..8).map(|_| nl.net()).collect();
        for (name, bus) in [("wdata", &wdata), ("waddr", &waddr), ("raddr", &raddr)] {
            for &n in bus.iter() {
                nl.add_cell(CellKind::Input { name: name.into() }, vec![], vec![n]);
            }
            nl.inputs.push((name.into(), bus.to_vec()));
        }
        nl.add_cell(CellKind::Input { name: "we".into() }, vec![], vec![we]);
        nl.inputs.push(("we".into(), vec![we]));
        let mut ins = wdata.clone();
        ins.extend(&waddr);
        ins.push(we);
        ins.extend(&raddr);
        nl.add_cell(CellKind::Ramb18 { width: 8, depth: 16 }, ins, rdata.clone());
        nl.outputs.push(("rdata".into(), rdata));
        let mut sim = Sim::new(&nl).unwrap();
        sim.set_input("wdata", 0xCD);
        sim.set_input("waddr", 5);
        sim.set_input("we", 1);
        sim.set_input("raddr", 5);
        sim.settle();
        sim.tick(); // write lands; read of OLD value (0) captured
        sim.set_input("we", 0);
        sim.settle();
        sim.tick(); // read of 0xCD captured into rd reg
        assert_eq!(sim.output_unsigned("rdata"), 0xCD);
    }

    // ---------------- lane-parallel coverage ----------------

    #[test]
    fn prop_lut_lane_eval_matches_table_lookup() {
        forall("lut_eval_lanes == per-lane lookup", 400, |g| {
            let k = g.usize_in(1, 6);
            let table_bits = 1usize << k;
            // Draw the INIT in 16-bit chunks to keep draws shrinkable.
            let mut init = 0u64;
            for chunk in 0..table_bits.div_ceil(16) {
                init |= (g.i64_in(0, 0xFFFF) as u64) << (chunk * 16);
            }
            if table_bits < 64 {
                init &= (1u64 << table_bits) - 1;
            }
            let xs: Vec<u64> = (0..k)
                .map(|_| {
                    // Two 32-bit halves per lane word.
                    ((g.i64_in(0, u32::MAX as i64) as u64) << 32)
                        | (g.i64_in(0, u32::MAX as i64) as u64)
                })
                .collect();
            let word = lut_eval_lanes(init, &xs);
            for lane in 0..64 {
                let mut idx = 0u64;
                for (i, x) in xs.iter().enumerate() {
                    idx |= ((x >> lane) & 1) << i;
                }
                let want = (init >> idx) & 1;
                if (word >> lane) & 1 != want {
                    return Err(format!("k={k} init={init:#x} lane={lane}"));
                }
            }
            Ok(())
        });
    }

    /// Build a random arithmetic circuit: outputs `s` (a±b), `p`
    /// (pipelined a*b) and `q` (registered sum) over random widths.
    fn random_arith(wa: usize, wb: usize, sub: bool, cut: bool) -> Netlist {
        let mut nl = Netlist::new();
        let mut b = Builder::new(&mut nl);
        let a_bus = b.input("a", wa);
        let b_bus = b.input("b", wb);
        let s = if sub { b.sub(&a_bus, &b_bus) } else { b.add(&a_bus, &b_bus) };
        let ce = b.one();
        let r = b.zero();
        let cuts: &[usize] = if cut { &[1] } else { &[] };
        let (p, _) = b.mul_signed(&a_bus, &b_bus, cuts, ce, r);
        let q = b.register(&s, ce, r);
        b.output("s", &s);
        b.output("p", &p);
        b.output("q", &q);
        nl
    }

    /// Differential property: a `lanes`-lane Sim must be cycle-for-cycle
    /// bit-identical to `lanes` independent scalar Sims — outputs AND
    /// exact toggle totals (the power-model contract).
    #[test]
    fn prop_lane_sim_matches_scalar_sims() {
        forall("lane sim == scalar sims", 25, |g| {
            let wa = g.usize_in(2, 8);
            let wb = g.usize_in(2, 8);
            let sub = g.bool();
            let cut = g.bool();
            let lanes = g.usize_in(2, 8);
            let cycles = g.usize_in(2, 6);
            let nl = random_arith(wa, wb, sub, cut);
            // Per-lane stimulus streams.
            let stim: Vec<Vec<(i64, i64)>> = (0..lanes)
                .map(|_| {
                    (0..cycles)
                        .map(|_| (g.signed_bits(wa as u32), g.signed_bits(wb as u32)))
                        .collect()
                })
                .collect();
            let amask = (1u64 << wa) - 1;
            let bmask = (1u64 << wb) - 1;
            let mut lane_sim = Sim::with_lanes(&nl, lanes).unwrap();
            let mut scalars: Vec<Sim> = (0..lanes).map(|_| Sim::new(&nl).unwrap()).collect();
            let outs = ["s", "p", "q"];
            for t in 0..cycles {
                for (lane, s) in stim.iter().enumerate() {
                    let (av, bv) = s[t];
                    lane_sim.set_input_lane("a", lane, (av as u64) & amask);
                    lane_sim.set_input_lane("b", lane, (bv as u64) & bmask);
                    scalars[lane].set_input("a", (av as u64) & amask);
                    scalars[lane].set_input("b", (bv as u64) & bmask);
                }
                lane_sim.settle();
                for sc in scalars.iter_mut() {
                    sc.settle();
                }
                for name in outs {
                    let ox = lane_sim.output_index(name);
                    for (lane, sc) in scalars.iter().enumerate() {
                        let got = lane_sim.output_signed_lane_at(ox, lane);
                        let want = sc.output_signed(name);
                        if got != want {
                            return Err(format!(
                                "wa={wa} wb={wb} sub={sub} cut={cut} t={t} lane={lane} {name}: {got} != {want}"
                            ));
                        }
                    }
                }
                lane_sim.tick();
                for sc in scalars.iter_mut() {
                    sc.tick();
                }
            }
            // Toggle exactness: lane total == sum of scalar totals, and
            // the normalized rate is the scalar rates' exact mean.
            let scalar_total: u64 = scalars.iter().map(|s| s.toggle_total()).sum();
            if lane_sim.toggle_total() != scalar_total {
                return Err(format!(
                    "toggle totals diverge: lane={} scalar-sum={scalar_total}",
                    lane_sim.toggle_total()
                ));
            }
            let denom = nl.n_nets() as f64 * lane_sim.cycles() as f64 * lanes as f64;
            if lane_sim.mean_toggle_rate() != scalar_total as f64 / denom {
                return Err("mean_toggle_rate not the exact per-lane mean".into());
            }
            Ok(())
        });
    }

    #[test]
    fn full_occupancy_dsp_lanes_independent() {
        use crate::fabric::dsp48::Config;
        // One DSP in MACC mode, 64 lanes each accumulating a different
        // pair sequence; every lane must match its own scalar model.
        let mut nl = Netlist::new();
        let mut b = Builder::new(&mut nl);
        let a = b.input("a", 8);
        let bb = b.input("b", 8);
        let zm = b.input("zm", 2);
        let c = b.const_bus(0, 48);
        let d = b.const_bus(0, 27);
        let ce = b.one();
        let p = b.dsp(Config::full_macc(false), &a, &bb, &c, &d, &zm, ce);
        b.output("p", &p);
        let mut sim = Sim::with_lanes(&nl, LANES).unwrap();
        let a_ix = sim.input_index("a");
        let b_ix = sim.input_index("b");
        let mut rng = Rng::new(21);
        let pairs: Vec<Vec<(i64, i64)>> = (0..LANES)
            .map(|_| (0..4).map(|_| (rng.signed_bits(8), rng.signed_bits(8))).collect())
            .collect();
        for t in 0..4 + 3 {
            for (lane, seq) in pairs.iter().enumerate() {
                let (av, bv) = if t < 4 { seq[t] } else { (0, 0) };
                sim.set_input_lane_at(a_ix, lane, (av as u64) & 0xFF);
                sim.set_input_lane_at(b_ix, lane, (bv as u64) & 0xFF);
            }
            sim.set_input("zm", if t == 0 { 0 } else { 1 });
            sim.settle();
            sim.tick();
        }
        let p_ix = sim.output_index("p");
        for (lane, seq) in pairs.iter().enumerate() {
            let want: i64 = seq.iter().map(|&(x, y)| x * y).sum();
            assert_eq!(sim.output_signed_lane_at(p_ix, lane), want, "lane {lane}");
        }
    }

    #[test]
    fn bram_lanes_have_independent_contents() {
        // Reuse the roundtrip netlist shape at 8 lanes: each lane writes
        // a different byte at a different address and must read back its
        // own.
        let mut nl = Netlist::new();
        let wdata: Vec<_> = (0..8).map(|_| nl.net()).collect();
        let waddr: Vec<_> = (0..4).map(|_| nl.net()).collect();
        let we = nl.net();
        let raddr: Vec<_> = (0..4).map(|_| nl.net()).collect();
        let rdata: Vec<_> = (0..8).map(|_| nl.net()).collect();
        for (name, bus) in [("wdata", &wdata), ("waddr", &waddr), ("raddr", &raddr)] {
            for &n in bus.iter() {
                nl.add_cell(CellKind::Input { name: name.into() }, vec![], vec![n]);
            }
            nl.inputs.push((name.into(), bus.to_vec()));
        }
        nl.add_cell(CellKind::Input { name: "we".into() }, vec![], vec![we]);
        nl.inputs.push(("we".into(), vec![we]));
        let mut ins = wdata.clone();
        ins.extend(&waddr);
        ins.push(we);
        ins.extend(&raddr);
        nl.add_cell(CellKind::Ramb18 { width: 8, depth: 16 }, ins, rdata.clone());
        nl.outputs.push(("rdata".into(), rdata));
        let lanes = 8;
        let mut sim = Sim::with_lanes(&nl, lanes).unwrap();
        let wd_ix = sim.input_index("wdata");
        let wa_ix = sim.input_index("waddr");
        let ra_ix = sim.input_index("raddr");
        for lane in 0..lanes {
            sim.set_input_lane_at(wd_ix, lane, 0x30 + lane as u64);
            sim.set_input_lane_at(wa_ix, lane, lane as u64);
            sim.set_input_lane_at(ra_ix, lane, lane as u64);
        }
        sim.set_input("we", 1);
        sim.settle();
        sim.tick();
        sim.set_input("we", 0);
        sim.settle();
        sim.tick();
        let out_ix = sim.output_index("rdata");
        for lane in 0..lanes {
            assert_eq!(sim.output_unsigned_lane_at(out_ix, lane), 0x30 + lane as u64, "lane {lane}");
        }
    }

    // ---------------- wide-bus regression (>64-bit ports) ----------------

    /// A 72-bit pass-through bus: in -> register -> out.
    fn wide_bus_nl() -> Netlist {
        let mut nl = Netlist::new();
        let mut b = Builder::new(&mut nl);
        let x = b.input("x", 72);
        let ce = b.one();
        let r = b.zero();
        let q = b.register(&x, ce, r);
        b.output("q", &q);
        nl
    }

    #[test]
    fn wide_bus_roundtrips_through_field_accessors() {
        let nl = wide_bus_nl();
        let mut sim = Sim::new(&nl).unwrap();
        let x_ix = sim.input_index("x");
        // Pack 9 bytes, read them back through 8-bit output slices.
        for e in 0..9 {
            sim.set_input_field_at(x_ix, e * 8, 8, 0xA0 + e as u64);
        }
        sim.settle();
        sim.tick();
        for e in 0..9 {
            let bus: Vec<_> = nl.outputs[0].1[e * 8..(e + 1) * 8].to_vec();
            assert_eq!(sim.get_unsigned(&bus), 0xA0 + e as u64, "byte {e}");
        }
    }

    #[test]
    #[should_panic(expected = "72 bits wide")]
    fn wide_bus_whole_set_panics_instead_of_wrapping() {
        let nl = wide_bus_nl();
        let mut sim = Sim::new(&nl).unwrap();
        // Silently wrapped the shift (or debug-panicked deep in the loop)
        // before; now a clear width assert fires at the API boundary.
        sim.set_input("x", 1);
    }

    #[test]
    #[should_panic(expected = "72 bits wide")]
    fn wide_bus_whole_get_panics_instead_of_wrapping() {
        let nl = wide_bus_nl();
        let sim = Sim::new(&nl).unwrap();
        let _ = sim.output_unsigned("q");
    }

    #[test]
    fn non_power_of_two_ram_depth_simulates() {
        // depth 12 -> 4 address bits via ram_addr_bits; a sim over it
        // must construct and round-trip (regression for the float
        // log2().ceil() duplication).
        let mut nl = Netlist::new();
        let wdata: Vec<_> = (0..4).map(|_| nl.net()).collect();
        let waddr: Vec<_> = (0..4).map(|_| nl.net()).collect();
        let we = nl.net();
        let raddr: Vec<_> = (0..4).map(|_| nl.net()).collect();
        let rdata: Vec<_> = (0..4).map(|_| nl.net()).collect();
        for (name, bus) in [("wdata", &wdata), ("waddr", &waddr), ("raddr", &raddr)] {
            for &n in bus.iter() {
                nl.add_cell(CellKind::Input { name: name.into() }, vec![], vec![n]);
            }
            nl.inputs.push((name.into(), bus.to_vec()));
        }
        nl.add_cell(CellKind::Input { name: "we".into() }, vec![], vec![we]);
        nl.inputs.push(("we".into(), vec![we]));
        let mut ins = wdata.clone();
        ins.extend(&waddr);
        ins.push(we);
        ins.extend(&raddr);
        nl.add_cell(CellKind::Ramb18 { width: 4, depth: 12 }, ins, rdata.clone());
        nl.outputs.push(("rdata".into(), rdata));
        let mut sim = Sim::new(&nl).unwrap();
        sim.set_input("wdata", 0x9);
        sim.set_input("waddr", 11);
        sim.set_input("raddr", 11);
        sim.set_input("we", 1);
        sim.settle();
        sim.tick();
        sim.set_input("we", 0);
        sim.settle();
        sim.tick();
        assert_eq!(sim.output_unsigned("rdata"), 0x9);
    }

    #[test]
    fn xor_reg_full_occupancy_differential() {
        // All 64 lanes carry distinct streams; spot-check the smallest
        // sequential netlist at maximum width.
        let nl = xor_reg();
        let mut lane_sim = Sim::with_lanes(&nl, LANES).unwrap();
        let mut scalars: Vec<Sim> = (0..LANES).map(|_| Sim::new(&nl).unwrap()).collect();
        let mut rng = Rng::new(3);
        let streams: Vec<Vec<(u64, u64)>> = (0..LANES)
            .map(|_| (0..8).map(|_| (rng.below(2), rng.below(2))).collect())
            .collect();
        let a_ix = lane_sim.input_index("a");
        let b_ix = lane_sim.input_index("b");
        for t in 0..8 {
            for (lane, s) in streams.iter().enumerate() {
                lane_sim.set_input_lane_at(a_ix, lane, s[t].0);
                lane_sim.set_input_lane_at(b_ix, lane, s[t].1);
                scalars[lane].set_input("a", s[t].0);
                scalars[lane].set_input("b", s[t].1);
            }
            lane_sim.settle();
            lane_sim.tick();
            for sc in scalars.iter_mut() {
                sc.settle();
                sc.tick();
            }
            let q_ix = lane_sim.output_index("q");
            for (lane, sc) in scalars.iter().enumerate() {
                assert_eq!(
                    lane_sim.output_unsigned_lane_at(q_ix, lane),
                    sc.output_unsigned("q"),
                    "t={t} lane={lane}"
                );
            }
        }
        let scalar_total: u64 = scalars.iter().map(|s| s.toggle_total()).sum();
        assert_eq!(lane_sim.toggle_total(), scalar_total);
    }
}
